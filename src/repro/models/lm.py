"""Model assembly: every assigned architecture as (defs, forward, prefill,
decode_step) driven by one ModelConfig.

Families:
  dense  — llama-style decoder (minitron, yi, qwen[+bias], gemma3[5:1 pattern])
  moe    — GQA or MLA attention + GShard MoE (moonshot, deepseek-v3)
  audio  — whisper backbone: encoder (stubbed conv frontend) + cross-attn dec
  vlm    — pixtral backbone: patch-embedding prefix + mistral-nemo decoder
  ssm    — mamba2 SSD stack
  hybrid — zamba2: mamba2 stack + shared attention block every k layers

Layer stacks are scanned (homogeneous per stack) so HLO size is O(1) in
depth; heterogeneous patterns (gemma3 5:1, zamba2 shared block, deepseek
leading dense layers) are expressed as *group* scans with the odd layer
unrolled inside the group — still O(1) HLO.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding as SH
from repro.configs.base import ModelConfig
from repro.models import attention as ATT
from repro.models import common as C
from repro.models import mla as MLA
from repro.models import mlp as MLP
from repro.models import moe as MOE
from repro.models import ssm as SSM


# ---------------------------------------------------------------------------
# Sub-config builders
# ---------------------------------------------------------------------------


def _norm_defs(d: int, cfg: ModelConfig) -> Dict[str, C.ParamDef]:
    if cfg.norm == "rms":
        return {"w": C.ParamDef((d,), (None,), init="zeros")}
    return {"w": C.ParamDef((d,), (None,), init="ones"),
            "b": C.ParamDef((d,), (None,), init="zeros")}


def _apply_norm(p, x, cfg: ModelConfig):
    if cfg.norm == "rms":
        return C.rmsnorm(x, p["w"])
    return C.layernorm(x, p["w"], p["b"])


def _attn_cfg(cfg: ModelConfig, *, window: Optional[int] = None,
              theta: Optional[float] = None, causal: bool = True) -> ATT.AttnConfig:
    return ATT.AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads_, n_kv_heads=cfg.n_kv_heads_,
        head_dim=cfg.head_dim_, qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm,
        rope_theta=theta if theta is not None else cfg.rope_theta,
        causal=causal, window=window)


def _mla_cfg(cfg: ModelConfig) -> MLA.MLAConfig:
    return MLA.MLAConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads_, q_lora_rank=cfg.q_lora_rank,
        kv_lora_rank=cfg.kv_lora_rank, qk_nope_dim=cfg.qk_nope_dim,
        qk_rope_dim=cfg.qk_rope_dim, v_head_dim=cfg.v_head_dim,
        rope_theta=cfg.rope_theta)


def _moe_cfg(cfg: ModelConfig) -> MOE.MoEConfig:
    return MOE.MoEConfig(
        d_model=cfg.d_model, n_experts=cfg.n_experts, top_k=cfg.top_k,
        expert_ff=cfg.expert_ff, n_shared=cfg.n_shared_experts,
        shared_ff=cfg.expert_ff, capacity_factor=cfg.capacity_factor)


def _ssm_cfg(cfg: ModelConfig) -> SSM.SSMConfig:
    return SSM.SSMConfig(d_model=cfg.d_model, d_state=cfg.d_state,
                         headdim=cfg.ssm_headdim, chunk=cfg.ssm_chunk)


def _gemma_groups(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(n_groups, locals_per_group, n_tail_locals) for the 5:1 pattern."""
    ge = cfg.global_every
    n_groups = cfg.n_layers // ge
    tail = cfg.n_layers - n_groups * ge
    assert tail < ge, "tail must be all-local"
    return n_groups, ge - 1, tail


def _zamba_groups(cfg: ModelConfig) -> int:
    assert cfg.n_layers % cfg.attn_every == 0
    return cfg.n_layers // cfg.attn_every


# ---------------------------------------------------------------------------
# Layer defs
# ---------------------------------------------------------------------------


def _dense_layer_defs(cfg: ModelConfig, acfg: ATT.AttnConfig) -> Dict:
    return {
        "attn": ATT.attn_defs(acfg),
        "mlp": MLP.gated_defs(cfg.d_model, cfg.d_ff),
        "norm1": _norm_defs(cfg.d_model, cfg),
        "norm2": _norm_defs(cfg.d_model, cfg),
    }


def _moe_layer_defs(cfg: ModelConfig) -> Dict:
    attn = (MLA.mla_defs(_mla_cfg(cfg)) if cfg.use_mla
            else ATT.attn_defs(_attn_cfg(cfg)))
    return {
        "attn": attn,
        "moe": MOE.moe_defs(_moe_cfg(cfg)),
        "norm1": _norm_defs(cfg.d_model, cfg),
        "norm2": _norm_defs(cfg.d_model, cfg),
    }


def _moe_dense_layer_defs(cfg: ModelConfig) -> Dict:
    attn = (MLA.mla_defs(_mla_cfg(cfg)) if cfg.use_mla
            else ATT.attn_defs(_attn_cfg(cfg)))
    return {
        "attn": attn,
        "mlp": MLP.gated_defs(cfg.d_model, cfg.moe_ff_dense or cfg.d_ff),
        "norm1": _norm_defs(cfg.d_model, cfg),
        "norm2": _norm_defs(cfg.d_model, cfg),
    }


def _enc_layer_defs(cfg: ModelConfig) -> Dict:
    acfg = _attn_cfg(cfg, causal=False)
    acfg = dataclasses.replace(acfg, rope_theta=None)
    return {
        "attn": ATT.attn_defs(acfg),
        "mlp": MLP.plain_defs(cfg.d_model, cfg.d_ff),
        "norm1": _norm_defs(cfg.d_model, cfg),
        "norm2": _norm_defs(cfg.d_model, cfg),
    }


def _dec_layer_defs(cfg: ModelConfig) -> Dict:
    acfg = dataclasses.replace(_attn_cfg(cfg), rope_theta=None)
    return {
        "self_attn": ATT.attn_defs(acfg),
        "cross_attn": ATT.cross_defs(acfg),
        "mlp": MLP.plain_defs(cfg.d_model, cfg.d_ff),
        "norm1": _norm_defs(cfg.d_model, cfg),
        "norm2": _norm_defs(cfg.d_model, cfg),
        "norm3": _norm_defs(cfg.d_model, cfg),
    }


def _ssm_layer_defs(cfg: ModelConfig) -> Dict:
    return {"ssm": SSM.ssm_defs(_ssm_cfg(cfg)),
            "norm1": _norm_defs(cfg.d_model, cfg)}


def model_defs(cfg: ModelConfig, max_seq: int = 4096) -> Dict:
    d, v = cfg.d_model, cfg.vocab_
    defs: Dict[str, Any] = {
        # 1/sqrt(d) keeps tied-head logits unit-scale; tied inputs are
        # re-scaled by sqrt(d) in _embed (gemma convention).
        "embed": C.ParamDef((v, d), ("vocab", "embed"), scale=d ** -0.5),
        "final_norm": _norm_defs(d, cfg),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = C.ParamDef((d, v), ("embed", "vocab"))

    fam = cfg.family
    if fam in ("dense", "vlm"):
        if cfg.global_every > 1:
            ng, nl, tail = _gemma_groups(cfg)
            local = _dense_layer_defs(cfg, _attn_cfg(
                cfg, window=cfg.window_size, theta=cfg.rope_theta_local))
            glob = _dense_layer_defs(cfg, _attn_cfg(cfg))
            defs["groups"] = C.stack_tree(
                {"locals": C.stack_tree(local, nl), "global": glob}, ng)
            if tail:
                defs["tail"] = C.stack_tree(local, tail)
        else:
            defs["layers"] = C.stack_tree(
                _dense_layer_defs(cfg, _attn_cfg(cfg)), cfg.n_layers)
    elif fam == "moe":
        nd = cfg.n_dense_layers
        if nd:
            defs["dense_layers"] = C.stack_tree(_moe_dense_layer_defs(cfg), nd)
        defs["layers"] = C.stack_tree(_moe_layer_defs(cfg), cfg.n_layers - nd)
    elif fam == "audio":
        defs["enc_layers"] = C.stack_tree(_enc_layer_defs(cfg), cfg.enc_layers)
        defs["enc_norm"] = _norm_defs(d, cfg)
        defs["dec_layers"] = C.stack_tree(_dec_layer_defs(cfg), cfg.n_layers)
        defs["dec_pos"] = C.ParamDef((max_seq, d), (None, "embed"), scale=0.01)
    elif fam == "ssm":
        defs["layers"] = C.stack_tree(_ssm_layer_defs(cfg), cfg.n_layers)
    elif fam == "hybrid":
        ng = _zamba_groups(cfg)
        defs["layers"] = C.stack_tree(_ssm_layer_defs(cfg), cfg.n_layers)
        defs["shared"] = _dense_layer_defs(cfg, _attn_cfg(cfg))
    else:
        raise ValueError(fam)
    return defs


# ---------------------------------------------------------------------------
# Forward (train)
# ---------------------------------------------------------------------------


def _maybe_remat(fn, remat: bool):
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable) \
        if remat else fn


def _embed(params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.tie_embeddings:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    return SH.constrain(x, "batch", "act_seq", "act_embed")


def _reshard_residual(x: jax.Array) -> jax.Array:
    """Keep the residual stream sequence-sharded between blocks."""
    return SH.constrain(x, "batch", "act_seq", "act_embed")


def _head(params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = _apply_norm(params["final_norm"], x, cfg)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    return SH.constrain(logits, "batch", None, "vocab")


def _dense_layer_fwd(lp, x, cfg: ModelConfig, acfg: ATT.AttnConfig):
    h = ATT.forward(lp["attn"], _apply_norm(lp["norm1"], x, cfg), acfg)
    x = _reshard_residual(x + h)
    h = MLP.gated_forward(lp["mlp"], _apply_norm(lp["norm2"], x, cfg), cfg.act)
    return _reshard_residual(x + h)


def _moe_layer_fwd(lp, x, cfg: ModelConfig):
    if cfg.use_mla:
        h = MLA.forward(lp["attn"], _apply_norm(lp["norm1"], x, cfg), _mla_cfg(cfg))
    else:
        h = ATT.forward(lp["attn"], _apply_norm(lp["norm1"], x, cfg), _attn_cfg(cfg))
    x = _reshard_residual(x + h)
    h, aux = MOE.forward(lp["moe"], _apply_norm(lp["norm2"], x, cfg), _moe_cfg(cfg))
    return _reshard_residual(x + h), aux


def _moe_dense_layer_fwd(lp, x, cfg: ModelConfig):
    if cfg.use_mla:
        h = MLA.forward(lp["attn"], _apply_norm(lp["norm1"], x, cfg), _mla_cfg(cfg))
    else:
        h = ATT.forward(lp["attn"], _apply_norm(lp["norm1"], x, cfg), _attn_cfg(cfg))
    x = _reshard_residual(x + h)
    h = MLP.gated_forward(lp["mlp"], _apply_norm(lp["norm2"], x, cfg), cfg.act)
    return _reshard_residual(x + h)


def _ssm_layer_fwd(lp, x, cfg: ModelConfig):
    return _reshard_residual(
        x + SSM.forward(lp["ssm"], _apply_norm(lp["norm1"], x, cfg), _ssm_cfg(cfg)))


def _scan(fn, params_stack, x, remat: bool):
    def body(carry, lp):
        return fn(lp, carry), None
    body = _maybe_remat(body, remat)
    x, _ = jax.lax.scan(body, x, params_stack)
    return x


def forward(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            remat: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits (B,S,V), aux_loss scalar)."""
    tokens = batch["tokens"]
    aux = jnp.float32(0.0)
    fam = cfg.family

    if fam == "audio":
        enc = batch["frames"].astype(cfg.jdtype)  # (B, enc_seq, D) stub
        enc = enc + C.sinusoidal_pos(enc.shape[1], cfg.d_model).astype(enc.dtype)
        acfg_e = dataclasses.replace(_attn_cfg(cfg, causal=False), rope_theta=None)
        enc = _scan(lambda lp, h: _enc_dec_enc_fwd(lp, h, cfg, acfg_e),
                    params["enc_layers"], enc, remat)
        enc = _apply_norm(params["enc_norm"], enc, cfg)

        x = _embed(params, cfg, tokens)
        s = x.shape[1]
        x = x + params["dec_pos"][:s][None].astype(x.dtype)
        acfg_d = dataclasses.replace(_attn_cfg(cfg), rope_theta=None)

        def dec_body(carry, lp):
            return _dec_layer_fwd(lp, carry, enc, cfg, acfg_d), None

        x, _ = jax.lax.scan(_maybe_remat(dec_body, remat),
                            x, params["dec_layers"])
        return _head(params, cfg, x), aux

    x = _embed(params, cfg, tokens)
    if fam == "vlm":
        patches = batch["patches"].astype(x.dtype)   # (B, P, D) stub
        x = jnp.concatenate([patches, x], axis=1)

    if fam in ("dense", "vlm"):
        if cfg.global_every > 1:
            ng, nl, tail = _gemma_groups(cfg)
            a_local = _attn_cfg(cfg, window=cfg.window_size,
                                theta=cfg.rope_theta_local)
            a_glob = _attn_cfg(cfg)

            def group_body(carry, gp):
                h = _scan(lambda lp, hh: _dense_layer_fwd(lp, hh, cfg, a_local),
                          gp["locals"], carry, remat)
                h = _dense_layer_fwd(gp["global"], h, cfg, a_glob)
                return h, None

            x, _ = jax.lax.scan(group_body, x, params["groups"])
            if tail:
                x = _scan(lambda lp, hh: _dense_layer_fwd(lp, hh, cfg, a_local),
                          params["tail"], x, remat)
        else:
            acfg = _attn_cfg(cfg)
            x = _scan(lambda lp, hh: _dense_layer_fwd(lp, hh, cfg, acfg),
                      params["layers"], x, remat)
    elif fam == "moe":
        if cfg.n_dense_layers:
            x = _scan(lambda lp, hh: _moe_dense_layer_fwd(lp, hh, cfg),
                      params["dense_layers"], x, remat)

        def moe_body(carry, lp):
            h, a = carry
            h2, aux_l = _moe_layer_fwd(lp, h, cfg)
            return (h2, a + aux_l), None

        (x, aux), _ = jax.lax.scan(_maybe_remat(moe_body, remat),
                                   (x, aux), params["layers"])
    elif fam == "ssm":
        x = _scan(lambda lp, hh: _ssm_layer_fwd(lp, hh, cfg),
                  params["layers"], x, remat)
    elif fam == "hybrid":
        ng = _zamba_groups(cfg)
        ge = cfg.attn_every
        shared = params["shared"]
        acfg = _attn_cfg(cfg)
        grouped = jax.tree.map(
            lambda a: a.reshape((ng, ge) + a.shape[1:]), params["layers"])

        def hyb_body(carry, gp):
            h = _scan(lambda lp, hh: _ssm_layer_fwd(lp, hh, cfg), gp, carry, remat)
            h = _dense_layer_fwd(shared, h, cfg, acfg)
            return h, None

        x, _ = jax.lax.scan(hyb_body, x, grouped)
    else:
        raise ValueError(fam)

    return _head(params, cfg, x), aux


def _enc_dec_enc_fwd(lp, x, cfg: ModelConfig, acfg: ATT.AttnConfig):
    h = ATT.forward(lp["attn"], _apply_norm(lp["norm1"], x, cfg), acfg)
    x = x + h
    h = MLP.plain_forward(lp["mlp"], _apply_norm(lp["norm2"], x, cfg))
    return x + h


def _dec_layer_fwd(lp, x, enc, cfg: ModelConfig, acfg: ATT.AttnConfig):
    x = x + ATT.forward(lp["self_attn"], _apply_norm(lp["norm1"], x, cfg), acfg)
    x = x + ATT.cross_forward(lp["cross_attn"],
                              _apply_norm(lp["norm2"], x, cfg), enc, acfg)
    x = x + MLP.plain_forward(lp["mlp"], _apply_norm(lp["norm3"], x, cfg))
    return x


# ---------------------------------------------------------------------------
# Decode caches
# ---------------------------------------------------------------------------


def cache_defs(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    fam = cfg.family
    pos = C.ParamDef((), (), init="zeros", dtype=jnp.int32)
    if fam in ("dense", "vlm"):
        if cfg.global_every > 1:
            ng, nl, tail = _gemma_groups(cfg)
            a_local = _attn_cfg(cfg, window=cfg.window_size,
                                theta=cfg.rope_theta_local)
            a_glob = _attn_cfg(cfg)
            w = min(cfg.window_size, max_len)
            d = {"groups": C.stack_tree({
                "locals": C.stack_tree(ATT.ring_cache_defs(a_local, batch, w), nl),
                "global": ATT.cache_defs(a_glob, batch, max_len)}, ng),
                "pos": pos}
            if tail:
                d["tail"] = C.stack_tree(
                    ATT.ring_cache_defs(a_local, batch, w), tail)
            return d
        acfg = _attn_cfg(cfg)
        return {"layers": C.stack_tree(
            ATT.cache_defs(acfg, batch, max_len), cfg.n_layers), "pos": pos}
    if fam == "moe":
        sub = (MLA.cache_defs(_mla_cfg(cfg), batch, max_len) if cfg.use_mla
               else ATT.cache_defs(_attn_cfg(cfg), batch, max_len))
        d = {"layers": C.stack_tree(sub, cfg.n_layers - cfg.n_dense_layers),
             "pos": pos}
        if cfg.n_dense_layers:
            d["dense_layers"] = C.stack_tree(sub, cfg.n_dense_layers)
        return d
    if fam == "audio":
        acfg = dataclasses.replace(_attn_cfg(cfg), rope_theta=None)
        return {
            "layers": C.stack_tree(ATT.cache_defs(acfg, batch, max_len),
                                   cfg.n_layers),
            "cross": C.stack_tree(ATT.cross_cache_defs(acfg, batch, cfg.enc_seq),
                                  cfg.n_layers),
            "pos": pos,
        }
    if fam == "ssm":
        return {"layers": C.stack_tree(
            SSM.cache_defs(_ssm_cfg(cfg), batch), cfg.n_layers), "pos": pos}
    if fam == "hybrid":
        ng = _zamba_groups(cfg)
        return {
            "layers": C.stack_tree(SSM.cache_defs(_ssm_cfg(cfg), batch),
                                   cfg.n_layers),
            "shared_kv": C.stack_tree(
                ATT.cache_defs(_attn_cfg(cfg), batch, max_len), ng),
            "pos": pos,
        }
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# Prefill  (fills caches over the prompt, returns last-position logits)
# ---------------------------------------------------------------------------


def prefill(params, cfg: ModelConfig, tokens: jax.Array, cache: Dict,
            frames: Optional[jax.Array] = None,
            patches: Optional[jax.Array] = None) -> Tuple[jax.Array, Dict]:
    fam = cfg.family
    x = _embed(params, cfg, tokens)
    if fam == "vlm" and patches is not None:
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
    s = x.shape[1]

    if fam in ("dense", "vlm"):
        if cfg.global_every > 1:
            ng, nl, tail = _gemma_groups(cfg)
            a_local = _attn_cfg(cfg, window=cfg.window_size,
                                theta=cfg.rope_theta_local)
            a_glob = _attn_cfg(cfg)
            w = cache["groups"]["locals"]["k"].shape[3]

            def group_body(carry, xs):
                h = carry
                gp, gc = xs

                def loc_body(hh, xs2):
                    lp, lc = xs2
                    o, nc = ATT_ring_layer_prefill(lp, hh, cfg, a_local, lc, w)
                    return o, nc

                h, new_loc = jax.lax.scan(loc_body, h, (gp["locals"], gc["locals"]))
                o, new_glob = _layer_prefill(gp["global"], h, cfg, a_glob,
                                             gc["global"])
                return o, {"locals": new_loc, "global": new_glob}

            x, new_groups = jax.lax.scan(group_body, x,
                                         (params["groups"], cache["groups"]))
            new_cache = {"groups": new_groups, "pos": jnp.int32(s)}
            if tail:
                def tail_body(hh, xs2):
                    lp, lc = xs2
                    return ATT_ring_layer_prefill(lp, hh, cfg, a_local, lc, w)

                x, new_tail = jax.lax.scan(tail_body, x,
                                           (params["tail"], cache["tail"]))
                new_cache["tail"] = new_tail
            return _last_logits(params, cfg, x), new_cache

        acfg = _attn_cfg(cfg)

        def body(carry, xs):
            lp, lc = xs
            return _layer_prefill(lp, carry, cfg, acfg, lc)

        x, new_layers = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
        return _last_logits(params, cfg, x), {"layers": new_layers,
                                              "pos": jnp.int32(s)}

    if fam == "moe":
        new_cache = {"pos": jnp.int32(s)}
        if cfg.n_dense_layers:
            def dbody(carry, xs):
                lp, lc = xs
                return _moe_dense_prefill(lp, carry, cfg, lc)
            x, nd = jax.lax.scan(dbody, x,
                                 (params["dense_layers"], cache["dense_layers"]))
            new_cache["dense_layers"] = nd

        def mbody(carry, xs):
            lp, lc = xs
            return _moe_layer_prefill(lp, carry, cfg, lc)

        x, nl_ = jax.lax.scan(mbody, x, (params["layers"], cache["layers"]))
        new_cache["layers"] = nl_
        return _last_logits(params, cfg, x), new_cache

    if fam == "audio":
        enc = frames.astype(cfg.jdtype)
        enc = enc + C.sinusoidal_pos(enc.shape[1], cfg.d_model).astype(enc.dtype)
        acfg_e = dataclasses.replace(_attn_cfg(cfg, causal=False), rope_theta=None)
        enc = _scan(lambda lp, hh: _enc_dec_enc_fwd(lp, hh, cfg, acfg_e),
                    params["enc_layers"], enc, False)
        enc = _apply_norm(params["enc_norm"], enc, cfg)
        acfg = dataclasses.replace(_attn_cfg(cfg), rope_theta=None)
        x = x + params["dec_pos"][:s][None].astype(x.dtype)

        def body(carry, xs):
            lp, lc = xs
            h = carry
            hn = _apply_norm(lp["norm1"], h, cfg)
            o, new_self = ATT.prefill(lp["self_attn"], hn, acfg, lc)
            h = h + o
            cross_kv = ATT.cross_fill(lp["cross_attn"], enc, acfg)
            h = h + ATT.cross_decode(lp["cross_attn"],
                                     _apply_norm(lp["norm2"], h, cfg), acfg,
                                     cross_kv)
            h = h + MLP.plain_forward(lp["mlp"], _apply_norm(lp["norm3"], h, cfg))
            return h, (new_self,
                       jax.tree.map(lambda a: a.astype(cfg.jdtype), cross_kv))

        x, (new_self, new_cross) = jax.lax.scan(
            body, x, (params["dec_layers"], cache["layers"]))
        return _last_logits(params, cfg, x), {
            "layers": new_self, "cross": new_cross, "pos": jnp.int32(s)}

    if fam == "ssm":
        def body(carry, xs):
            lp, lc = xs
            hn = _apply_norm(lp["norm1"], carry, cfg)
            o, nc = SSM.forward(lp["ssm"], hn, _ssm_cfg(cfg), return_cache=True)
            return carry + o, nc

        x, new_layers = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
        return _last_logits(params, cfg, x), {"layers": new_layers,
                                              "pos": jnp.int32(s)}

    if fam == "hybrid":
        ng = _zamba_groups(cfg)
        ge = cfg.attn_every
        acfg = _attn_cfg(cfg)
        shared = params["shared"]
        grouped = jax.tree.map(
            lambda a: a.reshape((ng, ge) + a.shape[1:]), params["layers"])

        def group_body(carry, xs):
            gp, kv = xs
            h = carry

            def mbody(hh, lp):
                hn = _apply_norm(lp["norm1"], hh, cfg)
                o, nc = SSM.forward(lp["ssm"], hn, _ssm_cfg(cfg), return_cache=True)
                return hh + o, nc

            h, ssm_caches = jax.lax.scan(mbody, h, gp)
            o, new_kv = _layer_prefill(shared, h, cfg, acfg, kv)
            return o, (ssm_caches, new_kv)

        x, (ssm_caches, new_kv) = jax.lax.scan(
            group_body, x, (grouped, cache["shared_kv"]))
        ssm_caches = jax.tree.map(
            lambda a: a.reshape((ng * ge,) + a.shape[2:]), ssm_caches)
        return _last_logits(params, cfg, x), {
            "layers": ssm_caches, "shared_kv": new_kv, "pos": jnp.int32(s)}

    raise ValueError(fam)


def _last_logits(params, cfg, x):
    return _head(params, cfg, x[:, -1:, :])[:, 0]


def _layer_prefill(lp, x, cfg, acfg, lc):
    hn = _apply_norm(lp["norm1"], x, cfg)
    o, nc = ATT.prefill(lp["attn"], hn, acfg, lc)
    x = x + o
    x = x + MLP.gated_forward(lp["mlp"], _apply_norm(lp["norm2"], x, cfg), cfg.act)
    return x, nc


def ATT_ring_layer_prefill(lp, x, cfg, acfg, lc, w):
    hn = _apply_norm(lp["norm1"], x, cfg)
    o, nc = ATT.ring_prefill(lp["attn"], hn, acfg, lc, w)
    x = x + o
    x = x + MLP.gated_forward(lp["mlp"], _apply_norm(lp["norm2"], x, cfg), cfg.act)
    return x, nc


def _moe_layer_prefill(lp, x, cfg, lc):
    hn = _apply_norm(lp["norm1"], x, cfg)
    if cfg.use_mla:
        o, nc = MLA.prefill(lp["attn"], hn, _mla_cfg(cfg), lc)
    else:
        o, nc = ATT.prefill(lp["attn"], hn, _attn_cfg(cfg), lc)
    x = x + o
    h, _ = MOE.forward(lp["moe"], _apply_norm(lp["norm2"], x, cfg), _moe_cfg(cfg))
    return x + h, nc


def _moe_dense_prefill(lp, x, cfg, lc):
    hn = _apply_norm(lp["norm1"], x, cfg)
    if cfg.use_mla:
        o, nc = MLA.prefill(lp["attn"], hn, _mla_cfg(cfg), lc)
    else:
        o, nc = ATT.prefill(lp["attn"], hn, _attn_cfg(cfg), lc)
    x = x + o
    x = x + MLP.gated_forward(lp["mlp"], _apply_norm(lp["norm2"], x, cfg), cfg.act)
    return x, nc


# ---------------------------------------------------------------------------
# Decode (one token)
# ---------------------------------------------------------------------------


def decode_step(params, cfg: ModelConfig, tokens: jax.Array, cache: Dict
                ) -> Tuple[jax.Array, Dict]:
    """tokens: (B, 1) int32. Returns (logits (B, V), new cache)."""
    fam = cfg.family
    pos = cache["pos"]
    x = _embed(params, cfg, tokens)

    if fam in ("dense", "vlm"):
        if cfg.global_every > 1:
            ng, nl, tail = _gemma_groups(cfg)
            a_local = _attn_cfg(cfg, window=cfg.window_size,
                                theta=cfg.rope_theta_local)
            a_glob = _attn_cfg(cfg)
            w = cache["groups"]["locals"]["k"].shape[3]

            def group_body(carry, xs):
                gp, gc = xs

                def loc_body(hh, xs2):
                    lp, lc = xs2
                    return _ring_layer_decode(lp, hh, cfg, a_local, lc, pos, w)

                h, new_loc = jax.lax.scan(loc_body, carry,
                                          (gp["locals"], gc["locals"]))
                o, new_glob = _layer_decode(gp["global"], h, cfg, a_glob,
                                            gc["global"], pos)
                return o, {"locals": new_loc, "global": new_glob}

            x, new_groups = jax.lax.scan(group_body, x,
                                         (params["groups"], cache["groups"]))
            out = {"groups": new_groups, "pos": pos + 1}
            if tail:
                def tail_body(hh, xs2):
                    lp, lc = xs2
                    return _ring_layer_decode(lp, hh, cfg, a_local, lc, pos, w)
                x, new_tail = jax.lax.scan(tail_body, x,
                                           (params["tail"], cache["tail"]))
                out["tail"] = new_tail
            return _head(params, cfg, x)[:, 0], out

        acfg = _attn_cfg(cfg)

        def body(carry, xs):
            lp, lc = xs
            return _layer_decode(lp, carry, cfg, acfg, lc, pos)

        x, new_layers = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
        return _head(params, cfg, x)[:, 0], {"layers": new_layers, "pos": pos + 1}

    if fam == "moe":
        out = {"pos": pos + 1}
        if cfg.n_dense_layers:
            def dbody(carry, xs):
                lp, lc = xs
                return _moe_dense_decode(lp, carry, cfg, lc, pos)
            x, nd = jax.lax.scan(dbody, x,
                                 (params["dense_layers"], cache["dense_layers"]))
            out["dense_layers"] = nd

        def mbody(carry, xs):
            lp, lc = xs
            return _moe_layer_decode(lp, carry, cfg, lc, pos)

        x, nl_ = jax.lax.scan(mbody, x, (params["layers"], cache["layers"]))
        out["layers"] = nl_
        return _head(params, cfg, x)[:, 0], out

    if fam == "audio":
        acfg = dataclasses.replace(_attn_cfg(cfg), rope_theta=None)
        x = x + jax.lax.dynamic_slice_in_dim(
            params["dec_pos"], pos, 1, axis=0)[None].astype(x.dtype)

        def body(carry, xs):
            lp, lc, cc = xs
            h = carry
            o, ns = ATT.decode_step(lp["self_attn"],
                                    _apply_norm(lp["norm1"], h, cfg), acfg, lc, pos)
            h = h + o
            h = h + ATT.cross_decode(lp["cross_attn"],
                                     _apply_norm(lp["norm2"], h, cfg), acfg, cc)
            h = h + MLP.plain_forward(lp["mlp"], _apply_norm(lp["norm3"], h, cfg))
            return h, ns

        x, new_self = jax.lax.scan(
            body, x, (params["dec_layers"], cache["layers"], cache["cross"]))
        return _head(params, cfg, x)[:, 0], {
            "layers": new_self, "cross": cache["cross"], "pos": pos + 1}

    if fam == "ssm":
        def body(carry, xs):
            lp, lc = xs
            hn = _apply_norm(lp["norm1"], carry, cfg)
            o, nc = SSM.decode_step(lp["ssm"], hn, _ssm_cfg(cfg), lc)
            return carry + o, nc

        x, new_layers = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
        return _head(params, cfg, x)[:, 0], {"layers": new_layers, "pos": pos + 1}

    if fam == "hybrid":
        ng = _zamba_groups(cfg)
        ge = cfg.attn_every
        acfg = _attn_cfg(cfg)
        shared = params["shared"]
        grouped = jax.tree.map(
            lambda a: a.reshape((ng, ge) + a.shape[1:]), params["layers"])
        gcache = jax.tree.map(
            lambda a: a.reshape((ng, ge) + a.shape[1:]), cache["layers"])

        def group_body(carry, xs):
            gp, gc, kv = xs

            def mbody(hh, xs2):
                lp, lc = xs2
                hn = _apply_norm(lp["norm1"], hh, cfg)
                o, nc = SSM.decode_step(lp["ssm"], hn, _ssm_cfg(cfg), lc)
                return hh + o, nc

            h, ssm_caches = jax.lax.scan(mbody, carry, (gp, gc))
            o, new_kv = _layer_decode(shared, h, cfg, acfg, kv, pos)
            return o, (ssm_caches, new_kv)

        x, (ssm_caches, new_kv) = jax.lax.scan(
            group_body, x, (grouped, gcache, cache["shared_kv"]))
        ssm_caches = jax.tree.map(
            lambda a: a.reshape((ng * ge,) + a.shape[2:]), ssm_caches)
        return _head(params, cfg, x)[:, 0], {
            "layers": ssm_caches, "shared_kv": new_kv, "pos": pos + 1}

    raise ValueError(fam)


def _layer_decode(lp, x, cfg, acfg, lc, pos):
    hn = _apply_norm(lp["norm1"], x, cfg)
    o, nc = ATT.decode_step(lp["attn"], hn, acfg, lc, pos)
    x = x + o
    x = x + MLP.gated_forward(lp["mlp"], _apply_norm(lp["norm2"], x, cfg), cfg.act)
    return x, nc


def _ring_layer_decode(lp, x, cfg, acfg, lc, pos, w):
    hn = _apply_norm(lp["norm1"], x, cfg)
    o, nc = ATT.ring_decode_step(lp["attn"], hn, acfg, lc, pos, w)
    x = x + o
    x = x + MLP.gated_forward(lp["mlp"], _apply_norm(lp["norm2"], x, cfg), cfg.act)
    return x, nc


def _moe_layer_decode(lp, x, cfg, lc, pos):
    hn = _apply_norm(lp["norm1"], x, cfg)
    if cfg.use_mla:
        o, nc = MLA.decode_step(lp["attn"], hn, _mla_cfg(cfg), lc, pos)
    else:
        o, nc = ATT.decode_step(lp["attn"], hn, _attn_cfg(cfg), lc, pos)
    x = x + o
    h, _ = MOE.forward(lp["moe"], _apply_norm(lp["norm2"], x, cfg), _moe_cfg(cfg))
    return x + h, nc


def _moe_dense_decode(lp, x, cfg, lc, pos):
    hn = _apply_norm(lp["norm1"], x, cfg)
    if cfg.use_mla:
        o, nc = MLA.decode_step(lp["attn"], hn, _mla_cfg(cfg), lc, pos)
    else:
        o, nc = ATT.decode_step(lp["attn"], hn, _attn_cfg(cfg), lc, pos)
    x = x + o
    x = x + MLP.gated_forward(lp["mlp"], _apply_norm(lp["norm2"], x, cfg), cfg.act)
    return x, nc
