"""Fault tolerance: the deterministic injection registry (`repro.faults`),
checkpoint shard checksums + fallback, engine crash/corrupt resume
bit-identity, `ga.repack_checkpoint` pack slicing, and the scheduler's
retry/backoff, pack-isolation quarantine, deadline and journal-recovery
paths — every failure is injected, never timed."""

import json
import os
import threading

import numpy as np
import pytest

from repro import faults as FLT
from repro import ga
from repro.ckpt import checkpoint as CKPT
from repro.serve import journal as JRN
from repro.serve.engine import GAMetricsRegistry
from repro.serve.scheduler import (DEADLINE_EXCEEDED, DONE, FAILED, QUEUED,
                                   GAScheduler, retry_backoff)


def _spec(**kw):
    base = dict(problem="F3", n=32, bits_per_var=10, mode="arith",
                mutation_rate=0.05, seed=11, generations=20)
    base.update(kw)
    return ga.GASpec(**base)


class FakeClock:
    """Injectable monotonic clock: deadline/backoff tests advance time
    explicitly instead of sleeping."""

    def __init__(self, t=0.0):
        self.t = float(t)

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# ---------------------------------------------------------------------------
# Rule grammar + injector determinism
# ---------------------------------------------------------------------------


def test_parse_rule_fields():
    r = FLT.parse_rule("chunk_crash@ga-3:at=2,5:seed=7")
    assert r.site == "chunk_crash" and r.match == "ga-3"
    assert r.at == (2, 5) and r.seed == 7

    r = FLT.parse_rule("ckpt_corrupt:after=3:times=2")
    assert r.after == 3 and r.times == 2.0
    assert [n for n in range(1, 8) if r.decides(n)] == [4, 5]

    r = FLT.parse_rule("slow_chunk:delay=0.01:times=inf")
    assert r.delay_s == 0.01
    assert r.decides(1) and r.decides(10_000)

    with pytest.raises(ValueError, match="unknown fault site"):
        FLT.parse_rule("no_such_site")
    with pytest.raises(ValueError, match="unknown fault rule field"):
        FLT.parse_rule("chunk_crash:bogus=1")
    with pytest.raises(ValueError, match="p must be"):
        FLT.parse_rule("chunk_crash:p=1.5")


def test_probabilistic_rules_are_seed_deterministic():
    r = FLT.parse_rule("chunk_crash:p=0.3:seed=42")
    fire1 = [n for n in range(1, 200) if r.decides(n)]
    fire2 = [n for n in range(1, 200) if r.decides(n)]
    assert fire1 == fire2 and fire1, "same seed must give same decisions"
    other = FLT.parse_rule("chunk_crash:p=0.3:seed=43")
    assert fire1 != [n for n in range(1, 200) if other.decides(n)]
    # p bounds the empirical rate loosely (deterministic, so exact replay)
    assert 0.15 < len(fire1) / 199 < 0.45


def test_injector_counts_occurrences_and_filters_by_tag():
    inj = FLT.parse_faults("chunk_crash@job-a:at=2")
    # tag without the match substring never counts toward the rule
    assert inj.fires("chunk_crash", "job-b|chunk=1") is None
    assert inj.fires("chunk_crash", "job-a|chunk=1") is None   # occurrence 1
    assert inj.fires("chunk_crash", "job-a|chunk=2") is not None
    assert inj.fires("chunk_crash", "job-a|chunk=3") is None
    assert inj.stats() == {"chunk_crash": 1}
    with pytest.raises(FLT.ChunkCrash):
        FLT.parse_faults("chunk_crash:at=1").inject("chunk_crash", "x")
    with pytest.raises(FLT.CompileFail):
        FLT.parse_faults("compile_fail:at=1").inject("compile_fail", "x")


def test_resolve_faults_semantics(monkeypatch):
    monkeypatch.delenv(FLT.ENV_VAR, raising=False)
    assert FLT.resolve_faults(False) is None
    assert FLT.resolve_faults(None) is None        # no ambient env
    inj = FLT.FaultInjector()
    assert FLT.resolve_faults(inj) is inj          # instance passes through
    assert isinstance(FLT.resolve_faults("chunk_crash:at=1"),
                      FLT.FaultInjector)
    with pytest.raises(TypeError):
        FLT.resolve_faults(123)
    # ambient env memoizes per rule string: counters survive re-resolution
    monkeypatch.setenv(FLT.ENV_VAR, "chunk_crash:at=999")
    assert FLT.resolve_faults(None) is FLT.resolve_faults(None)
    # False disarms even against an armed env
    assert FLT.resolve_faults(False) is None


def test_classify_error():
    assert FLT.classify_error(FLT.ChunkCrash("x")) == "transient"
    assert FLT.classify_error(RuntimeError("xla oom")) == "transient"
    assert FLT.classify_error(OSError("disk")) == "transient"
    for exc in (ValueError("bad"), TypeError("bad"), KeyError("bad"),
                AssertionError("bad")):
        assert FLT.classify_error(exc) == "permanent"


def test_corrupt_file_is_deterministic(tmp_path):
    p1, p2 = tmp_path / "a.bin", tmp_path / "b.bin"
    payload = bytes(range(256)) * 16
    p1.write_bytes(payload)
    p2.write_bytes(payload)
    FLT.corrupt_file(str(p1), seed=3)
    FLT.corrupt_file(str(p2), seed=3)
    assert p1.read_bytes() == p2.read_bytes() != payload


def test_retry_backoff_deterministic_and_exponential():
    d = [retry_backoff(0.05, a, token="unit-7") for a in (1, 2, 3)]
    assert d == [retry_backoff(0.05, a, token="unit-7") for a in (1, 2, 3)]
    # base doubling, jitter bounded to +25%
    for attempt, delay in enumerate(d, start=1):
        base = 0.05 * 2 ** (attempt - 1)
        assert base <= delay <= base * 1.25
    # different units decorrelate
    assert retry_backoff(0.05, 1, token="unit-8") != d[0]


# ---------------------------------------------------------------------------
# Checkpoint checksums: validation, fallback, typed corruption error
# ---------------------------------------------------------------------------


def _save_steps(ckpt_dir, steps):
    tree = {"w": np.arange(12, dtype=np.float32).reshape(3, 4)}
    for s in steps:
        CKPT.save(str(ckpt_dir), step=s, tree=tree, extra={"s": s})
    return tree


def test_ckpt_validate_and_fallback(tmp_path):
    tree = _save_steps(tmp_path, [5, 10])
    assert CKPT.validate_step(str(tmp_path), 10) is None
    assert CKPT.latest_step(str(tmp_path)) == 10

    FLT.corrupt_file(os.path.join(str(tmp_path), "step_00000010",
                                  "shard_0.npz"))
    assert "checksum" in CKPT.validate_step(str(tmp_path), 10)
    with pytest.warns(UserWarning, match="failed validation"):
        assert CKPT.latest_step(str(tmp_path)) == 5   # falls back
    assert CKPT.latest_step(str(tmp_path), validate=False) == 10
    with pytest.raises(CKPT.CheckpointCorrupt):
        CKPT.restore(str(tmp_path), 10, tree)
    restored, extra = CKPT.restore(str(tmp_path), 5, tree)
    np.testing.assert_array_equal(np.asarray(restored["w"]), tree["w"])
    assert extra["s"] == 5


def test_ckpt_legacy_manifest_without_shards_validates(tmp_path):
    _save_steps(tmp_path, [3])
    mpath = os.path.join(str(tmp_path), "step_00000003", "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    del manifest["shards"]      # pre-checksum manifest format
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    assert CKPT.validate_step(str(tmp_path), 3) is None
    assert CKPT.latest_step(str(tmp_path)) == 3


def test_ckpt_corrupt_injection_site(tmp_path):
    inj = FLT.parse_faults("ckpt_corrupt:at=1")
    tree = {"w": np.arange(8, dtype=np.float32)}
    CKPT.save(str(tmp_path), step=1, tree=tree, faults=inj, fault_tag="t")
    assert inj.stats() == {"ckpt_corrupt": 1}
    # corruption lands AFTER the checksum was recorded: validation catches it
    assert "checksum" in CKPT.validate_step(str(tmp_path), 1)


# ---------------------------------------------------------------------------
# Engine: injected crash / corruption, resume stays bit-identical
# ---------------------------------------------------------------------------


def test_engine_chunk_crash_then_resume_bit_identical(tmp_path):
    spec = _spec(generations=40)
    want = ga.solve(spec, backend="reference")

    inj = FLT.parse_faults("chunk_crash:at=3")
    eng = ga.Engine(spec, "reference",
                    options=ga.EngineOptions(faults=inj))
    seen = []
    with pytest.raises(FLT.ChunkCrash):
        for tele in eng.run_chunked(chunk_generations=10,
                                    ckpt_dir=str(tmp_path)):
            seen.append(tele["gens_done"])
    assert seen == [10, 20]     # chunk 3's work was lost pre-checkpoint

    eng2 = ga.Engine(spec, "reference")    # a "restarted process": no faults
    last = None
    for tele in eng2.run_chunked(chunk_generations=10,
                                 ckpt_dir=str(tmp_path)):
        if last is None:
            assert tele["resumed_from"] == 20
        else:
            assert tele["resumed_from"] is None   # first chunk only
        last = tele
    assert last["gens_done"] == 40
    assert last["best_fitness"] == want.best_fitness
    np.testing.assert_array_equal(np.asarray(last["best_params"]),
                                  np.asarray(want.best_params))


def test_engine_corrupt_ckpt_falls_back_a_step(tmp_path):
    spec = _spec(generations=40)
    want = ga.solve(spec, backend="reference")

    inj = FLT.parse_faults("ckpt_corrupt:at=2")
    eng = ga.Engine(spec, "reference", options=ga.EngineOptions(faults=inj))
    for _ in eng.run_chunked(chunk_generations=10, ckpt_dir=str(tmp_path),
                             generations=20):
        pass
    assert inj.stats() == {"ckpt_corrupt": 1}   # step 20's shard is rotten

    eng2 = ga.Engine(spec, "reference")
    last = None
    with pytest.warns(UserWarning, match="failed validation"):
        for tele in eng2.run_chunked(chunk_generations=10,
                                     ckpt_dir=str(tmp_path)):
            if last is None:
                assert tele["resumed_from"] == 10   # fell back past step 20
            last = tele
    assert last["gens_done"] == 40
    assert last["best_fitness"] == want.best_fitness


def test_repack_checkpoint_slices_bit_identically(tmp_path):
    specs = [_spec(seed=11, generations=40), _spec(seed=40, generations=40),
             _spec(seed=7, generations=40)]
    pack_dir = str(tmp_path / "pack")
    pe = ga.PackedEngine(specs, "reference")
    for tele in pe.run_chunked(chunk_generations=10, ckpt_dir=pack_dir):
        if tele["gens_done"] >= 20:
            break                       # pack parked at generation 20

    solo_dir = str(tmp_path / "solo1")
    step = ga.repack_checkpoint(pack_dir, specs, [1], solo_dir, "reference")
    assert step == 20
    last = None
    for tele in ga.Engine(specs[1], "reference").run_chunked(
            chunk_generations=10, ckpt_dir=solo_dir):
        last = tele
    want = ga.solve(specs[1], backend="reference")
    assert last["best_fitness"] == want.best_fitness
    np.testing.assert_array_equal(np.asarray(last["best_params"]),
                                  np.asarray(want.best_params))

    pair_dir = str(tmp_path / "pair")
    assert ga.repack_checkpoint(pack_dir, specs, [0, 2], pair_dir,
                                "reference") == 20
    pe2 = ga.PackedEngine([specs[0], specs[2]], "reference")
    last = None
    for tele in pe2.run_chunked(chunk_generations=10, ckpt_dir=pair_dir):
        last = tele
    for spec, jt in zip((specs[0], specs[2]), last["jobs"]):
        assert jt["best_fitness"] == ga.solve(
            spec, backend="reference").best_fitness


# ---------------------------------------------------------------------------
# Scheduler: retry, quarantine, deadlines, recovery
# ---------------------------------------------------------------------------


def _sched(tmp_path, **kw):
    kw.setdefault("registry", GAMetricsRegistry())
    kw.setdefault("backend", "reference")
    kw.setdefault("ckpt_root", str(tmp_path / "root"))
    return GAScheduler(**kw)


def test_scheduler_retries_transient_crash(tmp_path):
    inj = FLT.FaultInjector()
    sched = _sched(tmp_path, chunk_generations=10, paused=True,
                   options=ga.EngineOptions(faults=inj))
    try:
        spec = _spec(seed=11, generations=40)
        job = sched.submit(spec)
        inj.add_rule(f"chunk_crash@{job}:at=2")
        sched.resume_dispatch()
        res = sched.result(job, timeout=120)
        assert res["best_fitness"] == ga.solve(
            spec, backend="reference").best_fitness
        assert sched.job(job).retries == 1
        assert sched.stats()["retries"] == 1
        assert sched.registry.metrics()["jobs"][job]["retries"] == 1
    finally:
        sched.shutdown()


def test_scheduler_quarantines_poison_job_pack_survives(tmp_path):
    inj = FLT.FaultInjector()
    sched = _sched(tmp_path, chunk_generations=10, paused=True,
                   max_retries=1, options=ga.EngineOptions(faults=inj))
    try:
        specs = [_spec(seed=11, generations=40), _spec(seed=40,
                                                       generations=40),
                 _spec(seed=7, generations=40)]
        jobs = [sched.submit(s) for s in specs]
        poison = jobs[1]
        # fires on EVERY chunk after the first: the first chunk checkpoints,
        # so the split resumes survivors from the sliced pack state
        inj.add_rule(f"chunk_crash@{poison}:after=1:times=inf")
        sched.resume_dispatch()

        for job, spec in zip(jobs, specs):
            if job == poison:
                continue
            res = sched.result(job, timeout=120)
            want = ga.solve(spec, backend="reference")
            assert res["best_fitness"] == want.best_fitness
            np.testing.assert_array_equal(np.asarray(res["best_params"]),
                                          np.asarray(want.best_params))
        with pytest.raises(RuntimeError, match="injected chunk crash"):
            sched.result(poison, timeout=120)
        pj = sched.job(poison)
        assert pj.state == FAILED and pj.quarantined
        assert sched.stats()["quarantined"] == 1
        assert sched.registry.metrics()["jobs"][poison]["quarantined"] == 1
    finally:
        sched.shutdown()


def test_scheduler_permanent_error_fails_without_retry(tmp_path):
    sched = _sched(tmp_path)
    try:
        # BackendUnsupported is a ValueError: the work is wrong, not the
        # world — the job must fail immediately without burning retries
        job = sched.submit(_spec(generations=10), backend="no_such_backend")
        with pytest.raises(RuntimeError, match="unknown backend"):
            sched.result(job, timeout=120)
        assert sched.job(job).state == FAILED
        assert sched.job(job).retries == 0
        assert sched.stats()["retries"] == 0
    finally:
        sched.shutdown()


def test_scheduler_deadline_exceeded_before_dispatch(tmp_path):
    clock = FakeClock()
    sched = _sched(tmp_path, paused=True, clock=clock)
    try:
        job = sched.submit(_spec(generations=40), deadline_s=10.0)
        clock.advance(11.0)          # blows the budget while still queued
        sched.resume_dispatch()
        with pytest.raises(RuntimeError, match="deadline"):
            sched.result(job, timeout=60)
        assert sched.job(job).state == DEADLINE_EXCEEDED
        assert sched.stats()["deadline_exceeded"] == 1
        assert (sched.registry.metrics()["jobs"][job]["status"]
                == DEADLINE_EXCEEDED)
    finally:
        sched.shutdown()


def test_scheduler_journal_records_lifecycle(tmp_path):
    sched = _sched(tmp_path)
    try:
        job = sched.submit(_spec(generations=20))
        sched.result(job, timeout=120)
    finally:
        sched.shutdown()
    events = JRN.read_journal(sched._journal_path)
    kinds = [e["ev"] for e in events]
    assert kinds[0] == "submit" and "dispatch" in kinds and "done" in kinds
    done = [e for e in events if e["ev"] == "done"][0]
    assert done["job_id"] == job
    assert "best_fitness" in done["result"]


def test_scheduler_recovery_restores_done_and_requeues_pending(tmp_path):
    reg = GAMetricsRegistry()
    root = str(tmp_path / "root")
    spec_done = _spec(seed=11, generations=20)
    spec_pend = _spec(seed=40, generations=20)
    sched = GAScheduler(registry=reg, backend="reference", ckpt_root=root)
    done_id = sched.submit(spec_done)
    res = sched.result(done_id, timeout=120)
    sched.shutdown()

    # simulate a crash mid-life: journal a submit the old process never ran
    j = JRN.SchedulerJournal(os.path.join(root, JRN.JOURNAL_NAME))
    pend_id = "ga-99-F3"
    j.append({"ev": "submit", "job_id": pend_id,
              "spec": JRN.spec_to_json(spec_pend), "backend": "reference",
              "priority": 0, "deadline_s": None, "max_retries": None})
    j.close()

    reg2 = GAMetricsRegistry()
    sched2 = GAScheduler(registry=reg2, backend="reference", ckpt_root=root,
                         recover=True)
    try:
        assert sched2.recovered_total == 1
        # terminal job: result restored without recomputation
        got = sched2.result(done_id, timeout=5)
        assert got["best_fitness"] == res["best_fitness"]
        # pending job: re-enqueued, runs to the solo-identical answer
        got2 = sched2.result(pend_id, timeout=120)
        assert got2["best_fitness"] == ga.solve(
            spec_pend, backend="reference").best_fitness
        assert sched2.job(pend_id).recovered
        # new ids never collide with journaled ones
        fresh = sched2.submit(_spec(seed=7, generations=10))
        assert fresh not in (done_id, pend_id)
        sched2.result(fresh, timeout=120)
    finally:
        sched2.shutdown()


def test_scheduler_recovery_fails_blackbox_jobs_clearly(tmp_path):
    root = str(tmp_path / "root")
    os.makedirs(root, exist_ok=True)
    j = JRN.SchedulerJournal(os.path.join(root, JRN.JOURNAL_NAME))
    j.append({"ev": "submit", "job_id": "ga-1-blackbox", "spec": None,
              "backend": "reference", "priority": 0, "deadline_s": None,
              "max_retries": None})
    j.close()
    sched = GAScheduler(registry=GAMetricsRegistry(), backend="reference",
                        ckpt_root=root, recover=True)
    try:
        job = sched.job("ga-1-blackbox")
        assert job.state == FAILED
        assert "not recoverable" in job.error
    finally:
        sched.shutdown()


def test_journal_replay_folds_last_event_wins(tmp_path):
    events = [
        {"ev": "submit", "job_id": "a", "spec": {"problem": "F3"}},
        {"ev": "submit", "job_id": "b", "spec": {"problem": "F3"}},
        {"ev": "dispatch", "seq": 0, "job_ids": ["a", "b"],
         "ckpt_dir": "/x/pack-0"},
        {"ev": "park", "seq": 0, "job_ids": ["a", "b"],
         "ckpt_dir": "/x/pack-0"},
        {"ev": "done", "job_id": "a", "result": {"best_fitness": 1.0}},
    ]
    jobs, units, job_unit, max_seq = JRN.replay(events)
    assert jobs["a"].terminal and jobs["a"].result == {"best_fitness": 1.0}
    assert jobs["b"].state == "preempted" and not jobs["b"].terminal
    assert units[0]["ckpt_dir"] == "/x/pack-0" and max_seq == 0
    assert job_unit["b"] == 0


def test_journal_torn_tail_is_end_of_log(tmp_path):
    path = str(tmp_path / "journal.jsonl")
    with open(path, "w") as f:
        f.write('{"ev":"submit","job_id":"a","spec":null}\n')
        f.write('{"ev":"dispatch","seq":0,"job_ids":["a"')   # torn mid-append
    events = JRN.read_journal(path)
    assert [e["ev"] for e in events] == ["submit"]


def test_scheduler_worker_alive_and_stream_abort(tmp_path):
    sched = _sched(tmp_path, paused=True)
    assert sched.stats()["worker_alive"] is True
    job = sched.submit(_spec(generations=40))
    got = {}

    def consume():
        try:
            for _ in sched.stream(job, timeout=60):
                pass
        except RuntimeError as e:
            got["err"] = str(e)

    t = threading.Thread(target=consume)
    t.start()
    sched.shutdown()            # job never dispatched: no organic end event
    t.join(timeout=30)
    assert not t.is_alive()
    assert "aborted" in got["err"] and "shut down" in got["err"]
    assert sched.stats()["worker_alive"] is False
    assert sched.job(job).state == QUEUED    # survives for recover=True
