"""Pluggable GA operator stages (SM / CM / MM) with registries.

The paper's datapath hardwires one operator per stage (2-way tournament,
single-point crossover, XOR mutation).  The GA-survey literature treats the
choice of selection scheme and variation operators as the main quality lever,
so the engine makes each stage a protocol + registry:

  * ``SelectionOp(x, y, sel_lfsr, cfg) -> (w, sel_lfsr')``
  * ``CrossoverOp(w, cross_lfsr, cfg) -> (z, cross_lfsr')``
  * ``MutationOp(z, mut_lfsr, cfg)   -> (x', mut_lfsr')``

All operators consume the same LFSR banks as the paper's modules, so GAState
layout (and checkpoints) are identical whichever combination is selected.
Register your own with the ``register_*`` decorators; every registered
selection scheme is runnable through ``repro.ga.solve`` on the reference,
islands and eager backends.  The fused Pallas backend implements the
paper's fixed OPERATOR pipeline only — its FFM stage, by contrast, is fully
pluggable (`FitnessProgram.stage` traced into the kernel) — so non-paper
operator combinations route to the reference backend via the capability
check while any problem's fitness still runs fused under the paper ops.
"""

from __future__ import annotations

from typing import Callable, Dict, Protocol, Tuple

import jax
import jax.numpy as jnp

from repro.core import ga as G
from repro.core import lfsr
from repro.core import selection as SEL
from repro.core.ga import GAConfig, GAState


class SelectionOp(Protocol):
    def __call__(self, x: jax.Array, y: jax.Array, sel_lfsr: jax.Array,
                 cfg: GAConfig) -> Tuple[jax.Array, jax.Array]: ...


class CrossoverOp(Protocol):
    def __call__(self, w: jax.Array, cross_lfsr: jax.Array,
                 cfg: GAConfig) -> Tuple[jax.Array, jax.Array]: ...


class MutationOp(Protocol):
    def __call__(self, z: jax.Array, mut_lfsr: jax.Array,
                 cfg: GAConfig) -> Tuple[jax.Array, jax.Array]: ...


SELECTION: Dict[str, SelectionOp] = {}
CROSSOVER: Dict[str, CrossoverOp] = {}
MUTATION: Dict[str, MutationOp] = {}


def register_selection(name: str):
    def deco(fn):
        SELECTION[name] = fn
        return fn
    return deco


def register_crossover(name: str):
    def deco(fn):
        CROSSOVER[name] = fn
        return fn
    return deco


def register_mutation(name: str):
    def deco(fn):
        MUTATION[name] = fn
        return fn
    return deco


# ---------------------------------------------------------------------------
# Built-in selection schemes (paper SM + the Sec. 2 survey variants)
# ---------------------------------------------------------------------------

SELECTION["tournament"] = SEL.tournament        # the paper's hardware SM
SELECTION["tournament4"] = SEL.tournament_k     # k=4, stronger pressure
SELECTION["roulette"] = SEL.roulette            # fitness-proportional
SELECTION["rank"] = SEL.rank                    # linear-rank
SELECTION["tournament_elite"] = SEL.with_elitism(SEL.tournament, n_elite=1)


# ---------------------------------------------------------------------------
# Built-in crossover operators
# ---------------------------------------------------------------------------

@register_crossover("single_point")
def single_point(w, cross_lfsr, cfg: GAConfig):
    """The paper's CM: mask-shift single-point crossover (Eqs. 12-20)."""
    return G._crossover(w, cross_lfsr, cfg)


@register_crossover("uniform")
def uniform(w, cross_lfsr, cfg: GAConfig):
    """Uniform crossover: each bit of each offspring pair is swapped
    independently with p=1/2, using the pair's CM LFSR word as the mask.
    Bit-conserving like the paper's CM (same XOR-sum invariant)."""
    cross_lfsr, r = lfsr.draw(cross_lfsr, cfg.steps_per_draw)   # [V, N/2]
    m = (r & jnp.uint32(cfg.var_mask)).T                        # [N/2, V]
    w1, w2 = w[0::2], w[1::2]
    z1 = (w1 & m) | (w2 & ~m)
    z2 = (w2 & m) | (w1 & ~m)
    z = jnp.stack([z1, z2], axis=1).reshape(cfg.n, cfg.v)
    return z, cross_lfsr


@register_crossover("none")
def no_crossover(w, cross_lfsr, cfg: GAConfig):
    """Pass-through CM (selection + mutation only)."""
    return w, cross_lfsr


# ---------------------------------------------------------------------------
# Built-in mutation operators
# ---------------------------------------------------------------------------

@register_mutation("xor")
def xor_first_p(z, mut_lfsr, cfg: GAConfig):
    """The paper's MM: XOR the first P individuals with LFSR words."""
    return G._mutate(z, mut_lfsr, cfg)


@register_mutation("none")
def no_mutation(z, mut_lfsr, cfg: GAConfig):
    """Pass-through MM."""
    return z, mut_lfsr


# ---------------------------------------------------------------------------
# Pipeline composition
# ---------------------------------------------------------------------------

PAPER_PIPELINE = ("tournament", "single_point", "xor")


def resolve(selection: str, crossover: str, mutation: str
            ) -> Tuple[SelectionOp, CrossoverOp, MutationOp]:
    try:
        return (SELECTION[selection], CROSSOVER[crossover],
                MUTATION[mutation])
    except KeyError as e:
        registry = {"selection": SELECTION, "crossover": CROSSOVER,
                    "mutation": MUTATION}
        for kind, reg in registry.items():
            name = {"selection": selection, "crossover": crossover,
                    "mutation": mutation}[kind]
            if name not in reg:
                raise ValueError(
                    f"unknown {kind} operator {name!r}; registered: "
                    f"{sorted(reg)}") from e
        raise


def make_generation(selection: str = "tournament",
                    crossover: str = "single_point",
                    mutation: str = "xor") -> Callable:
    """Build a ``generation_fn(state, cfg, fit) -> (state', y)`` from named
    operators — drop-in for `repro.core.ga.generation` in `G.run_scan`,
    `islands.make_local_step`, and the engine backends."""
    sel, cx, mu = resolve(selection, crossover, mutation)
    if (selection, crossover, mutation) == PAPER_PIPELINE:
        return G.generation   # identical pipeline; keep the core fast path

    def generation_fn(state: GAState, cfg: GAConfig, fit: G.FitnessFn):
        y = fit(state.x)
        w, sel_lfsr = sel(state.x, y, state.sel_lfsr, cfg)
        z, cross_lfsr = cx(w, state.cross_lfsr, cfg)
        x_new, mut_lfsr = mu(z, state.mut_lfsr, cfg)
        return GAState(x_new, sel_lfsr, cross_lfsr, mut_lfsr,
                       state.k + 1), y

    return generation_fn


def make_apply_ops(selection: str = "tournament",
                   crossover: str = "single_point",
                   mutation: str = "xor") -> Callable:
    """Build ``apply_ops(state, y, cfg) -> state'`` (fitness supplied by the
    caller) — the eager-backend analogue of `G.generation_with_y`."""
    sel, cx, mu = resolve(selection, crossover, mutation)

    def apply_ops(state: GAState, y: jax.Array, cfg: GAConfig) -> GAState:
        w, sel_lfsr = sel(state.x, y, state.sel_lfsr, cfg)
        z, cross_lfsr = cx(w, state.cross_lfsr, cfg)
        x_new, mut_lfsr = mu(z, state.mut_lfsr, cfg)
        return GAState(x_new, sel_lfsr, cross_lfsr, mut_lfsr, state.k + 1)

    return apply_ops
