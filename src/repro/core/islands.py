"""Island-model parallel GA — how the paper's one-FPGA datapath scales to pods.

The paper instantiates the full GA once per FPGA; its cited related work [19]
(Guo et al., multi-FPGA parallel GAs) scales by running isolated populations
("islands") that periodically exchange good individuals.  We map that to the
TPU production mesh:

  * a device holds `islands_per_device` independent populations,
    vmapped over the leading axis (the VPU analogue of replicated datapaths);
  * the global island array is sharded over EVERY mesh axis with `shard_map`;
  * every `migrate_every` generations the best individual of each island is
    ring-shipped to the next device with `jax.lax.ppermute`
    (collective-permute == the inter-FPGA links of [19]), replacing the
    recipient island's worst individual.

Migration is overlapped with compute by construction: the permute is issued
on a [I_local, V]-sized elite buffer (tiny) while the next local-generation
scan runs on values that do not depend on it until the splice point.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import ga as G
from repro.core import lfsr


@dataclasses.dataclass(frozen=True)
class IslandConfig:
    ga: G.GAConfig
    n_islands: int               # global island count I
    migrate_every: int = 16      # generations between migrations
    axis_names: tuple = ("data", "model")  # mesh axes the islands shard over


def init_islands(cfg: IslandConfig) -> G.GAState:
    """Stack of I island states with decorrelated seeds."""
    states = []
    for i in range(cfg.n_islands):
        sub = dataclasses.replace(cfg.ga, seed=cfg.ga.seed + 7919 * (i + 1))
        states.append(G.init_state(sub))
    return jax.tree.map(lambda *xs: jnp.stack(xs), *states)


def init_islands_fast(cfg: IslandConfig) -> G.GAState:
    """Vectorized init (no per-island python loop) for large I."""
    I, n, v = cfg.n_islands, cfg.ga.n, cfg.ga.v
    per = 2 * n + v * (n // 2) + 2 * v * n
    s = lfsr.seeds(cfg.ga.seed, I * per).reshape(I, per)
    sel = s[:, : 2 * n].reshape(I, 2, n)
    cross = s[:, 2 * n: 2 * n + v * (n // 2)].reshape(I, v, n // 2)
    mut = s[:, 2 * n + v * (n // 2): 2 * n + v * (n // 2) + v * n].reshape(I, v, n)
    init_bank = s[:, -v * n:].reshape(I, n, v)
    x = lfsr.truncate(lfsr.steps(init_bank, 8), cfg.ga.c)
    return G.GAState(x=x, sel_lfsr=sel, cross_lfsr=cross, mut_lfsr=mut,
                     k=jnp.zeros((I,), jnp.int32))


# ---------------------------------------------------------------------------
# Local (single-device) island stepping
# ---------------------------------------------------------------------------


def _local_generations(states: G.GAState, cfg: IslandConfig,
                       fit: G.FitnessFn, gens: int,
                       generation_fn=None) -> Tuple[G.GAState, jax.Array]:
    """Run `gens` generations on a stack of islands; returns final fitness.
    `generation_fn` swaps the operator pipeline (default: paper ops)."""
    step = functools.partial(generation_fn or G.generation, cfg=cfg.ga,
                             fit=fit)

    def one(st, _):
        st2, y = jax.vmap(lambda s: step(s))(st)
        return st2, None

    states, _ = jax.lax.scan(one, states, None, length=gens)
    y = jax.vmap(fit)(states.x)
    return states, y


def _splice_elites(states: G.GAState, y: jax.Array, elites: jax.Array,
                   cfg: IslandConfig) -> G.GAState:
    """Replace each island's worst individual with the incoming elite."""
    return splice_elites(states, y, elites, minimize=cfg.ga.minimize)


def splice_elites(states: G.GAState, y: jax.Array, elites: jax.Array,
                  *, minimize: bool) -> G.GAState:
    """Replace each island's worst individual with the incoming elite.
    states: island-stacked [I, ...]; y: fitness of states.x [I, N]."""
    yf = y.astype(jnp.float32)
    worst = jnp.argmax(yf, axis=1) if minimize else jnp.argmin(yf, axis=1)
    I = states.x.shape[0]
    x = states.x.at[jnp.arange(I), worst].set(elites)
    return states._replace(x=x)


def _best_of(states: G.GAState, y: jax.Array, cfg: IslandConfig):
    return best_of(states, y, minimize=cfg.ga.minimize)


def best_of(states: G.GAState, y: jax.Array, *, minimize: bool):
    """Per-island elite: (elite_x [I, V], elite_y [I]) of the current pops."""
    yf = y.astype(jnp.float32)
    best = jnp.argmin(yf, axis=1) if minimize else jnp.argmax(yf, axis=1)
    I = states.x.shape[0]
    return states.x[jnp.arange(I), best], yf[jnp.arange(I), best]


def migrate_ring(states: G.GAState, y: jax.Array, *, minimize: bool
                 ) -> Tuple[G.GAState, jax.Array, jax.Array]:
    """One on-host ring migration over an island-stacked state.

    The best individual of island i replaces the worst individual of island
    (i + 1) mod I — the `jnp.roll` analogue of the inter-FPGA elite links
    ([19]); `lax.ppermute` plays the same role on a device mesh (see
    `make_sharded_step`).  This is THE migration step shared by
    `make_local_step` and the engine's island_ring topology (any executor):
    migration happens *between* generation blocks / kernel launches, so the
    fused Pallas executor composes with islands without touching the kernel.

    Returns (new_states, elite_x [I, V], elite_y [I]).
    """
    elite_x, elite_y = best_of(states, y, minimize=minimize)
    shifted = jnp.roll(elite_x, 1, axis=0)
    states = splice_elites(states, y, shifted, minimize=minimize)
    return states, elite_x, elite_y


# ---------------------------------------------------------------------------
# Sharded multi-pod runner
# ---------------------------------------------------------------------------


def make_sharded_step(cfg: IslandConfig, fit: G.FitnessFn, mesh: Mesh,
                      generation_fn=None
                      ) -> Callable[[G.GAState], Tuple[G.GAState, jax.Array]]:
    """Build the jit/shard_map epoch step for the production mesh.

    One call = `migrate_every` local generations + one ring migration.
    Island axis is sharded over all `cfg.axis_names` mesh axes jointly.
    """
    axes = cfg.axis_names
    spec_leading = P(axes)  # shard leading (island) dim over all axes

    def spec_for(x):
        return P(axes, *([None] * (x.ndim - 1)))

    def epoch(states: G.GAState) -> Tuple[G.GAState, jax.Array]:
        states, y = _local_generations(states, cfg, fit, cfg.migrate_every,
                                       generation_fn)
        elite_x, elite_y = _best_of(states, y, cfg)
        # ring-migrate elites to the next device along the *last* mesh axis,
        # composing rings across axes (pod ring at the wrap point).
        perm_axis = axes[-1]
        n_dev = np.prod([mesh.shape[a] for a in axes])
        size_last = mesh.shape[perm_axis]
        shifted = jax.lax.ppermute(
            elite_x, perm_axis,
            perm=[(i, (i + 1) % size_last) for i in range(size_last)])
        states = _splice_elites(states, y, shifted, cfg)
        del n_dev
        return states, elite_x, elite_y

    state_specs = G.GAState(
        x=spec_for(jnp.zeros((1, 1, 1))),
        sel_lfsr=spec_for(jnp.zeros((1, 1, 1))),
        cross_lfsr=spec_for(jnp.zeros((1, 1, 1))),
        mut_lfsr=spec_for(jnp.zeros((1, 1, 1))),
        k=P(axes),
    )
    sharded = shard_map(epoch, mesh=mesh, in_specs=(state_specs,),
                        out_specs=(state_specs, P(axes, None), P(axes)),
                        check_rep=False)
    return jax.jit(sharded)


def run_sharded(cfg: IslandConfig, fit: G.FitnessFn, mesh: Mesh,
                epochs: int, states: Optional[G.GAState] = None,
                generation_fn=None):
    """Drive `epochs` migration epochs on the mesh; returns best over all.

    Deprecated entry-point shim — use `repro.ga.solve(spec, mesh=mesh)`."""
    warnings.warn(
        "repro.core.islands.run_sharded is a deprecated entry point; use "
        "repro.ga.solve(spec with n_islands>1, mesh=mesh) instead",
        DeprecationWarning, stacklevel=2)
    if states is None:
        states = init_islands_fast(cfg)
        sharding = jax.tree.map(
            lambda _: NamedSharding(mesh, P(cfg.axis_names)), states,
            is_leaf=lambda x: False)
        states = jax.tree.map(
            lambda x: jax.device_put(x, NamedSharding(
                mesh, P(cfg.axis_names, *([None] * (x.ndim - 1))))), states)
        del sharding
    step = make_sharded_step(cfg, fit, mesh, generation_fn)
    best = None
    for _ in range(epochs):
        states, _elite_x, elite_y = step(states)
        e = float(jnp.min(elite_y) if cfg.ga.minimize else jnp.max(elite_y))
        best = e if best is None else (min(best, e) if cfg.ga.minimize else max(best, e))
    return states, best


# ---------------------------------------------------------------------------
# Single-host convenience (vmap only, no mesh) — used by tests/benchmarks
# ---------------------------------------------------------------------------


def make_local_step(cfg: IslandConfig, fit: G.FitnessFn, generation_fn=None):
    """Jitted epoch for a single-host island stack: `migrate_every` local
    generations + one on-host ring migration.  Shared by `run_local` and the
    engine's islands backend.  Returns (states, elite_x, elite_y)."""

    @jax.jit
    def epoch(states):
        states, y = _local_generations(states, cfg, fit, cfg.migrate_every,
                                       generation_fn)
        states, elite_x, elite_y = migrate_ring(states, y,
                                                minimize=cfg.ga.minimize)
        return states, elite_x, elite_y

    return epoch


def run_local(cfg: IslandConfig, fit: G.FitnessFn, epochs: int,
              states: Optional[G.GAState] = None, generation_fn=None):
    """Deprecated entry-point shim — use `repro.ga.solve(spec with
    n_islands>1, backend="islands")`; the engine shares `migrate_ring`."""
    warnings.warn(
        "repro.core.islands.run_local is a deprecated entry point; use "
        "repro.ga.solve(spec with n_islands>1) instead",
        DeprecationWarning, stacklevel=2)
    if states is None:
        states = init_islands_fast(cfg)
    epoch = make_local_step(cfg, fit, generation_fn)
    best = None
    for _ in range(epochs):
        states, _elite_x, elite_y = epoch(states)
        e = float(jnp.min(elite_y) if cfg.ga.minimize else jnp.max(elite_y))
        best = e if best is None else (min(best, e) if cfg.ga.minimize else max(best, e))
    return states, best
