"""Versioned per-host cost tables: measured gens/s per epoch-plan point.

The epoch planner (`ga/backends.IslandRingTopology._epoch_plan`) used to be
purely *modeled*: a hand-written VMEM byte estimator picked resident vs.
gridded.  This module is the measured half of the two-tier decision — a
JSON-persisted table mapping each plan POINT to observed generations/second:

  point  = (executor, epoch mode, migration, N, islands-per-shard, c,
            problem-stage kind, shard count, migrate_every,
            selection lane)                                   [POINT_FIELDS]
  axis   = gens_per_launch — the generations one launch folds; the one
           continuous knob, so `lookup` linearly interpolates between
           measured axis values (and returns None outside the measured
           range: no extrapolation, the planner falls back to the
           heuristic instead of trusting an invented number).

Tables are keyed to a HOST fingerprint (platform + device count; the
device kind is recorded for the report but not gated, so fake-device CI
hosts match).  `resolve_table` is the single discovery entry point:

  resolve_table(False)          -> None (explicitly disabled — bit-identical
                                  pre-measurement behavior, what tests and
                                  the bench's static rows pin)
  resolve_table(CostTable)      -> itself
  resolve_table("path.json")    -> load, TRUSTED (no host check: the caller
                                  chose the file, e.g. a committed CI
                                  snapshot measured on a fake-device host)
  resolve_table(None)           -> the ambient default: REPRO_GA_COST_TABLE
                                  ("", "0", "off", "none" disable; a path
                                  pins a trusted file) or else the per-host
                                  cache file under ~/.cache/repro-ga/
                                  (REPRO_GA_AUTOTUNE_CACHE overrides the
                                  dir), loaded STRICTLY — version or host
                                  mismatch silently yields None.

Loads are memoized by (path, mtime), so per-Engine-build resolution costs a
stat(2), not a parse.
"""

from __future__ import annotations

import bisect
import json
import os
import warnings
from typing import Any, Dict, Iterator, Optional, Tuple

TABLE_VERSION = 2   # v2: plan points gained the "lane" field (sel_lane)

# identity of one measured plan point (the table key; gens_per_launch is the
# interpolation axis, n_repeats is deliberately EXCLUDED — the replica axis
# rides the kernel grid / vmap and scales throughput, it does not change
# which mode wins, and keying on it would shatter the table)
POINT_FIELDS = ("executor", "mode", "migration", "n", "i_local", "c",
                "stage", "shards", "E", "lane")

_DISABLE_VALUES = {"", "0", "off", "none", "false"}


def point_key(point: Dict[str, Any]) -> Tuple:
    """Canonical hashable key of a plan point dict (POINT_FIELDS order)."""
    return tuple(point[f] for f in POINT_FIELDS)


def host_fingerprint() -> Dict[str, Any]:
    """This process's device identity (lazy jax import — table files are
    readable without initializing a backend)."""
    import jax
    devs = jax.devices()
    return {"platform": str(jax.default_backend()),
            "device_kind": str(getattr(devs[0], "device_kind", "unknown")),
            "device_count": len(devs)}


def hosts_match(a: Optional[dict], b: Optional[dict]) -> bool:
    """Platform + device count decide whether measurements transfer; the
    device kind is informational (fake-device hosts report the host CPU)."""
    if not a or not b:
        return False
    return (a.get("platform") == b.get("platform")
            and a.get("device_count") == b.get("device_count"))


def default_cache_dir() -> str:
    override = os.environ.get("REPRO_GA_AUTOTUNE_CACHE")
    if override:
        return override
    return os.path.join(os.path.expanduser("~"), ".cache", "repro-ga")


def default_table_path() -> str:
    """The ambient per-host cost-table file `resolve_table(None)` discovers
    (host identity is checked at load, not encoded in the name)."""
    return os.path.join(default_cache_dir(), "cost_table.json")


class CostTable:
    """gens/s measurements keyed by plan point, with per-point linear
    interpolation over the gens_per_launch axis."""

    def __init__(self, host: Optional[dict] = None,
                 version: int = TABLE_VERSION):
        self.version = version
        self.host = dict(host) if host else None
        # point key tuple -> {gens_per_launch: {"gens_per_s", "reps", "cov"}}
        self._series: Dict[Tuple, Dict[int, Dict[str, Any]]] = {}

    # ---- mutation -------------------------------------------------------

    def add(self, point: Dict[str, Any], gens_per_launch: int,
            gens_per_s: float, *, reps: int = 1, cov: float = 0.0) -> None:
        series = self._series.setdefault(point_key(point), {})
        series[int(gens_per_launch)] = {"gens_per_s": float(gens_per_s),
                                        "reps": int(reps),
                                        "cov": round(float(cov), 5)}

    def merge(self, other: "CostTable") -> None:
        """Fold `other`'s points in (other wins on conflicts)."""
        for key, series in other._series.items():
            self._series.setdefault(key, {}).update(
                {g: dict(e) for g, e in series.items()})

    # ---- queries --------------------------------------------------------

    def lookup(self, point: Dict[str, Any],
               gens_per_launch: int) -> Optional[float]:
        """Measured (or interpolated) gens/s for a plan point, or None when
        the table does not cover it — exact axis hit wins; between two
        measured gens_per_launch values the estimate is linear; outside the
        measured range there is no answer (never extrapolate)."""
        series = self._series.get(point_key(point))
        if not series:
            return None
        g = int(gens_per_launch)
        if g in series:
            return series[g]["gens_per_s"]
        gs = sorted(series)
        if g < gs[0] or g > gs[-1]:
            return None
        i = bisect.bisect_left(gs, g)
        glo, ghi = gs[i - 1], gs[i]
        ylo, yhi = series[glo]["gens_per_s"], series[ghi]["gens_per_s"]
        t = (g - glo) / (ghi - glo)
        return ylo + t * (yhi - ylo)

    def entries(self) -> Iterator[Dict[str, Any]]:
        """Flat iterator of measured rows (point fields + axis + stats) —
        the serialization shape and the roofline report's feed."""
        for key, series in sorted(self._series.items(),
                                  key=lambda kv: tuple(map(str, kv[0]))):
            point = dict(zip(POINT_FIELDS, key))
            for g in sorted(series):
                yield {**point, "gens_per_launch": g, **series[g]}

    def __len__(self) -> int:
        return sum(len(s) for s in self._series.values())

    # ---- persistence ----------------------------------------------------

    def to_json(self) -> Dict[str, Any]:
        return {"version": self.version, "host": self.host,
                "entries": list(self.entries())}

    def save(self, path: str) -> str:
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2)
            f.write("\n")
        return path

    @classmethod
    def from_json(cls, obj: Dict[str, Any]) -> "CostTable":
        table = cls(host=obj.get("host"),
                    version=int(obj.get("version", -1)))
        for e in obj.get("entries", ()):
            point = {f: e[f] for f in POINT_FIELDS}
            table.add(point, e["gens_per_launch"], e["gens_per_s"],
                      reps=e.get("reps", 1), cov=e.get("cov", 0.0))
        return table

    @classmethod
    def load(cls, path: str,
             expect_host: Optional[dict] = None) -> Optional["CostTable"]:
        """Load a table file, or None when it is unusable: missing/corrupt,
        a stale TABLE_VERSION, or (when `expect_host` is given — the strict
        ambient-discovery path) a host-fingerprint mismatch."""
        try:
            with open(path) as f:
                obj = json.load(f)
        except FileNotFoundError:
            return None
        except (OSError, ValueError) as e:
            warnings.warn(f"cost table {path!r} is unreadable ({e!r}); "
                          "planner falls back to the heuristic",
                          stacklevel=2)
            return None
        if int(obj.get("version", -1)) != TABLE_VERSION:
            warnings.warn(
                f"cost table {path!r} has version {obj.get('version')!r} "
                f"(this build speaks {TABLE_VERSION}); ignoring it — "
                "re-run the autotune sweep", stacklevel=2)
            return None
        if expect_host is not None and not hosts_match(obj.get("host"),
                                                       expect_host):
            return None     # silently: another host's cache entry, not ours
        return cls.from_json(obj)


# memoized loads: (abspath, mtime_ns, strict?) -> CostTable | None
_LOAD_MEMO: Dict[Tuple, Optional[CostTable]] = {}


def _load_cached(path: str,
                 expect_host: Optional[dict]) -> Optional[CostTable]:
    apath = os.path.abspath(path)
    try:
        mtime = os.stat(apath).st_mtime_ns
    except OSError:
        if expect_host is None:     # an explicitly-named file should exist
            warnings.warn(f"cost table {path!r} not found; planner falls "
                          "back to the heuristic", stacklevel=3)
        return None
    memo_key = (apath, mtime, expect_host is None)
    if memo_key not in _LOAD_MEMO:
        _LOAD_MEMO[memo_key] = CostTable.load(apath, expect_host=expect_host)
    return _LOAD_MEMO[memo_key]


def resolve_table(cost_table=None) -> Optional[CostTable]:
    """The one cost-table discovery entry point (see module docstring):
    False disables, a CostTable passes through, a path loads TRUSTED, and
    None discovers the ambient default (env pin, else the strict per-host
    cache file)."""
    if cost_table is False:
        return None
    if isinstance(cost_table, CostTable):
        return cost_table
    if isinstance(cost_table, (str, os.PathLike)):
        return _load_cached(os.fspath(cost_table), expect_host=None)
    if cost_table is not None:
        raise TypeError(
            "cost_table must be False (disable), None (ambient discovery), "
            f"a path or a CostTable — got {type(cost_table).__name__}")
    env = os.environ.get("REPRO_GA_COST_TABLE")
    if env is not None:
        if env.strip().lower() in _DISABLE_VALUES:
            return None
        return _load_cached(env, expect_host=None)
    path = default_table_path()
    if not os.path.exists(path):
        return None
    return _load_cached(path, expect_host=host_fingerprint())
