"""GA-as-a-service: async multi-tenant job scheduler over one device mesh.

`run_ga_job` made the engine a telemetered *single-job* service; this module
makes it multi-tenant.  A `GAScheduler` owns the mesh and a worker thread;
clients `submit(spec)` and get a job id back immediately:

    sched = GAScheduler(mesh=mesh)
    a = sched.submit(spec_a)                  # QUEUED
    b = sched.submit(spec_b)                  # shape-compatible with a
    hot = sched.submit(urgent, priority=10)   # preempts the running pack
    for event in sched.stream(a):             # live per-chunk telemetry
        print(event["gens_done"], event["best_fitness"])
    print(sched.result(a)["best_fitness"])    # blocks until DONE

Three mechanisms carry the multiplexing:

* **Packing** — queued jobs whose specs share `GASpec.compile_key()` (and
  `generations`) are packed down the engine's `n_repeats` replica axis into
  ONE `PackedEngine` launch, up to `max_pack` slots.  Slot seeding follows
  the solo convention exactly, so per-job results are bit-identical to
  running each job alone (asserted in tests).
* **Compile cache** — runners live in the process-global
  `repro.ga.compile_cache.RUNNER_CACHE`, keyed by spec shape: the second
  submission of an identical spec shape skips tracing/compilation entirely
  (the hit/miss counters are exported through `stats()` → /metrics).
* **Preemption** — the worker drives `PackedEngine.run_chunked` with a
  checkpoint directory; between chunks it checks for strictly
  higher-priority queued work, and if present parks the pack (jobs →
  PREEMPTED, state already on disk) and requeues it.  Resume restores the
  packed state bit-identically — `run_chunked`'s checkpoint/resume path IS
  the preemption primitive, no new state format.

Job states: QUEUED → RUNNING → DONE, with RUNNING → PREEMPTED → QUEUED
loops and any state → FAILED on error.  Telemetry flows through a
`GAMetricsRegistry` (per-chunk pub/sub feeds the metrics_http SSE and
long-poll endpoints; `attach_scheduler_stats` adds queue-depth /
jobs-running / cache-hit gauges to every /metrics scrape).

Two trace-driven extensions ride on top:

* **Cost-table ordering** — when a `cost_table` (see `repro.autotune`) is
  attached, every submission gets a measured gens/s estimate for its
  planned launch shape; within a priority level the dispatcher runs
  shortest-estimated-wall first.  The table also flows into every
  `PackedEngine` so each launch uses the measured epoch plan.  With no
  table the ordering is bit-identical to plain priority/FIFO.
* **TTL GC** — `job_ttl_s` bounds how long DONE/FAILED jobs linger in the
  scheduler and registry; the worker sweeps them out between dispatches
  (`repro_ga_sched_evicted_total` counts evictions).

Fault tolerance (exercised by `scripts/chaos_smoke.py` and
`tests/test_faults.py` through the `repro.faults` injection registry):

* **Retry with backoff** — a unit failing with a *transient* error
  (`repro.faults.classify_error`: injected faults, I/O, runtime/XLA blow-
  ups) requeues frozen with exponential backoff + deterministic jitter
  (`retry_backoff`), resuming from its last pack checkpoint; each job
  spends one retry of its budget (`max_retries`, per-job override at
  submit).  *Permanent* errors (ValueError and friends — the work is
  wrong, not the world) skip straight to failure handling.
* **Pack isolation / quarantine** — when a multi-job pack exhausts its
  budget (or hits a permanent error), the pack SPLITS: each job re-enters
  the queue as a solo frozen unit resuming from a checkpoint sliced out
  of the pack's (`ga.repack_checkpoint` — the packing bit-identity
  invariant run in reverse).  The poison job re-fails alone and is
  quarantined as FAILED; the survivors complete bit-identically to an
  undisturbed run.
* **Deadlines** — `submit(..., deadline_s=)` bounds a job's wall clock
  from submission; enforcement is at chunk granularity (queued jobs past
  deadline never dispatch; running jobs are marked between chunks) with
  the terminal DEADLINE_EXCEEDED state.
* **Durability** — every submit / dispatch / park / requeue / terminal
  transition appends to `journal.jsonl` under `ckpt_root`
  (`repro.serve.journal`); `GAScheduler(recover=True)` replays it so a
  restarted server re-enqueues pending jobs (frozen packs resume from
  their checkpoints) and restores finished results.
"""

from __future__ import annotations

import dataclasses
import itertools
import os
import tempfile
import threading
import time as _time
import warnings
import zlib
from typing import Any, Dict, Iterator, List, Optional

from repro import faults as FLT
from repro.serve import journal as JRN
from repro.serve.engine import GA_METRICS, GAMetricsRegistry

QUEUED = "queued"
RUNNING = "running"
PREEMPTED = "preempted"
DONE = "done"
FAILED = "failed"
DEADLINE_EXCEEDED = "deadline_exceeded"

TERMINAL_STATES = (DONE, FAILED, DEADLINE_EXCEEDED)


def retry_backoff(base_s: float, attempt: int, token: str = "") -> float:
    """Exponential backoff with deterministic jitter: `base * 2^(attempt-1)`
    stretched by up to +25% keyed on `token` (the unit id) — retries of
    different units decorrelate without `random`, and the same unit backs
    off identically on every replay."""
    jitter = (zlib.crc32(f"{token}:{attempt}".encode()) % 1000) / 4000.0
    return base_s * (2 ** max(attempt - 1, 0)) * (1.0 + jitter)


@dataclasses.dataclass
class Job:
    """One submitted GASpec and its scheduler-side lifecycle."""

    job_id: str
    spec: Any
    backend: str = "auto"
    priority: int = 0
    state: str = QUEUED
    result: Optional[Dict[str, Any]] = None
    error: Optional[str] = None
    done: threading.Event = dataclasses.field(default_factory=threading.Event)
    est_gens_per_s: Optional[float] = None   # cost-table throughput estimate
    finished_at: Optional[float] = None      # clock() terminal stamp
    deadline_s: Optional[float] = None       # wall budget from submission
    max_retries: Optional[int] = None        # per-job retry budget override
    retries: int = 0                         # retry dispatches consumed
    quarantined: bool = False                # failed as the isolated poison
    submitted_at: float = 0.0                # clock() submission stamp
    recovered: bool = False                  # re-enqueued by journal replay


@dataclasses.dataclass
class _Unit:
    """One schedulable queue entry: fresh single jobs (packable at dispatch)
    or a preempted pack (membership frozen — its checkpoint holds the whole
    packed state, so it must resume with the same jobs in the same order)."""

    seq: int
    jobs: List[Job]
    packable: bool = True
    ckpt_dir: Optional[str] = None
    attempts: int = 0            # dispatches that ended in failure
    not_before: float = 0.0      # clock() gate for retry backoff
    isolated: bool = False       # solo split out of a quarantined pack

    @property
    def priority(self) -> int:
        return max(j.priority for j in self.jobs)

    def live_jobs(self) -> List[Job]:
        """Members not yet in a terminal state (a frozen pack keeps its
        full membership for checkpoint-seed identity, but deadline-expired
        jobs inside it no longer receive chunks or results)."""
        return [j for j in self.jobs if j.state not in TERMINAL_STATES]


class GAScheduler:
    """Async multi-tenant GA job scheduler (one worker thread owns the mesh).

    Parameters: `mesh` is handed to every engine build; `backend` is the
    default backend request; `max_pack` caps slots per launch;
    `chunk_generations` sets the telemetry/preemption granularity;
    `ckpt_root` is where pack checkpoints live (a temp dir by default);
    `job_ttl_s` evicts DONE/FAILED jobs that many seconds after they
    finish (None keeps them forever); `cost_table` follows
    `repro.autotune.table.resolve_table` semantics — None discovers the
    ambient table, False disables, a path or CostTable pins one.
    Engine knobs can also arrive as one `ga.EngineOptions` via `options=`
    (mesh/cost_table then live there; mixing both is an error) — that is
    how the streamed lane's vmem_budget / stream_tile_islands reach every
    packed launch.
    """

    def __init__(self, *, mesh=None, registry: Optional[GAMetricsRegistry]
                 = None, backend: str = "auto", max_pack: int = 8,
                 chunk_generations: Optional[int] = None,
                 ckpt_root: Optional[str] = None,
                 job_ttl_s: Optional[float] = None,
                 cost_table=None, options=None,
                 max_retries: int = 3, retry_backoff_s: float = 0.05,
                 recover: bool = False, paused: bool = False,
                 clock=None):
        from repro.autotune import resolve_table   # import-light (no jax)
        from repro.ga.options import resolve_options   # import-light too

        self.options = resolve_options(options, mesh=mesh,
                                       cost_table=cost_table)
        self.mesh = self.options.mesh
        self.registry = registry if registry is not None else GA_METRICS
        self.backend = backend
        self.max_pack = max(1, int(max_pack))
        self.chunk_generations = chunk_generations
        self.ckpt_root = ckpt_root or tempfile.mkdtemp(prefix="ga-sched-")
        self.job_ttl_s = None if job_ttl_s is None else float(job_ttl_s)
        self.max_retries = max(0, int(max_retries))
        self.retry_backoff_s = float(retry_backoff_s)
        # injectable clock: deadlines / backoff gates / TTL stamps all read
        # it, so fault tests drive time without sleeping
        self._clock = clock if clock is not None else _time.monotonic
        # resolved ONCE: the injector instance (occurrence counters included)
        # is shared with every engine build via EngineOptions.faults
        self.faults = FLT.resolve_faults(self.options.faults)
        # resolve once: every engine build + submit estimate reuses it
        self.cost_table = resolve_table(self.options.cost_table)
        self._cv = threading.Condition()
        self._queue: List[_Unit] = []
        self._jobs: Dict[str, Job] = {}
        self._seq = itertools.count()
        self._stop = False
        self._paused = bool(paused)
        self._running: List[Job] = []
        self.packs_launched = 0
        self.preemptions = 0
        self.jobs_packed = 0        # jobs that shared a launch with >=1 other
        self.jobs_evicted = 0       # finished jobs TTL-swept from registry
        self.plans_measured = 0     # launches planned from the cost table
        self.plans_heuristic = 0    # launches planned by the static heuristic
        self.retries_total = 0      # job retry dispatches after transients
        self.quarantined_total = 0  # poison jobs isolated + failed
        self.recovered_total = 0    # jobs re-enqueued by journal replay
        self.deadline_exceeded_total = 0
        self._journal_path = os.path.join(self.ckpt_root, JRN.JOURNAL_NAME)
        # "a" mode never truncates, so opening before replay is safe —
        # and recovery's own transitions get journaled too
        self._journal = JRN.SchedulerJournal(self._journal_path)
        if recover:
            self._recover()
        self.registry.attach_scheduler_stats(self.stats)
        self._worker = threading.Thread(target=self._run, name="ga-scheduler",
                                        daemon=True)
        self._worker.start()

    # ---- client API -----------------------------------------------------

    def submit(self, spec, *, backend: Optional[str] = None,
               priority: int = 0, deadline_s: Optional[float] = None,
               max_retries: Optional[int] = None) -> str:
        """Enqueue a GASpec; returns its job id immediately (state QUEUED).

        `deadline_s` bounds the job's wall clock from this moment —
        enforced at chunk granularity, ending in DEADLINE_EXCEEDED.
        `max_retries` overrides the scheduler's per-job retry budget."""
        with self._cv:
            if self._stop:
                raise RuntimeError("scheduler is shut down")
        job_id = self.registry.allocate_job_id(spec.problem or "blackbox")
        job = Job(job_id=job_id, spec=spec,
                  backend=backend if backend is not None else self.backend,
                  priority=int(priority),
                  deadline_s=None if deadline_s is None else float(deadline_s),
                  max_retries=max_retries,
                  submitted_at=self._clock())
        if self.cost_table is not None:
            from repro.autotune import estimate_gens_per_s
            try:   # an estimate is a scheduling hint, never a submit error
                job.est_gens_per_s = estimate_gens_per_s(
                    spec, self.cost_table, backend=job.backend,
                    mesh=self.mesh)
            except Exception:
                job.est_gens_per_s = None
        self.registry.queue_job(job_id, problem=spec.problem or "blackbox",
                                gens_total=spec.generations, n_vars=spec.v,
                                priority=job.priority, deadline_s=deadline_s)
        self._journal.append({"ev": "submit", "job_id": job_id,
                              "spec": JRN.spec_to_json(spec),
                              "backend": job.backend,
                              "priority": job.priority,
                              "deadline_s": job.deadline_s,
                              "max_retries": job.max_retries})
        with self._cv:
            self._jobs[job_id] = job
            self._queue.append(_Unit(seq=next(self._seq), jobs=[job]))
            self._cv.notify_all()
        return job_id

    def pause(self) -> None:
        """Stop dispatching new units (the unit in flight finishes its
        chunk loop normally).  Lets a chaos harness arm job-targeted fault
        rules between submit and first dispatch without racing the worker."""
        with self._cv:
            self._paused = True

    def resume_dispatch(self) -> None:
        with self._cv:
            self._paused = False
            self._cv.notify_all()

    def job(self, job_id: str) -> Job:
        with self._cv:
            return self._jobs[job_id]

    def result(self, job_id: str, timeout: Optional[float] = None
               ) -> Dict[str, Any]:
        """Block until the job finishes; returns its final telemetry dict.
        Raises RuntimeError if it FAILED, TimeoutError on timeout."""
        job = self.job(job_id)
        if not job.done.wait(timeout):
            raise TimeoutError(f"job {job_id} still {job.state} "
                               f"after {timeout}s")
        if job.state in (FAILED, DEADLINE_EXCEEDED):
            raise RuntimeError(f"job {job_id} {job.state}: {job.error}")
        return job.result

    def stream(self, job_id: str, timeout: Optional[float] = None
               ) -> Iterator[Dict[str, Any]]:
        """Yield per-chunk telemetry events live until the job ends (the
        same feed the metrics_http SSE endpoint serves)."""
        job = self.job(job_id)
        q = self.registry.subscribe(job_id)
        try:
            # subscribed after the job ended -> the end event predates the
            # subscription and will never arrive; don't block on it
            st = self.registry.metrics()["jobs"].get(job_id, {}).get("status")
            if job.done.is_set() or st in TERMINAL_STATES:
                return
            while True:
                event = q.get(timeout=timeout)
                if (event.get("event") == "end"
                        and event.get("status") == "aborted"):
                    # the worker died or the scheduler shut down under us —
                    # no organic end event is coming
                    raise RuntimeError(
                        f"job {job_id} stream aborted: {event.get('error')}")
                yield event
                if event.get("event") == "end":
                    return
        finally:
            self.registry.unsubscribe(job_id, q)

    def wait_all(self, timeout: Optional[float] = None) -> None:
        """Block until every submitted job is DONE or FAILED."""
        import time as _t
        deadline = None if timeout is None else _t.monotonic() + timeout
        for job in list(self._jobs.values()):
            left = None if deadline is None else deadline - _t.monotonic()
            if left is not None and left <= 0:
                raise TimeoutError("jobs still pending")
            if not job.done.wait(left):
                raise TimeoutError(f"job {job.job_id} still {job.state}")

    def stats(self) -> Dict[str, Any]:
        """Scheduler gauges for /metrics (queue depth, running, packing and
        compile-cache counters)."""
        from repro.ga.compile_cache import RUNNER_CACHE
        with self._cv:
            depth = sum(len(u.jobs) for u in self._queue)
            running = len(self._running)
        cache = RUNNER_CACHE.stats()
        return {"queue_depth": depth, "jobs_running": running,
                "packs_launched": self.packs_launched,
                "preemptions": self.preemptions,
                "jobs_packed": self.jobs_packed,
                "max_pack": self.max_pack,
                "cache_hits": cache["hits"],
                "cache_misses": cache["misses"],
                "cache_entries": cache["entries"],
                "jobs_evicted": self.jobs_evicted,
                "plans_measured": self.plans_measured,
                "plans_heuristic": self.plans_heuristic,
                "plan_table_entries": (len(self.cost_table)
                                       if self.cost_table is not None else 0),
                "retries": self.retries_total,
                "quarantined": self.quarantined_total,
                "recovered": self.recovered_total,
                "deadline_exceeded": self.deadline_exceeded_total,
                "worker_alive": (self._worker.is_alive()
                                 if hasattr(self, "_worker") else False)}

    def gc_now(self, now: Optional[float] = None) -> int:
        """Evict DONE/FAILED jobs older than `job_ttl_s`; returns the count.
        The worker calls this between dispatches; tests call it directly.
        Registry eviction happens outside `_cv` (its Condition lock is not
        reentrant and the registry takes its own lock)."""
        if self.job_ttl_s is None:
            return 0
        now = self._clock() if now is None else now
        with self._cv:
            stale = [j for j in self._jobs.values()
                     if j.state in TERMINAL_STATES
                     and j.finished_at is not None
                     and now - j.finished_at >= self.job_ttl_s]
            for j in stale:
                del self._jobs[j.job_id]
        for j in stale:
            self.registry.evict_job(j.job_id)
        self.jobs_evicted += len(stale)
        return len(stale)

    def shutdown(self, wait: bool = True, timeout: float = 30.0) -> None:
        """Stop the worker after the unit in flight; queued jobs stay QUEUED
        (their journal entries let a `recover=True` restart re-enqueue
        them).  With `wait`, a worker that fails to join within `timeout`
        is surfaced loudly — `stats()["worker_alive"]` stays True so
        callers (scheduler_smoke asserts this) can detect the stuck
        thread instead of silently leaking it."""
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        if wait:
            self._worker.join(timeout)
            if self._worker.is_alive():
                warnings.warn(
                    f"GAScheduler worker did not stop within {timeout}s "
                    "(stuck mid-unit?); it remains joinable via "
                    "stats()['worker_alive']", stacklevel=2)
        # release any stream()/SSE clients blocked on jobs that will now
        # never produce an organic end event
        self.registry.abort_streams("scheduler shut down")
        if not self._worker.is_alive():
            self._journal.close()

    # ---- worker ---------------------------------------------------------

    def _pack_sig(self, job: Job):
        return (job.spec.compile_key(), job.spec.generations, job.backend)

    def _unit_order_key(self, u: _Unit):
        """Dispatch order: priority first, then (with a cost table) shortest
        estimated wall, then FIFO.  Estimated units outrank unestimated ones
        within a level; with no table every unit gets the same middle terms,
        so the order is bit-identical to plain priority/FIFO."""
        ests = [j.spec.generations / j.est_gens_per_s for j in u.jobs
                if j.est_gens_per_s]
        if not ests:
            return (u.priority, 0, 0.0, -u.seq)
        return (u.priority, 1, -min(ests), -u.seq)

    def _take_unit(self, ready: List[_Unit]) -> Optional[_Unit]:
        """Pop the best-priority READY unit; pack compatible fresh jobs onto
        it.  FIFO within a priority level (seq breaks ties)."""
        best = max(ready, key=self._unit_order_key)
        self._queue.remove(best)
        now = self._clock()
        if best.packable:
            sig = self._pack_sig(best.jobs[0])
            room = self.max_pack - best.jobs[0].spec.n_repeats
            for u in sorted([u for u in self._queue
                             if u.packable and u.not_before <= now],
                            key=lambda u: u.seq):
                if room <= 0:
                    break
                cand = u.jobs[0]
                if (self._pack_sig(cand) == sig
                        and cand.spec.n_repeats <= room):
                    self._queue.remove(u)
                    best.jobs.append(cand)
                    room -= cand.spec.n_repeats
        return best

    def _higher_priority_waiting(self, priority: int) -> bool:
        with self._cv:
            now = self._clock()
            return any(u.priority > priority and u.not_before <= now
                       for u in self._queue)

    def _run(self) -> None:
        try:
            self._run_loop()
        except BaseException as e:
            # the worker is the only dispatcher: its death strands every
            # stream()/SSE client — release them with a typed sentinel
            self.registry.abort_streams(f"scheduler worker died: {e!r}")
            raise

    def _run_loop(self) -> None:
        # with a TTL, wake periodically so finished jobs age out even while
        # the queue is idle; gc runs OUTSIDE _cv (it takes _cv itself plus
        # the registry lock)
        wait_s = None if self.job_ttl_s is None else min(1.0, self.job_ttl_s)
        while True:
            with self._cv:
                unit = None
                while not self._stop:
                    now = self._clock()
                    ready = ([] if self._paused else
                             [u for u in self._queue if u.not_before <= now])
                    if ready:
                        unit = self._take_unit(ready)
                        break
                    if self._queue or self._paused:
                        # backoff-delayed units (or a paused dispatcher):
                        # poll — an injected fake clock advances without a
                        # notify, so a real-time cap keeps the worker live
                        self._cv.wait(timeout=0.05)
                    else:
                        self._cv.wait(timeout=wait_s)
                        break   # idle wake: run the TTL sweep
                if self._stop:
                    return
                if unit is not None:
                    for j in unit.live_jobs():
                        j.state = RUNNING
                    self._running = unit.live_jobs()
            if unit is None:
                self.gc_now()
                continue
            try:
                self._run_unit(unit)
            except Exception as e:     # noqa: BLE001 — job-level failure wall
                self._handle_unit_failure(unit, e)
            finally:
                with self._cv:
                    self._running = []
                self.gc_now()

    # ---- failure handling ----------------------------------------------

    def _retry_budget(self, job: Job) -> int:
        return self.max_retries if job.max_retries is None \
            else max(0, int(job.max_retries))

    def _fail_job(self, job: Job, err: str, *, quarantined: bool = False,
                  state: str = FAILED) -> None:
        job.state = state
        job.error = err
        job.quarantined = quarantined
        job.finished_at = self._clock()
        if quarantined:
            self.quarantined_total += 1
        self.registry.finish_job(job.job_id, error=err, status=state,
                                 quarantined=quarantined)
        self._journal.append({"ev": "state", "job_id": job.job_id,
                              "state": state, "error": err})
        job.done.set()

    def _handle_unit_failure(self, unit: _Unit, exc: Exception) -> None:
        """Classify, then retry / split / quarantine.

        Transient + budget left: the whole unit requeues frozen with
        backoff, resuming from its last checkpoint.  Budget exhausted (or
        a permanent error) on a multi-job pack: split into solo frozen
        units, each resuming from a checkpoint sliced out of the pack's —
        the poison job re-fails alone and lands here again as a singleton,
        where it is quarantined; the survivors complete untouched."""
        unit.attempts += 1
        live = unit.live_jobs()
        err = repr(exc)
        kind = FLT.classify_error(exc)
        if not live:
            return
        if kind == "transient" and all(j.retries < self._retry_budget(j)
                                       for j in live):
            delay = retry_backoff(self.retry_backoff_s, unit.attempts,
                                  token=f"unit-{unit.seq}")
            for j in live:
                j.retries += 1
                j.state = QUEUED
                self.registry.note_retry(j.job_id)
                self.registry.set_status(j.job_id, QUEUED)
            self.retries_total += len(live)
            unit.packable = False      # membership freezes with its ckpt
            unit.not_before = self._clock() + delay
            self._journal.append({"ev": "requeue", "seq": unit.seq,
                                  "job_ids": [j.job_id for j in unit.jobs],
                                  "ckpt_dir": unit.ckpt_dir,
                                  "error": err, "backoff_s": delay})
            with self._cv:
                self._queue.append(unit)
                self._cv.notify_all()
            return
        if len(live) > 1:
            self._split_unit(unit, live, err)
            return
        self._fail_job(live[0], err, quarantined=unit.isolated
                       or live[0].retries >= self._retry_budget(live[0]))

    def _split_unit(self, unit: _Unit, live: List[Job], err: str) -> None:
        """Pack isolation: one solo frozen unit per live job, each resuming
        from a slice of the pack checkpoint (`ga.repack_checkpoint`)."""
        from repro import ga
        specs = [j.spec for j in unit.jobs]
        opts = dataclasses.replace(self.options, cost_table=self.cost_table,
                                   faults=False)   # recovery ≠ injection site
        new_units = []
        for j in live:
            idx = unit.jobs.index(j)
            seq = next(self._seq)
            solo_dir = os.path.join(self.ckpt_root, f"pack-{seq}")
            if unit.ckpt_dir is not None:
                try:
                    ga.repack_checkpoint(unit.ckpt_dir, specs, [idx],
                                         solo_dir, j.backend, options=opts)
                except Exception as slice_err:   # noqa: BLE001
                    # an unsliceable/corrupt pack ckpt costs progress, not
                    # correctness: the solo unit restarts from generation 0
                    warnings.warn(
                        f"could not slice pack checkpoint for {j.job_id} "
                        f"({slice_err!r}); its solo retry restarts fresh",
                        stacklevel=2)
            j.state = QUEUED
            self.registry.set_status(j.job_id, QUEUED)
            new_units.append(_Unit(seq=seq, jobs=[j], packable=False,
                                   ckpt_dir=solo_dir, isolated=True))
            self._journal.append({"ev": "requeue", "seq": seq,
                                  "job_ids": [j.job_id],
                                  "ckpt_dir": solo_dir, "error": err,
                                  "isolated": True})
        with self._cv:
            self._queue.extend(new_units)
            self._cv.notify_all()

    # ---- deadlines ------------------------------------------------------

    def _expire_deadlines(self, jobs: List[Job]) -> List[Job]:
        """Mark any over-deadline job terminal; returns the expired ones."""
        now = self._clock()
        expired = []
        for j in jobs:
            if j.state in TERMINAL_STATES or j.deadline_s is None:
                continue
            spent = now - j.submitted_at
            if spent >= j.deadline_s:
                self.deadline_exceeded_total += 1
                self._fail_job(
                    j, f"deadline {j.deadline_s}s exceeded after {spent:.3f}s "
                       f"({j.spec.generations} generations requested)",
                    state=DEADLINE_EXCEEDED)
                expired.append(j)
        return expired

    # ---- journal recovery ----------------------------------------------

    def _recover(self) -> None:
        """Replay `journal.jsonl`: restore terminal jobs (with their
        JSON-safe results), re-enqueue everything else.  Pending jobs whose
        last unit was dispatched/parked come back as frozen units pointing
        at that unit's checkpoint dir, so the pack resumes bit-identically
        from its last completed chunk.  Blackbox jobs (callable fitness —
        not journal-serializable) still pending are FAILED with a clear
        reason rather than silently dropped.  Deadlines restart from
        recovery time (the journal records the budget, not elapsed wall)."""
        events = JRN.read_journal(self._journal_path)
        if not events:
            return
        rec_jobs, rec_units, job_unit, max_seq = JRN.replay(events)
        self._seq = itertools.count(max_seq + 1)
        id_nums = []
        for jid in rec_jobs:
            try:
                id_nums.append(int(jid.split("-")[1]))
            except (IndexError, ValueError):
                pass
        if id_nums:
            self.registry.ensure_next_id(max(id_nums) + 1)
        now = self._clock()
        pending_by_unit: Dict[Optional[int], List[Job]] = {}
        for rj in rec_jobs.values():
            spec = None
            if rj.spec_json is not None:
                spec = JRN.spec_from_json(rj.spec_json)
            job = Job(job_id=rj.job_id, spec=spec, backend=rj.backend,
                      priority=rj.priority, deadline_s=rj.deadline_s,
                      max_retries=rj.max_retries, submitted_at=now,
                      recovered=True)
            self._jobs[rj.job_id] = job
            problem = (spec.problem or "blackbox") if spec is not None \
                else "blackbox"
            self.registry.queue_job(
                rj.job_id, problem=problem,
                gens_total=spec.generations if spec is not None else 0,
                n_vars=spec.v if spec is not None else 0,
                priority=rj.priority, deadline_s=rj.deadline_s)
            if rj.terminal:
                job.state = rj.state
                job.error = rj.error
                job.result = rj.result
                job.finished_at = now
                self.registry.finish_job(rj.job_id, error=rj.error,
                                         status=rj.state)
                job.done.set()
                continue
            if spec is None:
                self._fail_job(job, "not recoverable after restart: a "
                               "blackbox (callable) fitness cannot be "
                               "journal-serialized; resubmit the job")
                continue
            pending_by_unit.setdefault(job_unit.get(rj.job_id),
                                       []).append(job)
        for seq, jobs in pending_by_unit.items():
            unit_info = rec_units.get(seq) if seq is not None else None
            ids = unit_info["job_ids"] if unit_info else []
            if (unit_info is not None
                    and sorted(ids) == sorted(j.job_id for j in jobs)):
                # full membership survived: resume the frozen pack from its
                # checkpoint (journal order = slot order = seed identity)
                order = {jid: i for i, jid in enumerate(ids)}
                jobs = sorted(jobs, key=lambda j: order[j.job_id])
                self._queue.append(_Unit(seq=seq, jobs=jobs, packable=False,
                                         ckpt_dir=unit_info["ckpt_dir"]))
            else:
                # membership changed (some members finished) — the pack
                # checkpoint no longer matches; restart each job fresh
                for j in jobs:
                    self._queue.append(_Unit(seq=next(self._seq), jobs=[j]))
            self.recovered_total += len(jobs)
            for j in jobs:
                self.registry.set_status(j.job_id, QUEUED)

    # result keys that survive journaling (scalars + decoded params only —
    # numpy trajectories and RunTelemetry objects are not JSON)
    _RESULT_JSON_KEYS = ("chunk", "gens_done", "gens_total", "chunk_gens",
                         "chunk_best", "best_fitness", "wall_s", "gens_per_s",
                         "backend", "problem", "n_vars", "migrations",
                         "job_index", "pack_size")

    def _run_unit(self, unit: _Unit) -> None:
        from repro.ga.engine import PackedEngine   # lazy: jax import cost

        jobs = unit.jobs
        # a queued job can blow its deadline before ever dispatching
        self._expire_deadlines(jobs)
        live = unit.live_jobs()
        if not live:
            return
        if unit.packable:
            # fresh unit: expired members simply leave the pack
            unit.jobs = jobs = live
        if unit.ckpt_dir is None:
            unit.ckpt_dir = os.path.join(self.ckpt_root, f"pack-{unit.seq}")
        fault_tag = ",".join(j.job_id for j in jobs)
        if self.faults is not None:
            # the compile_fail site: a trace/build blow-up before any chunk
            self.faults.inject("compile_fail", fault_tag)
        pe = PackedEngine(
            [j.spec for j in jobs], jobs[0].backend,
            options=dataclasses.replace(
                self.options, cost_table=self.cost_table,
                # share THIS injector instance (counters and all); False
                # stops a disarmed engine re-resolving the ambient env
                faults=self.faults if self.faults is not None else False))
        self.packs_launched += 1
        if len(jobs) > 1:
            self.jobs_packed += len(jobs)
        for j in live:
            self.registry.start_job(j.job_id, backend=pe.backend_name,
                                    gens_total=j.spec.generations,
                                    problem=j.spec.problem or "blackbox",
                                    n_vars=j.spec.v)
        self._journal.append({"ev": "dispatch", "seq": unit.seq,
                              "job_ids": [j.job_id for j in jobs],
                              "ckpt_dir": unit.ckpt_dir,
                              "attempt": unit.attempts})
        priority = unit.priority
        last: Optional[Dict[str, Any]] = None
        for tele in pe.run_chunked(chunk_generations=self.chunk_generations,
                                   ckpt_dir=unit.ckpt_dir, resume=True,
                                   fault_tag=fault_tag):
            if last is None:   # count the plan once per dispatch
                tj = tele["jobs"][0].get("telemetry")
                ps = tj.plan.source if tj is not None else None
                if ps == "measured":
                    self.plans_measured += 1
                elif ps is not None and ps != "-":
                    self.plans_heuristic += 1
            last = tele
            for j, jt in zip(jobs, tele["jobs"]):
                if j.state not in TERMINAL_STATES:
                    self.registry.record_chunk(j.job_id, jt)
            # deadline enforcement at chunk granularity: expired members of
            # a frozen pack stay in the launch (the checkpoint's membership
            # identity) but stop receiving chunks/results; a pack with no
            # live member left stops computing entirely
            self._expire_deadlines(jobs)
            if not unit.live_jobs():
                return
            if (tele["gens_done"] < tele["gens_total"]
                    and self._higher_priority_waiting(priority)):
                # park the pack: state is already checkpointed; membership
                # freezes so the packed checkpoint resumes with these jobs
                for j in unit.live_jobs():
                    j.state = PREEMPTED
                    self.registry.set_status(j.job_id, PREEMPTED)
                self.preemptions += 1
                self._journal.append({"ev": "park", "seq": unit.seq,
                                      "job_ids": [j.job_id for j in jobs],
                                      "ckpt_dir": unit.ckpt_dir})
                with self._cv:
                    # jobs stay PREEMPTED while waiting (the informative
                    # state); the unit re-enters the queue and flips them
                    # back to RUNNING when re-dispatched
                    self._queue.append(_Unit(seq=unit.seq, jobs=jobs,
                                             packable=False,
                                             ckpt_dir=unit.ckpt_dir,
                                             attempts=unit.attempts,
                                             isolated=unit.isolated))
                    self._cv.notify_all()
                return
        now = self._clock()
        for j, jt in zip(jobs, last["jobs"]):
            if j.state in TERMINAL_STATES:
                continue
            j.result = dict(jt)
            j.result["best_params"] = [float(v) for v in jt["best_params"]]
            j.state = DONE
            j.finished_at = now
            self.registry.finish_job(j.job_id)
            safe = {k: j.result[k] for k in self._RESULT_JSON_KEYS
                    if k in j.result}
            safe["best_params"] = j.result["best_params"]
            self._journal.append({"ev": "done", "job_id": j.job_id,
                                  "result": safe})
            j.done.set()
