"""Checkpoint manifest/restore semantics + gradient-compression correctness."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as CKPT
from repro.optim import compress as GC


def test_save_restore_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16)}}
    CKPT.save(str(tmp_path), 7, tree, extra={"data_step": 7})
    assert CKPT.latest_step(str(tmp_path)) == 7
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    restored, extra = CKPT.restore(str(tmp_path), 7, like)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16
    assert extra["data_step"] == 7


def test_partial_write_is_invisible(tmp_path):
    """Crash mid-save must not leave a checkpoint latest_step would trust."""
    d = tmp_path / "step_00000009.tmp"
    d.mkdir(parents=True)
    (d / "shard_0.npz").write_bytes(b"garbage")
    assert CKPT.latest_step(str(tmp_path)) is None


def test_async_checkpointer_overlap(tmp_path):
    tree = {"w": jnp.ones((256, 256))}
    ck = CKPT.AsyncCheckpointer()
    ck.save(str(tmp_path), 1, tree)
    ck.wait()
    assert CKPT.latest_step(str(tmp_path)) == 1


def test_elastic_restore_to_other_sharding(tmp_path):
    """A checkpoint written on one topology restores onto another (here:
    unsharded -> explicit single-device sharding) — the elastic path."""
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    CKPT.save(str(tmp_path), 3, tree)
    like = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
    sh = {"w": jax.sharding.SingleDeviceSharding(jax.devices()[0])}
    restored, _ = CKPT.restore(str(tmp_path), 3, like, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


# ---------------------------------------------------------------------------


def test_int8_quantization_bounded_error():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
    q, s = GC.quantize_int8(g)
    deq = GC.dequantize_int8(q, s)
    assert float(jnp.max(jnp.abs(deq - g))) <= float(s) * 0.5 + 1e-7


def test_error_feedback_unbiased_over_time():
    """EF accumulates what quantization drops: summed compressed updates
    converge to summed true gradients."""
    rng = np.random.default_rng(1)
    true_sum = np.zeros(64, np.float32)
    sent_sum = np.zeros(64, np.float32)
    r = jnp.zeros(64, jnp.float32)
    for i in range(200):
        g = jnp.asarray(rng.normal(size=64).astype(np.float32))
        true_sum += np.asarray(g)
        gq = g + r
        q, s = GC.quantize_int8(gq)
        deq = GC.dequantize_int8(q, s)
        r = gq - deq
        sent_sum += np.asarray(deq)
    resid = np.abs(true_sum - sent_sum)
    assert resid.max() <= float(jnp.max(jnp.abs(r))) + 1e-5
