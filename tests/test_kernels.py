"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracle."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fitness as F
from repro.core import ga as G
from repro.core import islands as ISL
from repro.core import lfsr
from repro.kernels import ops, ref


def _states(cfg, n_islands=2):
    icfg = ISL.IslandConfig(ga=cfg, n_islands=n_islands)
    return ISL.init_islands_fast(icfg)


@pytest.mark.parametrize("n", [16, 64, 256, 1024])
@pytest.mark.parametrize("problem", ["F1", "F2", "F3"])
def test_ga_step_matches_ref_population_sweep(n, problem):
    cfg = G.GAConfig(n=n, c=10, v=2, mutation_rate=0.03, seed=n, mode="arith")
    spec = F.ArithSpec.for_problem(F.PROBLEMS[problem])
    st = _states(cfg)
    k = ops.ga_generation(st.x, st.sel_lfsr, st.cross_lfsr, st.mut_lfsr,
                          cfg=cfg, spec=spec)
    r = ref.ga_generation_ref(st.x, st.sel_lfsr, st.cross_lfsr, st.mut_lfsr,
                              cfg=cfg, spec=spec)
    for a, b in zip(k[:4], r[:4]):       # uint32 state: bit-exact
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_allclose(np.asarray(k[4]), np.asarray(r[4]), rtol=2e-5)


@pytest.mark.parametrize("c", [6, 10, 14, 15])
@pytest.mark.parametrize("mr", [0.01, 0.1])
def test_ga_step_matches_ref_width_sweep(c, mr):
    cfg = G.GAConfig(n=64, c=c, v=2, mutation_rate=mr, seed=c, mode="arith")
    spec = F.ArithSpec.for_problem(F.F3)
    st = _states(cfg, n_islands=3)
    k = ops.ga_generation(st.x, st.sel_lfsr, st.cross_lfsr, st.mut_lfsr,
                          cfg=cfg, spec=spec)
    r = ref.ga_generation_ref(st.x, st.sel_lfsr, st.cross_lfsr, st.mut_lfsr,
                              cfg=cfg, spec=spec)
    for a, b in zip(k[:4], r[:4]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("minimize", [True, False])
def test_ga_step_minimize_maximize(minimize):
    cfg = G.GAConfig(n=128, c=10, v=2, mutation_rate=0.02, seed=5,
                     minimize=minimize, mode="arith")
    spec = F.ArithSpec.for_problem(F.F2)
    st = _states(cfg)
    k = ops.ga_generation(st.x, st.sel_lfsr, st.cross_lfsr, st.mut_lfsr,
                          cfg=cfg, spec=spec)
    r = ref.ga_generation_ref(st.x, st.sel_lfsr, st.cross_lfsr, st.mut_lfsr,
                              cfg=cfg, spec=spec)
    np.testing.assert_array_equal(np.asarray(k[0]), np.asarray(r[0]))


def test_ga_kernel_multi_generation_converges():
    cfg = G.GAConfig(n=64, c=10, v=2, mutation_rate=0.05, seed=11, mode="arith")
    spec = F.ArithSpec.for_problem(F.F3)
    st = _states(cfg, n_islands=4)
    # ga_run_kernel is a deprecated entry-point shim (the engine's
    # fused executor replaced it) but must keep working until removed
    with pytest.warns(DeprecationWarning, match="deprecated entry point"):
        st2, best = ops.ga_run_kernel(st, 100, cfg=cfg, spec=spec)
    assert float(jnp.min(best)) < 1.0  # near the F3 optimum


@pytest.mark.parametrize("shape", [(7,), (128,), (3, 5), (2, 130)])
@pytest.mark.parametrize("steps", [1, 3, 13, 40])
def test_lfsr_kernel_matches_ref(shape, steps):
    s = lfsr.seeds(99, int(np.prod(shape))).reshape(shape)
    got = ops.lfsr_advance(s, steps)
    want = ref.lfsr_advance_ref(s, steps)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kernel_rejects_oversize_population():
    cfg = G.GAConfig(n=2048, c=10, v=2, seed=1, mode="arith")
    spec = F.ArithSpec.for_problem(F.F3)
    st = _states(cfg, 1)
    with pytest.raises(AssertionError):
        ops.ga_generation(st.x, st.sel_lfsr, st.cross_lfsr, st.mut_lfsr,
                          cfg=cfg, spec=spec)
