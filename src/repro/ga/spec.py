"""`GASpec` — one frozen description of a GA run.

A spec bundles everything the old divergent drivers used to take through
ad-hoc plumbing: the problem (a registered benchmark or a blackbox fitness
over a box), the chromosome encoding, the operator pipeline, the run policy
(generations, repeats, islands) and the population topology.  Every
(topology × executor) backend consumes the same spec, so swapping
`"reference"` ↔ `"fused"` ↔ `"islands"` ↔ `"fused-islands"` ↔ `"eager"`
is a string, not a rewrite.

The fitness side of a spec compiles to a `repro.core.fitness.FitnessProgram`
(`spec.program()`): one object lowering the problem to the LUT ROMs, the
XLA arith path AND the Pallas in-kernel FFM stage — which is why any
registered n-variable problem (``problem="rastrigin:8"``) or traceable
blackbox runs on every executor.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import numpy as np

from repro.core import fitness as F
from repro.core import ga as G
from repro.ga import operators as OPS


@dataclasses.dataclass(frozen=True)
class GASpec:
    """Problem + encoding + operator choices + run policy (all frozen).

    Exactly one of ``problem`` (a registered benchmark name — ``"F1"``..,
    ``"sphere"``, ``"rastrigin"``, .. — optionally with a ``:V`` suffix,
    e.g. ``"rastrigin:8"``) or ``fitness`` (a batch blackbox
    ``(N, V) float32 -> (N,)`` with ``bounds``) must be set.
    """

    # ---- problem --------------------------------------------------------
    problem: Optional[str] = None
    fitness: Optional[Callable] = None
    bounds: Optional[Tuple[Tuple[float, float], ...]] = None

    # ---- encoding -------------------------------------------------------
    n: int = 32                    # population size N (even)
    bits_per_var: int = 10         # c (paper: m/2)
    n_vars: Optional[int] = None   # V; default from the problem registry
    mode: str = "arith"            # FFM mode: "lut" (ROMs) | "arith" (VPU)
    # fused-kernel tournament gather lane: "onehot" ((N, N) MXU matmul
    # gathers, N <= 1024), "gather" (jnp.take dynamic indexing, O(N·V),
    # no cap) or "auto" (onehot while legal, gather past the cap; with a
    # cost table the planner argmaxes MEASURED gens/s across both lanes).
    # Both lanes are bit-identical; this knob trades VMEM for MXU work.
    sel_lane: str = "auto"

    # ---- operators ------------------------------------------------------
    selection: str = "tournament"
    crossover: str = "single_point"
    mutation: str = "xor"
    mutation_rate: float = 0.02
    minimize: bool = True
    steps_per_draw: int = 3

    # ---- run policy -----------------------------------------------------
    generations: int = 100
    seed: int = 1
    n_repeats: int = 1             # independent vmapped replicas (Table 3)
    n_islands: int = 1             # >1 -> island model with migration
    migrate_every: int = 16
    jit_fitness: bool = True       # False -> fitness not traceable (eager)
    # generations folded INSIDE one Pallas launch (fused executors): >1
    # amortizes launch overhead at small migrate_every.  Population/LFSR
    # state and the running best individual stay bit-identical to
    # gens_per_epoch=1; only the best/mean trajectory coarsens to one
    # sample per launch.  Ignored by the reference/eager executors.
    # On an island_ring topology with migration="ring" and a fused
    # executor, >= migrate_every engages the RESIDENT epoch kernel: the
    # whole island shard stays in VMEM and the ring migration runs inside
    # the launch, folding gens_per_epoch//migrate_every migration intervals
    # per launch — so values beyond migrate_every must be a whole multiple
    # of it (validated here; migration="none" has no interval boundary and
    # is exempt — for it the planner offers the RESIDENT-FREE mode, which
    # folds the full gens_per_epoch in one VMEM-resident launch with no
    # migration pauses and no whole-multiple rule).  When the stack does
    # NOT fit the VMEM budget, the STREAMED mode tiles the island axis
    # through VMEM with a double-buffered HBM pipeline instead of giving
    # up kernel residency.  Which feasible mode actually runs is the
    # two-tier epoch-plan decision (kernels/ga_step module docstring): the
    # VMEM byte estimator gates feasibility, and an autotune cost table —
    # when one covers the spec — picks the best MEASURED gens/s among the
    # survivors (result.telemetry.plan — mode / source / fallback — reports
    # the outcome; with no table the choice is the original static
    # heuristic, bit-identically).
    gens_per_epoch: int = 1

    # ---- topology (how populations are arranged + exchanged) ------------
    # None/"auto" derives from n_islands; "single" pins one population
    # (n_repeats replicas at most), "island_ring" pins the ring-migrating
    # island layout.  `migration` picks the exchange between epochs:
    # "ring" (the [19] elite ring) or "none" (isolated islands ablation).
    topology: Optional[str] = None
    migration: str = "ring"
    # mesh policy: which mesh axes the island axis shards over when a mesh
    # is passed to the Engine.  None -> all axes of the given mesh.
    mesh_axes: Optional[Tuple[str, ...]] = None

    def __post_init__(self):
        if (self.problem is None) == (self.fitness is None):
            raise ValueError("set exactly one of problem= or fitness=")
        if self.mode not in ("lut", "arith"):
            raise ValueError(f"mode must be 'lut' or 'arith', got {self.mode!r}")
        if self.sel_lane not in ("auto", "onehot", "gather"):
            raise ValueError(f"sel_lane must be 'onehot', 'gather' or "
                             f"'auto', got {self.sel_lane!r}")
        if self.sel_lane == "onehot" and self.n > G.ONEHOT_MAX_N:
            # the lane-aware kernel gate, surfaced at spec build: an
            # explicit onehot pin past the one-hot VMEM cap can never run
            # on the fused kernel path
            raise ValueError(
                f"sel_lane='onehot' pinned with N={self.n} > "
                f"{G.ONEHOT_MAX_N}: the (N, N) one-hot tournament matrices "
                "would exceed VMEM in every fused kernel.  Fix: split the "
                "population across more islands (smaller per-island N), or "
                "switch to the O(N*V) dynamic-indexing lane with "
                "sel_lane='gather'")
        if self.problem is not None:
            # resolve "name:V" shorthand into (problem, n_vars) and validate
            # through the SAME rule set compile_program enforces
            pdef, v_suffix = F.resolve_problem(self.problem)
            if v_suffix is not None:
                if self.n_vars is not None and self.n_vars != v_suffix:
                    raise ValueError(
                        f"problem {self.problem!r} pins V={v_suffix} but "
                        f"n_vars={self.n_vars} was also given")
                object.__setattr__(self, "problem", pdef.name)
                object.__setattr__(self, "n_vars", v_suffix)
            F.resolve_vars(pdef, self.n_vars)
            F.check_mode(pdef, self.mode)
        if self.fitness is not None and self.bounds is None:
            raise ValueError("blackbox fitness requires bounds=")
        if self.fitness is not None and self.mode == "lut":
            raise ValueError("blackbox fitness has no LUT lowering; "
                             "run mode='arith'")
        if self.bounds is not None:
            object.__setattr__(self, "bounds",
                               tuple((float(lo), float(hi))
                                     for lo, hi in self.bounds))
        # operator names must exist — fail at spec build, not mid-run
        OPS.resolve(self.selection, self.crossover, self.mutation)
        for field, lo in (("n", 2), ("bits_per_var", 1), ("generations", 1),
                          ("n_repeats", 1), ("n_islands", 1),
                          ("migrate_every", 1), ("gens_per_epoch", 1)):
            if getattr(self, field) < lo:
                raise ValueError(f"{field} must be >= {lo}")
        if self.topology == "auto":
            object.__setattr__(self, "topology", None)
        if self.topology not in (None, "single", "island_ring"):
            raise ValueError(
                f"topology must be 'single', 'island_ring' or None/'auto', "
                f"got {self.topology!r}")
        if self.topology == "single" and self.n_islands > 1:
            raise ValueError("topology='single' is inconsistent with "
                             f"n_islands={self.n_islands}; drop one of them")
        if self.topology == "island_ring" and self.n_islands == 1:
            raise ValueError("topology='island_ring' needs n_islands > 1")
        if self.migration not in ("ring", "none"):
            raise ValueError(f"migration must be 'ring' or 'none', "
                             f"got {self.migration!r}")
        # the whole-interval rule only binds when a ring actually runs —
        # the migration='none' ablation has no interval boundary to respect
        if (self.effective_topology == "island_ring"
                and self.migration == "ring"
                and self.gens_per_epoch > self.migrate_every
                and self.gens_per_epoch % self.migrate_every):
            raise ValueError(
                f"gens_per_epoch={self.gens_per_epoch} is not a multiple of "
                f"migrate_every={self.migrate_every}: on an island_ring "
                "topology a resident launch folds WHOLE migration intervals "
                "(the ring migration runs in VMEM between them), so "
                "gens_per_epoch beyond migrate_every must be a multiple of "
                "it — round to a multiple or lower it to migrate_every")
        if self.mesh_axes is not None:
            if (not self.mesh_axes
                    or not all(isinstance(a, str) and a
                               for a in self.mesh_axes)):
                raise ValueError("mesh_axes must be a non-empty tuple of "
                                 f"axis names, got {self.mesh_axes!r}")
            object.__setattr__(self, "mesh_axes", tuple(self.mesh_axes))

    # ---- derived --------------------------------------------------------

    @property
    def v(self) -> int:
        if self.bounds is not None:
            return len(self.bounds)
        return F.resolve_vars(self.problem_def(), self.n_vars)

    @property
    def resolved_sel_lane(self) -> str:
        """The concrete kernel selection lane this spec defaults to: an
        explicit pin wins; "auto" keeps the MXU one-hot lane while it is
        legal (N <= ONEHOT_MAX_N) and switches to the dynamic-indexing
        gather lane past the cap.  With an autotune cost table the planner
        may still move an "auto" spec to the measured-faster lane at plan
        time (see IslandRingTopology._epoch_plan); this value is the
        heuristic starting point."""
        if self.sel_lane != "auto":
            return self.sel_lane
        return "onehot" if self.n <= G.ONEHOT_MAX_N else "gather"

    @property
    def effective_topology(self) -> str:
        """The topology this spec runs on: the explicit `topology` field, or
        derived from `n_islands` when left as None/'auto'."""
        if self.topology is not None:
            return self.topology
        return "island_ring" if self.n_islands > 1 else "single"

    @property
    def uses_paper_pipeline(self) -> bool:
        return (self.selection, self.crossover,
                self.mutation) == OPS.PAPER_PIPELINE

    def ga_config(self) -> G.GAConfig:
        return G.GAConfig(n=self.n, c=self.bits_per_var, v=self.v,
                          mutation_rate=self.mutation_rate,
                          minimize=self.minimize,
                          steps_per_draw=self.steps_per_draw,
                          seed=self.seed, mode=self.mode,
                          sel_lane=self.resolved_sel_lane)

    def problem_def(self) -> Optional[F.ProblemDef]:
        return F.PROBLEMS[self.problem] if self.problem is not None else None

    def program(self) -> F.FitnessProgram:
        """The spec's fitness compiled for every executor (LUT ROMs when
        mode='lut', the shared XLA/in-kernel arith stage always).

        Cached per spec instance: every caller (capability checks, epoch
        planning, executor construction) sees the SAME FitnessProgram, so
        its bound `.stage` method hashes/compares equal across calls and
        downstream trace caches (kernels.ga_step) key on one object instead
        of re-tracing a fresh program each time."""
        cached = self.__dict__.get("_program")
        if cached is None:
            cached = F.compile_program(problem=self.problem,
                                       fitness=self.fitness,
                                       bounds=self.bounds, n_vars=self.v,
                                       bits_per_var=self.bits_per_var,
                                       mode=self.mode, minimize=self.minimize)
            object.__setattr__(self, "_program", cached)
        return cached

    def compile_key(self) -> tuple:
        """Hashable trace-shape identity: two specs with equal keys lower to
        identical traced computations — only `seed` (consumed exclusively by
        `init_state`), `generations` and `n_repeats` (loop/stack extents the
        runners re-trace by shape anyway) may differ.  This is the key the
        compiled-runner cache (repro.ga.compile_cache) and the serving
        scheduler's job packing both use.

        Blackbox fitnesses are keyed by callable identity — safe because a
        cache entry's runner closes over the fitness (keeping it alive), so
        an id can never be recycled while its entry exists."""
        fit_id = (self.problem if self.problem is not None
                  else ("blackbox", id(self.fitness), self.bounds))
        return (fit_id, self.v, self.n, self.bits_per_var, self.mode,
                self.resolved_sel_lane,
                self.selection, self.crossover, self.mutation,
                self.mutation_rate, self.minimize, self.steps_per_draw,
                self.n_islands, self.migrate_every, self.gens_per_epoch,
                self.effective_topology, self.migration, self.mesh_axes,
                self.jit_fitness)

    def fitness_fn(self) -> G.FitnessFn:
        return self.program().fitness(self.mode)

    def fitness_scale(self) -> float:
        """Raw-fitness units per real unit (lut mode is fixed-point)."""
        return self.program().scale(self.mode)

    def var_domains(self) -> Tuple[Tuple[float, float], ...]:
        """Per-variable decode range."""
        if self.bounds is not None:
            return self.bounds
        return (self.problem_def().domain,) * self.v

    def decode(self, x: np.ndarray) -> np.ndarray:
        """Decode a uint32[V] chromosome to real variable values."""
        u = np.asarray(x, np.uint64) & np.uint64((1 << self.bits_per_var) - 1)
        doms = self.var_domains()
        lo = np.array([d[0] for d in doms])
        hi = np.array([d[1] for d in doms])
        return lo + u.astype(np.float64) * (hi - lo) / \
            ((1 << self.bits_per_var) - 1)


def paper_spec(problem: str = "F3", n: int = 32, m: int = 20,
               mode: str = "lut", **kw) -> GASpec:
    """The paper's experiment grid as a spec: chromosome m = 2c bits."""
    return GASpec(problem=problem, n=n, bits_per_var=m // 2, n_vars=2,
                  mode=mode, **kw)
