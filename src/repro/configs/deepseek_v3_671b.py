"""deepseek-v3-671b — MLA + 256-expert MoE [arXiv:2412.19437; hf].

Faithful: MLA latent attention (q_lora 1536 / kv_lora 512 / rope 64, the
compressed-latent KV cache), 1 shared + 256 routed experts top-8, first 3
layers dense (d_ff 18432).  Deviations (DESIGN.md): softmax top-k routing in
place of sigmoid+group-bias; the MTP head is not implemented.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    vocab=129280, rope_theta=10_000.0,
    n_experts=256, top_k=8, expert_ff=2048, n_shared_experts=1,
    n_dense_layers=3, moe_ff_dense=18432,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
)
