"""GA-as-a-service: replica-axis packing (PackedEngine), the spec-keyed
compile cache, FFM single-trace sharing, preemption via run_chunked
checkpoint/resume, the GAScheduler end-to-end, registry thread safety and
the streaming HTTP endpoints."""

import dataclasses
import json
import os
import threading
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro import ga
from repro.ga.compile_cache import RUNNER_CACHE
from repro.serve.engine import GAMetricsRegistry
from repro.serve.scheduler import (DONE, PREEMPTED, GAScheduler)


def _spec(**kw):
    base = dict(problem="F3", n=32, bits_per_var=10, mode="arith",
                mutation_rate=0.05, seed=11, generations=20)
    base.update(kw)
    return ga.GASpec(**base)


# ---------------------------------------------------------------------------
# Replica-axis packing: PackedEngine results are bit-identical to solo runs
# ---------------------------------------------------------------------------


def test_packed_engine_bit_identical_to_solo_reference():
    """Acceptance: K shape-compatible jobs packed down the n_repeats axis
    produce per-job results bit-identical to running each job alone —
    slot seeding follows the solo convention exactly."""
    specs = [_spec(seed=11), _spec(seed=40), _spec(seed=7, n_repeats=2)]
    packed = ga.PackedEngine(specs, "reference").run()
    assert len(packed) == 3
    for spec, jt in zip(specs, packed):
        solo = ga.solve(spec, backend="reference")
        assert jt["best_fitness"] == solo.best_fitness
        np.testing.assert_array_equal(np.asarray(jt["best_params"]),
                                      np.asarray(solo.best_params))
        assert jt["pack_size"] == 3


def test_packed_engine_bit_identical_to_solo_islands():
    specs = [_spec(seed=11, n_islands=4, migrate_every=5, generations=15),
             _spec(seed=23, n_islands=4, migrate_every=5, generations=15)]
    packed = ga.PackedEngine(specs, "islands").run()
    for spec, jt in zip(specs, packed):
        solo = ga.solve(spec, backend="islands")
        assert jt["best_fitness"] == solo.best_fitness
        np.testing.assert_array_equal(np.asarray(jt["best_params"]),
                                      np.asarray(solo.best_params))
        assert jt["migrations"] == solo.telemetry.topology.migrations


def test_packed_engine_single_job_delegates():
    spec = _spec(seed=3)
    packed = ga.PackedEngine([spec], "reference").run()
    solo = ga.solve(spec, backend="reference")
    assert packed[0]["best_fitness"] == solo.best_fitness


def test_packed_engine_rejects_incompatible():
    with pytest.raises(ga.BackendUnsupported):
        ga.PackedEngine([_spec(), _spec(n=64)], "reference")
    with pytest.raises(ga.BackendUnsupported):
        ga.PackedEngine([_spec(), _spec(generations=40)], "reference")
    with pytest.raises(ga.BackendUnsupported):
        ga.PackedEngine([_spec(), _spec()], "eager")


# ---------------------------------------------------------------------------
# Compile cache: identical spec shapes share one jitted runner
# ---------------------------------------------------------------------------


def test_compile_cache_hit_on_identical_shape():
    """Acceptance: the second engine with the same spec shape (differing
    only in seed — a trace-invariant field) is a cache hit, not a retrace."""
    RUNNER_CACHE.reset()
    a = ga.Engine(_spec(seed=1), "reference")
    a.backend.segment(a.init_state(), 20)
    after_first = RUNNER_CACHE.stats()
    b = ga.Engine(_spec(seed=999), "reference")
    b.backend.segment(b.init_state(), 20)
    after_second = RUNNER_CACHE.stats()
    assert after_second["misses"] == after_first["misses"]
    assert after_second["hits"] == after_first["hits"] + 1


def test_compile_cache_miss_on_different_shape():
    RUNNER_CACHE.reset()
    a = ga.Engine(_spec(), "reference")
    a.backend.segment(a.init_state(), 20)
    b = ga.Engine(_spec(n=64), "reference")
    b.backend.segment(b.init_state(), 20)
    stats = RUNNER_CACHE.stats()
    assert stats["misses"] == 2 and stats["hits"] == 0


def test_spec_compile_key_excludes_run_policy():
    assert _spec(seed=1).compile_key() == _spec(seed=2).compile_key()
    assert (_spec(generations=20).compile_key()
            == _spec(generations=99).compile_key())
    assert _spec().compile_key() != _spec(n=64).compile_key()
    assert _spec().compile_key() != _spec(mutation_rate=0.2).compile_key()


# ---------------------------------------------------------------------------
# FFM trace sharing: one fused build traces the fitness stage exactly once
# ---------------------------------------------------------------------------


def test_ffm_stage_traced_once_per_fused_build():
    """The const-gate, the epoch-plan budget check and the kernel hoist all
    consume one shared jaxpr (kernels.ga_step._ffm_jaxpr) — a blackbox
    fitness's FFM stage is traced exactly once per fused-islands engine
    build + run (was up to 3x before the shared trace cache)."""
    from repro.kernels.ga_step import _ffm_jaxpr, ffm_trace_cache_info

    calls = []

    def fit(x):
        calls.append(1)
        return -((x[:, 0] - 0.5) ** 2 + (x[:, 1] + 0.25) ** 2)

    # migration="none" isolates the FFM stage: ring migration additionally
    # evaluates fitness on the stacked state inside the epoch jit, which is
    # a different computation, not a redundant FFM-stage trace
    spec = _spec(fitness=fit, problem=None,
                 bounds=((-1.0, 1.0), (-1.0, 1.0)),
                 n_islands=2, migrate_every=4, migration="none",
                 generations=8)
    _ffm_jaxpr.cache_clear()
    eng = ga.Engine(spec, "fused-islands")
    eng.backend.segment(eng.init_state(), 8)
    assert sum(calls) == 1, f"fitness traced {sum(calls)}x, expected 1"
    info = ffm_trace_cache_info()
    assert info.misses == 1        # one real trace ...
    assert info.hits >= 1          # ... shared by every other consumer


# ---------------------------------------------------------------------------
# Preemption primitive: run_chunked checkpoint/resume is bit-identical
# ---------------------------------------------------------------------------


def _final_ckpt_arrays(ckpt_dir):
    from repro.ckpt import checkpoint as CKPT
    step = CKPT.latest_step(ckpt_dir)
    assert step is not None
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "shard_0.npz")
    return step, dict(np.load(path))


@pytest.mark.parametrize("backend,kw", [
    ("reference", {}),
    ("fused-islands", dict(n_islands=2, migrate_every=4)),
])
def test_preempt_resume_bit_identical(tmp_path, backend, kw):
    """Interrupt after the first chunk, resume in a fresh engine: the final
    checkpointed state (every population + LFSR bank) and the final best are
    bit-identical to the uninterrupted run."""
    spec = _spec(generations=16, **kw)
    ck_full = str(tmp_path / "full")
    ck_cut = str(tmp_path / "cut")
    full = list(ga.Engine(spec, backend).run_chunked(
        chunk_generations=8, ckpt_dir=ck_full))

    it = ga.Engine(spec, backend).run_chunked(chunk_generations=8,
                                              ckpt_dir=ck_cut)
    next(it)            # 8 generations, then "preempt"
    del it
    resumed = list(ga.Engine(spec, backend).run_chunked(
        chunk_generations=8, ckpt_dir=ck_cut))
    assert [t["gens_done"] for t in resumed] == [16]
    assert resumed[-1]["best_fitness"] == full[-1]["best_fitness"]
    np.testing.assert_array_equal(np.asarray(resumed[-1]["best_params"]),
                                  np.asarray(full[-1]["best_params"]))
    step_f, arr_f = _final_ckpt_arrays(ck_full)
    step_c, arr_c = _final_ckpt_arrays(ck_cut)
    assert step_f == step_c
    assert set(arr_f) == set(arr_c)
    for key in arr_f:
        np.testing.assert_array_equal(arr_f[key], arr_c[key], err_msg=key)


def test_packed_preempt_resume_bit_identical(tmp_path):
    """The scheduler's actual primitive: a PackedEngine pack interrupted
    mid-run resumes bit-identically from its checkpoint."""
    specs = [_spec(seed=11, generations=16), _spec(seed=40, generations=16)]
    ck = str(tmp_path / "pack")
    full = ga.PackedEngine(specs, "reference").run(chunk_generations=8)

    it = ga.PackedEngine(specs, "reference").run_chunked(
        chunk_generations=8, ckpt_dir=ck)
    next(it)
    del it
    resumed = list(ga.PackedEngine(specs, "reference").run_chunked(
        chunk_generations=8, ckpt_dir=ck))
    for jt_full, jt_res in zip(full, resumed[-1]["jobs"]):
        assert jt_res["best_fitness"] == jt_full["best_fitness"]
        np.testing.assert_array_equal(np.asarray(jt_res["best_params"]),
                                      np.asarray(jt_full["best_params"]))


def test_packed_ckpt_rejects_mismatched_pack(tmp_path):
    ck = str(tmp_path / "pack")
    it = ga.PackedEngine([_spec(seed=11, generations=16),
                          _spec(seed=40, generations=16)],
                         "reference").run_chunked(chunk_generations=8,
                                                  ckpt_dir=ck)
    next(it)
    del it
    other = ga.PackedEngine([_spec(seed=40, generations=16),
                             _spec(seed=11, generations=16)], "reference")
    with pytest.raises(ValueError, match="same jobs in the same order"):
        next(other.run_chunked(chunk_generations=8, ckpt_dir=ck))


# ---------------------------------------------------------------------------
# GAScheduler end-to-end
# ---------------------------------------------------------------------------


def test_scheduler_packs_and_matches_solo(tmp_path):
    """Acceptance: >= 2 shape-compatible jobs get packed onto one launch
    and every per-job result is bit-identical to its solo run."""
    reg = GAMetricsRegistry()
    sched = GAScheduler(registry=reg, ckpt_root=str(tmp_path),
                        chunk_generations=10)
    try:
        sa, sb = _spec(seed=11, generations=40), _spec(seed=40,
                                                       generations=40)
        sc = _spec(problem="rastrigin:4", seed=5, generations=40)
        with sched._cv:     # hold dispatch so a and b are packable together
            a = sched.submit(sa)
            b = sched.submit(sb)
            c = sched.submit(sc)
        ra, rb, rc = (sched.result(i, timeout=120) for i in (a, b, c))
        assert ra["pack_size"] == 2 and rb["pack_size"] == 2
        assert rc["pack_size"] == 1
        for spec, res in ((sa, ra), (sb, rb), (sc, rc)):
            solo = ga.solve(spec, backend="reference")
            assert res["best_fitness"] == solo.best_fitness
        stats = sched.stats()
        assert stats["jobs_packed"] == 2
        assert stats["cache_misses"] >= 1
        assert sched.job(a).state == DONE
        snap = reg.metrics()
        assert snap["jobs_done"] == 3
        assert snap["scheduler"]["packs_launched"] == stats["packs_launched"]
    finally:
        sched.shutdown()


def test_scheduler_compile_cache_hit_on_resubmit(tmp_path):
    RUNNER_CACHE.reset()
    reg = GAMetricsRegistry()
    sched = GAScheduler(registry=reg, ckpt_root=str(tmp_path))
    try:
        sched.result(sched.submit(_spec(seed=1)), timeout=120)
        h0 = sched.stats()["cache_hits"]
        sched.result(sched.submit(_spec(seed=2)), timeout=120)
        assert sched.stats()["cache_hits"] == h0 + 1
    finally:
        sched.shutdown()


def test_scheduler_preempts_and_resumes_bit_identically(tmp_path):
    """A higher-priority arrival parks the running pack between chunks; the
    parked job reports PREEMPTED, resumes from its checkpoint, and finishes
    with the same result as an undisturbed run."""
    reg = GAMetricsRegistry()
    sched = GAScheduler(registry=reg, ckpt_root=str(tmp_path),
                        chunk_generations=5)
    try:
        lo_spec = _spec(seed=11, generations=80)
        lo = sched.submit(lo_spec, priority=0)
        saw_preempted = False
        hot = None
        for event in sched.stream(lo, timeout=120):
            if event.get("event") == "chunk" and hot is None:
                hot = sched.submit(_spec(problem="rastrigin:4", seed=5,
                                         generations=10), priority=10)
            if sched.job(lo).state == PREEMPTED:
                saw_preempted = True
            if event.get("event") == "end":
                break
        rlo = sched.result(lo, timeout=120)
        sched.result(hot, timeout=120)
        assert saw_preempted or sched.stats()["preemptions"] >= 1
        assert reg.metrics()["jobs"][lo]["preemptions"] >= 1
        solo = ga.solve(lo_spec, backend="reference")
        assert rlo["best_fitness"] == solo.best_fitness
    finally:
        sched.shutdown()


def test_scheduler_failed_job_raises(tmp_path):
    reg = GAMetricsRegistry()
    sched = GAScheduler(registry=reg, ckpt_root=str(tmp_path))
    try:
        def boom(x):
            raise ValueError("bad fitness")

        bad = sched.submit(_spec(fitness=boom, problem=None,
                                 bounds=((-1.0, 1.0),)))
        with pytest.raises(RuntimeError, match="failed"):
            sched.result(bad, timeout=120)
        assert reg.metrics()["jobs"][bad]["status"] == "failed"
    finally:
        sched.shutdown()


# ---------------------------------------------------------------------------
# Registry thread safety
# ---------------------------------------------------------------------------


def test_registry_thread_safe_under_concurrent_writers():
    """N writer threads hammering start/record/finish against concurrent
    metrics() readers: no exceptions, no lost chunks."""
    reg = GAMetricsRegistry()
    n_threads, n_chunks = 8, 50
    errors = []

    def writer(i):
        try:
            job_id = reg.allocate_job_id(f"w{i}")
            reg.start_job(job_id, backend="reference",
                          gens_total=n_chunks, problem="F3", n_vars=2)
            for c in range(n_chunks):
                reg.record_chunk(job_id, {
                    "gens_done": c + 1, "chunk_gens": 1, "wall_s": 1e-4,
                    "best_fitness": float(c), "migrations": 0})
                reg.metrics()
            reg.finish_job(job_id)
        except Exception as e:      # noqa: BLE001 — collected for the assert
            errors.append(repr(e))

    threads = [threading.Thread(target=writer, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(30)
    assert not errors, errors
    snap = reg.metrics()
    assert snap["job_count"] == n_threads
    assert snap["jobs_done"] == n_threads
    assert all(j["chunks"] == n_chunks for j in snap["jobs"].values())
    assert snap["generations_total"] == n_threads * n_chunks


def test_registry_pubsub_delivers_chunks_and_end():
    reg = GAMetricsRegistry()
    job_id = reg.allocate_job_id("F3")
    reg.start_job(job_id, backend="reference", gens_total=2,
                  problem="F3", n_vars=2)
    sub = reg.subscribe(job_id)
    reg.record_chunk(job_id, {"gens_done": 1, "chunk_gens": 1,
                              "wall_s": 1e-4, "best_fitness": 1.0})
    reg.finish_job(job_id)
    events = [sub.get(timeout=5), sub.get(timeout=5)]
    assert events[0]["event"] == "chunk"
    assert events[0]["gens_done"] == 1
    assert events[1]["event"] == "end"
    assert events[1]["status"] == "done"
    reg.unsubscribe(job_id, sub)


# ---------------------------------------------------------------------------
# Streaming HTTP endpoints (SSE + long-poll + scheduler gauges)
# ---------------------------------------------------------------------------


def test_metrics_http_streaming_endpoints(tmp_path):
    """Acceptance: per-chunk telemetry streams to an HTTP client WHILE the
    job runs (SSE), the long-poll endpoint blocks until new chunks land,
    and /metrics exports the scheduler + compile-cache gauges."""
    from repro.serve.metrics_http import start_metrics_server

    reg = GAMetricsRegistry()
    sched = GAScheduler(registry=reg, ckpt_root=str(tmp_path),
                        chunk_generations=8)
    server = start_metrics_server(0, registry=reg, host="127.0.0.1")
    port = server.server_address[1]
    try:
        a = sched.submit(_spec(seed=3, generations=48))
        events = []

        def read_sse():
            req = urllib.request.urlopen(
                f"http://127.0.0.1:{port}/jobs/{a}/stream", timeout=60)
            buf = b""
            while True:
                line = req.readline()
                if not line:
                    return
                buf += line
                if line == b"\n":
                    for ln in buf.split(b"\n"):
                        if ln.startswith(b"data: "):
                            events.append(json.loads(ln[len(b"data: "):]))
                    if b"event: end" in buf:
                        return
                    buf = b""

        t = threading.Thread(target=read_sse)
        t.start()
        sched.result(a, timeout=120)
        t.join(30)
        assert events and events[-1].get("event") == "end"
        assert any(e.get("event") == "chunk" for e in events)

        b = sched.submit(_spec(seed=99, generations=48))
        lp = json.loads(urllib.request.urlopen(
            f"http://127.0.0.1:{port}/jobs/{b}?after=0&timeout=30",
            timeout=60).read())
        assert lp["chunks"] > 0
        sched.result(b, timeout=120)

        jobs = json.loads(urllib.request.urlopen(
            f"http://127.0.0.1:{port}/jobs", timeout=10).read())
        assert a in jobs["jobs"] and b in jobs["jobs"]
        text = urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics", timeout=10).read().decode()
        for gauge in ("repro_ga_sched_queue_depth",
                      "repro_ga_sched_packs_launched",
                      "repro_ga_compile_cache_hits",
                      "repro_ga_job_status", "repro_ga_pack_size"):
            assert gauge in text, gauge
        with pytest.raises(urllib.error.HTTPError) as err:
            urllib.request.urlopen(f"http://127.0.0.1:{port}/jobs/nope",
                                   timeout=10)
        assert err.value.code == 404
    finally:
        server.shutdown()
        sched.shutdown()
