"""Mamba2 SSD: the chunked dual form must match the sequential recurrence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm as S


def _naive(x, dt, a, b, c):
    bsz, L, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    hg = H // G
    bh = jnp.repeat(b, hg, axis=2)
    ch = jnp.repeat(c, hg, axis=2)
    stt = jnp.zeros((bsz, H, N, P))
    ys = []
    for t in range(L):
        da = jnp.exp(dt[:, t] * a[None, :])
        stt = stt * da[..., None, None] + jnp.einsum(
            "bhn,bhp->bhnp", bh[:, t], x[:, t] * dt[:, t][..., None])
        ys.append(jnp.einsum("bhn,bhnp->bhp", ch[:, t], stt))
    return jnp.stack(ys, 1), stt


@pytest.mark.parametrize("chunk,L", [(8, 32), (16, 64), (32, 32)])
@pytest.mark.parametrize("G", [1, 2])
def test_chunked_matches_naive(chunk, L, G):
    cfg = S.SSMConfig(d_model=64, d_state=16, headdim=8, chunk=chunk,
                      n_groups=G)
    B, H, P, N = 2, cfg.n_heads, cfg.headdim, cfg.d_state
    ks = jax.random.split(jax.random.key(L + G), 5)
    x = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    b = jax.random.normal(ks[3], (B, L, G, N))
    c = jax.random.normal(ks[4], (B, L, G, N))
    y, stt = S._ssd_chunked(x, dt, a, b, c, cfg)
    y_ref, st_ref = _naive(x, dt, a, b, c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(stt), np.asarray(st_ref),
                               rtol=1e-4, atol=1e-4)


def test_init_state_continuation():
    """Splitting a sequence into two chunked calls with state carry equals
    one full call (prefill-then-continue correctness)."""
    cfg = S.SSMConfig(d_model=64, d_state=16, headdim=8, chunk=8)
    B, L, H, P, N = 1, 32, cfg.n_heads, cfg.headdim, cfg.d_state
    ks = jax.random.split(jax.random.key(0), 5)
    x = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    b = jax.random.normal(ks[3], (B, L, 1, N))
    c = jax.random.normal(ks[4], (B, L, 1, N))
    y_full, st_full = S._ssd_chunked(x, dt, a, b, c, cfg)
    h = L // 2
    y1, st1 = S._ssd_chunked(x[:, :h], dt[:, :h], a, b[:, :h], c[:, :h], cfg)
    y2, st2 = S._ssd_chunked(x[:, h:], dt[:, h:], a, b[:, h:], c[:, h:], cfg,
                             init_state=st1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                               rtol=1e-4, atol=1e-4)


def test_block_decode_matches_forward():
    """Full block: forward(return_cache) then decode_step == forward on S+1."""
    cfg = S.SSMConfig(d_model=32, d_state=8, headdim=8, chunk=8)
    p = {}
    from repro.models import common as C
    defs = S.ssm_defs(cfg)
    params = C.init_params(defs, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 17, 32), jnp.float32) * 0.3
    y_full = S.forward(params, x.astype(jnp.bfloat16), cfg)
    y_pre, cache = S.forward(params, x[:, :16].astype(jnp.bfloat16), cfg,
                             return_cache=True)
    y_dec, _ = S.decode_step(params, x[:, 16:17].astype(jnp.bfloat16), cfg, cache)
    err = float(jnp.max(jnp.abs(y_dec.astype(jnp.float32) -
                                y_full[:, 16:17].astype(jnp.float32))))
    assert err < 0.15, err  # bf16 path tolerance
