"""GA launcher — run the paper's experiments (and beyond) from the CLI.

    PYTHONPATH=src python -m repro.launch.ga_run --problem F1 --n 32 --m 26
    PYTHONPATH=src python -m repro.launch.ga_run --problem F3 --backend fused
    PYTHONPATH=src python -m repro.launch.ga_run --problem rastrigin:8 \
        --backend fused --mode arith
    PYTHONPATH=src python -m repro.launch.ga_run --problem F3 --islands 16
    PYTHONPATH=src python -m repro.launch.ga_run --problem ackley:4 \
        --islands 8 --backend fused-islands --mesh auto --gens-per-epoch 4
    PYTHONPATH=src python -m repro.launch.ga_run --problem F3 --chunk 25 \
        --metrics-port 9100      # scrape http://localhost:9100/metrics

`--problem` takes any registered problem name (repro.core.fitness.PROBLEMS:
F1/F2/F3 pin the paper's two-variable layout; sphere/rastrigin/rosenbrock/
ackley take an optional `:V` variable-count suffix).  Any registered backend
(reference | fused | islands | fused-islands | eager | auto — each a
topology × executor composition) runs any problem the capability matrix
allows; the fused Pallas executors trace the problem's FFM stage into the
kernel, n-variable suites and blackboxes included.  `--mesh` shards the
island axis over devices ("auto", "4", "2x4", ... — see
repro.launch.mesh.parse_mesh) with `lax.ppermute` ring migration,
bit-identical to the single-device run; `--gens-per-epoch` folds generations
inside one Pallas launch; `--metrics-port` exposes live GA_METRICS as a
Prometheus /metrics endpoint while the run streams; `--kernel` is kept as a
deprecated alias for `--backend fused`.
"""

from __future__ import annotations

import argparse

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--problem", default="F3",
                    help="registered problem, optionally 'name:V' "
                         "(F1 | F2 | F3 | sphere | rastrigin | rosenbrock "
                         "| ackley; e.g. 'rastrigin:8')")
    ap.add_argument("--n", type=int, default=32)
    ap.add_argument("--m", type=int, default=20,
                    help="paper chromosome bits for V=2 problems (c = m/2 "
                         "bits per variable)")
    ap.add_argument("--k", type=int, default=100, help="generations")
    ap.add_argument("--mode", default="lut", choices=["lut", "arith"])
    ap.add_argument("--mutation-rate", type=float, default=0.02)
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "reference", "fused", "islands",
                             "fused-islands", "eager"])
    ap.add_argument("--topology", default="auto",
                    choices=["auto", "single", "island_ring"],
                    help="population layout (auto derives from --islands)")
    ap.add_argument("--selection", default="tournament",
                    help="registered selection scheme (see repro.ga.SELECTION)")
    ap.add_argument("--islands", type=int, default=0,
                    help=">1 runs the island model (implies an island_ring "
                         "backend)")
    ap.add_argument("--migration", default="ring", choices=["ring", "none"],
                    help="inter-island exchange (none = isolated ablation)")
    ap.add_argument("--migrate-every", type=int, default=16)
    ap.add_argument("--repeats", type=int, default=1,
                    help="independent replicas vmapped into one run")
    ap.add_argument("--mesh", default=None,
                    help="shard the island axis over devices: 'auto' (all), "
                         "'4', '2x4', ... (repro.launch.mesh.parse_mesh)")
    ap.add_argument("--gens-per-epoch", type=int, default=1,
                    help=">1 folds generations inside one Pallas launch "
                         "(fused executors; amortizes launch overhead); "
                         ">= migrate_every engages the RESIDENT epoch "
                         "kernel with in-VMEM ring migration (whole "
                         "multiples fold several intervals per launch)")
    ap.add_argument("--kernel", action="store_true",
                    help="deprecated: same as --backend fused")
    ap.add_argument("--chunk", type=int, default=0,
                    help="stream telemetry every CHUNK generations")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint/resume directory for chunked runs")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="opt-in: serve GA_METRICS as Prometheus text at "
                         "http://0.0.0.0:PORT/metrics for the run's duration")
    ap.add_argument("--seed", type=int, default=1)
    from repro.ga.options import EngineOptions
    EngineOptions.add_cli_args(ap)   # --cost-table/--plan-override/--vmem-...
    args = ap.parse_args()

    from repro import ga
    from repro.core import fitness as F

    backend = args.backend
    if args.kernel:
        backend = "fused"
    n_islands = max(args.islands, 1)
    pdef, _ = F.resolve_problem(args.problem)   # fail fast on unknown names
    mode = args.mode
    if backend in ("fused", "fused-islands") and mode == "lut":
        mode = "arith"   # the kernel's FFM is arithmetic-only
    if mode == "lut" and not pdef.separable:
        print(f"note: {pdef.name} has no LUT (ROM) lowering; using arith")
        mode = "arith"

    spec = ga.GASpec(problem=args.problem, n=args.n, bits_per_var=args.m // 2,
                     mode=mode, mutation_rate=args.mutation_rate,
                     seed=args.seed, generations=args.k, n_islands=n_islands,
                     migrate_every=args.migrate_every,
                     n_repeats=args.repeats, selection=args.selection,
                     gens_per_epoch=args.gens_per_epoch,
                     topology=None if args.topology == "auto"
                     else args.topology,
                     migration=args.migration)

    mesh = None
    if args.mesh:
        from repro.launch.mesh import parse_mesh
        mesh = parse_mesh(args.mesh)
        print(f"mesh: {dict(mesh.shape)} ({mesh.devices.size} device(s))")
    options = EngineOptions.from_args(args, mesh=mesh)

    server = None
    if args.metrics_port is not None:
        from repro.serve.metrics_http import start_metrics_server
        server = start_metrics_server(args.metrics_port)
        print(f"metrics: http://0.0.0.0:{server.server_address[1]}/metrics")

    if args.chunk > 0 or server is not None:
        from repro.serve.engine import GA_METRICS
        eng = ga.Engine(spec, backend, options=options)
        last = None
        job = GA_METRICS.start_job(
            GA_METRICS.allocate_job_id(spec.problem), backend=eng.backend_name,
            gens_total=spec.generations, problem=spec.problem,
            n_vars=spec.v)
        try:
            for tele in eng.run_chunked(
                    chunk_generations=args.chunk or None,
                    ckpt_dir=args.ckpt_dir):
                GA_METRICS.record_chunk(job.job_id, tele)
                print(f"[{tele['backend']}] chunk {tele['chunk']}: "
                      f"{tele['gens_done']}/{tele['gens_total']} gens, "
                      f"best={tele['best_fitness']:.4f}, "
                      f"{tele['gens_per_s']:.0f} gens/s, "
                      f"{tele.get('migrations', 0)} migrations")
                last = tele
            GA_METRICS.finish_job(job.job_id)
        except BaseException as e:   # mirror run_ga_job: /metrics must not
            GA_METRICS.finish_job(job.job_id, error=repr(e))   # stay "running"
            raise
        finally:
            if server is not None:
                server.shutdown()
        if last is not None:
            print(f"decoded vars: {np.round(last['best_params'], 4)}")
        return

    out = ga.solve(spec, backend=backend, options=options)
    tele = out.telemetry
    comp = (f" ({tele.topology.executor} x {tele.topology.topology})"
            if tele.topology.executor != "-" else "")
    print(f"backend: {out.backend}{comp}")
    print(f"problem: {tele.problem or spec.problem or 'blackbox'} "
          f"({spec.v} variable(s), mode={mode})")
    if tele.topology.sharded:
        shards = max(1, tele.topology.n_shards)
        print(f"shards: {shards} "
              f"({spec.n_islands // shards} island(s) each)")
    if tele.plan.mode != "-":
        tile = (f", tile={tele.plan.tile_islands}"
                if tele.plan.tile_islands else "")
        lane = f", lane={tele.plan.lane}" if tele.plan.lane != "-" else ""
        print(f"epoch plan: {tele.plan.mode} "
              f"({tele.plan.source}{lane}{tile})")
    if tele.topology.migrations:
        print(f"migrations: {tele.topology.migrations}")
    print(f"best fitness: {out.best_fitness:.4f}")
    print(f"decoded vars: {np.round(out.best_params, 4)}")
    traj = np.asarray(out.traj_best)
    if traj.size:
        print(f"trajectory (best, every 10 entries): {traj[::10]}")
    total_gens = out.generations * max(n_islands, args.repeats, 1)
    print(f"{out.wall_s*1e3:.1f} ms total -> {total_gens/out.wall_s:.0f} "
          f"generations/s (wall)")


if __name__ == "__main__":
    main()
