"""Serving launcher: batched generation on a reduced config.

    PYTHONPATH=src python -m repro.launch.serve --arch minitron-8b --reduced \
        --batch 4 --new-tokens 16
"""

from __future__ import annotations

import argparse

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.configs.base import reduced as make_reduced
    from repro.models import common as C
    from repro.models import lm as LM
    from repro.serve.engine import Engine, EngineConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)

    defs = LM.model_defs(cfg, max_seq=args.max_len)
    params = C.init_params(defs, jax.random.key(0))
    engine = Engine(cfg, params,
                    EngineConfig(batch=args.batch, max_len=args.max_len))

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len),
                           dtype=np.int32)
    kw = {}
    if cfg.family == "audio":
        kw["frames"] = jax.numpy.asarray(
            rng.normal(size=(args.batch, cfg.enc_seq, cfg.d_model)) * 0.1,
            dtype=jax.numpy.float32)
    if cfg.family == "vlm":
        kw["patches"] = jax.numpy.asarray(
            rng.normal(size=(args.batch, cfg.n_patches, cfg.d_model)) * 0.1,
            dtype=jax.numpy.float32)
    toks, stats = engine.generate(prompts, args.new_tokens, **kw)
    print("generated:", toks[:, :8], "...")
    print(f"prefill {stats['prefill_s']*1e3:.1f} ms; "
          f"decode {stats['decode_tok_per_s']:.1f} tok/s")


if __name__ == "__main__":
    main()
