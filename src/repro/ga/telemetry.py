"""Typed run telemetry — the structured successor of the `extras` dict.

Segments and engine results used to report how a run executed through a
stringly-keyed `extras` dict (`epoch_mode`, `plan_source`, `plan_fallback`,
`per_repeat_best`, ... scattered across every consumer).  `RunTelemetry`
replaces that contract with a versioned dataclass of three facets:

  * `plan: PlanInfo` — the epoch-plan decision (mode, provenance, fallback
    reason, launch fold shape, streamed tile size, VMEM estimate);
  * `topology: TopologyInfo` — how the run was laid out (executor ×
    topology names, island/shard counts, launch and migration counters);
  * `per_repeat: ReplicaStats | None` — per-replica best/trajectory arrays
    when the run stacked `n_repeats` replicas.

`Segment.extras` / `EngineResult.extras` remain as DEPRECATED read-only
dict views (`to_extras()`) for one release; every in-repo consumer reads
the typed fields.  `version` is bumped whenever a field changes meaning so
persisted telemetry (e.g. scheduler job streams) stays interpretable.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, Optional

TELEMETRY_VERSION = 1


@dataclasses.dataclass
class PlanInfo:
    """The epoch-plan decision a segment ran under.

    mode: "gridded" | "resident" | "resident-sharded" | "resident-free" |
    "streamed" | "-" (no plan: single topology / reference executor).
    source: "heuristic" | "measured" | "forced" | "-".  fallback carries
    the VMEM-estimator reason when the resident shape was rejected (set for
    both the gridded fallback AND the streamed lane, which exists because
    of that rejection).  tile_islands is the streamed mode's island tile.
    lane is the selection lane the fused kernels ran ("onehot" | "gather" |
    "-" for executors without one).  gens_per_s is the measured rate that
    justified a "measured" choice."""

    mode: str = "-"
    source: str = "-"
    fallback: Optional[str] = None
    epochs_per_launch: int = 1
    gens_per_launch: int = 1
    tile_islands: Optional[int] = None
    lane: str = "-"
    vmem_estimate_bytes: Optional[int] = None
    gens_per_s: Optional[float] = None

    @classmethod
    def from_plan(cls, plan: Dict[str, Any]) -> "PlanInfo":
        """Build from an `IslandRingTopology._epoch_plan` dict."""
        return cls(mode=plan.get("mode", "-"),
                   source=plan.get("plan_source", "heuristic"),
                   fallback=plan.get("fallback"),
                   epochs_per_launch=int(plan.get("epochs_per_launch", 1)),
                   gens_per_launch=int(plan.get("gens_per_launch", 1)),
                   tile_islands=plan.get("tile_islands"),
                   lane=plan.get("lane", "-"),
                   vmem_estimate_bytes=plan.get("vmem_estimate_bytes"),
                   gens_per_s=plan.get("plan_gens_per_s"))


@dataclasses.dataclass
class TopologyInfo:
    """How the run was laid out and what it counted."""

    executor: str = "-"
    topology: str = "-"
    n_islands: int = 1
    n_shards: int = 1
    sharded: bool = False
    launches: int = 0
    migrations: int = 0
    # generations represented by ONE trajectory sample (resident/streamed
    # launches fold many generations per sample)
    telemetry_unit_gens: int = 1


@dataclasses.dataclass
class ReplicaStats:
    """Per-replica results of an `n_repeats`-stacked run (numpy arrays:
    best [R], best_x [R, V], traj_best/traj_mean [R, samples])."""

    best: Any = None
    best_x: Any = None
    traj_best: Any = None
    traj_mean: Any = None


@dataclasses.dataclass
class RunTelemetry:
    """Versioned telemetry for one segment / one engine result."""

    version: int = TELEMETRY_VERSION
    plan: PlanInfo = dataclasses.field(default_factory=PlanInfo)
    topology: TopologyInfo = dataclasses.field(default_factory=TopologyInfo)
    per_repeat: Optional[ReplicaStats] = None
    problem: Optional[str] = None
    n_vars: Optional[int] = None
    resumed_from: Optional[int] = None   # ckpt step (gens) this segment
                                         # resumed from, first chunk only

    def job_view(self) -> "RunTelemetry":
        """Plan/topology facets without the per-repeat arrays — what a
        packed job's telemetry carries after its slots are sliced out."""
        return dataclasses.replace(self, per_repeat=None)

    def to_extras(self) -> Dict[str, Any]:
        """The legacy `extras` dict (exact historical keys).  Deprecated —
        read the typed fields; this view exists for one release."""
        d: Dict[str, Any] = {}
        t, p = self.topology, self.plan
        if t.executor != "-":
            d["executor"] = t.executor
            d["topology"] = t.topology
        if self.problem is not None:
            d["problem"] = self.problem
            d["n_vars"] = self.n_vars
        if p.mode != "-":
            d["telemetry_unit_gens"] = t.telemetry_unit_gens
            d["n_islands"] = t.n_islands
            d["n_shards"] = t.n_shards
            d["epoch_mode"] = p.mode
            d["plan_source"] = p.source
            d["launches"] = t.launches
            d["migrations"] = t.migrations
            if p.tile_islands is not None:
                d["tile_islands"] = p.tile_islands
            if p.lane != "-":
                d["sel_lane"] = p.lane
            if p.fallback is not None:
                d["resident_fallback"] = p.fallback
                d["plan_fallback"] = p.fallback
            if t.sharded:
                d["sharded"] = True
        r = self.per_repeat
        if r is not None:
            if r.best is not None:
                d["per_repeat_best"] = r.best
            if r.best_x is not None:
                d["per_repeat_best_x"] = r.best_x
            if r.traj_best is not None:
                d["per_repeat_traj_best"] = r.traj_best
            if r.traj_mean is not None:
                d["per_repeat_traj_mean"] = r.traj_mean
        return d


def deprecated_extras(telemetry: RunTelemetry, owner: str) -> Dict[str, Any]:
    """The `.extras` property body: warn once per call site, return the
    legacy dict view."""
    warnings.warn(
        f"{owner}.extras is deprecated; read the typed {owner}.telemetry "
        "(ga.RunTelemetry: .plan / .topology / .per_repeat) instead — the "
        "dict view will be removed in the next release",
        DeprecationWarning, stacklevel=3)
    return telemetry.to_extras()
