"""Paper Table 2: speedup over the sequential software GA.

The paper compares its FPGA against prior FPGA GAs; the honest software
analogue here is our vectorized engine (reference backend through
`repro.ga`) vs a sequential NumPy GA at the same (N, k) settings the table
uses."""

from __future__ import annotations

from benchmarks.ga_common import bench_engine, numpy_sequential_ga, time_call
from repro.core import fitness as F

SETTINGS = [  # (ref, N, k) rows of Table 2
    ("vavouras09", 32, 100),
    ("deliparaschos08", 32, 60),
    ("fernando08", 32, 32),
    ("zhu07", 64, 500),
]


def run():
    rows = []
    for ref, n, k in SETTINGS:
        eng = bench_engine("F3", n=n, m=20, generations=k, mode="arith")
        dt, _ = time_call(eng.run, iters=3)
        t_seq, _ = numpy_sequential_ga(F.F3, n, 20, k)
        rows.append((f"table2_{ref}_N{n}_k{k}", dt * 1e6,
                     f"speedup_vs_sequential={t_seq/dt:.0f}x"))
    return rows
