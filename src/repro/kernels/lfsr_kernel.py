"""Pallas TPU kernel: bulk LFSR-32 advance.

Advances a large bank of independent LFSR lanes `steps` clocks.  Used to
(re)seed island farms and to stream random words for the pure-JAX GA path
without materializing intermediate states in HBM.

Tiling: the lane array is viewed as (rows, 128); each program instance
processes an (8, 128) VMEM tile — the native f32/int32 TPU tile, so the
bitwise VPU ops are perfectly aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

TILE_R, TILE_C = 8, 128


def _kernel(s_ref, o_ref, *, steps: int):
    s = s_ref[...]

    def one(_, s):
        fb = ((s >> 31) ^ (s >> 21) ^ (s >> 1) ^ s) & jnp.uint32(1)
        return (s << 1) | fb

    o_ref[...] = jax.lax.fori_loop(0, steps, one, s) if steps > 8 else \
        functools.reduce(lambda a, _: one(0, a), range(steps), s)


def lfsr_advance_kernel(state: jax.Array, steps: int,
                        interpret: bool = False) -> jax.Array:
    """Advance every lane of `state` (any shape, uint32) `steps` clocks."""
    shape = state.shape
    flat = state.reshape(-1)
    n = flat.shape[0]
    per_tile = TILE_R * TILE_C
    pad = (-n) % per_tile
    if pad:
        flat = jnp.concatenate([flat, jnp.ones((pad,), jnp.uint32)])
    rows = flat.shape[0] // TILE_C
    grid = (rows // TILE_R,)
    out = pl.pallas_call(
        functools.partial(_kernel, steps=steps),
        grid=grid,
        in_specs=[pl.BlockSpec((TILE_R, TILE_C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((TILE_R, TILE_C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, TILE_C), jnp.uint32),
        interpret=interpret,
    )(flat.reshape(rows, TILE_C))
    return out.reshape(-1)[:n].reshape(shape)
