"""The unified `repro.ga` Engine API: backend parity, operator registry,
capability checks / fallback, vmapped repeats, chunked checkpoint/resume."""

import dataclasses
import warnings

import jax
import numpy as np
import pytest

from repro import ga
from repro.core import ga as G


def _spec(**kw):
    base = dict(problem="F3", n=32, bits_per_var=10, mode="arith",
                mutation_rate=0.05, seed=11, generations=20)
    base.update(kw)
    return ga.GASpec(**base)


# ---------------------------------------------------------------------------
# Backend parity: the fused Pallas kernel must be bit-identical to the
# pure-JAX reference scan (interpret mode on CPU)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("problem", ["F1", "F3", "rastrigin:6", "ackley:4",
                                     "rosenbrock:5"])
def test_reference_vs_fused_bit_exact(problem):
    """Paper problems AND the n-variable suite: the kernel's pluggable FFM
    stage is the same traced function the reference executor evaluates."""
    spec = _spec(problem=problem, n=64, generations=4)
    ref = ga.Engine(spec, "reference")
    fus = ga.Engine(spec, "fused")
    seg_r = ref.backend.segment(ref.init_state(), 4)
    seg_f = fus.backend.segment(fus.init_state(), 4)
    # populations and every LFSR bank after 4 generations: bit-exact
    np.testing.assert_array_equal(np.asarray(seg_f.state.x)[0],
                                  np.asarray(seg_r.state.x))
    np.testing.assert_array_equal(np.asarray(seg_f.state.sel_lfsr)[0],
                                  np.asarray(seg_r.state.sel_lfsr))
    np.testing.assert_array_equal(np.asarray(seg_f.state.cross_lfsr)[0],
                                  np.asarray(seg_r.state.cross_lfsr))
    np.testing.assert_array_equal(np.asarray(seg_f.state.mut_lfsr)[0],
                                  np.asarray(seg_r.state.mut_lfsr))
    # identical trajectories and best chromosome
    np.testing.assert_array_equal(seg_f.traj_best, seg_r.traj_best)
    np.testing.assert_array_equal(seg_f.best_x, seg_r.best_x)
    assert seg_f.best_y == seg_r.best_y


def test_every_backend_from_one_spec():
    """Acceptance: one spec object runs F1 and F3 on every registered
    (topology × executor) backend — island_ring backends get the spec's
    island variant, everything else the single-population variant."""
    for problem, thresh in (("F1", -6.0e10), ("F3", 3.0)):
        spec = _spec(problem=problem, n=64, generations=60)
        ispec = dataclasses.replace(spec, n_islands=4, migrate_every=10)
        results = {}
        for b in sorted(ga.BACKENDS):
            cls = ga.BACKENDS[b]
            this = spec if cls.supports(spec) is None else ispec
            assert cls.supports(this) is None, (b, cls.supports(this))
            results[b] = ga.solve(this, backend=b)
        for b, r in results.items():
            assert r.backend == b
            assert np.isfinite(r.best_fitness), (problem, b)
            assert r.best_fitness < thresh, (problem, b, r.best_fitness)
            assert r.best_params.shape == (2,)
        # the jitted paths agree exactly; eager fitness runs op-by-op so
        # XLA's fusion/FMA choices may differ by float ulps
        assert results["reference"].best_fitness == \
            results["fused"].best_fitness
        assert results["islands"].best_fitness == \
            results["fused-islands"].best_fitness
        assert results["reference"].best_fitness == pytest.approx(
            results["eager"].best_fitness, rel=1e-4)


def test_blackbox_runs_fused_bit_exact():
    """Acceptance: a traceable blackbox (no closed form, captures its own
    arrays) is no longer rejected by the fused backend and runs the Pallas
    kernel bit-identical to the reference executor."""
    import jax.numpy as jnp
    target = jnp.asarray([0.25, -1.5, 2.0], jnp.float32)
    spec = ga.GASpec(fitness=lambda p: jnp.sum((p - target) ** 2, axis=-1),
                     bounds=((-4.0, 4.0),) * 3, n=32, bits_per_var=12,
                     mutation_rate=0.05, seed=13, generations=12)
    assert ga.capability_matrix(spec)["fused"] is None
    r = ga.solve(spec, backend="reference")
    f = ga.solve(spec, backend="fused")
    assert f.backend == "fused"
    assert r.best_fitness == f.best_fitness
    np.testing.assert_array_equal(r.best_x, f.best_x)
    np.testing.assert_array_equal(r.traj_best, f.traj_best)
    assert r.best_params.shape == (3,)


def test_problem_registry_spec_plumbing():
    """'name:V' shorthand, registry validation and per-problem telemetry."""
    spec = _spec(problem="rastrigin:8")
    assert spec.problem == "rastrigin" and spec.v == 8
    assert spec.program().modes == ("lut", "arith")
    r = ga.solve(spec, backend="reference")
    assert r.telemetry.problem == "rastrigin" and r.telemetry.n_vars == 8
    assert r.best_params.shape == (8,)
    with pytest.raises(ValueError, match="unknown problem"):
        _spec(problem="nope")
    with pytest.raises(ValueError, match="V=2"):
        _spec(problem="F3:4")
    with pytest.raises(ValueError, match="at least 2"):
        _spec(problem="rosenbrock:1")
    with pytest.raises(ValueError, match="separable"):
        _spec(problem="ackley", mode="lut")
    # custom problems register and run end to end (on the fused kernel too)
    import jax.numpy as jnp
    ga.register_problem(ga.ProblemDef(
        name="_test_tilted",
        fn=lambda v: jnp.sum(v * v + 0.5 * v, axis=-1),
        domain=(-3.0, 3.0)))
    try:
        r = ga.solve(_spec(problem="_test_tilted:3", generations=10),
                     backend="fused")
        assert r.backend == "fused" and np.isfinite(r.best_fitness)
    finally:
        del ga.PROBLEMS["_test_tilted"]


# ---------------------------------------------------------------------------
# Operator registry
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(ga.SELECTION))
def test_every_selection_variant_runs_under_solve(name):
    r = ga.solve(_spec(selection=name, generations=30), backend="reference")
    assert np.isfinite(r.best_fitness)
    assert r.best_fitness < 10.0   # all schemes make progress on F3


def test_custom_registered_selection_runs():
    @ga.register_selection("_test_random")
    def random_selection(x, y, sel_lfsr, cfg):
        from repro.core import lfsr
        sel_lfsr, r = lfsr.draw(sel_lfsr, cfg.steps_per_draw)
        i = lfsr.truncate(r[0], cfg.idx_bits).astype(np.int32) % cfg.n
        return x[i], sel_lfsr

    try:
        r = ga.solve(_spec(selection="_test_random"), backend="reference")
        assert np.isfinite(r.best_fitness)
    finally:
        del ga.SELECTION["_test_random"]


def test_unknown_operator_rejected_at_spec_build():
    with pytest.raises(ValueError, match="unknown selection"):
        _spec(selection="nope")


def test_uniform_crossover_conserves_bits():
    spec = _spec(crossover="uniform", mutation="none", generations=5)
    eng = ga.Engine(spec, "reference")
    st = eng.init_state()
    y = eng.backend.executor.fit(st.x)
    cfg = spec.ga_config()
    w, _ = ga.SELECTION["tournament"](st.x, y, st.sel_lfsr, cfg)
    z, _ = ga.CROSSOVER["uniform"](w, st.cross_lfsr, cfg)
    w1, w2 = np.asarray(w[0::2]), np.asarray(w[1::2])
    z1, z2 = np.asarray(z[0::2]), np.asarray(z[1::2])
    np.testing.assert_array_equal(w1 ^ w2, z1 ^ z2)


# ---------------------------------------------------------------------------
# Capability checks and fallback
# ---------------------------------------------------------------------------


def test_capability_matrix_and_fallback():
    lut = _spec(mode="lut")
    caps = ga.capability_matrix(lut)
    assert caps["reference"] is None
    assert "arith" in caps["fused"]
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        r = ga.solve(lut, backend="fused")
    assert r.backend == "reference"
    assert any("falling back" in str(x.message) for x in w)

    # non-pow2 N is fused-incompatible on every lane; N past the onehot
    # cap resolves to the gather lane (sel_lane="auto") and stays fused,
    # while an explicit onehot pin is rejected
    assert ga.capability_matrix(_spec(n=30))["fused"] is not None
    assert ga.capability_matrix(_spec(n=2048))["fused"] is None
    assert _spec(n=2048).resolved_sel_lane == "gather"
    with pytest.raises(ValueError, match="sel_lane='gather'"):
        _spec(n=2048, sel_lane="onehot")
    # non-paper pipeline routes off the fused kernel
    assert ga.capability_matrix(_spec(selection="rank"))["fused"] is not None
    # eager fitness only runs on the eager backend
    caps = ga.capability_matrix(_spec(jit_fitness=False))
    assert caps["eager"] is None and caps["reference"] is not None
    assert ga.resolve_backend(_spec(jit_fitness=False)) == "eager"


def test_unknown_backend_raises():
    with pytest.raises(ga.BackendUnsupported):
        ga.solve(_spec(), backend="gpu_farm")


def test_large_captured_consts_route_off_the_kernel():
    """A fitness closing over a big array (> the hoisted-const VMEM gate)
    is fused-incompatible with an actionable reason and falls back to the
    reference path instead of replicating the array per grid step."""
    import jax.numpy as jnp

    big = jnp.arange(1024 * 1024, dtype=jnp.float32)     # 4 MiB of consts
    spec = ga.GASpec(fitness=lambda p: jnp.sum(p * p, axis=-1) + big[0],
                     bounds=((-1.0, 1.0),) * 2, n=16, bits_per_var=8,
                     generations=5, seed=3)
    caps = ga.capability_matrix(spec)
    assert caps["fused"] is not None and "VMEM gate" in caps["fused"]
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        r = ga.solve(spec, backend="fused")
    assert r.backend == "reference"
    assert any("falling back" in str(x.message) for x in w)
    # a small captured const stays fused-eligible
    small = jnp.asarray([0.5, -0.5], jnp.float32)
    ok = ga.GASpec(fitness=lambda p: jnp.sum((p - small) ** 2, axis=-1),
                   bounds=((-1.0, 1.0),) * 2, n=16, bits_per_var=8,
                   generations=5, seed=3)
    assert ga.capability_matrix(ok)["fused"] is None


# ---------------------------------------------------------------------------
# Vmapped multi-seed repeats (paper Table 3 methodology)
# ---------------------------------------------------------------------------


def test_repeats_replica_zero_matches_solo_run():
    spec = _spec(generations=25)
    solo = ga.solve(spec, backend="reference")
    rep = ga.solve(dataclasses.replace(spec, n_repeats=4),
                   backend="reference")
    per = rep.telemetry.per_repeat.best
    assert per.shape == (4,)
    assert float(per[0]) == solo.best_fitness
    assert rep.best_fitness == float(np.min(per))
    # replicas are decorrelated — not all identical
    assert len(np.unique(per)) > 1


def test_repeats_match_across_backends():
    spec = _spec(n=32, generations=10, n_repeats=3)
    r_ref = ga.solve(spec, backend="reference")
    r_fus = ga.solve(spec, backend="fused")
    np.testing.assert_array_equal(r_ref.telemetry.per_repeat.best,
                                  r_fus.telemetry.per_repeat.best)


# ---------------------------------------------------------------------------
# Chunked streaming + checkpoint/resume
# ---------------------------------------------------------------------------


def test_chunked_equals_straight_run(tmp_path):
    spec = _spec(generations=40)
    eng = ga.Engine(spec, "reference")
    teles = list(eng.run_chunked(chunk_generations=10))
    assert [t["gens_done"] for t in teles] == [10, 20, 30, 40]
    straight = ga.solve(spec, backend="reference")
    assert teles[-1]["best_fitness"] == straight.best_fitness


def test_checkpoint_resume(tmp_path):
    spec = _spec(generations=40)
    ckpt = str(tmp_path / "ga_ck")
    full = list(ga.Engine(spec, "reference").run_chunked(
        chunk_generations=10))

    it = ga.Engine(spec, "reference").run_chunked(chunk_generations=10,
                                                  ckpt_dir=ckpt)
    next(it), next(it)      # 20 generations, then "crash"
    del it
    resumed = list(ga.Engine(spec, "reference").run_chunked(
        chunk_generations=10, ckpt_dir=ckpt))
    assert [t["gens_done"] for t in resumed] == [30, 40]
    assert resumed[-1]["best_fitness"] == full[-1]["best_fitness"]


def test_islands_backend_chunks_by_epoch():
    spec = _spec(n_islands=4, migrate_every=8, generations=32)
    r = ga.solve(spec)   # auto routes to islands
    assert r.backend == "islands"
    assert r.generations == 32
    assert len(r.traj_best) == 4   # one telemetry entry per migration epoch


# ---------------------------------------------------------------------------
# Result semantics
# ---------------------------------------------------------------------------


def test_lut_fixed_point_descaled():
    spec = ga.paper_spec("F1", n=32, m=26, mode="lut", mutation_rate=0.05,
                         seed=7, generations=100)
    r = ga.solve(spec, backend="reference")
    # real units, not fixed-point: the paper's global minimum ~ -6.897e10
    assert r.best_fitness == pytest.approx(-6.897e10, rel=0.01)
    assert r.best_params[1] == pytest.approx(-4096.0, abs=2.0)


def test_deprecated_entry_points_folded():
    """Deprecation clock part 2: the old shim drivers are gone — the engine
    is the only entry point — while the engine-internal building blocks
    (`run_scan`) still agree with `ga.solve` bit-for-bit."""
    from repro.core import islands as ISL
    from repro.kernels import ops
    for mod, name in ((G, "run"), (G, "run_unjitted"),
                      (ISL, "run_local"), (ISL, "run_sharded"),
                      (ops, "ga_run_kernel")):
        assert not hasattr(mod, name), f"{mod.__name__}.{name} should be gone"

    spec = _spec(generations=30)
    old = G.run_scan(spec.ga_config(), spec.fitness_fn(), 30)
    new = ga.solve(spec, backend="reference")
    assert float(old.best_y) == new.best_fitness
    np.testing.assert_array_equal(np.asarray(old.best_x), new.best_x)
