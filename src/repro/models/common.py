"""Shared model substrate: parameter definitions, initializers, norms, RoPE.

Parameters are plain pytrees (nested dicts of jnp arrays).  A parallel tree of
`ParamDef`s carries shapes + logical sharding axes so the same model code can
(a) materialize real weights on any mesh, (b) produce ShapeDtypeStructs for
the multi-pod dry-run without allocating anything.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding as SH


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axes, same rank as shape
    init: str = "normal"              # normal | zeros | ones | embed
    dtype: Any = jnp.bfloat16
    scale: Optional[float] = None     # override stddev

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def stacked(d: ParamDef, layers: int) -> ParamDef:
    """Prepend a scan (layers) dim."""
    return dataclasses.replace(d, shape=(layers,) + d.shape,
                               axes=(None,) + d.axes)


def stack_tree(defs, layers: int):
    return jax.tree.map(lambda d: stacked(d, layers), defs, is_leaf=is_def)


# ---------------------------------------------------------------------------
# Materialization
# ---------------------------------------------------------------------------


def _stddev(d: ParamDef) -> float:
    if d.scale is not None:
        return d.scale
    # fan-in on the last-but-one dim for matrices, d_model for embeddings
    fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
    return 1.0 / math.sqrt(max(fan_in, 1))


def init_params(defs, key: jax.Array):
    """Materialize weights; respects the active mesh via NamedSharding."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, d in zip(keys, leaves):
        sharding = SH.named_sharding(d.axes, d.shape)
        if d.init == "zeros":
            v = jnp.zeros(d.shape, d.dtype)
        elif d.init == "ones":
            v = jnp.ones(d.shape, d.dtype)
        else:
            v = (jax.random.normal(k, d.shape, jnp.float32) *
                 _stddev(d)).astype(d.dtype)
        if sharding is not None:
            v = jax.device_put(v, sharding)
        out.append(v)
    return jax.tree.unflatten(treedef, out)


def abstract_params(defs):
    """ShapeDtypeStructs (with shardings when a mesh is active) — dry-run."""
    def mk(d: ParamDef):
        sh = SH.named_sharding(d.axes, d.shape)
        return jax.ShapeDtypeStruct(d.shape, d.dtype, sharding=sh)
    return jax.tree.map(mk, defs, is_leaf=is_def)


def axes_tree(defs):
    return jax.tree.map(lambda d: d.axes, defs, is_leaf=is_def)


def spec_tree(defs):
    return jax.tree.map(lambda d: SH.logical_spec(d.axes, d.shape), defs, is_leaf=is_def)


def param_count(defs) -> int:
    return sum(int(np.prod(d.shape)) for d in
               jax.tree.leaves(defs, is_leaf=is_def))


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------


def dense(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None) -> jax.Array:
    y = jnp.einsum("...d,df->...f", x, w)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Statistics in f32, application in the input dtype: keeping the wide
    multiply in f32 promotes the whole backward residual path (and its TP
    all-reduces) to f32 — 2x the collective bytes for no useful precision."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * (1.0 + w.astype(x.dtype))


def layernorm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (NeoX half-rotation convention)
# ---------------------------------------------------------------------------


def rope_tables(positions: jax.Array, head_dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables for integer positions: each (..., head_dim/2) f32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, hd); cos/sin: (B, S, hd/2) or (S, hd/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    o1 = xf1 * cos - xf2 * sin
    o2 = xf2 * cos + xf1 * sin
    return jnp.concatenate([o1, o2], axis=-1).astype(x.dtype)


def sinusoidal_pos(seq: int, dim: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings (S, D) f32."""
    pos = np.arange(seq)[:, None]
    i = np.arange(dim // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * i / dim)
    emb = np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)
    return jnp.asarray(emb, jnp.float32)


def softmax_fp32(scores: jax.Array, axis: int = -1) -> jax.Array:
    return jax.nn.softmax(scores.astype(jnp.float32), axis=axis)
