"""GA launcher — run the paper's experiments from the command line.

    PYTHONPATH=src python -m repro.launch.ga_run --problem F1 --n 32 --m 26
    PYTHONPATH=src python -m repro.launch.ga_run --problem F3 --backend fused
    PYTHONPATH=src python -m repro.launch.ga_run --problem F3 --islands 16
    PYTHONPATH=src python -m repro.launch.ga_run --problem F3 --islands 8 \
        --backend fused-islands --topology island_ring
    PYTHONPATH=src python -m repro.launch.ga_run --selection roulette \
        --backend reference --repeats 8
    PYTHONPATH=src python -m repro.launch.ga_run --problem F3 --islands 8 \
        --backend fused-islands --mesh auto --gens-per-epoch 4

Any registered backend (reference | fused | islands | fused-islands | eager
| auto — each a topology × executor composition) and any registered
selection scheme work from one spec; `--topology` pins the population
layout explicitly; `--mesh` shards the island axis over devices ("auto",
"4", "2x4", ... — see repro.launch.mesh.parse_mesh) with `lax.ppermute`
ring migration, bit-identical to the single-device run; `--gens-per-epoch`
folds generations inside one Pallas launch on the fused executors;
`--kernel` is kept as a deprecated alias for `--backend fused`.
"""

from __future__ import annotations

import argparse

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--problem", default="F3", choices=["F1", "F2", "F3"])
    ap.add_argument("--n", type=int, default=32)
    ap.add_argument("--m", type=int, default=20,
                    help="chromosome bits (2 variables of m/2 bits)")
    ap.add_argument("--k", type=int, default=100, help="generations")
    ap.add_argument("--mode", default="lut", choices=["lut", "arith"])
    ap.add_argument("--mutation-rate", type=float, default=0.02)
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "reference", "fused", "islands",
                             "fused-islands", "eager"])
    ap.add_argument("--topology", default="auto",
                    choices=["auto", "single", "island_ring"],
                    help="population layout (auto derives from --islands)")
    ap.add_argument("--selection", default="tournament",
                    help="registered selection scheme (see repro.ga.SELECTION)")
    ap.add_argument("--islands", type=int, default=0,
                    help=">1 runs the island model (implies an island_ring "
                         "backend)")
    ap.add_argument("--migration", default="ring", choices=["ring", "none"],
                    help="inter-island exchange (none = isolated ablation)")
    ap.add_argument("--migrate-every", type=int, default=16)
    ap.add_argument("--repeats", type=int, default=1,
                    help="independent replicas vmapped into one run")
    ap.add_argument("--mesh", default=None,
                    help="shard the island axis over devices: 'auto' (all), "
                         "'4', '2x4', ... (repro.launch.mesh.parse_mesh)")
    ap.add_argument("--gens-per-epoch", type=int, default=1,
                    help=">1 folds generations inside one Pallas launch "
                         "(fused executors; amortizes launch overhead)")
    ap.add_argument("--kernel", action="store_true",
                    help="deprecated: same as --backend fused")
    ap.add_argument("--chunk", type=int, default=0,
                    help="stream telemetry every CHUNK generations")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint/resume directory for chunked runs")
    ap.add_argument("--seed", type=int, default=1)
    args = ap.parse_args()

    from repro import ga

    backend = args.backend
    if args.kernel:
        backend = "fused"
    n_islands = max(args.islands, 1)
    mode = args.mode
    if backend in ("fused", "fused-islands") and mode == "lut":
        mode = "arith"   # the kernel's FFM is arithmetic-only

    spec = ga.paper_spec(args.problem, n=args.n, m=args.m, mode=mode,
                         mutation_rate=args.mutation_rate, seed=args.seed,
                         generations=args.k, n_islands=n_islands,
                         migrate_every=args.migrate_every,
                         n_repeats=args.repeats, selection=args.selection,
                         gens_per_epoch=args.gens_per_epoch,
                         topology=None if args.topology == "auto"
                         else args.topology,
                         migration=args.migration)

    mesh = None
    if args.mesh:
        from repro.launch.mesh import parse_mesh
        mesh = parse_mesh(args.mesh)
        print(f"mesh: {dict(mesh.shape)} ({mesh.devices.size} device(s))")

    if args.chunk > 0:
        eng = ga.Engine(spec, backend, mesh=mesh)
        last = None
        for tele in eng.run_chunked(chunk_generations=args.chunk,
                                    ckpt_dir=args.ckpt_dir):
            print(f"[{tele['backend']}] chunk {tele['chunk']}: "
                  f"{tele['gens_done']}/{tele['gens_total']} gens, "
                  f"best={tele['best_fitness']:.4f}, "
                  f"{tele['gens_per_s']:.0f} gens/s, "
                  f"{tele.get('migrations', 0)} migrations")
            last = tele
        if last is not None:
            print(f"decoded vars: {np.round(last['best_params'], 4)}")
        return

    out = ga.solve(spec, backend=backend, mesh=mesh)
    exec_name = out.extras.get("executor")
    topo_name = out.extras.get("topology")
    comp = f" ({exec_name} x {topo_name})" if exec_name and topo_name else ""
    print(f"backend: {out.backend}{comp}")
    if out.extras.get("sharded"):
        print(f"shards: {out.extras['n_shards']} "
              f"({spec.n_islands // out.extras['n_shards']} island(s) each)")
    if out.extras.get("migrations"):
        print(f"migrations: {out.extras['migrations']}")
    print(f"best fitness: {out.best_fitness:.4f}")
    print(f"decoded vars: {np.round(out.best_params, 4)}")
    traj = np.asarray(out.traj_best)
    if traj.size:
        print(f"trajectory (best, every 10 entries): {traj[::10]}")
    total_gens = out.generations * max(n_islands, args.repeats, 1)
    print(f"{out.wall_s*1e3:.1f} ms total -> {total_gens/out.wall_s:.0f} "
          f"generations/s (wall)")


if __name__ == "__main__":
    main()
