"""Durable scheduler journal: append-only JSONL under the checkpoint root.

The in-memory job queue is the one scheduler structure a process death
loses — pack checkpoints already persist the *state* of running work, but
nothing persisted *which* jobs existed and where they stood.  This module
closes that gap with the smallest durable structure that can: an
append-only JSONL event log (`journal.jsonl` next to the pack checkpoint
dirs) that `GAScheduler(recover=True)` replays on startup.

Events (one JSON object per line, `"ev"` discriminates):

  * ``submit``   — job id, serialized GASpec, backend/priority/deadline/
    retry budget.  Blackbox specs (callable fitness) are not serializable;
    they journal with ``"spec": null`` and replay marks any such job still
    pending as FAILED with a clear reason instead of silently dropping it.
  * ``dispatch`` — a unit (job ids + ckpt dir) started running.
  * ``park``     — the unit was preempted (membership frozen, ckpt on disk).
  * ``requeue``  — the unit went back to the queue for a retry.
  * ``state``    — a job reached failed / deadline_exceeded (with error).
  * ``done``     — a job finished, with a JSON-safe result subset.

Replay folds the log in order: the LAST event wins per job/unit, so a job
that was submitted, dispatched, parked, re-dispatched and finished replays
straight to its final result.  Jobs left queued / preempted / running
re-enqueue; their latest unit's checkpoint directory lets the pack resume
bit-identically from its last completed chunk.

Appends are flushed + fsynced — events are per state transition (not per
chunk), so durability costs nothing measurable.  A torn final line (the
process died mid-append) is treated as the end of the log, never an error.
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading
from typing import Any, Dict, List, Optional, Tuple

JOURNAL_NAME = "journal.jsonl"

# states a replayed job can rest in (mirrors serve.scheduler's constants;
# duplicated here so the journal stays import-light)
_TERMINAL = ("done", "failed", "deadline_exceeded")


def spec_to_json(spec) -> Optional[Dict[str, Any]]:
    """A GASpec as a JSON-safe dict, or None when it cannot round-trip (a
    blackbox callable fitness has no serialization)."""
    if getattr(spec, "fitness", None) is not None:
        return None
    d = dataclasses.asdict(spec)
    d.pop("fitness", None)
    return d


def spec_from_json(d: Dict[str, Any]):
    """Rebuild a GASpec from `spec_to_json` output (GASpec.__post_init__
    re-tuples bounds/mesh_axes, so JSON lists round-trip cleanly)."""
    from repro.ga.spec import GASpec   # lazy: journal reads stay light
    kw = dict(d)
    for key in ("bounds", "mesh_axes"):
        if kw.get(key) is not None:
            kw[key] = tuple(tuple(x) if isinstance(x, list) else x
                            for x in kw[key])
    return GASpec(**kw)


class SchedulerJournal:
    """Append-only JSONL writer (thread-safe, flush+fsync per event)."""

    def __init__(self, path: str):
        self.path = path
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._f = open(path, "a", encoding="utf-8")
        self._lock = threading.Lock()
        self._closed = False

    def append(self, event: Dict[str, Any]) -> None:
        line = json.dumps(event, separators=(",", ":"))
        with self._lock:
            if self._closed:
                return
            self._f.write(line + "\n")
            self._f.flush()
            os.fsync(self._f.fileno())

    def close(self) -> None:
        with self._lock:
            if not self._closed:
                self._closed = True
                self._f.close()


def read_journal(path: str) -> List[Dict[str, Any]]:
    """All well-formed events in order.  A torn tail line — the process
    died mid-append — ends the log; everything before it is trusted."""
    events: List[Dict[str, Any]] = []
    if not os.path.exists(path):
        return events
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                events.append(json.loads(line))
            except json.JSONDecodeError:
                break
    return events


@dataclasses.dataclass
class RecoveredJob:
    """One job's folded journal history."""

    job_id: str
    spec_json: Optional[Dict[str, Any]]
    backend: str = "auto"
    priority: int = 0
    deadline_s: Optional[float] = None
    max_retries: Optional[int] = None
    state: str = "queued"
    error: Optional[str] = None
    result: Optional[Dict[str, Any]] = None

    @property
    def terminal(self) -> bool:
        return self.state in _TERMINAL


def replay(events: List[Dict[str, Any]]) -> Tuple[
        Dict[str, RecoveredJob], Dict[int, Dict[str, Any]],
        Dict[str, int], int]:
    """Fold an event list into recovery state.

    Returns ``(jobs, units, job_unit, max_seq)``: every journaled job with
    its last known state/result, the last composition seen for each unit
    seq (job ids + ckpt dir), each job's latest unit seq, and the highest
    unit seq (so a recovering scheduler numbers new units past it)."""
    jobs: Dict[str, RecoveredJob] = {}
    units: Dict[int, Dict[str, Any]] = {}
    job_unit: Dict[str, int] = {}
    max_seq = -1
    for ev in events:
        t = ev.get("ev")
        if t == "submit":
            jobs[ev["job_id"]] = RecoveredJob(
                job_id=ev["job_id"], spec_json=ev.get("spec"),
                backend=ev.get("backend", "auto"),
                priority=int(ev.get("priority", 0)),
                deadline_s=ev.get("deadline_s"),
                max_retries=ev.get("max_retries"))
        elif t in ("dispatch", "park", "requeue"):
            seq = int(ev["seq"])
            max_seq = max(max_seq, seq)
            units[seq] = {"job_ids": list(ev["job_ids"]),
                          "ckpt_dir": ev.get("ckpt_dir")}
            state = {"dispatch": "running", "park": "preempted",
                     "requeue": "queued"}[t]
            for jid in ev["job_ids"]:
                job_unit[jid] = seq
                if jid in jobs and not jobs[jid].terminal:
                    jobs[jid].state = state
        elif t == "state":
            jid = ev["job_id"]
            if jid in jobs:
                jobs[jid].state = ev["state"]
                jobs[jid].error = ev.get("error")
        elif t == "done":
            jid = ev["job_id"]
            if jid in jobs:
                jobs[jid].state = "done"
                jobs[jid].result = ev.get("result")
    return jobs, units, job_unit, max_seq
