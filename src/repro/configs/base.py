"""ModelConfig — one dataclass describes every assigned architecture.

`reduced()` gives the small-same-family variant used by CPU smoke tests;
full configs are only ever lowered abstractly (dry-run).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


def _round_up(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | audio | vlm | ssm | hybrid
    n_layers: int
    d_model: int
    vocab: int
    # --- attention ---
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0                # 0 -> d_model // n_heads
    qkv_bias: bool = False           # qwen1.5
    qk_norm: bool = False            # gemma3
    rope_theta: float = 10_000.0
    # gemma3 local:global pattern — every `global_every`-th layer is global
    global_every: int = 0            # 0 = all layers global attention
    window_size: int = 1024
    rope_theta_local: float = 10_000.0
    # --- mlp ---
    d_ff: int = 0
    act: str = "silu"                # silu | gelu
    # --- moe ---
    n_experts: int = 0
    top_k: int = 0
    expert_ff: int = 0
    n_shared_experts: int = 0
    n_dense_layers: int = 0          # deepseek: first k layers dense
    moe_ff_dense: int = 0            # hidden dim of those dense layers
    capacity_factor: float = 1.25
    # --- MLA (deepseek) ---
    use_mla: bool = False
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # --- ssm / hybrid ---
    d_state: int = 0
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    attn_every: int = 0              # zamba2: shared attn block cadence
    # --- enc-dec (whisper) ---
    enc_layers: int = 0
    enc_seq: int = 1500              # stubbed conv frontend output length
    # --- vlm (pixtral) ---
    n_patches: int = 0               # stubbed ViT patch embeddings
    # --- misc ---
    norm: str = "rms"                # rms | ln
    tie_embeddings: bool = False
    vocab_pad_to: int = 256
    dtype: str = "bfloat16"
    # head padding for even 16-way TP (qwen 40 -> 48)
    pad_heads_to: int = 0

    # ----- derived -----
    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def n_heads_(self) -> int:
        if self.pad_heads_to:
            return _round_up(self.n_heads, self.pad_heads_to)
        return self.n_heads

    @property
    def n_kv_heads_(self) -> int:
        if self.pad_heads_to and self.n_kv_heads == self.n_heads:
            return self.n_heads_          # MHA-style: pad kv along with q
        return self.n_kv_heads

    @property
    def vocab_(self) -> int:
        return _round_up(self.vocab, self.vocab_pad_to)

    @property
    def jdtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """sub-quadratic decode: SSM, hybrid, or sliding-window-dominated."""
        return self.family in ("ssm", "hybrid") or self.global_every > 1

    def param_count(self) -> int:
        """Analytic parameter count (embedding + layers), for 6ND."""
        d, v = self.d_model, self.vocab_
        total = v * d * (1 if self.tie_embeddings else 2)
        if self.family in ("dense", "vlm"):
            total += self.n_layers * self._dense_layer_params()
        elif self.family == "moe":
            att = self._attn_params()
            moe = (3 * self.n_experts * d * self.expert_ff
                   + d * self.n_experts
                   + 3 * d * self.expert_ff * self.n_shared_experts)
            dense_l = att + 3 * d * self.moe_ff_dense
            total += self.n_dense_layers * dense_l
            total += (self.n_layers - self.n_dense_layers) * (att + moe)
        elif self.family == "audio":
            total += (self.enc_layers * self._dense_layer_params(causal=False)
                      + self.n_layers * self._dec_layer_params())
        elif self.family == "ssm":
            total += self.n_layers * self._ssm_layer_params()
        elif self.family == "hybrid":
            total += self.n_layers * self._ssm_layer_params()
            total += self._dense_layer_params()  # one shared block
        return total

    def active_param_count(self) -> int:
        """MoE: params touched per token (for 6·N_active·D)."""
        if self.family != "moe":
            return self.param_count()
        d, v = self.d_model, self.vocab_
        total = v * d * 2
        att = self._attn_params()
        act_moe = (3 * (self.top_k + self.n_shared_experts) * d * self.expert_ff
                   + d * self.n_experts)
        total += self.n_dense_layers * (att + 3 * d * self.moe_ff_dense)
        total += (self.n_layers - self.n_dense_layers) * (att + act_moe)
        return total

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.head_dim_
        if self.use_mla:
            return (d * self.q_lora_rank
                    + self.q_lora_rank * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
                    + d * self.kv_lora_rank + d * self.qk_rope_dim
                    + self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                    + self.n_heads * self.v_head_dim * d)
        return d * hd * (self.n_heads_ + 2 * self.n_kv_heads_) + self.n_heads_ * hd * d

    def _dense_layer_params(self, causal: bool = True) -> int:
        return self._attn_params() + 3 * self.d_model * self.d_ff

    def _dec_layer_params(self) -> int:
        # self-attn + cross-attn + plain mlp
        return 2 * self._attn_params() + 2 * self.d_model * self.d_ff

    def _ssm_layer_params(self) -> int:
        di = 2 * self.d_model
        gn = self.d_state  # n_groups = 1
        h = di // self.ssm_headdim
        in_proj = self.d_model * (2 * di + 2 * gn + h)
        return in_proj + di * self.d_model + 4 * (di + 2 * gn)


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests."""
    kw = dict(
        n_layers=min(cfg.n_layers, 2 if cfg.attn_every == 0 else cfg.attn_every + 1),
        d_model=128,
        vocab=512,
        d_ff=256 if cfg.d_ff else 0,
        head_dim=32 if cfg.n_heads else 0,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        pad_heads_to=0,
        vocab_pad_to=64,
    )
    if cfg.n_kv_heads == cfg.n_heads:
        kw["n_kv_heads"] = 4
    if cfg.family == "moe":
        kw.update(n_experts=4, top_k=2, expert_ff=64,
                  n_shared_experts=min(cfg.n_shared_experts, 1),
                  n_dense_layers=min(cfg.n_dense_layers, 1), moe_ff_dense=256)
        kw["n_layers"] = 3
    if cfg.use_mla:
        kw.update(q_lora_rank=64, kv_lora_rank=32, qk_nope_dim=32,
                  qk_rope_dim=16, v_head_dim=32)
    if cfg.d_state:
        kw.update(d_state=16, ssm_headdim=32, ssm_chunk=32)
    if cfg.attn_every:
        kw.update(attn_every=2, n_layers=4)
    if cfg.enc_layers:
        kw.update(enc_layers=2, enc_seq=64)
    if cfg.n_patches:
        kw.update(n_patches=16)
    if cfg.global_every:
        kw.update(global_every=3, window_size=16, n_layers=6)
    return dataclasses.replace(cfg, name=cfg.name + "-reduced", **kw)
