"""The indexed-gather selection lane vs the one-hot MXU lane.

The fused kernels grow a second, bit-identical way to realize tournament
selection: `sel_lane="gather"` reads fitness and splices winners through
dynamic indexing (`jnp.take`, O(N·V) working set) instead of one-hot
matmul contractions (O(N²)).  Because the one-hot matmuls were already
EXACT (uint32 split into 16-bit halves, f32 HIGHEST-precision dots), the
two lanes must agree bit-for-bit with each other and with the pure-jnp
reference on every shape — which is what this file pins, along with the
lifted N cap, the lane-aware rejection errors, the measured cross-lane
planner, and the eager backend's pooled host-fitness determinism.
"""

import os
import subprocess
import sys
import dataclasses

import numpy as np
import pytest

from repro import ga
from repro.core import ga as G


def _spec(**kw):
    base = dict(problem="F3", n=32, bits_per_var=8, mode="arith",
                mutation_rate=0.05, seed=7, generations=16,
                n_islands=2, migrate_every=4, gens_per_epoch=8)
    base.update(kw)
    return ga.GASpec(**base)


def _solve(spec, backend, **opt_kw):
    opts = ga.EngineOptions(cost_table=False, **opt_kw)
    return ga.solve(spec, backend=backend, options=opts)


# ---------------------------------------------------------------------------
# Bit-identity: gather == onehot == reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("problem", ["F1", "F2", "F3", "rastrigin:4"])
def test_lanes_bit_identical_to_reference(problem):
    """Both lanes of the fused resident epoch (gens_per_epoch > 1, ring
    migration in VMEM) reproduce the islands reference bit-for-bit."""
    spec = _spec(problem=problem)
    ref = _solve(spec, "islands")
    for lane in ("onehot", "gather"):
        res = _solve(dataclasses.replace(spec, sel_lane=lane),
                     "fused-islands")
        assert res.telemetry.plan.lane == lane
        assert res.best_fitness == ref.best_fitness, lane
        np.testing.assert_array_equal(np.asarray(res.best_x),
                                      np.asarray(ref.best_x),
                                      err_msg=lane)
        # resident launches sample the trajectory once per launch, the
        # reference once per generation — the final sample must agree
        assert res.traj_best[-1] == ref.traj_best[-1], lane


def test_lanes_bit_identical_with_stacked_repeats():
    """The replica axis (n_repeats > 1) rides both lanes identically."""
    spec = _spec(n_repeats=3, seed=5)
    ref = _solve(spec, "islands")
    for lane in ("onehot", "gather"):
        res = _solve(dataclasses.replace(spec, sel_lane=lane),
                     "fused-islands")
        assert res.best_fitness == ref.best_fitness, lane
        np.testing.assert_array_equal(
            np.asarray(res.telemetry.per_repeat.best),
            np.asarray(ref.telemetry.per_repeat.best), err_msg=lane)


def test_gather_lane_runs_past_the_onehot_cap():
    """N=2048 — impossible on the onehot lane — runs the fused kernel on
    the gather lane, and sel_lane='auto' resolves there on its own."""
    spec = ga.GASpec(problem="F1", n=2048, bits_per_var=8, mode="arith",
                     mutation_rate=0.02, seed=3, generations=4,
                     gens_per_epoch=2, n_islands=1)
    assert spec.resolved_sel_lane == "gather"
    res = _solve(spec, "fused", interpret=True)
    ref = _solve(spec, "reference")
    assert res.best_fitness == ref.best_fitness
    np.testing.assert_array_equal(np.asarray(res.best_x),
                                  np.asarray(ref.best_x))


def test_lanes_bit_identical_on_eight_fake_device_mesh():
    """Both lanes under the sharded ring (8 fake devices) agree with each
    other and the local islands reference (subprocess so the forced device
    count doesn't leak into the suite)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["REPRO_GA_COST_TABLE"] = "off"
import dataclasses, jax, numpy as np
from repro import ga
mesh = jax.make_mesh((8,), ("islands",))
spec = ga.GASpec(problem="F3", n=16, bits_per_var=8, mode="arith",
                 mutation_rate=0.02, seed=2, generations=16,
                 n_islands=8, migrate_every=4, gens_per_epoch=8)
ref = ga.solve(spec, backend="islands",
               options=ga.EngineOptions(cost_table=False))
for lane in ("onehot", "gather"):
    res = ga.solve(dataclasses.replace(spec, sel_lane=lane),
                   backend="fused-islands",
                   options=ga.EngineOptions(mesh=mesh, cost_table=False))
    assert res.telemetry.topology.n_shards == 8, res.telemetry.topology
    assert res.best_fitness == ref.best_fitness, lane
    np.testing.assert_array_equal(np.asarray(res.best_x),
                                  np.asarray(ref.best_x), err_msg=lane)
print("LANES_MESH_OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "LANES_MESH_OK" in r.stdout


# ---------------------------------------------------------------------------
# Lane resolution, rejection errors and the options override
# ---------------------------------------------------------------------------


def test_auto_lane_resolution_and_compile_key():
    assert _spec(n=64).resolved_sel_lane == "onehot"
    assert _spec(n=2048, n_islands=1).resolved_sel_lane == "gather"
    # the resolved lane is part of the compiled-runner identity
    k_on = _spec(sel_lane="onehot").compile_key()
    k_ga = _spec(sel_lane="gather").compile_key()
    assert k_on != k_ga


def test_onehot_pin_past_cap_rejected_with_actionable_error():
    with pytest.raises(ValueError, match="sel_lane='gather'"):
        _spec(n=2048, n_islands=1, sel_lane="onehot")
    # the options-level override flows through the same spec validation
    with pytest.raises(ValueError, match="sel_lane='gather'"):
        ga.Engine(_spec(n=2048, n_islands=1), "fused",
                  options=ga.EngineOptions(cost_table=False,
                                           sel_lane="onehot"))


def test_options_lane_override_reaches_the_kernel():
    spec = _spec()            # sel_lane defaults to "auto" -> onehot at N=32
    eng = ga.Engine(spec, "fused-islands",
                    options=ga.EngineOptions(cost_table=False,
                                             sel_lane="gather"))
    assert eng.backend.spec.sel_lane == "gather"
    assert eng.backend.topology.cfg.sel_lane == "gather"
    ref = _solve(spec, "islands")
    out = eng.run()
    assert out.best_fitness == ref.best_fitness
    assert out.telemetry.plan.lane == "gather"


def test_bad_lane_values_rejected():
    with pytest.raises(ValueError, match="sel_lane"):
        _spec(sel_lane="mxu")
    with pytest.raises(ValueError, match="sel_lane"):
        ga.EngineOptions(sel_lane="vpu")
    with pytest.raises(AssertionError, match="RESOLVED"):
        G.GAConfig(n=16, c=8, v=2, seed=1, sel_lane="auto")


def test_gather_lane_shrinks_the_vmem_estimate():
    """The planner's per-island working set drops from O(N²) to O(N·V)."""
    from repro.kernels import ga_step as K
    cfg = _spec(n=512).ga_config()
    on = K.resident_vmem_bytes(dataclasses.replace(cfg, sel_lane="onehot"), 1)
    ga_b = K.resident_vmem_bytes(dataclasses.replace(cfg, sel_lane="gather"),
                                 1)
    assert ga_b < on / 10     # 4·4·N² vs 4·6·N of selection scratch


# ---------------------------------------------------------------------------
# The measured cross-lane planner
# ---------------------------------------------------------------------------


def test_auto_spec_measured_plan_crosses_lanes():
    """With a cost table that rates the gather lane far above onehot, an
    'auto' spec's plan argmaxes ACROSS lanes, the telemetry shows the
    switch, and the run stays bit-identical to the reference."""
    from repro.autotune import runner as AR
    from repro.autotune import table as AT
    from repro.ga import compile_cache as CC

    spec = _spec()            # N=32: heuristic lane is onehot
    table = AT.CostTable(host=AT.host_fingerprint())
    for lane, rate in (("onehot", 10.0), ("gather", 1000.0)):
        for cand in AR.plan_candidates(spec, backend="fused-islands",
                                       sel_lane=lane):
            table.add(CC.plan_point(spec, executor="fused",
                                    mode=cand["mode"], n_shards=1,
                                    lane=cand["lane"]),
                      cand["gens_per_launch"], rate)
    eng = ga.Engine(spec, "fused-islands",
                    options=ga.EngineOptions(cost_table=table))
    plan = eng.backend.topology.plan
    assert plan["plan_source"] == "measured", plan
    assert plan["lane"] == "gather", plan
    assert eng.backend.topology.cfg.sel_lane == "gather"
    out = eng.run()
    assert out.telemetry.plan.lane == "gather"
    assert out.telemetry.plan.source == "measured"
    ref = _solve(spec, "islands")
    assert out.best_fitness == ref.best_fitness


def test_sweep_lanes_enumeration():
    from repro.autotune.runner import sweep_lanes
    assert sweep_lanes(_spec()) == ["onehot", "gather"]
    assert sweep_lanes(_spec(sel_lane="gather")) == ["gather"]
    assert sweep_lanes(_spec(n=2048, n_islands=1)) == ["gather"]


# ---------------------------------------------------------------------------
# Eager backend: population-parallel host fitness
# ---------------------------------------------------------------------------


def test_eager_pooled_fitness_is_deterministic():
    """fitness_workers > 1 splits the batch over a thread pool but keeps
    submission order, so results are bitwise identical to serial."""
    spec = ga.GASpec(problem="F3", n=32, bits_per_var=8, mode="arith",
                     mutation_rate=0.05, seed=9, generations=12,
                     jit_fitness=False)
    serial = ga.solve(spec, backend="eager",
                      options=ga.EngineOptions(cost_table=False))
    for workers in (2, 5):
        pooled = ga.solve(spec, backend="eager",
                          options=ga.EngineOptions(cost_table=False,
                                                   fitness_workers=workers))
        assert pooled.best_fitness == serial.best_fitness, workers
        np.testing.assert_array_equal(np.asarray(pooled.best_x),
                                      np.asarray(serial.best_x))
        np.testing.assert_array_equal(np.asarray(pooled.traj_best),
                                      np.asarray(serial.traj_best))


def test_fitness_workers_validation():
    with pytest.raises(ValueError, match="fitness_workers"):
        ga.EngineOptions(fitness_workers=0)
