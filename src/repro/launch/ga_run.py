"""GA launcher — run the paper's experiments from the command line.

    PYTHONPATH=src python -m repro.launch.ga_run --problem F1 --n 32 --m 26
    PYTHONPATH=src python -m repro.launch.ga_run --problem F3 --islands 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--problem", default="F3", choices=["F1", "F2", "F3"])
    ap.add_argument("--n", type=int, default=32)
    ap.add_argument("--m", type=int, default=20)
    ap.add_argument("--k", type=int, default=100)
    ap.add_argument("--mode", default="lut", choices=["lut", "arith"])
    ap.add_argument("--mutation-rate", type=float, default=0.02)
    ap.add_argument("--islands", type=int, default=0)
    ap.add_argument("--kernel", action="store_true",
                    help="use the fused Pallas generation kernel")
    ap.add_argument("--seed", type=int, default=1)
    args = ap.parse_args()

    from repro.core import fitness as F
    from repro.core import ga as G
    from repro.core import islands as ISL

    problem = F.PROBLEMS[args.problem]
    cfg = G.GAConfig(n=args.n, c=args.m // 2, v=2,
                     mutation_rate=args.mutation_rate, seed=args.seed,
                     mode=args.mode)
    fit = G.fitness_for_problem(problem, cfg)

    t0 = time.perf_counter()
    if args.kernel:
        from repro.kernels import ops
        spec = F.ArithSpec.for_problem(problem)
        icfg = ISL.IslandConfig(ga=cfg, n_islands=max(args.islands, 1))
        st = ISL.init_islands_fast(icfg)
        st, best = ops.ga_run_kernel(st, args.k, cfg=cfg, spec=spec)
        jax.block_until_ready(best)
        dt = time.perf_counter() - t0
        print(f"[kernel] best per island: {np.asarray(best)}")
    elif args.islands > 1:
        icfg = ISL.IslandConfig(ga=cfg, n_islands=args.islands)
        st, best = ISL.run_local(icfg, fit, max(1, args.k // icfg.migrate_every))
        dt = time.perf_counter() - t0
        print(f"[islands x{args.islands}] best: {best}")
    else:
        out = jax.jit(lambda: G.run(cfg, fit, args.k))()
        jax.block_until_ready(out.best_y)
        dt = time.perf_counter() - t0
        scale = 1.0
        if args.mode == "lut":
            scale = 2.0 ** F.build_tables(problem, args.m).frac_bits
        print(f"best fitness: {float(out.best_y)/scale:.4f}")
        print(f"decoded vars: {G.decode_best(out, cfg, problem.domain)}")
        print(f"trajectory (best/gen, every 10): "
              f"{np.asarray(out.traj_best)[::10]/scale}")
    gens = args.k * max(args.islands, 1)
    print(f"{dt*1e3:.1f} ms total -> {gens/dt:.0f} generations/s (CPU wall)")


if __name__ == "__main__":
    main()
