"""Full-parallel Genetic Algorithm — faithful JAX port of the paper's datapath.

One `generation()` call is the paper's 3-clock pipeline beat: it evaluates all
N fitness values, runs N tournaments, N/2 single-point crossovers and P
mutations, producing the next population — all as one fused tensor program
(the VPU lanes play the role of the N parallel hardware modules).

Chromosome layout: the paper packs x = px ‖ qx (m bits, two m/2-bit halves).
We generalize to V variables of c bits each, stored as uint32[N, V]
(V=2, c=m/2 reproduces the paper exactly; the paper itself notes more
variables need only "some adjustments on hardware architecture").

Module → code map (paper Sec. 3):
  FFM   -> fitness_fn (see core/fitness.py; LUT = faithful, arith = TPU-native)
  SM    -> tournament selection with per-slot LFSR pairs, MSB-truncated draws
  CM    -> mask-shift bitwise crossover, per-variable cut points (CMPQ1/CMPQ2)
  MM    -> XOR of the first P individuals with LFSR words
  SyncM -> the lax.scan over generations in `run_scan`
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fitness as F
from repro.core import lfsr


# Past this population size the onehot selection lane's (N, N) one-hot
# tournament matrices exceed a reasonable VMEM share — the gather lane
# (dynamic indexing, O(N·V)) has no such cap.
ONEHOT_MAX_N = 1024


@dataclasses.dataclass(frozen=True)
class GAConfig:
    n: int                       # population size N (even, paper uses 4..64)
    c: int                       # bits per variable (= m/2 for the paper)
    v: int = 2                   # number of variables packed per chromosome
    mutation_rate: float = 0.01  # MR; P = ceil(N * MR) individuals mutate
    minimize: bool = True        # SMMAXMIN
    steps_per_draw: int = 3      # LFSR clocks per generation (SyncM cadence)
    seed: int = 1234
    mode: str = "lut"            # "lut" (faithful ROMs) | "arith" (VPU)
    sel_lane: str = "onehot"     # "onehot" (MXU matmul gather) | "gather"
                                 # (VPU dynamic indexing); always resolved —
                                 # "auto" lives on GASpec, never here

    def __post_init__(self):
        assert self.n % 2 == 0, "N must be even (paper Sec. 2)"
        assert 1 <= self.c <= 31
        assert self.sel_lane in ("onehot", "gather"), (
            f"sel_lane={self.sel_lane!r}: GAConfig carries a RESOLVED lane "
            "('onehot' | 'gather'); 'auto' is resolved by GASpec")

    @property
    def m(self) -> int:
        return self.c * self.v

    @property
    def p(self) -> int:
        return max(1, math.ceil(self.n * self.mutation_rate))

    @property
    def idx_bits(self) -> int:
        return max(1, math.ceil(math.log2(self.n)))

    @property
    def cut_bits(self) -> int:
        return max(1, math.ceil(math.log2(self.c + 1)))

    @property
    def var_mask(self) -> int:
        return (1 << self.c) - 1


class GAState(NamedTuple):
    x: jax.Array          # uint32[N, V] population
    sel_lfsr: jax.Array   # uint32[2, N]   SMLFSR1/2 per selection slot
    cross_lfsr: jax.Array # uint32[V, N/2] CMPQLFSR per crossover submodule
    mut_lfsr: jax.Array   # uint32[V, N]   MMLFSR per mutation slot/variable
    k: jax.Array          # int32 generation counter


FitnessFn = Callable[[jax.Array], jax.Array]  # uint32[N, V] -> [N] (i32|f32)


# ---------------------------------------------------------------------------
# Fitness builders — thin wrappers over core.fitness.FitnessProgram
# ---------------------------------------------------------------------------


def make_lut_fitness(tables: F.LutTables) -> FitnessFn:
    """Faithful ROM-pipeline fitness over the whole chromosome matrix."""
    return lambda x: F.lut_fitness(x, tables)


def make_blackbox_fitness(fn: Callable[[jax.Array], jax.Array], c: int,
                          bounds) -> FitnessFn:
    """General V-variable fitness: decode each c-bit gene to its bound range
    and hand the (N, V) float matrix to `fn` (vectorized, jit-able)."""
    prog = F.compile_program(fitness=fn, bounds=bounds, bits_per_var=c)
    return prog.stage


def fitness_for_problem(problem, cfg: GAConfig) -> FitnessFn:
    """Fitness for a registry problem (name or ProblemDef) at cfg's V/c/mode."""
    name = problem.name if isinstance(problem, F.ProblemDef) else problem
    prog = F.compile_program(problem=name, n_vars=cfg.v, bits_per_var=cfg.c,
                             mode=cfg.mode, minimize=cfg.minimize)
    return prog.fitness(cfg.mode)


# ---------------------------------------------------------------------------
# State init
# ---------------------------------------------------------------------------


def init_state(cfg: GAConfig) -> GAState:
    """Seed every LFSR distinctly (the paper's CCseed) and draw the initial
    random population from a dedicated LFSR bank."""
    n, v = cfg.n, cfg.v
    total = 2 * n + v * (n // 2) + v * n + v * n  # sel + cross + mut + init
    s = lfsr.seeds(cfg.seed, total)
    sel = s[: 2 * n].reshape(2, n)
    cross = s[2 * n: 2 * n + v * (n // 2)].reshape(v, n // 2)
    mut = s[2 * n + v * (n // 2): 2 * n + v * (n // 2) + v * n].reshape(v, n)
    init_bank = s[-v * n:].reshape(n, v)
    # a few warmup clocks, then MSB-truncate to c bits per gene
    x = lfsr.truncate(lfsr.steps(init_bank, 8), cfg.c)
    return GAState(x=x, sel_lfsr=sel, cross_lfsr=cross, mut_lfsr=mut,
                   k=jnp.int32(0))


# ---------------------------------------------------------------------------
# The generation step (Algorithm 1, lines 3–14, fully parallel)
# ---------------------------------------------------------------------------


def _select(x, y, sel_lfsr, cfg: GAConfig):
    """SM: N parallel 2-way tournaments."""
    sel_lfsr, r = lfsr.draw(sel_lfsr, cfg.steps_per_draw)
    i1 = lfsr.truncate(r[0], cfg.idx_bits).astype(jnp.int32)
    i2 = lfsr.truncate(r[1], cfg.idx_bits).astype(jnp.int32)
    if cfg.n & (cfg.n - 1):  # non power-of-two N: fold into range
        i1 = i1 % cfg.n
        i2 = i2 % cfg.n
    y1, y2 = y[i1], y[i2]
    first_wins = jnp.where(cfg.minimize, y1 <= y2, y1 >= y2)
    w = jnp.where(first_wins[:, None], x[i1], x[i2])
    return w, sel_lfsr


def _crossover(w, cross_lfsr, cfg: GAConfig):
    """CM: N/2 parallel single-point crossovers, independent cut per variable.

    mask s = (2^c - 1) >> cut; offspring are (h1|t2, h2|t1) with
    h = w & ~s (head), t = w & s (tail) — paper Eqs. 12–20.
    """
    cross_lfsr, r = lfsr.draw(cross_lfsr, cfg.steps_per_draw)  # [V, N/2]
    cut = lfsr.truncate(r, cfg.cut_bits).astype(jnp.uint32)
    cut = jnp.minimum(cut, jnp.uint32(cfg.c))                  # clamp to c
    ones = jnp.uint32(cfg.var_mask)
    s = (ones >> cut).T                                        # [N/2, V]
    w1, w2 = w[0::2], w[1::2]                                  # [N/2, V]
    h1, t1 = w1 & ~s, w1 & s
    h2, t2 = w2 & ~s, w2 & s
    z1 = h1 | t2
    z2 = h2 | t1
    z = jnp.stack([z1, z2], axis=1).reshape(cfg.n, cfg.v)
    return z, cross_lfsr


def _mutate(z, mut_lfsr, cfg: GAConfig):
    """MM: XOR the first P offspring with LFSR words (paper Eq. 21 == XOR)."""
    mut_lfsr, r = lfsr.draw(mut_lfsr, cfg.steps_per_draw)      # [V, N]
    rbits = lfsr.truncate(r, cfg.c).T                          # [N, V]
    mut_row = (jnp.arange(cfg.n) < cfg.p)[:, None]
    return jnp.where(mut_row, z ^ rbits, z), mut_lfsr


def generation(state: GAState, cfg: GAConfig, fit: FitnessFn
               ) -> Tuple[GAState, jax.Array]:
    """One full GA generation. Returns (next_state, fitness_of_current_pop)."""
    y = fit(state.x)
    w, sel_lfsr = _select(state.x, y, state.sel_lfsr, cfg)
    z, cross_lfsr = _crossover(w, state.cross_lfsr, cfg)
    x_new, mut_lfsr = _mutate(z, state.mut_lfsr, cfg)
    return GAState(x_new, sel_lfsr, cross_lfsr, mut_lfsr, state.k + 1), y


# ---------------------------------------------------------------------------
# K-generation driver (SyncM analogue: one scan, no host round-trips)
# ---------------------------------------------------------------------------


class GARun(NamedTuple):
    state: GAState
    best_y: jax.Array      # [] best fitness ever seen
    best_x: jax.Array      # [V] its chromosome
    traj_best: jax.Array   # [K] per-generation population best
    traj_mean: jax.Array   # [K] per-generation population mean


GenerationFn = Callable[[GAState, GAConfig, FitnessFn],
                        Tuple[GAState, jax.Array]]


def run_scan(cfg: GAConfig, fit: FitnessFn, k_generations: int,
             state: Optional[GAState] = None,
             generation_fn: GenerationFn = None) -> GARun:
    """K-generation scan.  `generation_fn` swaps the operator pipeline
    (defaults to the paper's tournament/single-point/XOR `generation`).

    This is the reference *executor* of the engine (`repro.ga`); prefer
    `ga.solve(spec, backend="reference")` in new code."""
    if state is None:
        state = init_state(cfg)
    if generation_fn is None:
        generation_fn = generation

    neutral = jnp.float32(jnp.inf) if cfg.minimize else jnp.float32(-jnp.inf)

    def body(carry, _):
        st, by, bx = carry
        st2, y = generation_fn(st, cfg, fit)
        yf = y.astype(jnp.float32)
        idx = jnp.argmin(yf) if cfg.minimize else jnp.argmax(yf)
        gen_best = yf[idx]
        improved = gen_best < by if cfg.minimize else gen_best > by
        by2 = jnp.where(improved, gen_best, by)
        bx2 = jnp.where(improved, st.x[idx], bx)
        return (st2, by2, bx2), (gen_best, jnp.mean(yf))

    init = (state, neutral, jnp.zeros((cfg.v,), jnp.uint32))
    (st, by, bx), (tb, tm) = jax.lax.scan(body, init, None, length=k_generations)
    return GARun(st, by, bx, tb, tm)


def generation_with_y(state: GAState, y: jax.Array, cfg: GAConfig) -> GAState:
    """SM+CM+MM given externally-computed fitness — lets non-traceable
    fitness functions (e.g. 'train a model for 10 steps') drive the GA."""
    w, sel_lfsr = _select(state.x, y, state.sel_lfsr, cfg)
    z, cross_lfsr = _crossover(w, state.cross_lfsr, cfg)
    x_new, mut_lfsr = _mutate(z, state.mut_lfsr, cfg)
    return GAState(x_new, sel_lfsr, cross_lfsr, mut_lfsr, state.k + 1)


def run_eager(cfg: GAConfig, fit: FitnessFn, k_generations: int,
              state: Optional[GAState] = None,
              apply_ops_fn=None) -> GARun:
    """Python-loop driver for fitness functions that cannot be traced.
    The GA operators themselves stay jitted; only fitness runs eagerly.
    `apply_ops_fn(state, y, cfg) -> state` swaps the SM/CM/MM pipeline
    (defaults to `generation_with_y`)."""
    if state is None:
        state = init_state(cfg)
    step = jax.jit(functools.partial(apply_ops_fn or generation_with_y,
                                     cfg=cfg))
    sign = 1.0 if cfg.minimize else -1.0
    best_y, best_x = np.inf, np.zeros((cfg.v,), np.uint32)
    tb, tm = [], []
    for _ in range(k_generations):
        y = np.asarray(fit(state.x), np.float32)
        idx = int(np.argmin(sign * y))
        if sign * y[idx] < sign * best_y or not np.isfinite(best_y):
            best_y = float(y[idx])
            best_x = np.asarray(state.x[idx])
        tb.append(float(y[idx]))
        tm.append(float(y.mean()))
        state = step(state, jnp.asarray(y))
    return GARun(state, jnp.float32(best_y), jnp.asarray(best_x),
                 jnp.asarray(tb), jnp.asarray(tm))


def decode_best(run_out: GARun, cfg: GAConfig, domain) -> np.ndarray:
    """Decode the best chromosome's genes to real values."""
    u = np.asarray(run_out.best_x) & cfg.var_mask
    return np.asarray(F.decode(jnp.asarray(u), cfg.c, domain))
