"""Minimal stdlib /metrics endpoint for the GA serving telemetry.

`GA_METRICS` (repro.serve.engine) aggregates `Engine.run_chunked` telemetry
per job; this module makes that snapshot scrapeable before a full RPC stack
lands: a `http.server` daemon thread rendering the registry in Prometheus
text exposition format.

    from repro.serve.metrics_http import start_metrics_server
    server = start_metrics_server(9100)          # or 0 for an ephemeral port
    ... run GA jobs (serve.engine.run_ga_job) ...
    server.shutdown()

Endpoints: `/metrics` (Prometheus text, version 0.0.4) and `/healthz`.
Opt-in from the CLI with `repro.launch.ga_run --metrics-port PORT`.
"""

from __future__ import annotations

import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

_PREFIX = "repro_ga"

# per-job numeric gauges: (metrics()-dict key, prometheus suffix, help)
_JOB_GAUGES = (
    ("generations_done", "generations_done", "Generations completed"),
    ("generations_total", "generations_total", "Generations requested"),
    ("chunks", "chunks", "Telemetry chunks recorded"),
    ("generations_per_s", "generations_per_s", "Generations per second"),
    ("islands", "islands", "Concurrently evolving populations"),
    ("shards", "shards", "Mesh shards the island axis spans"),
    ("generations_per_s_per_shard", "generations_per_s_per_shard",
     "Island-generations per second per mesh shard"),
    ("best_fitness", "best_fitness", "Best fitness seen (real units)"),
    ("migration_count", "migrations", "Ring migrations performed"),
    ("n_vars", "n_vars", "Decoded variable count V"),
    ("wall_s", "wall_seconds", "Wall-clock seconds spent"),
)

_FLEET_GAUGES = (
    ("job_count", "jobs", "GA jobs known to the registry"),
    ("jobs_done", "jobs_done", "GA jobs finished successfully"),
    ("generations_total", "fleet_generations", "Generations done, all jobs"),
    ("migrations_total", "fleet_migrations", "Migrations, all jobs"),
)


def _esc(v) -> str:
    return str(v).replace("\\", r"\\").replace('"', r'\"').replace("\n", r"\n")


def render_prometheus(snapshot: dict) -> str:
    """Serialize a `GAMetricsRegistry.metrics()` snapshot as Prometheus
    text exposition format (one gauge family per numeric job stat, the job
    identity carried in labels)."""
    lines = []
    jobs = snapshot.get("jobs", {})

    def label_str(j):
        return (f'job_id="{_esc(j["job_id"])}",backend="{_esc(j["backend"])}"'
                f',problem="{_esc(j["problem"])}"')

    for key, suffix, help_ in _JOB_GAUGES:
        name = f"{_PREFIX}_{suffix}"
        lines.append(f"# HELP {name} {help_}")
        lines.append(f"# TYPE {name} gauge")
        for j in jobs.values():
            val = j.get(key)
            if val is None:
                continue
            lines.append(f"{name}{{{label_str(j)}}} {float(val):g}")
    # job status as a one-hot info gauge
    name = f"{_PREFIX}_job_status"
    lines.append(f"# HELP {name} Job state (1 for the current status label)")
    lines.append(f"# TYPE {name} gauge")
    for j in jobs.values():
        lines.append(
            f'{name}{{{label_str(j)},status="{_esc(j["status"])}"}} 1')
    for key, suffix, help_ in _FLEET_GAUGES:
        name = f"{_PREFIX}_{suffix}"
        lines.append(f"# HELP {name} {help_}")
        lines.append(f"# TYPE {name} gauge")
        lines.append(f"{name} {float(snapshot.get(key, 0)):g}")
    return "\n".join(lines) + "\n"


def start_metrics_server(port: int = 0, registry=None,
                         host: str = "0.0.0.0") -> ThreadingHTTPServer:
    """Serve `registry` (default: the process-global GA_METRICS) at
    /metrics on a daemon thread.  Returns the server; its bound port is
    `server.server_address[1]` (useful with port=0), stop with
    `server.shutdown()`."""
    if registry is None:
        from repro.serve.engine import GA_METRICS
        registry = GA_METRICS

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):  # noqa: N802  (http.server API)
            if self.path.split("?")[0] not in ("/metrics", "/healthz", "/"):
                self.send_error(404)
                return
            if self.path.startswith("/healthz"):
                body = b"ok\n"
                ctype = "text/plain"
            else:
                body = render_prometheus(registry.metrics()).encode()
                ctype = "text/plain; version=0.0.4; charset=utf-8"
            self.send_response(200)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):   # keep scrapes out of stdout
            pass

    server = ThreadingHTTPServer((host, port), Handler)
    thread = threading.Thread(target=server.serve_forever,
                              name="ga-metrics-http", daemon=True)
    thread.start()
    return server
