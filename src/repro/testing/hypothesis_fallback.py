"""`hypothesis` with a deterministic fallback.

The property tests use a small slice of the hypothesis API (`given`,
`settings`, `strategies.integers/floats/booleans/lists`).  Some environments
(including the reference container) do not ship hypothesis; importing it at
test-module top level then kills collection for the whole file.  This module
re-exports the real library when present and otherwise provides a minimal
shim that replays each property test over a fixed number of pseudo-random
examples drawn from a per-test deterministic seed — weaker than hypothesis
(no shrinking, no database) but the invariants still get exercised.

Usage in tests:

    from repro.testing.hypothesis_fallback import given, settings, st
"""

from __future__ import annotations

import zlib

try:  # pragma: no cover - exercised only where hypothesis is installed
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import numpy as _np

    HAVE_HYPOTHESIS = False
    _DEFAULT_EXAMPLES = 20

    class _Strategy:
        def example(self, rng):  # pragma: no cover - interface
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, lo, hi):
            self.lo, self.hi = int(lo), int(hi)

        def example(self, rng):
            return int(rng.integers(self.lo, self.hi + 1))

    class _Floats(_Strategy):
        def __init__(self, lo, hi):
            self.lo, self.hi = float(lo), float(hi)

        def example(self, rng):
            return float(rng.uniform(self.lo, self.hi))

    class _Booleans(_Strategy):
        def example(self, rng):
            return bool(rng.integers(0, 2))

    class _Lists(_Strategy):
        def __init__(self, elem, min_size=0, max_size=10):
            self.elem = elem
            self.min_size, self.max_size = int(min_size), int(max_size)

        def example(self, rng):
            size = int(rng.integers(self.min_size, self.max_size + 1))
            return [self.elem.example(rng) for _ in range(size)]

    class _StrategiesModule:
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Floats(min_value, max_value)

        @staticmethod
        def booleans():
            return _Booleans()

        @staticmethod
        def lists(elements, min_size=0, max_size=10, **_kw):
            return _Lists(elements, min_size=min_size, max_size=max_size)

    st = _StrategiesModule()

    def settings(max_examples=_DEFAULT_EXAMPLES, **_kw):
        def deco(fn):
            fn._fallback_max_examples = max_examples
            return fn

        return deco

    def given(*strategies):
        def deco(fn):
            n_examples = getattr(fn, "_fallback_max_examples",
                                 _DEFAULT_EXAMPLES)

            def wrapper():
                # per-test deterministic stream: same examples on every run
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = _np.random.default_rng(seed)
                for _ in range(n_examples):
                    drawn = [s.example(rng) for s in strategies]
                    fn(*drawn)

            # NOT functools.wraps: that sets __wrapped__ and pytest would
            # then introspect the original signature and demand fixtures
            # for the drawn parameters.
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco
