"""Process-wide compiled-runner cache for GA engine backends.

Each topology used to keep its jitted segment runners in a per-instance
dict, so two Engines built from identical specs each traced and compiled
their own runners — fine for a library, wasteful for a serving stack where
repeat traffic has the same handful of spec *shapes*.  This module hoists
those dicts into one process-global cache keyed by `GASpec.compile_key()`
(the spec's trace-shape identity: problem, V, N, encoding, operators,
islands, gens_per_epoch, topology, migration — everything except seed /
generations / n_repeats) plus the backend composition and mesh fingerprint.

A hit returns the SAME `jax.jit` callable the first Engine compiled, so
jax's own jit cache short-circuits tracing entirely — the second submission
of an identical spec shape pays neither trace nor compile.  Safe because
`cfg.seed` is consumed only by `init_state` (never inside a traced runner
body), so runners are seed-independent by construction.

Counters (`hits` / `misses` / `evictions`) are exported through the serving
scheduler's `/metrics` gauges and asserted by tests; `RUNNER_CACHE` is the
global instance.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Callable, Dict, Hashable, Optional, Tuple


def mesh_fingerprint(mesh) -> Optional[tuple]:
    """Hashable identity of a mesh: axis names, shape and device ids (two
    meshes over the same devices in the same layout compile identically)."""
    if mesh is None:
        return None
    return (tuple(mesh.axis_names),
            tuple(int(mesh.shape[a]) for a in mesh.axis_names),
            tuple(int(d.id) for d in mesh.devices.flat))


class CompileCache:
    """Thread-safe LRU of compiled segment runners with hit/miss counters."""

    def __init__(self, max_entries: int = 128):
        self._lock = threading.Lock()
        self._entries: "OrderedDict[Hashable, Any]" = OrderedDict()
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get_or_build(self, key: Hashable, builder: Callable[[], Any]) -> Any:
        with self._lock:
            if key in self._entries:
                self._entries.move_to_end(key)
                self.hits += 1
                return self._entries[key]
        # build outside the lock: builders wrap jax.jit (lazy, cheap) but may
        # trace eagerly in the future; a racing duplicate build is harmless —
        # first writer wins and both callers get a working runner
        fn = builder()
        with self._lock:
            if key in self._entries:
                self._entries.move_to_end(key)
                self.hits += 1
                return self._entries[key]
            self.misses += 1
            self._entries[key] = fn
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)
                self.evictions += 1
            return fn

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"entries": len(self._entries), "hits": self.hits,
                    "misses": self.misses, "evictions": self.evictions}

    def reset(self) -> None:
        """Drop every entry and zero the counters (tests)."""
        with self._lock:
            self._entries.clear()
            self.hits = self.misses = self.evictions = 0


RUNNER_CACHE = CompileCache()


def runner_key(spec, topology_name: str, executor_name: str,
               interpret, mesh, *parts: Hashable) -> Tuple:
    """Cache key for one compiled segment runner.

    `spec.n_repeats` rides along because the runner closures branch on the
    R==1 vs stacked layout (not just shapes); `parts` carries runner-local
    knobs (gens, solo flag, resident interval count, ...)."""
    return (spec.compile_key(), spec.n_repeats, topology_name,
            executor_name, interpret, mesh_fingerprint(mesh)) + parts


def stage_fingerprint(spec) -> str:
    """Problem-stage kind for autotune cost-table keying: registry problems
    are identified by name (their decode + arith stage shape is a pure
    function of it), blackboxes collapse to their variable count — two
    different user callables with the same V share timings, which is the
    right granularity for a *launch-shape* cost model."""
    if spec.problem is not None:
        return f"{spec.problem}:v{spec.v}"
    return f"blackbox:v{spec.v}"


def plan_point(spec, *, executor: str, mode: str, n_shards: int,
               lane: Optional[str] = None) -> dict:
    """The autotune cost-table identity of one epoch-plan candidate (the
    fields of `repro.autotune.table.POINT_FIELDS`).  Shares this module's
    shape-identity discipline: everything that changes the compiled launch
    is in the key, seed/generations/n_repeats are not.  `lane` is the
    selection lane the candidate runs on (defaults to the spec's resolved
    lane — pass the candidate's own "lane" when planning across lanes)."""
    i_local = max(1, spec.n_islands // max(1, n_shards))
    return {"executor": executor, "mode": mode, "migration": spec.migration,
            "n": spec.n, "i_local": i_local, "c": spec.bits_per_var,
            "stage": stage_fingerprint(spec), "shards": n_shards,
            "E": spec.migrate_every,
            "lane": spec.resolved_sel_lane if lane is None else lane}
