"""LM substrate sanity benchmarks (reduced configs, CPU): train step
tokens/s and decode tokens/s. Full-scale numbers live in the dry-run
roofline (EXPERIMENTS.md §Roofline)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.ga_common import time_call
from repro.configs import get_config, reduced
from repro.models import common as C
from repro.models import lm as LM
from repro.optim import adamw as OPT
from repro.train import step as TS

B, S = 4, 128


def run():
    rows = []
    for arch in ("minitron-8b", "mamba2-1.3b", "deepseek-v3-671b"):
        cfg = reduced(get_config(arch))
        defs = LM.model_defs(cfg, max_seq=S)
        params = C.init_params(defs, jax.random.key(0))
        opt = OPT.init(params, OPT.AdamWConfig())
        ts = jax.jit(TS.make_train_step(cfg))
        batch = {"tokens": jnp.ones((B, S), jnp.int32),
                 "labels": jnp.ones((B, S), jnp.int32)}
        dt, _ = time_call(lambda: ts(params, opt, batch), iters=3)
        rows.append((f"train_step_{arch}-reduced", dt * 1e6,
                     f"tokens_per_s={B*S/dt:.0f}"))
    return rows
