"""Jitted public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute with interpret=True — the kernel
body runs in Python/XLA for correctness validation; on TPU they compile to
Mosaic.  `interpret` is auto-detected from the backend unless forced.
"""

from __future__ import annotations

import functools
import warnings
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.fitness import ArithSpec
from repro.core.ga import GAConfig, GAState
from repro.kernels import ga_step as _ga_step
from repro.kernels import lfsr_kernel as _lfsr


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


@functools.partial(jax.jit, static_argnames=("steps", "interpret"))
def lfsr_advance(state: jax.Array, steps: int,
                 interpret: Optional[bool] = None) -> jax.Array:
    return _lfsr.lfsr_advance_kernel(state, steps,
                                     interpret=_auto_interpret(interpret))


def ga_generation(x, sel, cross, mut, *, cfg: GAConfig, spec: ArithSpec,
                  interpret: Optional[bool] = None, gens: int = 1):
    """Fused GA generation(s) over islands. See kernels/ga_step.py.
    gens > 1 keeps the GA state VMEM-resident between generations."""
    fn = functools.partial(_ga_step.ga_generation_kernel, cfg=cfg, spec=spec,
                           interpret=_auto_interpret(interpret), gens=gens)
    return jax.jit(fn)(x, sel, cross, mut)


def ga_run_kernel(states: GAState, k_generations: int, *, cfg: GAConfig,
                  spec: ArithSpec, interpret: Optional[bool] = None):
    """Scan the fused kernel K generations over stacked islands.

    states: island-stacked GAState (leading dim I). Returns
    (final states, best_y[I] over the run).

    Deprecated entry-point shim — use `repro.ga.solve(spec,
    backend="fused")` (or "fused-islands" for migrating islands).
    """
    warnings.warn(
        "repro.kernels.ops.ga_run_kernel is a deprecated entry point; use "
        "repro.ga.solve(spec, backend='fused') instead",
        DeprecationWarning, stacklevel=2)
    interp = _auto_interpret(interpret)

    @jax.jit
    def go(states):
        def body(carry, _):
            x, sel, cross, mut, best = carry
            x2, sel2, cross2, mut2, y = _ga_step.ga_generation_kernel(
                x, sel, cross, mut, cfg=cfg, spec=spec, interpret=interp)
            gb = jnp.min(y, axis=1) if cfg.minimize else jnp.max(y, axis=1)
            best = jnp.minimum(best, gb) if cfg.minimize else jnp.maximum(best, gb)
            return (x2, sel2, cross2, mut2, best), None

        i = states.x.shape[0]
        neutral = jnp.full((i,), jnp.inf if cfg.minimize else -jnp.inf, jnp.float32)
        init = (states.x, states.sel_lfsr, states.cross_lfsr, states.mut_lfsr, neutral)
        (x, sel, cross, mut, best), _ = jax.lax.scan(
            body, init, None, length=k_generations)
        return GAState(x, sel, cross, mut, states.k + k_generations), best

    return go(states)
