"""The topology × executor decomposition: fused×island_ring is bit-identical
to reference×island_ring, replicas vmap outside the island axis, migration
math is shared with repro.core.islands, and serve-side GA job telemetry."""

import dataclasses
import warnings

import numpy as np
import pytest

from repro import ga
from repro.core import islands as ISL


def _spec(**kw):
    base = dict(problem="F3", n=32, bits_per_var=10, mode="arith",
                mutation_rate=0.05, seed=11, generations=15,
                n_islands=4, migrate_every=5)
    base.update(kw)
    return ga.GASpec(**base)


def _segment(spec, backend, gens):
    eng = ga.Engine(spec, backend)
    return eng.backend.segment(eng.init_state(), gens)


# ---------------------------------------------------------------------------
# Acceptance: the fused Pallas executor under the island ring is bit-identical
# to the reference executor under the island ring (same seeds, same migration)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("problem", ["F1", "F2", "F3"])
def test_fused_islands_bit_identical_to_reference_islands(problem):
    spec = _spec(problem=problem)
    seg_r = _segment(spec, "islands", 15)
    seg_f = _segment(spec, "fused-islands", 15)
    # island-stacked populations and every LFSR bank after 3 migration
    # epochs: bit-exact (migration runs between kernel launches on the
    # same elite/worst decisions)
    for field in ("x", "sel_lfsr", "cross_lfsr", "mut_lfsr"):
        np.testing.assert_array_equal(np.asarray(getattr(seg_f.state, field)),
                                      np.asarray(getattr(seg_r.state, field)),
                                      err_msg=field)
    np.testing.assert_array_equal(seg_f.traj_best, seg_r.traj_best)
    np.testing.assert_array_equal(seg_f.best_x, seg_r.best_x)
    assert seg_f.best_y == seg_r.best_y
    assert seg_f.extras["migrations"] == seg_r.extras["migrations"] == 3
    assert seg_f.extras["executor"] == "fused"
    assert seg_r.extras["executor"] == "reference"
    assert seg_f.extras["topology"] == seg_r.extras["topology"] == "island_ring"


def test_fused_islands_end_to_end_solve():
    """`ga.solve(spec, backend="fused-islands")` runs the Pallas step kernel
    under an island ring with migration and converges on the paper problem."""
    spec = _spec(generations=40, migrate_every=8)
    r = ga.solve(spec, backend="fused-islands")
    assert r.backend == "fused-islands"
    assert r.extras["migrations"] == 5
    assert np.isfinite(r.best_fitness) and r.best_fitness < 3.0
    assert r.generations == 40
    assert len(r.traj_best) == 5   # telemetry unit = migration epoch


# ---------------------------------------------------------------------------
# Replica axis outside the island axis (n_repeats × n_islands)
# ---------------------------------------------------------------------------


def test_islands_n_repeats_per_replica_bests():
    solo = ga.solve(_spec(), backend="islands")
    rep = ga.solve(_spec(n_repeats=3), backend="islands")
    per = rep.extras["per_repeat_best"]
    assert per.shape == (3,)
    # replica 0 re-runs the n_repeats=1 island stack bit-exactly
    assert float(per[0]) == solo.best_fitness
    assert rep.best_fitness == float(np.min(per))
    # replicas are seeded distinctly — not all identical
    assert len(np.unique(per)) > 1


def test_fused_islands_n_repeats_matches_reference():
    spec = _spec(n_repeats=2, generations=10)
    r_ref = ga.solve(spec, backend="islands")
    r_fus = ga.solve(spec, backend="fused-islands")
    np.testing.assert_array_equal(r_ref.extras["per_repeat_best"],
                                  r_fus.extras["per_repeat_best"])
    assert r_ref.best_fitness == r_fus.best_fitness


# ---------------------------------------------------------------------------
# Shared migration math: the engine's island_ring == core/islands.py
# ---------------------------------------------------------------------------


def test_islands_backend_state_matches_run_local_shim():
    spec = _spec()
    icfg = ISL.IslandConfig(ga=spec.ga_config(), n_islands=4, migrate_every=5)
    with pytest.warns(DeprecationWarning, match="deprecated entry point"):
        old_states, _best = ISL.run_local(icfg, spec.fitness_fn(), epochs=3)
    seg = _segment(spec, "islands", 15)
    for a, b in zip(old_states, seg.state):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_migration_none_ablation():
    """migration='none' evolves isolated islands: epochs still chunk the
    run but no elites are exchanged."""
    ring = ga.solve(_spec(), backend="islands")
    none = ga.solve(_spec(migration="none"), backend="islands")
    assert none.extras["migrations"] == 0
    assert ring.extras["migrations"] == 3
    assert np.isfinite(none.best_fitness)


# ---------------------------------------------------------------------------
# Spec-level topology plumbing
# ---------------------------------------------------------------------------


def test_topology_field_validation():
    assert _spec().effective_topology == "island_ring"
    assert _spec(n_islands=1).effective_topology == "single"
    assert _spec(n_islands=1, topology="auto").topology is None
    with pytest.raises(ValueError, match="inconsistent"):
        _spec(topology="single")           # n_islands=4
    with pytest.raises(ValueError, match="n_islands > 1"):
        _spec(n_islands=1, topology="island_ring")
    with pytest.raises(ValueError, match="topology must be"):
        _spec(topology="torus")
    with pytest.raises(ValueError, match="migration must be"):
        _spec(migration="broadcast")


def test_auto_and_fallback_routing():
    # auto on CPU routes island specs to the reference×island_ring composition
    assert ga.resolve_backend(_spec()) == "islands"
    # fused-islands falls back to islands when the kernel can't run (lut FFM)
    lut = _spec(mode="lut")
    assert ga.capability_matrix(lut)["fused-islands"] is not None
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        r = ga.solve(lut, backend="fused-islands")
    assert r.backend == "islands"
    assert any("falling back" in str(x.message) for x in w)
    # pinned single topology keeps island backends off the table
    single = _spec(n_islands=1)
    caps = ga.capability_matrix(single)
    assert caps["reference"] is None
    assert caps["islands"] is None        # permissive: 1-island ring runs
    pinned = _spec(n_islands=1, topology="single")
    assert ga.capability_matrix(pinned)["islands"] is not None


def test_chunked_checkpoint_resume_on_islands(tmp_path):
    spec = _spec(generations=20, migrate_every=5)
    ckpt = str(tmp_path / "isl_ck")
    full = list(ga.Engine(spec, "islands").run_chunked(chunk_generations=5))
    assert [t["gens_done"] for t in full] == [5, 10, 15, 20]
    assert full[-1]["migrations"] == 4

    it = ga.Engine(spec, "islands").run_chunked(chunk_generations=5,
                                                ckpt_dir=ckpt)
    next(it), next(it)     # 2 epochs, then "crash"
    del it
    resumed = list(ga.Engine(spec, "islands").run_chunked(
        chunk_generations=5, ckpt_dir=ckpt))
    assert [t["gens_done"] for t in resumed] == [15, 20]
    assert resumed[-1]["best_fitness"] == full[-1]["best_fitness"]
    assert resumed[-1]["migrations"] == 4


# ---------------------------------------------------------------------------
# Serve-side GA job telemetry
# ---------------------------------------------------------------------------


def test_serve_ga_job_metrics():
    from repro.serve.engine import GAMetricsRegistry, run_ga_job

    reg = GAMetricsRegistry()
    spec = _spec(generations=10, migrate_every=5)
    out = run_ga_job(spec, backend="islands", job_id="job-a",
                     chunk_generations=5, registry=reg)
    assert out["status"] == "done"
    assert out["backend"] == "islands"
    assert out["generations_done"] == 10
    assert out["migration_count"] == 2
    assert out["generations_per_s"] > 0
    assert len(out["best_fitness_trajectory"]) == 2
    assert out["best_fitness"] == min(out["best_fitness_trajectory"])

    snap = reg.metrics()
    assert snap["job_count"] == 1 and snap["jobs_done"] == 1
    assert snap["migrations_total"] == 2
    assert snap["generations_total"] == 10
    assert "job-a" in snap["jobs"]
