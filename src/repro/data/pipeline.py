"""Data pipeline: deterministic synthetic LM token streams + mmap'd binary
corpora, per-host sharding, background prefetch.

Synthetic mode generates a stationary Markov-ish token process (so CE loss
has learnable structure — integration tests assert the loss drops), seeded
per (host, step) so every host of a multi-pod job reads a disjoint stream
deterministically, and a restart at step k reproduces the same batch k
(checkpoint-exactness).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    n_hosts: int = 1
    host_id: int = 0
    seed: int = 0
    kind: str = "synthetic"        # synthetic | mmap
    path: Optional[str] = None     # for mmap: flat int32 token file
    prefetch: int = 2

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


def _synthetic_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Markov chain over a small state space embedded in the vocab."""
    rng = np.random.default_rng(
        np.uint64(cfg.seed) * np.uint64(1_000_003)
        + np.uint64(step) * np.uint64(65_537) + np.uint64(cfg.host_id))
    b, s, v = cfg.host_batch, cfg.seq_len, cfg.vocab
    period = min(64, v - 1)
    base = rng.integers(0, period, size=(b, 1), dtype=np.int64)
    idx = np.arange(s + 1)[None, :]
    walk = (base + idx) % period
    noise = rng.integers(0, v, size=(b, s + 1))
    take_noise = rng.random((b, s + 1)) < 0.1
    toks = np.where(take_noise, noise, walk).astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def _mmap_batch(cfg: DataConfig, step: int, data: np.ndarray
                ) -> Dict[str, np.ndarray]:
    b, s = cfg.host_batch, cfg.seq_len
    n_tokens = data.shape[0]
    per_step = cfg.global_batch * (s + 1)
    start = (step * per_step + cfg.host_id * cfg.host_batch * (s + 1)) \
        % max(n_tokens - per_step - 1, 1)
    flat = data[start: start + b * (s + 1)]
    if flat.shape[0] < b * (s + 1):
        flat = np.resize(flat, b * (s + 1))
    toks = flat.reshape(b, s + 1).astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class DataIterator:
    """Step-indexed iterator with background prefetch."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step
        self._mmap = None
        if cfg.kind == "mmap":
            assert cfg.path, "mmap mode needs path"
            self._mmap = np.memmap(cfg.path, dtype=np.int32, mode="r")
        self._q: "queue.Queue" = queue.Queue(maxsize=cfg.prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        if self.cfg.kind == "synthetic":
            return _synthetic_batch(self.cfg, step)
        return _mmap_batch(self.cfg, step, self._mmap)

    def _worker(self):
        s = self.step
        while not self._stop.is_set():
            b = self.batch_at(s)
            while not self._stop.is_set():
                try:
                    self._q.put((s, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            s += 1

    def __next__(self) -> Dict[str, np.ndarray]:
        s, b = self._q.get()
        self.step = s + 1
        return b

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def close(self):
        self._stop.set()
