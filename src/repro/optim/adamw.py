"""Sharded AdamW with optional 8-bit (blockwise-quantized) moment states.

Optimizer states inherit the parameter sharding (ZeRO-style: with FSDP rules
active, params AND moments are sharded over the "data" axis, so a 671B-param
model's Adam states fit a 16 GB/chip pod slice — see EXPERIMENTS.md §Dry-run).

8-bit mode stores m/v as int8 with a per-block (128 elems) f32 absmax scale —
the standard 8-bit-Adam trick, here used to fit deepseek-v3 training state.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_bits: int = 32          # 32 or 8
    block: int = 128              # 8-bit quantization block


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    """Blockwise-quantized int8 tensor (shape/npad are static aux data)."""
    q: Any             # int8, padded-flat (nblocks, block)
    scale: Any         # f32 (nblocks, 1)
    shape: Tuple[int, ...] = ()
    npad: int = 0

    def tree_flatten(self):
        return (self.q, self.scale), (self.shape, self.npad)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0], aux[1])


def quantizable(shape: Tuple[int, ...], block: int) -> bool:
    """Blockwise-int8 along the LAST axis keeps the tensor's own shape (and
    therefore its sharding): dequantize is elementwise, no resharding.  A
    flat-blocks layout instead forces a cross-sharding reshape that GSPMD can
    only realize by replicating — observed as multi-TiB temps in the
    deepseek-v3 dry-run (EXPERIMENTS.md §Perf iteration 2)."""
    return len(shape) >= 1 and shape[-1] % block == 0 and shape[-1] >= block


def _quantize(x: jax.Array, block: int) -> QTensor:
    shape = x.shape
    blocks = x.reshape(shape[:-1] + (shape[-1] // block, block))
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return QTensor(q.reshape(shape), scale.astype(jnp.float32)[..., 0],
                   shape, 0)


def _dequantize(t: QTensor) -> jax.Array:
    shape = t.shape
    block = shape[-1] // t.scale.shape[-1]
    blocks = t.q.astype(jnp.float32).reshape(
        shape[:-1] + (t.scale.shape[-1], block))
    return (blocks * t.scale[..., None]).reshape(shape)


class AdamState(NamedTuple):
    step: jax.Array
    m: Any     # pytree of f32 or QTensor
    v: Any


def init(params, cfg: AdamWConfig) -> AdamState:
    def zeros_like(p):
        z = jnp.zeros(p.shape, jnp.float32)
        if cfg.state_bits == 8 and quantizable(p.shape, cfg.block):
            return _quantize(z, cfg.block)
        return z
    return AdamState(step=jnp.int32(0),
                     m=jax.tree.map(zeros_like, params),
                     v=jax.tree.map(zeros_like, params))


def _global_norm(grads) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(params, grads, state: AdamState, cfg: AdamWConfig):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    is_q = lambda x: isinstance(x, QTensor)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        mf = _dequantize(m) if is_q(m) else m
        # v is stored in sqrt-domain when quantized: halves the dynamic
        # range so blockwise int8 doesn't zero small second moments
        vf = jnp.square(_dequantize(v)) if is_q(v) else v
        mf = cfg.b1 * mf + (1 - cfg.b1) * g
        vf = cfg.b2 * vf + (1 - cfg.b2) * g * g
        upd_ = (mf / bc1) / (jnp.sqrt(vf / bc2) + cfg.eps)
        if cfg.state_bits == 8:
            # residual quantization noise can still inflate 1/sqrt(v);
            # bound the per-element update (bitsandbytes-style safety)
            upd_ = jnp.clip(upd_, -10.0, 10.0)
        pf = p.astype(jnp.float32)
        pf = pf - cfg.lr * (upd_ + cfg.weight_decay * pf)
        m2 = _quantize(mf, cfg.block) if is_q(m) else mf
        v2 = _quantize(jnp.sqrt(vf), cfg.block) if is_q(v) else vf
        return pf.astype(p.dtype), m2, v2

    flat_p, td = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m, is_leaf=is_q)
    flat_v = jax.tree.leaves(state.v, is_leaf=is_q)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(td, [o[0] for o in out])
    new_m = jax.tree.unflatten(td, [o[1] for o in out])
    new_v = jax.tree.unflatten(td, [o[2] for o in out])
    return new_p, AdamState(step, new_m, new_v), {"grad_norm": gnorm}


def state_axes(param_axes, cfg: AdamWConfig):
    """Logical axes tree for the optimizer state (mirrors params)."""
    if cfg.state_bits == 8:
        # quantized blocks are flat; shard nothing (already tiny) —
        # blockwise layout doesn't map onto the tensor's logical axes.
        q_axes = QTensor(q=(None, None), scale=(None, None), shape=(), npad=0)
        return AdamState(step=(),
                         m=jax.tree.map(lambda _: q_axes, param_axes,
                                        is_leaf=lambda t: isinstance(t, tuple)),
                         v=jax.tree.map(lambda _: q_axes, param_axes,
                                        is_leaf=lambda t: isinstance(t, tuple)))
    return AdamState(step=(),
                     m=param_axes,
                     v=param_axes)
