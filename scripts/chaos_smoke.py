#!/usr/bin/env python
"""CI chaos smoke: deterministic fault injection against the GA scheduler.

Forces an 8-device host-platform mesh and drives one scheduler through the
full failure menagerie — every fault injected through `repro.faults`
(occurrence counters + seeded hashes, never wall-clock or `random`), so a
failing run replays bit-for-bit:

  * a POISON job that crashes every chunk after its first: the pack it
    shares a launch with retries once, then splits — survivors resume from
    checkpoints sliced out of the pack's (`ga.repack_checkpoint`) and the
    poison job is quarantined as FAILED;
  * a FLAKY job hit by one injected compile failure, one corrupt
    checkpoint shard (caught by manifest checksums; resume falls back a
    step) and one chunk crash — three transient strikes, still finishes;
  * a forced PREEMPTION (late high-priority arrival parks a long run
    mid-flight), then a scheduler shutdown with the parked pack and the
    preemptor still pending;
  * a RESTART with `recover=True`: the journal replays, finished results
    are served without recomputation, the parked pack resumes from its
    checkpoint, and the pending jobs run to completion.

Every job that should finish must match its undisturbed solo `ga.solve`
run bit-identically; /metrics must export the fault gauges.

    PYTHONPATH=src python scripts/chaos_smoke.py
"""

import os
import re
import sys
import tempfile
import time
import urllib.request

# must precede the first jax import: fake an 8-device host platform
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import faults as FLT                     # noqa: E402
from repro import ga                                # noqa: E402
from repro.launch.mesh import make_island_mesh      # noqa: E402
from repro.serve.engine import GAMetricsRegistry    # noqa: E402
from repro.serve.metrics_http import start_metrics_server   # noqa: E402
from repro.serve.scheduler import (FAILED, PREEMPTED,       # noqa: E402
                                   GAScheduler)


def _spec(**kw):
    base = dict(problem="F3", n=32, bits_per_var=10, mode="arith",
                mutation_rate=0.05, seed=11, generations=48,
                n_islands=8, migrate_every=4)
    base.update(kw)
    return ga.GASpec(**base)


def _wait_state(sched, job_id, state, timeout=120.0):
    deadline = time.monotonic() + timeout
    while sched.job(job_id).state != state:
        if time.monotonic() > deadline:
            raise TimeoutError(f"{job_id} never reached {state!r} "
                               f"(stuck at {sched.job(job_id).state!r})")
        time.sleep(0.02)


def main():
    mesh = make_island_mesh(8)
    root = tempfile.mkdtemp(prefix="ga-chaos-")
    print(f"mesh: {dict(mesh.shape)}  ckpt_root: {root}")

    specs = {
        "pack_a": _spec(seed=11), "pack_b": _spec(seed=40),
        "poison": _spec(seed=7),
        "flaky": _spec(problem="rastrigin:4", seed=5),
        "long": _spec(seed=3, generations=96),
        "hot": _spec(problem="ackley:4", seed=9),
    }
    # undisturbed expectations: the chaos run must match these bit-for-bit
    want = {k: ga.solve(s, backend="islands", mesh=mesh)
            for k, s in specs.items()}

    inj = FLT.FaultInjector()
    reg = GAMetricsRegistry()
    sched = GAScheduler(registry=reg, backend="islands", chunk_generations=8,
                        ckpt_root=root, retry_backoff_s=0.01, paused=True,
                        options=ga.EngineOptions(mesh=mesh, faults=inj))
    j = {}
    try:
        # ---- phase 1: crash retry, corrupt ckpt, pack quarantine --------
        # paused: rules armed against job ids BEFORE anything dispatches
        for k in ("pack_a", "pack_b", "poison", "flaky"):
            j[k] = sched.submit(specs[k],
                                max_retries=1 if k == "poison" else None)
        inj.add_rule(f"chunk_crash@{j['poison']}:after=1:times=inf")
        inj.add_rule(f"compile_fail@{j['flaky']}:at=1")
        inj.add_rule(f"ckpt_corrupt@{j['flaky']}:at=2")
        inj.add_rule(f"chunk_crash@{j['flaky']}:at=3")
        sched.resume_dispatch()

        for k in ("pack_a", "pack_b", "flaky"):
            res = sched.result(j[k], timeout=600)
            assert res["best_fitness"] == want[k].best_fitness, \
                f"{k}: chaos best {res['best_fitness']} != undisturbed " \
                f"{want[k].best_fitness}"
            print(f"{j[k]} ({k}): best={res['best_fitness']:.6f} "
                  f"retries={sched.job(j[k]).retries} (== solo)")
        try:
            sched.result(j["poison"], timeout=600)
            raise AssertionError("poison job finished?!")
        except RuntimeError as e:
            assert "injected chunk crash" in str(e)
        pj = sched.job(j["poison"])
        assert pj.state == FAILED and pj.quarantined, \
            f"poison not quarantined: {pj.state} {pj.quarantined}"
        print(f"{j['poison']} (poison): quarantined after "
              f"{pj.retries} retry(s)")

        stats = sched.stats()
        fired = inj.stats()
        print(f"stats: retries={stats['retries']} "
              f"quarantined={stats['quarantined']}  fired={fired}")
        # pack retry (3 jobs) + flaky compile_fail + flaky chunk_crash
        assert stats["retries"] == 5, stats
        assert stats["quarantined"] == 1
        assert fired["chunk_crash"] >= 3 and fired["compile_fail"] == 1 \
            and fired["ckpt_corrupt"] == 1

        # ---- phase 2: forced preemption, shutdown with work pending ----
        j["long"] = sched.submit(specs["long"])
        hot = None
        for event in sched.stream(j["long"], timeout=600):
            if event.get("event") == "chunk":
                hot = sched.submit(specs["hot"], priority=10)
                j["hot"] = hot
                sched.pause()   # the park happens; nothing new dispatches
                break
        assert hot is not None, "long job ended before its first chunk"
        _wait_state(sched, j["long"], PREEMPTED)
        assert sched.stats()["preemptions"] >= 1
        print(f"{j['long']} parked mid-run; shutting the scheduler down "
              f"with it and {hot} pending")
    finally:
        sched.shutdown()
    assert sched.stats()["worker_alive"] is False

    # ---- phase 3: restart + journal recovery ----------------------------
    reg2 = GAMetricsRegistry()
    sched2 = GAScheduler(registry=reg2, backend="islands",
                         chunk_generations=8, ckpt_root=root, recover=True,
                         options=ga.EngineOptions(mesh=mesh))
    server = start_metrics_server(0, registry=reg2, host="127.0.0.1")
    port = server.server_address[1]
    try:
        assert sched2.recovered_total == 2, sched2.recovered_total  # long+hot
        # finished results come back from the journal, no recomputation
        for k in ("pack_a", "pack_b", "flaky"):
            got = sched2.result(j[k], timeout=5)
            assert got["best_fitness"] == want[k].best_fitness
        try:
            sched2.result(j["poison"], timeout=5)
            raise AssertionError("poison job revived?!")
        except RuntimeError as e:
            assert "injected chunk crash" in str(e)
        # the parked pack resumes from its checkpoint; the preemptor runs
        for k in ("long", "hot"):
            res = sched2.result(j[k], timeout=600)
            assert res["best_fitness"] == want[k].best_fitness, \
                f"{k} after restart: {res['best_fitness']} != " \
                f"{want[k].best_fitness}"
            assert sched2.job(j[k]).recovered
            print(f"{j[k]} ({k}): best={res['best_fitness']:.6f} "
                  "(recovered, == solo)")

        text = urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics", timeout=10).read().decode()
        for gauge in ("repro_ga_sched_retries_total",
                      "repro_ga_sched_quarantined_total",
                      "repro_ga_sched_recovered_total",
                      "repro_ga_sched_deadline_exceeded_total",
                      "repro_ga_sched_worker_alive"):
            assert gauge in text, f"missing gauge {gauge}"
        rec = float(re.search(r"^repro_ga_sched_recovered_total (\S+)$",
                              text, re.M).group(1))
        assert rec == 2.0, rec
        print(f"/metrics OK (recovered_total={rec:g})")
        print("chaos smoke OK")
    finally:
        server.shutdown()
        sched2.shutdown()


if __name__ == "__main__":
    main()
