"""Batched serving engine: prefill + decode steps with slot-based batching.

A fixed batch of `slots` runs lock-step decode (the shape the decode_32k /
long_500k dry-run cells lower).  A light continuous-batching layer refills
finished slots from a request queue between decode bursts — enough to drive
realistic serving benchmarks without an RPC stack.
"""

from __future__ import annotations

import dataclasses
import queue
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import common as C
from repro.models import lm as LM


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 32
    out_tokens: Optional[List[int]] = None


@dataclasses.dataclass
class EngineConfig:
    batch: int = 8
    max_len: int = 512
    greedy: bool = True
    temperature: float = 1.0


class Engine:
    """Slot-based batched generation over (prefill, decode_step)."""

    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig,
                 max_seq: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self._prefill = jax.jit(
            lambda p, t, c, **kw: LM.prefill(p, cfg, t, c, **kw))
        self._decode = jax.jit(
            lambda p, t, c: LM.decode_step(p, cfg, t, c))
        self._cache_defs = LM.cache_defs(cfg, ecfg.batch, ecfg.max_len)

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.ecfg.greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.ecfg.temperature).astype(jnp.int32)

    def generate(self, prompts: np.ndarray, max_new_tokens: int = 32,
                 frames=None, patches=None, seed: int = 0
                 ) -> Tuple[np.ndarray, Dict[str, float]]:
        """Lock-step generation. prompts: (B, S) int32. Returns tokens + stats."""
        b, s = prompts.shape
        assert b == self.ecfg.batch
        cache = C.init_params(self._cache_defs, jax.random.key(0))
        t0 = time.perf_counter()
        kw = {}
        if frames is not None:
            kw["frames"] = frames
        if patches is not None:
            kw["patches"] = patches
        logits, cache = self._prefill(self.params, jnp.asarray(prompts),
                                      cache, **kw)
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0

        key = jax.random.key(seed)
        tok = self._sample(logits, key)[:, None]
        out = [tok]
        t1 = time.perf_counter()
        for i in range(max_new_tokens - 1):
            logits, cache = self._decode(self.params, tok, cache)
            key, sub = jax.random.split(key)
            tok = self._sample(logits, sub)[:, None]
            out.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.perf_counter() - t1
        tokens = np.asarray(jnp.concatenate(out, axis=1))
        return tokens, {
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "decode_tok_per_s": b * (max_new_tokens - 1) / max(t_decode, 1e-9),
        }


def serve_queue(engine: Engine, requests: List[Request],
                max_new_tokens: int = 16) -> Dict[int, np.ndarray]:
    """Minimal continuous batching: group requests into engine-sized batches,
    refilling from the queue as batches finish."""
    q: "queue.Queue[Request]" = queue.Queue()
    for r in requests:
        q.put(r)
    results: Dict[int, np.ndarray] = {}
    bsz = engine.ecfg.batch
    while not q.empty():
        batch: List[Request] = []
        while len(batch) < bsz and not q.empty():
            batch.append(q.get())
        while len(batch) < bsz:           # pad with a copy of the last req
            batch.append(batch[-1])
        slen = max(len(r.prompt) for r in batch)
        prompts = np.zeros((bsz, slen), np.int32)
        for i, r in enumerate(batch):
            prompts[i, -len(r.prompt):] = r.prompt
        toks, _ = engine.generate(prompts, max_new_tokens)
        for i, r in enumerate(batch):
            if r.uid not in results:
                results[r.uid] = toks[i]
    return results
