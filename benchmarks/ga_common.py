"""Shared benchmark utilities (engine-based benchmarks build a GASpec via
`bench_engine` and time `Engine.run` — compilation is cached per Engine)."""

from __future__ import annotations

import time
from typing import Callable, Tuple

import jax
import numpy as np


def bench_engine(problem: str, n: int, m: int, generations: int,
                 mode: str = "lut", backend: str = "reference",
                 mutation_rate: float = 0.02, seed: int = 1, **kw):
    """An Engine warmed up (compiled) for timing loops."""
    from repro import ga
    spec = ga.paper_spec(problem, n=n, m=m, mode=mode,
                         mutation_rate=mutation_rate, seed=seed,
                         generations=generations, **kw)
    eng = ga.Engine(spec, backend)
    eng.run()   # compile + warm caches
    return eng


def planned_peak_vmem(eng):
    """Peak planned VMEM (bytes) of an engine's epoch plan — the working
    set the planner budgeted for one launch (double-buffered tile for the
    streamed mode, the whole stack for resident ones, one island for
    gridded fused launches).  None when the backend has no planner
    (reference / eager / single topologies)."""
    plan = getattr(getattr(eng.backend, "topology", None), "plan", None)
    if not plan:
        return None
    return plan.get("vmem_estimate_bytes")


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 5
              ) -> Tuple[float, object]:
    out = None
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    dt = (time.perf_counter() - t0) / iters
    return dt, out


def numpy_sequential_ga(problem, n: int, m: int, k: int, seed: int = 0,
                        mutation_rate: float = 0.02) -> Tuple[float, float]:
    """The 'software implementation' baseline of the paper's Table 2: a
    plain sequential NumPy GA (per-individual python loops, like the CPU
    programs the FPGA was compared against).  Returns (seconds, best)."""
    import math
    rng = np.random.default_rng(seed)
    c = m // 2
    lo, hi = problem.domain
    pop = rng.integers(0, 1 << c, size=(n, 2), dtype=np.uint32)
    p_count = max(1, math.ceil(n * mutation_rate))
    best = np.inf
    t0 = time.perf_counter()
    def np_fitness(vals):
        # separable problems (Table 2 uses F3) evaluate in pure numpy so the
        # timed loop is the sequential CPU program; non-separable ones pay
        # ONE jnp eager dispatch per generation — a small fixed overhead
        # that mildly overstates their baseline cost
        if problem.separable:
            d = sum(np.asarray(problem.term(vals[:, i], i), np.float64)
                    for i in range(vals.shape[1]))
            return d if problem.gamma is None else problem.gamma(d)
        return np.asarray(problem.f(vals), np.float64)

    for _ in range(k):
        vals = lo + pop * (hi - lo) / ((1 << c) - 1)
        y = np_fitness(vals)
        best = min(best, float(y.min()))
        w = np.empty_like(pop)
        for j in range(n):                      # tournament, sequential
            i1, i2 = rng.integers(0, n, 2)
            w[j] = pop[i1] if y[i1] <= y[i2] else pop[i2]
        z = np.empty_like(pop)
        for j in range(0, n, 2):                # single-point crossover
            for var in range(2):
                cut = rng.integers(0, c + 1)
                s = np.uint32(((1 << c) - 1) >> cut)
                h1, t1 = w[j, var] & ~s, w[j, var] & s
                h2, t2 = w[j + 1, var] & ~s, w[j + 1, var] & s
                z[j, var] = h1 | t2
                z[j + 1, var] = h2 | t1
        for j in range(p_count):                # mutation
            z[j] ^= rng.integers(0, 1 << c, 2, dtype=np.uint32)
        pop = z
    return time.perf_counter() - t0, best
