"""`EngineOptions` — one frozen options object for every engine entry point.

`Engine`, `PackedEngine`, `GAScheduler` and the `ga_run` / `ga_serve` /
`ga_autotune` CLIs all take the same execution knobs; before this object
each grew its own `mesh= / interpret= / cost_table= / plan_override=`
kwarg tail, and new knobs (the streamed mode's tile size, a forced VMEM
budget) would have widened five signatures.  Now they live in one place:

    opts = ga.EngineOptions(mesh=mesh, plan_override="streamed")
    ga.solve(spec, backend="fused-islands", options=opts)

The legacy kwargs still work on every constructor (they build an
`EngineOptions` internally via `resolve_options`), but mixing `options=`
with a non-default legacy kwarg is an error — one source of truth.

Knobs:
  * mesh — jax Mesh the island axis shards over (None = single device).
  * interpret — force Pallas interpret mode (None = auto: CPU hosts).
  * cost_table — autotune CostTable | path | None (ambient discovery) |
    False (disable measured planning).
  * plan_override — force an epoch mode ("gridded", "resident",
    "resident-sharded", "resident-free", "streamed"); infeasible forces
    raise with the feasibility reason.
  * vmem_budget — override the resident/streamed VMEM feasibility budget
    (bytes) for PLANNING only; the kernels still validate tiles against
    the real (env-derived) budget.  Lets benches/smokes exercise the
    streamed lane on small populations.
  * stream_tile_islands — pin the streamed mode's island tile size
    (must divide the local island count and fit double-buffered).
  * sel_lane — override the spec's fused-kernel tournament gather lane
    ("onehot" | "gather" | "auto"); None keeps the spec's own setting.
  * fitness_workers — eager backend only: size of the bounded thread pool
    dispatching host-side blackbox fitness population-parallel (1 = the
    serial batch call; results are order-preserving, so any worker count
    is bit-deterministic).
  * faults — deterministic fault injection (`repro.faults`): None
    discovers the ambient ``REPRO_GA_FAULTS`` env injector, False disarms,
    a rule string (``"chunk_crash:at=2;ckpt_corrupt@job-3"``) or a shared
    `FaultInjector` arms the chunk/compile/checkpoint injection sites.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

PLAN_MODES = ("gridded", "resident", "resident-sharded", "resident-free",
              "streamed")
SEL_LANES = ("onehot", "gather", "auto")


@dataclasses.dataclass(frozen=True)
class EngineOptions:
    mesh: Any = None
    interpret: Optional[bool] = None
    cost_table: Any = None
    plan_override: Optional[str] = None
    vmem_budget: Optional[int] = None
    stream_tile_islands: Optional[int] = None
    sel_lane: Optional[str] = None
    fitness_workers: int = 1
    faults: Any = None

    def __post_init__(self):
        if (self.plan_override is not None
                and self.plan_override not in PLAN_MODES):
            raise ValueError(
                f"plan_override must be one of {PLAN_MODES}, "
                f"got {self.plan_override!r}")
        if self.sel_lane is not None and self.sel_lane not in SEL_LANES:
            raise ValueError(f"sel_lane must be one of {SEL_LANES}, "
                             f"got {self.sel_lane!r}")
        for field in ("vmem_budget", "stream_tile_islands"):
            val = getattr(self, field)
            if val is not None and int(val) < 1:
                raise ValueError(f"{field} must be >= 1, got {val!r}")
        if int(self.fitness_workers) < 1:
            raise ValueError(f"fitness_workers must be >= 1, "
                             f"got {self.fitness_workers!r}")

    # ---- one flags→options parser shared by the CLIs --------------------

    @staticmethod
    def add_cli_args(ap) -> None:
        """Attach the shared engine-option flags to an ArgumentParser."""
        ap.add_argument("--cost-table", default=None, metavar="PATH",
                        help="autotune cost table for measured epoch plans "
                             "(default: ambient per-host table; 'off' "
                             "disables measured planning)")
        ap.add_argument("--plan-override", default=None, choices=PLAN_MODES,
                        help="force an epoch mode instead of the planner's "
                             "choice (errors if infeasible)")
        ap.add_argument("--vmem-budget", type=int, default=None,
                        metavar="BYTES",
                        help="override the planner's VMEM feasibility "
                             "budget (exercises the streamed lane on small "
                             "populations)")
        ap.add_argument("--stream-tile-islands", type=int, default=None,
                        metavar="T",
                        help="pin the streamed mode's island tile size")
        ap.add_argument("--sel-lane", default=None, choices=SEL_LANES,
                        help="fused-kernel tournament gather lane: 'onehot' "
                             "(MXU matmul, N <= 1024), 'gather' (dynamic "
                             "indexing, no cap) or 'auto' (default: the "
                             "spec's setting)")
        ap.add_argument("--fitness-workers", type=int, default=1,
                        metavar="W",
                        help="eager backend: thread-pool width for "
                             "host-side blackbox fitness dispatch "
                             "(1 = serial batch call)")
        ap.add_argument("--faults", default=None, metavar="RULES",
                        help="arm deterministic fault injection "
                             "(repro.faults rule grammar, e.g. "
                             "'chunk_crash:at=2'; 'off' disarms even the "
                             "REPRO_GA_FAULTS env; default: env-armed)")

    @classmethod
    def from_args(cls, args, *, mesh=None,
                  interpret: Optional[bool] = None) -> "EngineOptions":
        """Build options from parsed CLI args (+ an already-built mesh)."""
        ct = getattr(args, "cost_table", None)
        if isinstance(ct, str) and ct.lower() in ("off", "none", "0"):
            ct = False
        flt = getattr(args, "faults", None)
        if isinstance(flt, str) and flt.lower() in ("off", "none", "0"):
            flt = False
        return cls(mesh=mesh, interpret=interpret, cost_table=ct,
                   plan_override=getattr(args, "plan_override", None),
                   vmem_budget=getattr(args, "vmem_budget", None),
                   stream_tile_islands=getattr(args, "stream_tile_islands",
                                               None),
                   sel_lane=getattr(args, "sel_lane", None),
                   fitness_workers=getattr(args, "fitness_workers", 1),
                   faults=flt)


def resolve_options(options: Optional[EngineOptions] = None, *,
                    mesh=None, interpret=None, cost_table=None,
                    plan_override=None) -> EngineOptions:
    """Fold a constructor's legacy kwarg tail into one EngineOptions.

    With no `options=`, the legacy kwargs build one.  With `options=`, any
    non-default legacy kwarg is rejected — two sources of truth for the
    same knob is exactly the ambiguity this object removes."""
    if options is None:
        return EngineOptions(mesh=mesh, interpret=interpret,
                             cost_table=cost_table,
                             plan_override=plan_override)
    if not isinstance(options, EngineOptions):
        raise TypeError(f"options must be ga.EngineOptions, "
                        f"got {type(options).__name__}")
    clash = [name for name, val in (("mesh", mesh), ("interpret", interpret),
                                    ("cost_table", cost_table),
                                    ("plan_override", plan_override))
             if val is not None]
    if clash:
        raise ValueError(
            f"got both options= and legacy kwarg(s) {clash}: move them "
            "into EngineOptions (dataclasses.replace(options, ...))")
    return options
