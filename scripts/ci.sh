#!/usr/bin/env bash
# Tier-1 verification + a launch smoke of the unified GA engine.
#
#   bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== engine smoke (reference backend, ~5s) =="
timeout 120 python -m repro.launch.ga_run \
    --problem F1 --n 16 --k 20 --backend reference

echo "== backend-matrix smoke (1 tiny config per topology x executor combo) =="
mkdir -p artifacts
timeout 300 python -m benchmarks.engine_backends --smoke \
    --out artifacts/engine_backends.json
cat artifacts/engine_backends.json

echo "CI OK"
