"""repro.autotune — measured cost tables for the GA epoch planner.

Three layers:

  * `table`     — versioned per-host CostTable store + `resolve_table`
                  discovery (what `ga/backends.py` consults);
  * `stability` — repeat-until-stable replay timing with an injectable
                  clock;
  * `runner`    — the sweep: force each feasible epoch mode with
                  `plan_override`, replay to stability, persist.

The table/stability layers are import-light (no jax at import time) and
re-exported here; the runner pulls in the full engine stack, so its entry
points are wrapped lazily — `ga/backends.py` imports this package and
must not recurse back into itself.
"""

from repro.autotune.stability import Replay, replay_until_stable
from repro.autotune.table import (CostTable, POINT_FIELDS, TABLE_VERSION,
                                  default_table_path, host_fingerprint,
                                  resolve_table)

__all__ = [
    "CostTable", "POINT_FIELDS", "TABLE_VERSION", "Replay",
    "default_table_path", "estimate_gens_per_s", "host_fingerprint",
    "measure_candidate", "plan_candidates", "replay_until_stable",
    "resolve_table", "sweep",
]


def sweep(*args, **kwargs):
    from repro.autotune import runner
    return runner.sweep(*args, **kwargs)


def plan_candidates(*args, **kwargs):
    from repro.autotune import runner
    return runner.plan_candidates(*args, **kwargs)


def measure_candidate(*args, **kwargs):
    from repro.autotune import runner
    return runner.measure_candidate(*args, **kwargs)


def estimate_gens_per_s(*args, **kwargs):
    from repro.autotune import runner
    return runner.estimate_gens_per_s(*args, **kwargs)
