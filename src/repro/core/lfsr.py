"""Vectorized 32-bit Fibonacci LFSR — the paper's pseudo-random source.

The paper (Sec. 3, Fig. 1) uses independent 32-bit LFSRs based on the
polynomial  r^32 + r^22 + r^2 + 1  ([25] Goresky & Klapper), one per hardware
module, each seeded differently.  Hardware shifts one bit per clock and the
whole 32-bit register is the "draw"; draws are truncated to their most
significant bits when a narrower random value is needed (e.g. ceil(log2 N)
bits to index the population).

We reproduce this bit-exactly as a *lane array*: a uint32 vector where lane j
is the register of module j.  `step` advances every lane one clock;
`draw` advances `steps_per_draw` clocks and returns the registers.

TPU notes: everything is uint32 bitwise ops — pure VPU work, no gathers.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Polynomial r^32 + r^22 + r^2 + 1  -> taps at exponents {32, 22, 2, 1}.
# With the register holding bits s_31..s_0 (s_31 oldest), the feedback bit is
#   fb = s[31] ^ s[21] ^ s[1] ^ s[0]
# and the register shifts left, inserting fb at bit 0.
TAPS = (31, 21, 1, 0)
POLY_MASK = np.uint32((1 << 31) | (1 << 21) | (1 << 1) | (1 << 0))


def step(state: jax.Array) -> jax.Array:
    """Advance every LFSR lane one clock. state: uint32[...]"""
    s = state
    fb = (s >> 31) ^ (s >> 21) ^ (s >> 1) ^ s
    fb = fb & jnp.uint32(1)
    return (s << 1) | fb


def steps(state: jax.Array, n: int) -> jax.Array:
    """Advance n clocks (statically unrolled for small n, fori_loop else)."""
    if n <= 4:
        for _ in range(n):
            state = step(state)
        return state
    return jax.lax.fori_loop(0, n, lambda _, s: step(s), state)


def draw(state: jax.Array, steps_per_draw: int = 3) -> Tuple[jax.Array, jax.Array]:
    """Advance and return (new_state, 32-bit draws).

    Default ``steps_per_draw=3``: the paper's SyncM strobes a new generation
    every 3 clocks, so each module's LFSR has shifted 3 bits between draws.
    """
    state = steps(state, steps_per_draw)
    return state, state


def truncate(r: jax.Array, bits: int) -> jax.Array:
    """Keep the `bits` most significant bits (the paper's truncation)."""
    if bits <= 0:
        return jnp.zeros_like(r)
    return r >> np.uint32(32 - bits)


def seeds(key_or_int, n: int) -> jax.Array:
    """n distinct non-zero 32-bit seeds (CCseed in the paper).

    Deterministic: derived with a splitmix-style integer hash so tests and
    hardware-style reproducibility do not depend on jax.random.
    """
    base = int(key_or_int) & 0xFFFFFFFF
    idx = np.arange(1, n + 1, dtype=np.uint64) + np.uint64(base) * np.uint64(0x9E3779B9)
    z = idx * np.uint64(0xBF58476D1CE4E5B9)
    z ^= z >> np.uint64(31)
    z = z * np.uint64(0x94D049BB133111EB)
    z ^= z >> np.uint64(27)
    out = (z & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    out = np.where(out == 0, np.uint32(0xDEADBEEF), out)  # LFSR must not be 0
    return jnp.asarray(out)


# ---------------------------------------------------------------------------
# Leap-forward: advance t steps in O(log t) via GF(2) matrix powers.  Used to
# give islands decorrelated streams without iterating (beyond-paper utility).
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def leap_feedback_masks(t: int) -> Tuple[int, ...]:
    """GF(2) masks for a t-step leap in shift+parity form (0 < t < 32).

    Advancing the register t clocks is linear over GF(2): the top 32-t bits
    are a plain left shift, and each of the t inserted feedback bits is the
    parity of the ORIGINAL register masked by a precomputed 32-bit mask:

        state_t  =  (s << t)  |  Σ_j  parity(s & M_j) << j

    (bit j of the result was the feedback computed at clock t-1-j).  The
    masks come from symbolically simulating `step` with each state bit
    represented as a mask over the original bits — computed once per t and
    cached.  This is the kernel-side replacement for the unrolled
    shift-per-clock loop: the per-bit parities are independent (no
    clock-to-clock dependency chain) and share the `s >> b` subterms, so the
    VPU op count stops growing with the full feedback recurrence per step.
    Bit-identical to `steps(state, t)` by construction (asserted in
    tests/test_lfsr.py).
    """
    if not 0 < t < 32:
        raise ValueError(f"leap_feedback_masks needs 0 < t < 32, got {t}")
    bits = [1 << i for i in range(32)]   # bit i as a mask over the original s
    for _ in range(t):
        fb = 0
        for b in TAPS:
            fb ^= bits[b]
        bits = [fb] + bits[:-1]          # s' = (s << 1) | fb
    return tuple(bits[:t])


@functools.lru_cache(maxsize=None)
def _leap_matrix(t: int) -> Tuple[int, ...]:
    """Column representation of the t-step LFSR transition over GF(2).

    Returns 32 ints; column j is the new-state bitmask produced by old bit j.
    """
    # one-step: new_bit_i = old_bit_{i-1} for i>0 ; new_bit_0 = parity(taps)
    cols = []
    for j in range(32):
        col = 0
        if j + 1 < 32:
            col |= 1 << (j + 1)
        if j in TAPS:
            col |= 1
        cols.append(col)
    one = tuple(cols)

    def mul(a, b):  # c = a ∘ b  (apply b then a)
        out = []
        for j in range(32):
            v, acc = b[j], 0
            for i in range(32):
                if (v >> i) & 1:
                    acc ^= a[i]
            out.append(acc)
        return tuple(out)

    ident = tuple(1 << j for j in range(32))
    result, base = ident, one
    while t:
        if t & 1:
            result = mul(base, result)
        base = mul(base, base)
        t >>= 1
    return result


def leap(state: jax.Array, t: int) -> jax.Array:
    """Advance every lane t steps in O(1) jitted work (32 selects + XORs)."""
    cols = _leap_matrix(int(t))
    out = jnp.zeros_like(state)
    for j in range(32):
        bit = (state >> j) & jnp.uint32(1)
        out = out ^ (jnp.where(bit != 0, jnp.uint32(cols[j]), jnp.uint32(0)))
    return out


# ---------------------------------------------------------------------------
# NumPy reference (oracle for tests)
# ---------------------------------------------------------------------------


def np_step(state: np.ndarray) -> np.ndarray:
    s = state.astype(np.uint32)
    fb = ((s >> 31) ^ (s >> 21) ^ (s >> 1) ^ s) & np.uint32(1)
    return ((s << np.uint32(1)) | fb).astype(np.uint32)


def np_steps(state: np.ndarray, n: int) -> np.ndarray:
    for _ in range(n):
        state = np_step(state)
    return state
