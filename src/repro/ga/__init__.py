"""`repro.ga` — the public GA engine API (one spec, four backends).

The paper's contribution is a single full-parallel datapath (FFM→SM→CM→MM)
that scales by swapping hardware arrangements.  This package is that idea as
an API: a frozen :class:`GASpec` describes *what* to solve (problem,
encoding, operator pipeline, run policy) and the :class:`Engine` decides
*how*, via a backend registry:

    ============  =====================================================
    backend       execution
    ============  =====================================================
    reference     pure-JAX `lax.scan` — any operators, lut or arith FFM,
                  vmapped `n_repeats` replicas in one scan
    fused         one Pallas kernel per generation (VMEM-resident state,
                  MXU one-hot tournaments); arith FFM, paper pipeline,
                  power-of-two N <= 1024; bit-identical to reference
    islands       island model with ring migration; shard_mapped over a
                  device mesh when one is given
    eager         python-loop driver for non-traceable fitness
                  (operators stay jitted)
    ============  =====================================================

Typical use::

    from repro import ga

    result = ga.solve(ga.GASpec(problem="F1", n=32, bits_per_var=13,
                                mode="lut", generations=100))
    result = ga.solve(ga.paper_spec("F3", n=64, m=20, mode="arith"),
                      backend="fused")

Operator stages are pluggable protocols with registries
(`ga.SELECTION` / `ga.CROSSOVER` / `ga.MUTATION`; see
:mod:`repro.ga.operators`), chunked streaming + checkpoint/resume live on
:meth:`Engine.run_chunked`.

Old call sites map onto this API as follows (the old entry points remain as
thin shims):

    core.ga.run(cfg, fit, k)            -> solve(spec, backend="reference")
    core.ga.run_unjitted(cfg, fit, k)   -> solve(spec, backend="eager")
                                           (spec.jit_fitness=False)
    kernels.ops.ga_run_kernel(...)      -> solve(spec, backend="fused")
    islands.run_local/run_sharded(...)  -> solve(spec, backend="islands")
                                           (spec.n_islands>1[, mesh=...])
    core.evolve.evolve(fn, bounds)      -> unchanged signature, now a
                                           GASpec + Engine underneath
"""

from repro.ga.spec import GASpec, paper_spec
from repro.ga.operators import (CROSSOVER, MUTATION, PAPER_PIPELINE,
                                SELECTION, CrossoverOp, MutationOp,
                                SelectionOp, make_apply_ops, make_generation,
                                register_crossover, register_mutation,
                                register_selection)
from repro.ga.backends import BACKENDS, Backend, Segment
from repro.ga.engine import (BackendUnsupported, Engine, EngineResult,
                             capability_matrix, resolve_backend, solve)

__all__ = [
    "GASpec", "paper_spec",
    "Engine", "EngineResult", "solve", "resolve_backend",
    "capability_matrix", "BackendUnsupported",
    "BACKENDS", "Backend", "Segment",
    "SELECTION", "CROSSOVER", "MUTATION", "PAPER_PIPELINE",
    "SelectionOp", "CrossoverOp", "MutationOp",
    "register_selection", "register_crossover", "register_mutation",
    "make_generation", "make_apply_ops",
]
