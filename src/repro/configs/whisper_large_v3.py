"""whisper-large-v3 backbone — enc-dec transformer [arXiv:2212.04356;
unverified].  The conv frontend is a STUB: input_specs() provides
precomputed (B, 1500, 1280) frame embeddings.  32 enc + 32 dec layers,
LayerNorm + GELU, learned decoder positions, tied decoder embeddings.
Vocab 51866 padded to 51968 for 16-way TP."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, enc_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    head_dim=64, d_ff=5120, vocab=51866, norm="ln", act="gelu",
    enc_seq=1500, tie_embeddings=True,
    # 20 heads cannot shard on the 16-way model axis: unpadded, attention
    # replicates and every layer pays a resharding storm (116 s of
    # collectives in the prefill_32k baseline).  Padding to 32 heads costs
    # 60% more (tiny) attention FLOPs and removes it — §Perf whisper iter 1.
    pad_heads_to=16,
)
