"""Logical-axis sharding: one rule table maps every tensor in the framework
onto the production meshes.

Meshes (launch/mesh.py):
    single-pod: (16, 16)    axes ("data", "model")
    multi-pod : (2, 16, 16) axes ("pod", "data", "model")

Logical axes:
    batch    -> (pod,) data      (DP; batch dim of activations)
    embed    -> data if fsdp else None   (FSDP / ZeRO-3 on the d_model dim)
    vocab    -> model            (TP of embedding + LM head)
    heads    -> model            (TP of attention heads)
    kv_heads -> model            (TP of KV heads; may be uneven -> GSPMD pads)
    mlp      -> model            (TP of the FFN hidden dim)
    expert   -> model            (EP of MoE experts)
    seq/layers/state/... -> None

Models never name mesh axes directly — they call `logical_spec(...)` /
`constrain(x, ...)` so the same code runs on a laptop (no mesh), one pod, or
many pods.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:                                     # jax >= 0.6: promoted to jax core
    from jax import shard_map as _shard_map
except ImportError:                      # jax 0.4/0.5
    from jax.experimental.shard_map import shard_map as _shard_map


def shard_map(f, mesh: Mesh, in_specs, out_specs):
    """Version-compat `shard_map` with replication checking disabled.

    Every shard_map in this repo wraps bodies the checker cannot analyze
    (Pallas calls, ppermute cascades), so the check is always off; the
    disabling kwarg was renamed across jax releases (check_rep ->
    check_vma), hence this single compat point.
    """
    for kw in ({"check_rep": False}, {"check_vma": False}, {}):
        try:
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kw)
        except TypeError:
            continue
    raise RuntimeError("no compatible shard_map signature found")


def make_rules(mesh: Optional[Mesh], fsdp: bool = True) -> dict:
    if mesh is None:
        return {}
    axes = set(mesh.axis_names)
    batch = tuple(a for a in ("pod", "data") if a in axes)
    rules = {
        "batch": batch if batch else None,
        "vocab": "model" if "model" in axes else None,
        "heads": "model" if "model" in axes else None,
        "kv_heads": "model" if "model" in axes else None,
        "mlp": "model" if "model" in axes else None,
        "expert": "model" if "model" in axes else None,
        # full expert parallelism: expert banks sharded over data x model
        # jointly (deepseek: 256 experts / 256 chips = 1 per chip) — no
        # per-layer weight all-gather; tokens all-to-all to expert owners.
        "expert_full": (("data", "model") if ("data" in axes and
                                              "model" in axes)
                        else ("model" if "model" in axes else None)),
        "embed": ("data" if (fsdp and "data" in axes) else None),
        # activation feature dim: NOT FSDP-sharded (that's params-only);
        # hillclimb experiments may remap this to "model" (sequence/TP out)
        "act_embed": None,
        # Megatron-style sequence parallelism: the residual stream's token
        # dim is sharded over the TP axis between blocks (pointwise ops and
        # the MLP run sequence-sharded; GSPMD all-gathers only where
        # attention genuinely needs the full sequence, and reduce-scatters
        # back).  16× less residual memory + converts TP all-reduces into
        # RS+AG pairs.  Shape-aware fallback replicates when S % 16 != 0
        # (e.g. decode S=1).
        "act_seq": "model" if "model" in axes else None,
        # 8-bit optimizer-state blocks: flat layout, sharded over EVERYTHING
        # (ZeRO for quantized moments); shape-aware fallback leaves small
        # tensors replicated.
        "qblocks": batch + ("model",) if "model" in axes else batch or None,
    }
    return rules


class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: dict = {}


_CTX = _Ctx()


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], fsdp: bool = True, rules: Optional[dict] = None):
    """Activate a mesh + logical rules for model code in this thread."""
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh = mesh
    _CTX.rules = rules if rules is not None else make_rules(mesh, fsdp)
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def _axis_size(mesh: Mesh, r) -> int:
    if r is None:
        return 1
    if isinstance(r, (tuple, list)):
        n = 1
        for a in r:
            n *= mesh.shape[a]
        return n
    return mesh.shape[r]


def logical_spec(logical_axes: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None) -> P:
    """Translate logical axis names to a PartitionSpec under current rules.

    If `shape` is given, any mapping whose mesh-axis size does not evenly
    divide the dimension is dropped (replicated) — e.g. 8 KV heads on a
    16-way model axis.  This "best-effort" fallback keeps every config
    lowerable; padding heads instead is a per-arch config choice.
    """
    rules = _CTX.rules
    mesh = _CTX.mesh
    parts = []
    used = set()
    for i, ax in enumerate(logical_axes):
        r = rules.get(ax) if ax else None
        if r is not None and shape is not None and mesh is not None:
            if shape[i] % _axis_size(mesh, r) != 0:
                r = None
        # a mesh axis may appear once per spec: first logical axis wins
        # (e.g. KV caches: act_seq and kv_heads both -> "model")
        if r is not None:
            names = r if isinstance(r, (tuple, list)) else (r,)
            if any(n in used for n in names):
                r = None
            else:
                used.update(names)
        parts.append(r)
    return P(*parts)


def named_sharding(logical_axes: Sequence[Optional[str]],
                   shape: Optional[Sequence[int]] = None
                   ) -> Optional[NamedSharding]:
    if _CTX.mesh is None:
        return None
    return NamedSharding(_CTX.mesh, logical_spec(logical_axes, shape))


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint in logical axes; no-op without a mesh."""
    if _CTX.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX.mesh, logical_spec(logical_axes, x.shape)))


def spec_tree(axes_tree):
    """Map a pytree of logical-axes tuples to PartitionSpecs."""
    return jax.tree.map(
        lambda axes: logical_spec(axes),
        axes_tree,
        is_leaf=lambda t: isinstance(t, tuple) and all(
            a is None or isinstance(a, str) for a in t),
    )


def sharding_tree(axes_tree):
    """Map a pytree of logical-axes tuples to NamedShardings (or None)."""
    mesh = _CTX.mesh
    if mesh is None:
        return jax.tree.map(lambda _: None, axes_tree,
                            is_leaf=lambda t: isinstance(t, tuple))
    return jax.tree.map(
        lambda axes: NamedSharding(mesh, logical_spec(axes)),
        axes_tree,
        is_leaf=lambda t: isinstance(t, tuple) and all(
            a is None or isinstance(a, str) for a in t),
    )


def pad_to_multiple(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult
