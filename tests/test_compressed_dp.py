"""End-to-end int8-compressed DP training matches uncompressed training
closely and still learns (multi-device via subprocess with fake devices)."""

import os
import subprocess
import sys


def test_compressed_dp_training_learns():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig, DataIterator
from repro.models import common as C, lm as LM
from repro.optim import adamw as OPT
from repro.train import dp_compressed as DPC

mesh = jax.make_mesh((4,), ("data",))
cfg = reduced(get_config("minitron-8b"))
defs = LM.model_defs(cfg, max_seq=32)
params = C.init_params(defs, jax.random.key(0))
ocfg = OPT.AdamWConfig(lr=1e-3)
opt = OPT.init(params, ocfg)
residual = DPC.init_residual(params)
step = DPC.make_compressed_dp_step(cfg, mesh, ocfg)
it = DataIterator(DataConfig(vocab=cfg.vocab_, seq_len=32, global_batch=8))
losses = []
for i in range(25):
    b = {k: jnp.asarray(v) for k, v in it.batch_at(i).items()}
    params, opt, residual, m = step(params, opt, residual, b)
    losses.append(float(m["loss"]))
it.close()
assert losses[-1] < losses[0] - 0.5, losses
print("COMPRESSED_DP_OK", losses[0], "->", losses[-1])
"""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "COMPRESSED_DP_OK" in r.stdout
