"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracle.

The FFM stage is pluggable (`FitnessProgram.stage` traced into the kernel),
so the sweeps cover the paper problems, the n-variable registry suite AND a
user blackbox closing over its own arrays (the closure-constant hoisting
path)."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fitness as F
from repro.core import ga as G
from repro.core import islands as ISL
from repro.core import lfsr
from repro.kernels import ops, ref


def _states(cfg, n_islands=2):
    icfg = ISL.IslandConfig(ga=cfg, n_islands=n_islands)
    return ISL.init_islands_fast(icfg)


def _ffm(problem: str, cfg: G.GAConfig):
    return F.compile_program(problem=problem, n_vars=cfg.v,
                             bits_per_var=cfg.c).stage


@pytest.mark.parametrize("n", [16, 64, 256, 1024])
@pytest.mark.parametrize("problem", ["F1", "F2", "F3"])
def test_ga_step_matches_ref_population_sweep(n, problem):
    cfg = G.GAConfig(n=n, c=10, v=2, mutation_rate=0.03, seed=n, mode="arith")
    ffm = _ffm(problem, cfg)
    st = _states(cfg)
    k = ops.ga_generation(st.x, st.sel_lfsr, st.cross_lfsr, st.mut_lfsr,
                          cfg=cfg, ffm=ffm)
    r = ref.ga_generation_ref(st.x, st.sel_lfsr, st.cross_lfsr, st.mut_lfsr,
                              cfg=cfg, ffm=ffm)
    for a, b in zip(k[:4], r[:4]):       # uint32 state: bit-exact
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_allclose(np.asarray(k[4]), np.asarray(r[4]), rtol=2e-5)


@pytest.mark.parametrize("problem,v", [("sphere", 4), ("rastrigin", 6),
                                       ("rosenbrock", 4), ("ackley", 8)])
def test_ga_step_nvar_suite_matches_ref(problem, v):
    """The V-variable decode + suite objectives run inside the kernel and
    stay bit-exact with the oracle (which evaluates the same stage)."""
    cfg = G.GAConfig(n=64, c=10, v=v, mutation_rate=0.03, seed=v,
                     mode="arith")
    ffm = _ffm(problem, cfg)
    st = _states(cfg, n_islands=3)
    k = ops.ga_generation(st.x, st.sel_lfsr, st.cross_lfsr, st.mut_lfsr,
                          cfg=cfg, ffm=ffm)
    r = ref.ga_generation_ref(st.x, st.sel_lfsr, st.cross_lfsr, st.mut_lfsr,
                              cfg=cfg, ffm=ffm)
    for a, b in zip(k[:4], r[:4]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_allclose(np.asarray(k[4]), np.asarray(r[4]), rtol=2e-5)


def test_ga_step_blackbox_closure_constants():
    """A user fitness closing over its own arrays runs in-kernel: the
    captured constants are hoisted into kernel inputs (Pallas forbids
    implicit array captures), bit-exact with the XLA evaluation."""
    cfg = G.GAConfig(n=32, c=12, v=5, mutation_rate=0.05, seed=9,
                     mode="arith")
    target = jnp.asarray(np.linspace(-1.0, 1.0, 5), jnp.float32)
    weight = jnp.asarray([1.0, 2.0, 3.0, 4.0, 5.0], jnp.float32)
    prog = F.compile_program(
        fitness=lambda p: jnp.sum(weight * (p - target) ** 2, axis=-1),
        bounds=((-2.0, 2.0),) * 5, bits_per_var=cfg.c)
    st = _states(cfg, n_islands=2)
    k = ops.ga_generation(st.x, st.sel_lfsr, st.cross_lfsr, st.mut_lfsr,
                          cfg=cfg, ffm=prog.stage)
    r = ref.ga_generation_ref(st.x, st.sel_lfsr, st.cross_lfsr, st.mut_lfsr,
                              cfg=cfg, ffm=prog.stage)
    for a, b in zip(k[:4], r[:4]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_allclose(np.asarray(k[4]), np.asarray(r[4]), rtol=2e-5)


@pytest.mark.parametrize("c", [6, 10, 14, 15])
@pytest.mark.parametrize("mr", [0.01, 0.1])
def test_ga_step_matches_ref_width_sweep(c, mr):
    cfg = G.GAConfig(n=64, c=c, v=2, mutation_rate=mr, seed=c, mode="arith")
    ffm = _ffm("F3", cfg)
    st = _states(cfg, n_islands=3)
    k = ops.ga_generation(st.x, st.sel_lfsr, st.cross_lfsr, st.mut_lfsr,
                          cfg=cfg, ffm=ffm)
    r = ref.ga_generation_ref(st.x, st.sel_lfsr, st.cross_lfsr, st.mut_lfsr,
                              cfg=cfg, ffm=ffm)
    for a, b in zip(k[:4], r[:4]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("minimize", [True, False])
def test_ga_step_minimize_maximize(minimize):
    cfg = G.GAConfig(n=128, c=10, v=2, mutation_rate=0.02, seed=5,
                     minimize=minimize, mode="arith")
    ffm = _ffm("F2", cfg)
    st = _states(cfg)
    k = ops.ga_generation(st.x, st.sel_lfsr, st.cross_lfsr, st.mut_lfsr,
                          cfg=cfg, ffm=ffm)
    r = ref.ga_generation_ref(st.x, st.sel_lfsr, st.cross_lfsr, st.mut_lfsr,
                              cfg=cfg, ffm=ffm)
    np.testing.assert_array_equal(np.asarray(k[0]), np.asarray(r[0]))


def test_ga_kernel_multi_generation_converges():
    """One launch, 100 in-kernel generations (gens>1 VMEM residency), with
    the in-kernel best fold — converges near the F3 optimum."""
    cfg = G.GAConfig(n=64, c=10, v=2, mutation_rate=0.05, seed=11, mode="arith")
    ffm = _ffm("F3", cfg)
    st = _states(cfg, n_islands=4)
    out = ops.ga_generation(st.x, st.sel_lfsr, st.cross_lfsr, st.mut_lfsr,
                            cfg=cfg, ffm=ffm, gens=100, track_best=True)
    best_y = out[5]
    assert best_y.shape == (4,)
    assert float(jnp.min(best_y)) < 1.0  # near the F3 optimum


@pytest.mark.parametrize("gens", [1, 7])
def test_ga_kernel_track_best_matches_oracle(gens):
    """track_best folds the running best inside the kernel with the
    reference argmin tie rule: re-running generation by generation and
    folding outside must give bit-identical (best_y, best_x)."""
    cfg = G.GAConfig(n=32, c=10, v=2, mutation_rate=0.05, seed=3, mode="arith")
    ffm = _ffm("F1", cfg)
    st = _states(cfg, n_islands=3)
    out = ops.ga_generation(st.x, st.sel_lfsr, st.cross_lfsr, st.mut_lfsr,
                            cfg=cfg, ffm=ffm, gens=gens, track_best=True)
    by_k, bx_k = np.asarray(out[5]), np.asarray(out[6])

    x, sel, cross, mut = st.x, st.sel_lfsr, st.cross_lfsr, st.mut_lfsr
    by = np.full((3,), np.inf, np.float32)
    bx = np.zeros((3, cfg.v), np.uint32)
    for _ in range(gens):
        x2, sel, cross, mut, y = ops.ga_generation(x, sel, cross, mut,
                                                   cfg=cfg, ffm=ffm)
        y = np.asarray(y)
        idx = np.argmin(y, axis=1)
        gb = y[np.arange(3), idx]
        better = gb < by
        by = np.where(better, gb, by)
        bx = np.where(better[:, None], np.asarray(x)[np.arange(3), idx], bx)
        x = x2
    np.testing.assert_array_equal(by_k, by)
    np.testing.assert_array_equal(bx_k, bx)
    # and the state outputs are unchanged by the extra best outputs
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(x))


@pytest.mark.parametrize("shape", [(7,), (128,), (3, 5), (2, 130)])
@pytest.mark.parametrize("steps", [1, 3, 13, 40])
def test_lfsr_kernel_matches_ref(shape, steps):
    s = lfsr.seeds(99, int(np.prod(shape))).reshape(shape)
    got = ops.lfsr_advance(s, steps)
    want = ref.lfsr_advance_ref(s, steps)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ga_epoch_kernel_matches_local_step_oracle():
    """The resident-epoch kernel (islands in one VMEM block, ring migration
    inside the fori_loop) reproduces repro.core.islands.make_local_step —
    the independent between-launch oracle — bit-for-bit over 3 migration
    intervals in a SINGLE launch."""
    cfg = G.GAConfig(n=32, c=10, v=2, mutation_rate=0.05, seed=11,
                     mode="arith")
    ffm = _ffm("F3", cfg)
    icfg = ISL.IslandConfig(ga=cfg, n_islands=4, migrate_every=5)
    states = ISL.init_islands_fast(icfg)
    oracle = states
    epoch = ISL.make_local_step(icfg, ffm)
    for _ in range(3):
        oracle, _ex, _ey = epoch(oracle)

    x, sel, cross, mut, y, by, bx = ops.ga_epoch(
        states.x[None], states.sel_lfsr[None], states.cross_lfsr[None],
        states.mut_lfsr[None], cfg=cfg, ffm=ffm, migrate_every=5,
        intervals=3)
    np.testing.assert_array_equal(np.asarray(x[0]), np.asarray(oracle.x))
    np.testing.assert_array_equal(np.asarray(sel[0]),
                                  np.asarray(oracle.sel_lfsr))
    np.testing.assert_array_equal(np.asarray(cross[0]),
                                  np.asarray(oracle.cross_lfsr))
    np.testing.assert_array_equal(np.asarray(mut[0]),
                                  np.asarray(oracle.mut_lfsr))
    assert by.shape == (1, 4) and bx.shape == (1, 4, 2)
    assert y.shape == (1, 4, cfg.n)


def test_ga_epoch_kernel_boundary_is_partial_ring():
    """boundary=True leaves island 0 for the between-launch ppermute: the
    intra-shard splices match the full in-kernel ring everywhere but island
    0, and (send elite, island-0 worst slot) equal what the full ring would
    have used."""
    cfg = G.GAConfig(n=32, c=10, v=2, mutation_rate=0.05, seed=7,
                     mode="arith")
    ffm = _ffm("F1", cfg)
    st = _states(cfg, n_islands=4)
    full = ops.ga_epoch(st.x[None], st.sel_lfsr[None], st.cross_lfsr[None],
                        st.mut_lfsr[None], cfg=cfg, ffm=ffm,
                        migrate_every=3, intervals=1)
    part = ops.ga_epoch(st.x[None], st.sel_lfsr[None], st.cross_lfsr[None],
                        st.mut_lfsr[None], cfg=cfg, ffm=ffm,
                        migrate_every=3, intervals=1, boundary=True)
    xf, xp = np.asarray(full[0][0]), np.asarray(part[0][0])
    send, w0 = np.asarray(part[7][0]), int(np.asarray(part[8][0]))
    np.testing.assert_array_equal(xp[1:], xf[1:])       # intra-shard splices
    # island 0: splicing send (the wrap elite on a 1-shard ring) at w0
    # reproduces the full ring
    xp0 = xp[0].copy()
    xp0[w0] = send
    np.testing.assert_array_equal(xp0, xf[0])
    # migration fitness + best tracking identical either way
    np.testing.assert_array_equal(np.asarray(part[4]), np.asarray(full[4]))
    np.testing.assert_array_equal(np.asarray(part[5]), np.asarray(full[5]))


def test_kernel_ffm_const_size_gate():
    """Hoisted FFM closure constants above the VMEM gate are rejected with
    an actionable error instead of silently replicating per grid step."""
    cfg = G.GAConfig(n=16, c=8, v=2, seed=1, mode="arith")
    big = jnp.zeros((1024, 1024), jnp.float32)          # 4 MiB > 2 MiB gate
    prog = F.compile_program(
        fitness=lambda p: jnp.sum(p, axis=-1) + big[0, 0],
        bounds=((-1.0, 1.0),) * 2, bits_per_var=cfg.c)
    st = _states(cfg, 1)
    with pytest.raises(ValueError, match="VMEM gate"):
        ops.ga_generation(st.x, st.sel_lfsr, st.cross_lfsr, st.mut_lfsr,
                          cfg=cfg, ffm=prog.stage)


def test_kernel_rejects_oversize_population_on_onehot_lane():
    """The onehot lane's (N, N) tournament matrices cap N; the error names
    the gather lane as the fix, and the gather lane actually runs there."""
    cfg = G.GAConfig(n=2048, c=10, v=2, seed=1, mode="arith")
    ffm = _ffm("F3", cfg)
    st = _states(cfg, 1)
    with pytest.raises(ValueError, match="sel_lane='gather'"):
        ops.ga_generation(st.x, st.sel_lfsr, st.cross_lfsr, st.mut_lfsr,
                          cfg=cfg, ffm=ffm)
    out = ops.ga_generation(
        st.x, st.sel_lfsr, st.cross_lfsr, st.mut_lfsr,
        cfg=dataclasses.replace(cfg, sel_lane="gather"), ffm=ffm)
    assert out[0].shape == st.x.shape


def test_kernel_rejects_non_pow2_population():
    cfg = G.GAConfig(n=30, c=10, v=2, seed=1, mode="arith",
                     sel_lane="gather")
    ffm = _ffm("F3", cfg)
    st = _states(cfg, 1)
    with pytest.raises(ValueError, match="power-of-two"):
        ops.ga_generation(st.x, st.sel_lfsr, st.cross_lfsr, st.mut_lfsr,
                          cfg=cfg, ffm=ffm)
