"""Paper Table 1: generations/s vs population size N (m=20).

The FPGA reports ~16.8k gens/s at N=4 falling to ~11.5k at N=64 (50 MHz
clock / 3).  We report the engine's CPU wall-clock generations/s through the
`repro.ga` reference backend (a relative measure on this container) — the
TPU roofline-bound generations/s comes from the dry-run.
"""

from __future__ import annotations

from benchmarks.ga_common import bench_engine, time_call

K = 200


def run():
    rows = []
    for n in (4, 8, 16, 32, 64):
        eng = bench_engine("F3", n=n, m=20, generations=K, mode="lut")
        dt, _ = time_call(eng.run, iters=3)
        rows.append((f"table1_N{n}", dt / K * 1e6,
                     f"gens_per_s={K/dt:.0f}"))
    return rows
