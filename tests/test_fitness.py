"""FFM tests: the FitnessProgram abstraction — LUT (faithful stacked ROMs)
vs arithmetic (TPU-native) lowerings agree, the registry validates problem
shapes, and the bits -> values decode respects its bounds (property test)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fitness as F
from repro.core import ga as G
from repro.testing.hypothesis_fallback import given, settings, st


@pytest.mark.parametrize("name,n_vars", [("F1", 2), ("F2", 2), ("F3", 2),
                                         ("sphere", 4), ("rastrigin", 4)])
@pytest.mark.parametrize("c", [10, 13])
def test_lut_matches_arith_within_quantization(name, n_vars, c):
    """The stacked per-variable ROMs quantize the same function the arith
    stage evaluates — for the paper's F1–F3 AND the n-variable suite."""
    pdef = F.PROBLEMS[name]
    t = F.build_tables(pdef, c, n_vars)
    prog = F.compile_program(problem=name, n_vars=n_vars, bits_per_var=c,
                             mode="lut")
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 1 << c, (256, n_vars)), jnp.uint32)
    y_lut = np.asarray(prog.lut_stage(x)).astype(np.float64) / 2.0 ** t.frac_bits
    y_ari = np.asarray(prog.stage(x))
    scale = np.maximum(np.abs(y_ari), 1.0)
    # quantization: frac_bits rounding + γ table addressing granularity
    tol = (2.0 ** -t.frac_bits) * (2 + n_vars) \
        + (2.0 ** t.delta_shift) * 2.0 ** -t.frac_bits
    assert np.max(np.abs(y_lut - y_ari) / scale) < max(tol, 1e-2)


def test_tables_fixed_point_autoscale():
    t1 = F.build_tables(F.F1, 13, 2)   # F1 spans ±6.9e10 -> negative frac bits
    assert t1.frac_bits < 0
    t3 = F.build_tables(F.F3, 10, 2)   # F3 small range -> fractional precision
    assert t3.frac_bits > 0
    assert t3.gamma_t is not None      # sqrt needs the third ROM
    t2 = F.build_tables(F.F2, 10, 2)
    assert t2.gamma_t is None          # identity γ -> ROM elided (paper F1/F2)
    # per-variable ROMs stack on the leading axis
    assert F.build_tables(F.PROBLEMS["rastrigin"], 8, 6).var_t.shape == (6, 256)


def test_non_separable_problems_reject_lut():
    for name in ("rosenbrock", "ackley"):
        assert not F.PROBLEMS[name].separable
        with pytest.raises(ValueError, match="separable"):
            F.build_tables(F.PROBLEMS[name], 10, 4)
        with pytest.raises(ValueError, match="separable"):
            F.compile_program(problem=name, n_vars=4, bits_per_var=10,
                              mode="lut")
        # arith mode compiles fine and reports its modes honestly
        prog = F.compile_program(problem=name, n_vars=4, bits_per_var=10)
        assert prog.modes == ("arith",)
        with pytest.raises(ValueError, match="arith"):
            prog.fitness("lut")


def test_registry_resolution_and_validation():
    pdef, v = F.resolve_problem("rastrigin:8")
    assert pdef.name == "rastrigin" and v == 8
    assert F.resolve_problem("F3") == (F.F3, None)
    with pytest.raises(ValueError, match="unknown problem"):
        F.resolve_problem("nope")
    with pytest.raises(ValueError, match="integer"):
        F.resolve_problem("sphere:abc")
    # paper problems pin V=2
    with pytest.raises(ValueError, match="V=2"):
        F.compile_program(problem="F3", n_vars=5, bits_per_var=10)
    # rosenbrock's coupled terms need at least two variables
    with pytest.raises(ValueError, match="at least 2"):
        F.compile_program(problem="rosenbrock", n_vars=1, bits_per_var=10)


def test_known_optima_of_nvar_suite():
    """Every registry problem evaluates its known optimum correctly."""
    zeros = np.zeros((1, 4), np.float32)
    assert float(F.PROBLEMS["sphere"].f(zeros)[0]) == 0.0
    assert float(F.PROBLEMS["rastrigin"].f(zeros)[0]) == pytest.approx(0.0, abs=1e-4)
    assert float(F.PROBLEMS["ackley"].f(zeros)[0]) == pytest.approx(0.0, abs=1e-4)
    ones = np.ones((1, 4), np.float32)
    assert float(F.PROBLEMS["rosenbrock"].f(ones)[0]) == 0.0


def test_decode_domain_mapping():
    v = F.decode(jnp.asarray([0, (1 << 10) - 1]), 10, (-128.0, 127.0))
    np.testing.assert_allclose(np.asarray(v), [-128.0, 127.0], rtol=1e-6)


@given(st.integers(4, 16), st.integers(1, 6), st.integers(0, 10_000),
       st.floats(-100.0, 99.0), st.floats(0.5, 200.0))
@settings(max_examples=30, deadline=None)
def test_decode_round_trip_stays_in_bounds(c, n_vars, seed, lo, width):
    """Blackbox decode property: any c-bit gene pattern decodes inside its
    per-variable box, endpoints map to the box edges exactly, and the
    mapping is monotone in the gene value."""
    hi = lo + width
    prog = F.compile_program(fitness=lambda p: jnp.sum(p, -1),
                             bounds=((lo, hi),) * n_vars, bits_per_var=c)
    rng = np.random.default_rng(seed)
    # full uint32 words: decode must mask to c bits first
    x = jnp.asarray(rng.integers(0, 1 << 32, (64, n_vars), dtype=np.uint64)
                    .astype(np.uint32))
    vals = np.asarray(prog.decode(x))
    assert vals.shape == (64, n_vars)
    eps = 1e-4 * max(abs(lo), abs(hi), 1.0)
    assert (vals >= lo - eps).all() and (vals <= hi + eps).all()
    ends = np.asarray(prog.decode(
        jnp.asarray([[0] * n_vars, [(1 << c) - 1] * n_vars], jnp.uint32)))
    np.testing.assert_allclose(ends[0], lo, rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(ends[1], hi, rtol=1e-5, atol=1e-3)
    u = np.sort(rng.integers(0, 1 << c, 16))
    mono = np.asarray(prog.decode(
        jnp.asarray(np.tile(u[:, None], (1, n_vars)), jnp.uint32)))
    assert (np.diff(mono[:, 0]) >= 0).all()


@pytest.mark.parametrize("name,n,m,k", [("F1", 32, 26, 100),
                                        ("F3", 64, 20, 100)])
def test_paper_convergence_claims(name, n, m, k):
    """Paper Figs. 11–12: F1 (N=32, m=26) reaches its global minimum within
    100 generations; F3 (N=64, m=20) gets near zero."""
    pdef = F.PROBLEMS[name]
    best = np.inf
    for seed in (1, 2, 3):
        cfg = G.GAConfig(n=n, c=m // 2, v=2, mutation_rate=0.05, seed=seed,
                         mode="lut")
        t = F.build_tables(pdef, m // 2, 2)
        out = G.run_scan(cfg, G.make_lut_fitness(t), k)
        best = min(best, float(out.best_y) / 2.0 ** t.frac_bits)
    if name == "F1":
        target = float(pdef.f(np.array([0.0, -4096.0])))
        assert best <= target * 0.98  # within 2% of the global minimum
    else:
        assert best < 2.0             # near zero (grid-limited)
