"""Selection-method variants (paper Sec. 2 surveys these; the hardware
implements tournament-of-2 — we provide the others as drop-in SMs so the
engine covers the survey, all full-parallel).

Each returns (selected population W, new lfsr state); all consume the same
(2, N) LFSR bank as the tournament SM so the GAState layout is unchanged.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lfsr
from repro.core.ga import GAConfig


def tournament(x, y, sel_lfsr, cfg: GAConfig):
    """The paper's SM: N parallel 2-way tournaments (re-exported)."""
    from repro.core.ga import _select
    return _select(x, y, sel_lfsr, cfg)


def tournament_k(x, y, sel_lfsr, cfg: GAConfig, k: int = 4):
    """k-way tournament: draw k indices per slot (k/2 draws per bank lane by
    re-stepping), pick the best.  Stronger selection pressure than 2-way."""
    n = cfg.n
    state = sel_lfsr
    idx = []
    for _ in range(k):
        state, r = lfsr.draw(state, cfg.steps_per_draw)
        i = lfsr.truncate(r[0] ^ r[1], cfg.idx_bits).astype(jnp.int32)
        if n & (n - 1):
            i = i % n
        idx.append(i)
    idx = jnp.stack(idx, axis=1)                       # (N, k)
    ys = y[idx].astype(jnp.float32)                    # (N, k)
    pick = jnp.argmin(ys, axis=1) if cfg.minimize else jnp.argmax(ys, axis=1)
    winner = jnp.take_along_axis(idx, pick[:, None], axis=1)[:, 0]
    return x[winner], state


def roulette(x, y, sel_lfsr, cfg: GAConfig):
    """Fitness-proportional selection via inverse-CDF on LFSR draws.

    Minimization uses (max - y) weighting; ties/flat fitness degrade to
    uniform — matching the classical definition."""
    yf = y.astype(jnp.float32)
    w = (jnp.max(yf) - yf) if cfg.minimize else (yf - jnp.min(yf))
    w = w + 1e-9
    cdf = jnp.cumsum(w) / jnp.sum(w)                   # (N,)
    state, r = lfsr.draw(sel_lfsr, cfg.steps_per_draw)
    u = (r[0].astype(jnp.float32) / jnp.float32(2 ** 32))  # (N,) in [0,1)
    sel = jnp.searchsorted(cdf, u)
    sel = jnp.clip(sel, 0, cfg.n - 1)
    return x[sel], state


def rank(x, y, sel_lfsr, cfg: GAConfig):
    """Linear-rank selection: probability ∝ (N - rank)."""
    yf = y.astype(jnp.float32)
    order = jnp.argsort(yf) if cfg.minimize else jnp.argsort(-yf)
    ranks = jnp.zeros((cfg.n,), jnp.float32).at[order].set(
        jnp.arange(cfg.n, 0, -1, dtype=jnp.float32))
    cdf = jnp.cumsum(ranks) / jnp.sum(ranks)
    state, r = lfsr.draw(sel_lfsr, cfg.steps_per_draw)
    u = r[0].astype(jnp.float32) / jnp.float32(2 ** 32)
    sel = jnp.clip(jnp.searchsorted(cdf, u), 0, cfg.n - 1)
    return x[sel], state


def with_elitism(select_fn, n_elite: int = 1):
    """Wrap any SM so the n_elite best individuals always survive into W
    (slots 0..n_elite-1, i.e. they may still be mutated — set MR/P
    accordingly, or place them beyond index P to protect them)."""

    def fn(x, y, sel_lfsr, cfg: GAConfig):
        w, state = select_fn(x, y, sel_lfsr, cfg)
        yf = y.astype(jnp.float32)
        best = jnp.argsort(yf if cfg.minimize else -yf)[:n_elite]
        w = w.at[jnp.arange(n_elite) + cfg.p].set(x[best]) \
            if cfg.p + n_elite <= cfg.n else w.at[:n_elite].set(x[best])
        return w, state

    return fn


SELECTORS = {"tournament": tournament, "tournament4": tournament_k,
             "roulette": roulette, "rank": rank}


def generation_with(selector, state, cfg: GAConfig, fit):
    """A GA generation using an alternative SM (same CM/MM as the paper)."""
    from repro.core import ga as G
    y = fit(state.x)
    w, sel_lfsr = selector(state.x, y, state.sel_lfsr, cfg)
    z, cross_lfsr = G._crossover(w, state.cross_lfsr, cfg)
    x_new, mut_lfsr = G._mutate(z, state.mut_lfsr, cfg)
    return G.GAState(x_new, sel_lfsr, cross_lfsr, mut_lfsr, state.k + 1), y
