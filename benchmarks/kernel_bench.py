"""Fused Pallas kernel vs pure-JAX GA path (interpret mode on CPU — the
relative number is architecture-bound on TPU; see EXPERIMENTS.md §Perf)."""

from __future__ import annotations

import functools

import jax

from benchmarks.ga_common import time_call
from repro.core import fitness as F
from repro.core import ga as G
from repro.core import islands as ISL
from repro.kernels import ops

K = 50


def run():
    rows = []
    cfg = G.GAConfig(n=256, c=10, v=2, mutation_rate=0.02, seed=1,
                     mode="arith")
    spec = F.ArithSpec.for_problem(F.F3)
    icfg = ISL.IslandConfig(ga=cfg, n_islands=8)
    st = ISL.init_islands_fast(icfg)

    kern = functools.partial(ops.ga_run_kernel, cfg=cfg, spec=spec)
    dt_k, _ = time_call(lambda: kern(st, K), iters=2)
    rows.append(("kernel_fused_8x256", dt_k / K * 1e6,
                 f"island_gens_per_s={8*K/dt_k:.0f}"))

    fit = G.fitness_for_problem(F.F3, cfg)
    pure = jax.jit(lambda s: ISL._local_generations(s, icfg, fit, K))
    dt_p, _ = time_call(lambda: pure(st), iters=2)
    rows.append(("pure_jax_8x256", dt_p / K * 1e6,
                 f"island_gens_per_s={8*K/dt_p:.0f},"
                 f"kernel_speedup={dt_p/dt_k:.2f}x(cpu-interpret)"))
    return rows
