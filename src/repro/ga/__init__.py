"""`repro.ga` — the public GA engine API (one spec, topology × executor).

The paper's contribution is a single full-parallel datapath (FFM→SM→CM→MM)
that scales by swapping hardware arrangements.  This package is that idea as
an API: a frozen :class:`GASpec` describes *what* to solve (problem,
encoding, operator pipeline, run policy, topology) and the :class:`Engine`
decides *how*.  Backends are compositions of an *executor* (how a block of
generations is stepped) and a *topology* (how populations are laid out and
exchanged):

    =============  ===========  ============  ===========================
    backend        executor     topology      notes
    =============  ===========  ============  ===========================
    reference      JAX scan     single        any operators, lut or arith
                                              FFM, vmapped `n_repeats`
    fused          Pallas       single        VMEM-resident state, MXU
                   kernel                     one-hot tournaments; the
                                              spec's FitnessProgram.stage
                                              is traced in as the FFM (any
                                              registered problem or
                                              blackbox); bit-identical to
                                              reference; `gens_per_epoch`
                                              generations per launch
    islands        JAX scan     island_ring   ring migration; shard_mapped
                                              over a mesh when given
    fused-islands  Pallas       island_ring   ring migration *between*
                   kernel                     kernel launches; on a mesh,
                                              one launch per shard with
                                              `ppermute` migration —
                                              bit-identical to one device
    eager          python loop  single        non-traceable fitness
                                              (operators stay jitted)
    =============  ===========  ============  ===========================

    Problems are a registry too (`repro.core.fitness.PROBLEMS`): the
    paper's F1–F3 plus the n-variable suite (sphere / rastrigin /
    rosenbrock / ackley, `problem="rastrigin:8"` picks V) and
    user-registered definitions (`ga.register_problem`); each compiles to
    a `FitnessProgram` lowering it to LUT ROMs, the XLA arith path and
    the in-kernel FFM stage.

Typical use::

    from repro import ga

    result = ga.solve(ga.GASpec(problem="F1", n=32, bits_per_var=13,
                                mode="lut", generations=100))
    result = ga.solve(ga.paper_spec("F3", n=64, m=20, mode="arith"),
                      backend="fused")

Execution knobs (mesh, interpret, cost table, plan override, the streamed
mode's tile/budget) ride in one frozen :class:`EngineOptions` shared by
`Engine`, `PackedEngine`, `GAScheduler` and the CLIs; how a run executed
comes back as typed :class:`RunTelemetry` (``result.telemetry.plan`` /
``.topology`` / ``.per_repeat``) — the old ``result.extras`` dict is a
deprecated view.

Operator stages are pluggable protocols with registries
(`ga.SELECTION` / `ga.CROSSOVER` / `ga.MUTATION`; see
:mod:`repro.ga.operators`), chunked streaming + checkpoint/resume live on
:meth:`Engine.run_chunked`.

The pre-engine entry points (`core.ga.run`/`run_unjitted`,
`islands.run_local`/`run_sharded`, `kernels.ops.ga_run_kernel`) have been
REMOVED after their deprecation cycle — the mapping, for code migrating
from them:

    core.ga.run(cfg, fit, k)            -> solve(spec, backend="reference")
    core.ga.run_unjitted(cfg, fit, k)   -> solve(spec, backend="eager")
                                           (spec.jit_fitness=False)
    kernels.ops.ga_run_kernel(...)      -> solve(spec, backend="fused")
    islands.run_local/run_sharded(...)  -> solve(spec, backend="islands")
                                           (spec.n_islands>1[, mesh=...])
    core.evolve.evolve(fn, bounds)      -> unchanged signature, now a
                                           GASpec + Engine underneath
"""

from repro.core.fitness import (PROBLEMS, FitnessProgram, ProblemDef,
                                compile_program, register_problem,
                                resolve_problem)
from repro.ga.spec import GASpec, paper_spec
from repro.ga.operators import (CROSSOVER, MUTATION, PAPER_PIPELINE,
                                SELECTION, CrossoverOp, MutationOp,
                                SelectionOp, make_apply_ops, make_generation,
                                register_crossover, register_mutation,
                                register_selection)
from repro.ga.options import EngineOptions, resolve_options
from repro.ga.telemetry import (TELEMETRY_VERSION, PlanInfo, ReplicaStats,
                                RunTelemetry, TopologyInfo)
from repro.ga.backends import (BACKENDS, EXECUTORS, TOPOLOGIES, Backend,
                               Executor, Segment, Topology)
from repro.ga.compile_cache import RUNNER_CACHE, CompileCache
from repro.ga.engine import (BackendUnsupported, Engine, EngineResult,
                             PackedEngine, capability_matrix,
                             repack_checkpoint, resolve_backend, solve)

__all__ = [
    "GASpec", "paper_spec",
    "PROBLEMS", "ProblemDef", "FitnessProgram", "compile_program",
    "register_problem", "resolve_problem",
    "Engine", "EngineResult", "PackedEngine", "solve", "resolve_backend",
    "capability_matrix", "BackendUnsupported", "repack_checkpoint",
    "EngineOptions", "resolve_options",
    "RunTelemetry", "PlanInfo", "TopologyInfo", "ReplicaStats",
    "TELEMETRY_VERSION",
    "RUNNER_CACHE", "CompileCache",
    "BACKENDS", "Backend", "Segment",
    "EXECUTORS", "TOPOLOGIES", "Executor", "Topology",
    "SELECTION", "CROSSOVER", "MUTATION", "PAPER_PIPELINE",
    "SelectionOp", "CrossoverOp", "MutationOp",
    "register_selection", "register_crossover", "register_mutation",
    "make_generation", "make_apply_ops",
]
