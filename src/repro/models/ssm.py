"""Mamba2 — State Space Duality (SSD) blocks, chunked (arXiv:2405.21060).

Train/prefill uses the chunked dual form: intra-chunk attention-like einsums
(MXU-friendly) + an associative scan over chunk states (log-depth, no
sequential bottleneck).  Decode carries the (B, H, N, P) SSM state and the
depthwise-conv tail — O(1) per token, which is why mamba2/zamba2 run the
long_500k shape that quadratic attention cannot.

Layout: x_inner (B, S, H, P) with H = d_inner/headdim SSM heads on the
"heads" (TP) logical axis; B/C projections are per-group (G groups, G=1 here)
and replicated.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding as SH
from repro.models import common as C


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_state: int = 128          # N
    headdim: int = 64           # P
    expand: int = 2
    n_groups: int = 1           # G
    conv_width: int = 4
    chunk: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        assert self.d_inner % self.headdim == 0
        return self.d_inner // self.headdim

    @property
    def conv_channels(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state

    @property
    def in_proj_dim(self) -> int:
        # z, x_inner, B, C, dt
        return 2 * self.d_inner + 2 * self.n_groups * self.d_state + self.n_heads


def ssm_defs(cfg: SSMConfig) -> Dict[str, C.ParamDef]:
    d = cfg.d_model
    return {
        "in_proj": C.ParamDef((d, cfg.in_proj_dim), ("embed", "mlp")),
        "conv_w": C.ParamDef((cfg.conv_width, cfg.conv_channels), (None, "mlp"),
                             scale=0.2),
        "conv_b": C.ParamDef((cfg.conv_channels,), ("mlp",), init="zeros"),
        "a_log": C.ParamDef((cfg.n_heads,), ("heads",), init="zeros",
                            dtype=jnp.float32),
        "dt_bias": C.ParamDef((cfg.n_heads,), ("heads",), init="zeros",
                              dtype=jnp.float32),
        "d_skip": C.ParamDef((cfg.n_heads,), ("heads",), init="ones",
                             dtype=jnp.float32),
        "norm_w": C.ParamDef((cfg.d_inner,), ("mlp",), init="zeros"),
        "out_proj": C.ParamDef((cfg.d_inner, d), ("mlp", "embed")),
    }


def _split_proj(proj: jax.Array, cfg: SSMConfig):
    di, gn, h = cfg.d_inner, cfg.n_groups * cfg.d_state, cfg.n_heads
    z = proj[..., :di]
    xbc = proj[..., di: di + di + 2 * gn]   # conv input: x_inner ‖ B ‖ C
    dt = proj[..., di + di + 2 * gn:]
    return z, xbc, dt


def _split_xbc(xbc: jax.Array, cfg: SSMConfig):
    di, gn = cfg.d_inner, cfg.n_groups * cfg.d_state
    x = xbc[..., :di]
    b = xbc[..., di: di + gn]
    c = xbc[..., di + gn:]
    return x, b, c


def _causal_conv(xbc: jax.Array, w: jax.Array, bias: jax.Array) -> jax.Array:
    """Depthwise causal conv, width W: (B,S,C) -> (B,S,C)."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    s = xbc.shape[1]
    out = sum(pad[:, i: i + s, :] * w[i][None, None, :] for i in range(width))
    return jax.nn.silu((out + bias[None, None, :]).astype(jnp.float32)
                       ).astype(xbc.dtype)


def _dt_activation(dt: jax.Array, dt_bias: jax.Array, cfg: SSMConfig) -> jax.Array:
    return jax.nn.softplus(dt.astype(jnp.float32) + dt_bias[None, None, :])


def _ssd_chunked(x, dt, a, b, c, cfg: SSMConfig,
                 init_state: Optional[jax.Array] = None):
    """SSD dual form.

    x: (B,S,H,P) f32; dt: (B,S,H) f32; a: (H,) f32 (negative);
    b, c: (B,S,G,N) f32.  Returns (y (B,S,H,P), final_state (B,H,N,P)).
    """
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    q = cfg.chunk
    assert s % q == 0, f"seq {s} % chunk {q} != 0"
    nc = s // q
    hg = h // g  # heads per group

    # expand groups to heads
    bh = jnp.repeat(b, hg, axis=2)  # (B,S,H,N)
    ch = jnp.repeat(c, hg, axis=2)

    xc = x.reshape(bsz, nc, q, h, p)
    dtc = dt.reshape(bsz, nc, q, h)
    bc = bh.reshape(bsz, nc, q, h, n)
    cc = ch.reshape(bsz, nc, q, h, n)

    da = dtc * a[None, None, None, :]                   # (B,Nc,Q,H) ≤ 0
    cs = jnp.cumsum(da, axis=2)                         # within-chunk cumsum
    x_dt = xc * dtc[..., None]

    # intra-chunk (attention-like, lower-triangular decay kernel)
    li = cs[:, :, :, None, :] - cs[:, :, None, :, :]    # (B,Nc,Q,Q,H) i,j
    tri = jnp.tril(jnp.ones((q, q), bool))
    l_mat = jnp.where(tri[None, None, :, :, None], jnp.exp(li), 0.0)
    y_intra = jnp.einsum("bcihn,bcjhn,bcijh,bcjhp->bcihp",
                         cc[..., :, :], bc, l_mat, x_dt)

    # chunk states
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)       # (B,Nc,Q,H)
    states = jnp.einsum("bcjhn,bcjh,bcjhp->bchnp", bc, decay_to_end, x_dt)
    lam = jnp.exp(cs[:, :, -1, :])                      # (B,Nc,H)

    # inter-chunk recurrence: associative scan over (Λ, S)
    def combine(e1, e2):
        l1, s1 = e1
        l2, s2 = e2
        return l1 * l2, s1 * l2[..., None, None] + s2

    lam_s, st_s = jax.lax.associative_scan(combine, (lam, states), axis=1)
    if init_state is None:
        prev = jnp.concatenate(
            [jnp.zeros_like(st_s[:, :1]), st_s[:, :-1]], axis=1)
    else:
        # incorporate an incoming state (prefill continuation)
        shifted = jnp.concatenate(
            [jnp.zeros_like(st_s[:, :1]), st_s[:, :-1]], axis=1)
        lam_prev = jnp.concatenate(
            [jnp.ones_like(lam_s[:, :1]), lam_s[:, :-1]], axis=1)
        prev = shifted + init_state[:, None] * lam_prev[..., None, None]

    y_inter = jnp.einsum("bcihn,bchnp,bcih->bcihp",
                         cc, prev, jnp.exp(cs))
    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    final = st_s[:, -1]
    if init_state is not None:
        final = final + init_state * lam_s[:, -1][..., None, None]
    return y, final


def forward(p, x: jax.Array, cfg: SSMConfig,
            return_cache: bool = False):
    """Full-sequence mamba2 block (train / prefill). x: (B,S,D).

    With return_cache=True also returns the decode cache (final SSM state +
    the conv tail), i.e. this doubles as `prefill`.
    """
    s_orig = x.shape[1]
    pad = (-s_orig) % cfg.chunk
    if pad:
        # causal: trailing zero-pad never influences earlier outputs; the
        # final SSM state however would pick up extra decay, so caching
        # requires an aligned length.
        assert not return_cache, "prefill length must be a chunk multiple"
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    proj = C.dense(x, p["in_proj"])
    z, xbc, dt = _split_proj(proj, cfg)
    conv_tail = xbc[:, -(cfg.conv_width - 1):, :]
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xi, b, c = _split_xbc(xbc, cfg)

    bsz, s, _ = x.shape
    h, pd, g, n = cfg.n_heads, cfg.headdim, cfg.n_groups, cfg.d_state
    xi = xi.reshape(bsz, s, h, pd).astype(jnp.float32)
    xi = SH.constrain(xi, "batch", None, "heads", None)
    b = b.reshape(bsz, s, g, n).astype(jnp.float32)
    c = c.reshape(bsz, s, g, n).astype(jnp.float32)
    dtv = _dt_activation(dt, p["dt_bias"], cfg)
    a = -jnp.exp(p["a_log"])

    y, state = _ssd_chunked(xi, dtv, a, b, c, cfg)
    y = y + xi * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, s, cfg.d_inner).astype(x.dtype)
    y = C.rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                  p["norm_w"])
    out = C.dense(y, p["out_proj"])
    if pad:
        out = out[:, :s_orig]
    if return_cache:
        return out, {"state": state, "conv": conv_tail}
    return out


# ---------------------------------------------------------------------------
# Decode (recurrent form)
# ---------------------------------------------------------------------------


def cache_defs(cfg: SSMConfig, batch: int) -> Dict[str, C.ParamDef]:
    return {
        "state": C.ParamDef((batch, cfg.n_heads, cfg.d_state, cfg.headdim),
                            ("batch", "heads", None, None), init="zeros",
                            dtype=jnp.float32),
        "conv": C.ParamDef((batch, cfg.conv_width - 1, cfg.conv_channels),
                           ("batch", None, "mlp"), init="zeros"),
    }


def decode_step(p, x: jax.Array, cfg: SSMConfig, cache):
    """One token. x: (B,1,D); cache: {state (B,H,N,P), conv (B,W-1,C)}."""
    bsz = x.shape[0]
    proj = C.dense(x, p["in_proj"])
    z, xbc, dt = _split_proj(proj, cfg)

    # conv with cached tail
    window = jnp.concatenate([cache["conv"], xbc], axis=1)  # (B,W,C)
    w = p["conv_w"]
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                          w.astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    xbc_act = jax.nn.silu(conv_out)[:, None, :].astype(x.dtype)
    conv_cache = window[:, 1:, :]

    xi, b, c = _split_xbc(xbc_act, cfg)
    h, pd, g, n = cfg.n_heads, cfg.headdim, cfg.n_groups, cfg.d_state
    xi = xi.reshape(bsz, h, pd).astype(jnp.float32)
    b = b.reshape(bsz, g, n).astype(jnp.float32)
    c = c.reshape(bsz, g, n).astype(jnp.float32)
    hg = h // g
    bhh = jnp.repeat(b, hg, axis=1)   # (B,H,N)
    chh = jnp.repeat(c, hg, axis=1)

    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"][None, :])
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dtv * a[None, :])    # (B,H)

    state = cache["state"] * da[..., None, None] + \
        jnp.einsum("bhn,bhp->bhnp", bhh, xi * dtv[..., None])
    y = jnp.einsum("bhn,bhnp->bhp", chh, state) + \
        xi * p["d_skip"][None, :, None]
    y = y.reshape(bsz, 1, cfg.d_inner).astype(x.dtype)
    y = C.rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                  p["norm_w"])
    out = C.dense(y, p["out_proj"])
    return out, {"state": state, "conv": conv_cache}
