"""Checkpointing: sharded, async-capable, elastic-restore.

Layout (one directory per step):
    ckpt_dir/step_000100/
        manifest.json        # step, tree structure, shapes/dtypes, mesh info
        shard_<host>.npz     # this host's addressable shard data

Design points for the 1000+-node story:
  * every host writes only its addressable shards (no gather to host 0);
  * restore re-shards to whatever mesh is active — a job restarted on a
    different topology (elastic scaling) reassembles from the manifest;
  * `save_async` runs serialization off-thread so the train loop overlaps
    checkpoint I/O with compute;
  * integrity: manifest written last (atomic rename) — a crash mid-write
    leaves no valid-looking checkpoint; `latest_step` only trusts manifests.
    Each shard's CRC32 rides in the manifest, `validate_step` recomputes
    it, and `latest_step` skips a step whose shards fail validation
    (falling back to the newest earlier valid step with a warning) instead
    of letting resume crash mid-restore on an opaque npz error.  `restore`
    re-checks before reading and raises the typed `CheckpointCorrupt`.

Fault injection: `save` consults `repro.faults` (the ambient
``REPRO_GA_FAULTS`` injector, or one passed via ``faults=``) at the
``ckpt_corrupt`` site — when armed, it flips bytes in the just-written
shard AFTER its checksum was recorded, simulating bit-rot the validation
path must catch.

On this single-host container each "host" is host 0; the pathing and
manifest format are multi-host from day one.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import time
import warnings
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro import faults as FLT

_SEP = "/"


class CheckpointCorrupt(RuntimeError):
    """A checkpoint step failed shard-checksum validation."""


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = leaf
    return flat


def _crc32_file(path: str) -> int:
    crc = 0
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            crc = zlib.crc32(block, crc)
    return crc


def save(ckpt_dir: str, step: int, tree, extra: Optional[Dict] = None,
         host_id: int = 0, *, faults=None, fault_tag: str = "") -> str:
    """Synchronous sharded save. Returns the checkpoint path.

    Each shard's CRC32 + byte count land in the manifest so readers can
    validate before trusting the step.  `faults`/`fault_tag` hook the
    ``ckpt_corrupt`` injection site (see `repro.faults`): when a rule
    fires, the shard is corrupted AFTER its checksum was recorded."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    arrays, meta = {}, {}
    for k, v in flat.items():
        arr = np.asarray(jax.device_get(v))
        logical_dtype = str(arr.dtype)
        if logical_dtype not in ("float64", "float32", "float16", "int64",
                                 "int32", "int16", "int8", "uint64", "uint32",
                                 "uint16", "uint8", "bool"):
            # ml_dtypes (bfloat16, fp8...) — store the raw bytes
            arr = arr.view(np.uint8 if arr.dtype.itemsize == 1 else np.uint16)
        arrays[k.replace(_SEP, "__")] = arr
        meta[k] = {"shape": list(arr.shape), "dtype": logical_dtype}
    shard_name = f"shard_{host_id}.npz"
    shard_path = os.path.join(tmp, shard_name)
    np.savez(shard_path, **arrays)
    shards = {shard_name: {"crc32": _crc32_file(shard_path),
                           "bytes": os.path.getsize(shard_path)}}
    injector = FLT.resolve_faults(faults)
    if injector is not None:
        rule = injector.fires("ckpt_corrupt",
                              tag=f"{fault_tag}|{ckpt_dir}|step={step}")
        if rule is not None:   # bit-rot AFTER the checksum: readers must catch
            FLT.corrupt_file(shard_path, seed=rule.seed)
    manifest = {"step": step, "keys": meta, "extra": extra or {},
                "n_hosts": 1, "time": time.time(), "shards": shards}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    return path


class AsyncCheckpointer:
    """Overlap checkpoint serialization with training compute."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def save(self, ckpt_dir: str, step: int, tree, extra=None):
        self.wait()
        # device_get on the main thread (cheap on CPU; on TPU this is the
        # D2H copy we want off the critical path — but values must be
        # snapshotted before the optimizer mutates them).
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            self.last_path = save(ckpt_dir, step, host_tree, extra)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def validate_step(ckpt_dir: str, step: int) -> Optional[str]:
    """None when the step's shards match their manifest checksums, else a
    human-readable reason.  Manifests written before checksums existed
    (no "shards" key) validate trivially — they can't be checked."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return f"unreadable manifest: {e}"
    for shard_name, meta in (manifest.get("shards") or {}).items():
        shard_path = os.path.join(path, shard_name)
        if not os.path.exists(shard_path):
            return f"missing shard {shard_name}"
        if os.path.getsize(shard_path) != int(meta["bytes"]):
            return (f"shard {shard_name} is {os.path.getsize(shard_path)} "
                    f"bytes, manifest says {meta['bytes']}")
        crc = _crc32_file(shard_path)
        if crc != int(meta["crc32"]):
            return (f"shard {shard_name} checksum {crc:#010x} != manifest "
                    f"{int(meta['crc32']):#010x}")
    return None


def latest_step(ckpt_dir: str, validate: bool = True) -> Optional[int]:
    """Newest step whose manifest exists — and, with `validate` (the
    default), whose shards pass checksum validation: a corrupt newest step
    falls back to the previous valid one with a warning rather than
    handing resume a state that explodes mid-np.load."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp") and \
                os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            steps.append(int(d.split("_")[1]))
    for step in sorted(steps, reverse=True):
        if not validate:
            return step
        reason = validate_step(ckpt_dir, step)
        if reason is None:
            return step
        warnings.warn(
            f"checkpoint step {step} in {ckpt_dir} failed validation "
            f"({reason}); falling back to the previous step", stacklevel=2)
    return None


def restore(ckpt_dir: str, step: int, tree_like,
            shardings=None, validate: bool = True) -> Tuple[Any, Dict]:
    """Restore into the structure of `tree_like`, re-sharding if shardings
    (a matching pytree of NamedSharding or None) is given — this is the
    elastic-restart path: the saved mesh need not match the current one.
    With `validate` (default), shard checksums are re-checked first and a
    mismatch raises `CheckpointCorrupt` instead of an opaque npz error."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    if validate:
        reason = validate_step(ckpt_dir, step)
        if reason is not None:
            raise CheckpointCorrupt(
                f"checkpoint step {step} in {ckpt_dir} is corrupt: {reason}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "shard_0.npz"))

    flat_like = _flatten(tree_like)
    shard_flat = _flatten(shardings) if shardings is not None else {}
    keymeta = manifest["keys"]
    out = {}
    for k, like in flat_like.items():
        arr = data[k.replace(_SEP, "__")]
        logical = keymeta.get(k, {}).get("dtype", str(arr.dtype))
        if logical != str(arr.dtype):
            if arr.dtype in (np.uint16, np.uint8) and logical not in (
                    "uint16", "uint8"):
                arr = arr.view(jax.numpy.dtype(logical))  # raw-byte round-trip
            else:
                arr = arr.astype(logical)
        want_dtype = getattr(like, "dtype", arr.dtype)
        v = arr if str(want_dtype) == str(arr.dtype) else \
            np.asarray(jax.numpy.asarray(arr).astype(want_dtype))
        sh = shard_flat.get(k)
        out[k] = jax.device_put(v, sh) if sh is not None else jax.numpy.asarray(v)

    leaves_like, treedef = jax.tree_util.tree_flatten(tree_like)
    keys = list(_flatten(tree_like).keys())
    restored = jax.tree_util.tree_unflatten(treedef, [out[k] for k in keys])
    return restored, manifest.get("extra", {})
