"""Explicit-DP training step with int8 gradient compression.

With pjit, the DP gradient reduction is implicit (fused into the backward)
and cannot be compressed.  This variant shard_maps the grad computation over
the DP axes, quantizes local grads to int8 with error feedback, reduces, and
applies AdamW — ~4× less DP traffic for bf16/f32 grads.  The residual is
carried in the optimizer state, so long-run updates stay unbiased
(tests/test_ckpt_compress.py::test_error_feedback_unbiased_over_time, and
the end-to-end check in tests/test_compressed_dp.py).

Use when the DP axis rides slow links (the cross-pod "pod" axis): per-pod
gradients compress before crossing DCN.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from repro.sharding import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.optim import adamw as OPT
from repro.optim import compress as GC
from repro.train import step as TS


def make_compressed_dp_step(cfg: ModelConfig, mesh: Mesh,
                            opt_cfg: Optional[OPT.AdamWConfig] = None,
                            dp_axes: Tuple[str, ...] = ("data",),
                            remat: bool = False) -> Callable:
    """Returns step(params, opt_state, residual, batch) ->
    (params, opt_state, residual, metrics).  Params replicated over dp_axes
    (pure DP; compose with TP by keeping "model" out of dp_axes)."""
    opt_cfg = opt_cfg or OPT.AdamWConfig()
    loss_fn = TS.make_loss_fn(cfg, remat=remat)

    def local_grads(params, batch):
        (loss, extras), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, grads

    def step(params, opt_state, residual, batch):
        def inner(params, residual, batch):
            loss, grads = local_grads(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            mean_grads, new_residual = GC.compress_psum(
                grads, residual, dp_axes)
            loss = jax.lax.pmean(loss, dp_axes[0])
            for ax in dp_axes[1:]:
                loss = jax.lax.pmean(loss, ax)
            return loss, mean_grads, new_residual

        replicated = P()
        batch_spec = jax.tree.map(lambda _: P(dp_axes), batch)
        sharded = shard_map(
            inner, mesh,
            in_specs=(jax.tree.map(lambda _: replicated, params),
                      jax.tree.map(lambda _: replicated, residual),
                      batch_spec),
            out_specs=(replicated,
                       jax.tree.map(lambda _: replicated, params),
                       jax.tree.map(lambda _: replicated, residual)))
        loss, grads, residual = sharded(params, residual, batch)
        params, opt_state, om = OPT.update(params, grads, opt_state, opt_cfg)
        return params, opt_state, residual, {"loss": loss, **om}

    return jax.jit(step)


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
