"""Checkpointing: sharded, async-capable, elastic-restore.

Layout (one directory per step):
    ckpt_dir/step_000100/
        manifest.json        # step, tree structure, shapes/dtypes, mesh info
        shard_<host>.npz     # this host's addressable shard data

Design points for the 1000+-node story:
  * every host writes only its addressable shards (no gather to host 0);
  * restore re-shards to whatever mesh is active — a job restarted on a
    different topology (elastic scaling) reassembles from the manifest;
  * `save_async` runs serialization off-thread so the train loop overlaps
    checkpoint I/O with compute;
  * integrity: manifest written last (atomic rename) — a crash mid-write
    leaves no valid-looking checkpoint; `latest_step` only trusts manifests.

On this single-host container each "host" is host 0; the pathing and
manifest format are multi-host from day one.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = leaf
    return flat


def save(ckpt_dir: str, step: int, tree, extra: Optional[Dict] = None,
         host_id: int = 0) -> str:
    """Synchronous sharded save. Returns the checkpoint path."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    arrays, meta = {}, {}
    for k, v in flat.items():
        arr = np.asarray(jax.device_get(v))
        logical_dtype = str(arr.dtype)
        if logical_dtype not in ("float64", "float32", "float16", "int64",
                                 "int32", "int16", "int8", "uint64", "uint32",
                                 "uint16", "uint8", "bool"):
            # ml_dtypes (bfloat16, fp8...) — store the raw bytes
            arr = arr.view(np.uint8 if arr.dtype.itemsize == 1 else np.uint16)
        arrays[k.replace(_SEP, "__")] = arr
        meta[k] = {"shape": list(arr.shape), "dtype": logical_dtype}
    np.savez(os.path.join(tmp, f"shard_{host_id}.npz"), **arrays)
    manifest = {"step": step, "keys": meta, "extra": extra or {},
                "n_hosts": 1, "time": time.time()}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    return path


class AsyncCheckpointer:
    """Overlap checkpoint serialization with training compute."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def save(self, ckpt_dir: str, step: int, tree, extra=None):
        self.wait()
        # device_get on the main thread (cheap on CPU; on TPU this is the
        # D2H copy we want off the critical path — but values must be
        # snapshotted before the optimizer mutates them).
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            self.last_path = save(ckpt_dir, step, host_tree, extra)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp") and \
                os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, tree_like,
            shardings=None) -> Tuple[Any, Dict]:
    """Restore into the structure of `tree_like`, re-sharding if shardings
    (a matching pytree of NamedSharding or None) is given — this is the
    elastic-restart path: the saved mesh need not match the current one."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "shard_0.npz"))

    flat_like = _flatten(tree_like)
    shard_flat = _flatten(shardings) if shardings is not None else {}
    keymeta = manifest["keys"]
    out = {}
    for k, like in flat_like.items():
        arr = data[k.replace(_SEP, "__")]
        logical = keymeta.get(k, {}).get("dtype", str(arr.dtype))
        if logical != str(arr.dtype):
            if arr.dtype in (np.uint16, np.uint8) and logical not in (
                    "uint16", "uint8"):
                arr = arr.view(jax.numpy.dtype(logical))  # raw-byte round-trip
            else:
                arr = arr.astype(logical)
        want_dtype = getattr(like, "dtype", arr.dtype)
        v = arr if str(want_dtype) == str(arr.dtype) else \
            np.asarray(jax.numpy.asarray(arr).astype(want_dtype))
        sh = shard_flat.get(k)
        out[k] = jax.device_put(v, sh) if sh is not None else jax.numpy.asarray(v)

    leaves_like, treedef = jax.tree_util.tree_flatten(tree_like)
    keys = list(_flatten(tree_like).keys())
    restored = jax.tree_util.tree_unflatten(treedef, [out[k] for k in keys])
    return restored, manifest.get("extra", {})
