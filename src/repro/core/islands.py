"""Island-model parallel GA — how the paper's one-FPGA datapath scales to pods.

The paper instantiates the full GA once per FPGA; its cited related work [19]
(Guo et al., multi-FPGA parallel GAs) scales by running isolated populations
("islands") that periodically exchange good individuals.  We map that to the
TPU production mesh:

  * a device holds `islands_per_device` independent populations,
    vmapped over the leading axis (the VPU analogue of replicated datapaths);
  * the global island array is sharded over EVERY mesh axis with `shard_map`;
  * every `migrate_every` generations the best individual of each island is
    ring-shipped to the next device with `jax.lax.ppermute`
    (collective-permute == the inter-FPGA links of [19]), replacing the
    recipient island's worst individual.

Migration is overlapped with compute by construction: the permute is issued
on a [I_local, V]-sized elite buffer (tiny) while the next local-generation
scan runs on values that do not depend on it until the splice point.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import ga as G
from repro.core import lfsr


@dataclasses.dataclass(frozen=True)
class IslandConfig:
    ga: G.GAConfig
    n_islands: int               # global island count I
    migrate_every: int = 16      # generations between migrations
    axis_names: tuple = ("data", "model")  # mesh axes the islands shard over


def init_islands(cfg: IslandConfig) -> G.GAState:
    """Stack of I island states with decorrelated seeds."""
    states = []
    for i in range(cfg.n_islands):
        sub = dataclasses.replace(cfg.ga, seed=cfg.ga.seed + 7919 * (i + 1))
        states.append(G.init_state(sub))
    return jax.tree.map(lambda *xs: jnp.stack(xs), *states)


def init_islands_fast(cfg: IslandConfig) -> G.GAState:
    """Vectorized init (no per-island python loop) for large I."""
    I, n, v = cfg.n_islands, cfg.ga.n, cfg.ga.v
    per = 2 * n + v * (n // 2) + 2 * v * n
    s = lfsr.seeds(cfg.ga.seed, I * per).reshape(I, per)
    sel = s[:, : 2 * n].reshape(I, 2, n)
    cross = s[:, 2 * n: 2 * n + v * (n // 2)].reshape(I, v, n // 2)
    mut = s[:, 2 * n + v * (n // 2): 2 * n + v * (n // 2) + v * n].reshape(I, v, n)
    init_bank = s[:, -v * n:].reshape(I, n, v)
    x = lfsr.truncate(lfsr.steps(init_bank, 8), cfg.ga.c)
    return G.GAState(x=x, sel_lfsr=sel, cross_lfsr=cross, mut_lfsr=mut,
                     k=jnp.zeros((I,), jnp.int32))


# ---------------------------------------------------------------------------
# Local (single-device) island stepping
# ---------------------------------------------------------------------------


def _local_generations(states: G.GAState, cfg: IslandConfig,
                       fit: G.FitnessFn, gens: int,
                       generation_fn=None) -> Tuple[G.GAState, jax.Array]:
    """Run `gens` generations on a stack of islands; returns final fitness.
    `generation_fn` swaps the operator pipeline (default: paper ops)."""
    step = functools.partial(generation_fn or G.generation, cfg=cfg.ga,
                             fit=fit)

    def one(st, _):
        st2, y = jax.vmap(lambda s: step(s))(st)
        return st2, None

    states, _ = jax.lax.scan(one, states, None, length=gens)
    y = jax.vmap(fit)(states.x)
    return states, y


def _splice_elites(states: G.GAState, y: jax.Array, elites: jax.Array,
                   cfg: IslandConfig) -> G.GAState:
    """Replace each island's worst individual with the incoming elite."""
    return splice_elites(states, y, elites, minimize=cfg.ga.minimize)


# ---------------------------------------------------------------------------
# Kernel-traceable migration math — THE rule set for elite/worst selection
# and splicing, shared verbatim by the XLA epoch path AND the Pallas
# resident-epoch kernel (kernels/ga_step.ga_epoch_kernel).  Everything here
# is gather/scatter-free: first-occurrence argmin/argmax is a min-reduction
# over a masked 2-D iota, and "gather row idx" / "scatter row idx" are a
# masked sum / a select — exact for uint32 (single nonzero per mask) and
# legal inside a TPU kernel, where dynamic per-row gathers are not.
# ---------------------------------------------------------------------------


def best_slot(y: jax.Array, *, minimize: bool) -> jax.Array:
    """First-occurrence best index per island: (I, N) -> int32 (I,).
    Matches jnp.argmin/argmax (which take the FIRST hit on ties) for
    finite fitness — the engine's contract.  NaN fitness is out of
    contract: the masked-iota form returns the out-of-range sentinel N
    (no slot matches), making take_slot/splice_at no-ops rather than
    propagating an argmin-style NaN index."""
    yf = y.astype(jnp.float32)
    m = (jnp.min(yf, axis=1, keepdims=True) if minimize
         else jnp.max(yf, axis=1, keepdims=True))
    iota = jax.lax.broadcasted_iota(jnp.int32, yf.shape, 1)
    return jnp.min(jnp.where(yf == m, iota, yf.shape[1]), axis=1)


def worst_slot(y: jax.Array, *, minimize: bool) -> jax.Array:
    """First-occurrence worst index per island (the slot migration fills)."""
    return best_slot(y, minimize=not minimize)


def take_slot(a: jax.Array, slot: jax.Array) -> jax.Array:
    """a[i, slot[i]] for an island-stacked array (I, N, ...) — expressed as
    a one-hot masked sum (exact: one nonzero per row, any dtype)."""
    iota = jax.lax.broadcasted_iota(jnp.int32, a.shape[:2], 1)
    hit = iota == slot[:, None]
    hit = hit.reshape(hit.shape + (1,) * (a.ndim - 2))
    return jnp.sum(jnp.where(hit, a, jnp.zeros_like(a)), axis=1)


def splice_at(x: jax.Array, slot: jax.Array, rows: jax.Array,
              island_mask: jax.Array = None) -> jax.Array:
    """x with x[i, slot[i]] <- rows[i] (a select, no scatter).  island_mask
    (bool (I, 1), optional) disables the splice for masked-off islands —
    the sharded path uses it to leave island 0 for the boundary elite."""
    iota = jax.lax.broadcasted_iota(jnp.int32, x.shape[:2], 1)
    hit = iota == slot[:, None]
    if island_mask is not None:
        hit = hit & island_mask
    return jnp.where(hit[..., None], rows[:, None, :], x)


def elites_stack(x: jax.Array, y: jax.Array, *, minimize: bool
                 ) -> Tuple[jax.Array, jax.Array]:
    """Per-island elite over a raw stack: (elite_x [I, V], elite_y [I])."""
    slot = best_slot(y, minimize=minimize)
    return take_slot(x, slot), take_slot(y.astype(jnp.float32), slot)


def ring_migrate_stack(x: jax.Array, y: jax.Array, *, minimize: bool
                       ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One full ring migration over an in-block island stack (I, N, V):
    elite extraction -> shift-by-one across the island axis (the `jnp.roll`
    ring, written as a concat so it traces into a kernel) -> worst-slot
    splice.  Returns (x', elite_x, elite_y).  Shared by `migrate_ring`
    (XLA, between launches) and the resident-epoch kernel (in VMEM)."""
    elite_x, elite_y = elites_stack(x, y, minimize=minimize)
    shifted = jnp.concatenate([elite_x[-1:], elite_x[:-1]], axis=0)
    x2 = splice_at(x, worst_slot(y, minimize=minimize), shifted)
    return x2, elite_x, elite_y


def splice_elites(states: G.GAState, y: jax.Array, elites: jax.Array,
                  *, minimize: bool) -> G.GAState:
    """Replace each island's worst individual with the incoming elite.
    states: island-stacked [I, ...]; y: fitness of states.x [I, N]."""
    x = splice_at(states.x, worst_slot(y, minimize=minimize), elites)
    return states._replace(x=x)


def _best_of(states: G.GAState, y: jax.Array, cfg: IslandConfig):
    return best_of(states, y, minimize=cfg.ga.minimize)


def best_of(states: G.GAState, y: jax.Array, *, minimize: bool):
    """Per-island elite: (elite_x [I, V], elite_y [I]) of the current pops."""
    return elites_stack(states.x, y, minimize=minimize)


def migrate_ring(states: G.GAState, y: jax.Array, *, minimize: bool
                 ) -> Tuple[G.GAState, jax.Array, jax.Array]:
    """One on-host ring migration over an island-stacked state.

    The best individual of island i replaces the worst individual of island
    (i + 1) mod I — the `jnp.roll` analogue of the inter-FPGA elite links
    ([19]); `lax.ppermute` plays the same role on a device mesh (see
    `migrate_ring_sharded`).  This is THE migration step shared by
    `make_local_step` and the engine's island_ring topology (any executor).
    It delegates to `ring_migrate_stack`, the kernel-traceable form — so the
    between-launch XLA migration and the resident-epoch kernel's in-VMEM
    migration are the same math by construction.

    Returns (new_states, elite_x [I, V], elite_y [I]).
    """
    x2, elite_x, elite_y = ring_migrate_stack(states.x, y, minimize=minimize)
    return states._replace(x=x2), elite_x, elite_y


# ---------------------------------------------------------------------------
# Sharded ring migration (inside shard_map) — bit-identical to migrate_ring
# ---------------------------------------------------------------------------


def ring_shift_sharded(x: jax.Array, mesh: Mesh,
                       axis_names: Sequence[str]) -> jax.Array:
    """Send `x` to the next shard in row-major linear order over `axis_names`.

    The inverse view: each shard receives the previous shard's `x`.  With the
    island axis sharded over several mesh axes jointly, "next shard" means
    linear index +1 over the raveled (row-major) axis tuple — i.e. exactly
    one global ring, not one ring per leading-axis slice.  Implemented as a
    `lax.ppermute` cascade: shift along the last axis, then patch the wrap
    positions (trailing indices all zero) with progressively higher-axis
    shifts.  Must be called inside `shard_map` over `axis_names`.

    Device-order canonicalization: the ring is defined over LOGICAL mesh
    coordinates (`lax.axis_index` / the ppermute permutation), and XLA
    shards global arrays by the same logical coordinates — the physical
    device array backing the mesh never enters the ordering.  A mesh built
    with a custom device permutation (`Mesh(devices[perm], ...)`) therefore
    yields the SAME island ring as the local `jnp.roll`, bit-for-bit; only
    which physical chip hosts each logical shard changes.  Asserted in
    tests/test_topology.py (permuted-device mesh vs local run).
    """
    def shift(v, a):
        s = mesh.shape[a]
        return jax.lax.ppermute(v, a,
                                perm=[(i, (i + 1) % s) for i in range(s)])

    out = shift(x, axis_names[-1])
    for j in range(len(axis_names) - 2, -1, -1):
        nxt = shift(out, axis_names[j])
        cond = jnp.bool_(True)
        for a in axis_names[j + 1:]:
            cond = cond & (jax.lax.axis_index(a) == 0)
        out = jnp.where(cond, nxt, out)
    return out


def migrate_ring_sharded(states: G.GAState, y: jax.Array, *, minimize: bool,
                         mesh: Mesh, axis_names: Sequence[str]
                         ) -> Tuple[G.GAState, jax.Array, jax.Array]:
    """`migrate_ring` for one shard of an island axis sharded over a mesh.

    states/y hold this shard's [I_local, ...] block.  Globally the effect is
    bit-identical to the single-device `migrate_ring` (`jnp.roll` by one over
    the full island axis): locally elites shift down by one island, and the
    boundary elite (this shard's last island) is `ppermute`d to the next
    shard in ring order, landing on its first island.

    Returns (new_states, elite_x [I_local, V], elite_y [I_local]).
    """
    elite_x, elite_y = best_of(states, y, minimize=minimize)
    recv = ring_shift_sharded(elite_x[-1], mesh, axis_names)   # [V] from prev
    shifted = jnp.concatenate([recv[None], elite_x[:-1]], axis=0)
    states = splice_elites(states, y, shifted, minimize=minimize)
    return states, elite_x, elite_y




# ---------------------------------------------------------------------------
# Single-host convenience (vmap only, no mesh) — used by tests/benchmarks
# ---------------------------------------------------------------------------


def make_local_step(cfg: IslandConfig, fit: G.FitnessFn, generation_fn=None):
    """Jitted epoch for a single-host island stack: `migrate_every` local
    generations + one on-host ring migration.  The independent oracle the
    engine's islands backend is asserted against.  Returns
    (states, elite_x, elite_y)."""

    @jax.jit
    def epoch(states):
        states, y = _local_generations(states, cfg, fit, cfg.migrate_every,
                                       generation_fn)
        states, elite_x, elite_y = migrate_ring(states, y,
                                                minimize=cfg.ga.minimize)
        return states, elite_x, elite_y

    return epoch
