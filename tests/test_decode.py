"""Serving-path correctness: prefill + decode_step must reproduce the
train-time forward's next-token logits for every family."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, get_config, reduced
from repro.models import common as C
from repro.models import lm as LM

B, S = 2, 32

TOL = {  # bf16 accumulation/fusion-order differences between the two jits
    "dense": 0.03, "vlm": 0.03, "audio": 0.03,
    "moe": 0.03, "ssm": 0.10, "hybrid": 0.25,
}


@pytest.mark.parametrize("arch", sorted(REGISTRY))
def test_prefill_decode_matches_forward(arch):
    cfg = reduced(get_config(arch))
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops
    key = jax.random.key(1)
    P = cfg.n_patches if cfg.family == "vlm" else 0
    defs = LM.model_defs(cfg, max_seq=S + 8 + P)
    params = C.init_params(defs, jax.random.key(0))
    toks = jax.random.randint(key, (B, S + 2), 0, cfg.vocab)
    batch = {"tokens": toks}
    kw = {}
    if cfg.family == "audio":
        batch["frames"] = kw["frames"] = jax.random.normal(
            key, (B, cfg.enc_seq, cfg.d_model), jnp.float32) * 0.1
    if cfg.family == "vlm":
        batch["patches"] = kw["patches"] = jax.random.normal(
            key, (B, cfg.n_patches, cfg.d_model), jnp.float32) * 0.1

    logits_full, _ = LM.forward(params, cfg, batch)
    cache = C.init_params(LM.cache_defs(cfg, B, S + 8 + P), jax.random.key(2))
    lp, cache = LM.prefill(params, cfg, toks[:, :S], cache, **kw)
    tol = TOL[cfg.family]
    err_p = float(jnp.max(jnp.abs(lp - logits_full[:, P + S - 1])))
    assert err_p <= tol, f"prefill mismatch {err_p}"
    # two decode steps
    ld, cache = LM.decode_step(params, cfg, toks[:, S:S + 1], cache)
    err_d = float(jnp.max(jnp.abs(ld - logits_full[:, P + S])))
    assert err_d <= max(tol, 1e-6) * 4 + tol, f"decode mismatch {err_d}"
    ld2, cache = LM.decode_step(params, cfg, toks[:, S + 1:S + 2], cache)
    err_d2 = float(jnp.max(jnp.abs(ld2 - logits_full[:, P + S + 1])))
    assert err_d2 <= max(tol, 1e-6) * 4 + tol, f"decode2 mismatch {err_d2}"


def test_gemma_ring_cache_bounded():
    """gemma3 local layers keep a window-sized ring cache regardless of
    max_len — the long_500k enabler."""
    cfg = reduced(get_config("gemma3-27b"))
    cdefs = LM.cache_defs(cfg, batch=1, max_len=4096)
    local_k = cdefs["groups"]["locals"]["k"]
    assert local_k.shape[3] == cfg.window_size  # ring, not max_len
    glob_k = cdefs["groups"]["global"]["k"]
    assert glob_k.shape[2] == 4096              # globals keep full length


def test_mla_cache_is_compressed():
    cfg = reduced(get_config("deepseek-v3-671b"))
    cdefs = LM.cache_defs(cfg, batch=1, max_len=1024)
    leaf_names = set(cdefs["layers"].keys())
    assert leaf_names == {"c_kv", "k_rope"}     # latents only, no full K/V
    assert cdefs["layers"]["c_kv"].shape[-1] == cfg.kv_lora_rank


def test_ssm_cache_is_constant_size():
    cfg = reduced(get_config("mamba2-1.3b"))
    c1 = LM.cache_defs(cfg, batch=1, max_len=64)
    c2 = LM.cache_defs(cfg, batch=1, max_len=65536)
    assert c1["layers"]["state"].shape == c2["layers"]["state"].shape
