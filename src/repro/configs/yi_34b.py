"""yi-34b — llama-architecture GQA [arXiv:2403.04652; hf].

56 q-heads are padded to 64 for even 16-way tensor parallelism (GSPMD would
otherwise pad internally); kv=8 stays (uneven-sharded on the model axis).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b", family="dense",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=20480, vocab=64000, rope_theta=5_000_000.0, pad_heads_to=16,
)
