"""Fitness Function Module (FFM) — paper Sec. 3.1.

The paper computes  y = γ(α(px) + β(qx))  with three ROMs per individual:
α and β are LUTs over the c = m/2 bit halves of the chromosome, γ a LUT over
the d-bit sum δ.  Any separable two-variable function fits (Eq. 11); products
of the two variables do not (paper's stated limitation — same here).

Two modes:
  * ``lut``   — faithful: int32 fixed-point tables, XLA gathers (ROM analogue).
  * ``arith`` — TPU-native: α/β/γ evaluated in f32 on the VPU. On TPU, HBM
    gathers are far more expensive than a few FMAs; this is the first
    beyond-paper optimization (recorded in EXPERIMENTS.md §Perf).

Both modes share the same domain mapping: a c-bit unsigned chromosome half u
decodes to   v = lo + u * (hi - lo) / (2^c - 1).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Problem:
    """A separable two-variable optimisation problem (Eq. 11 of the paper)."""

    name: str
    alpha: Callable[[np.ndarray], np.ndarray]   # α(px)
    beta: Callable[[np.ndarray], np.ndarray]    # β(qx)
    gamma: Callable[[np.ndarray], np.ndarray]   # γ(δ)
    domain: tuple  # (lo, hi) for each decoded variable
    minimize: bool = True
    single_var: bool = False  # paper's one-variable case: α(px)=0, only qx used

    def f(self, px: np.ndarray, qx: np.ndarray) -> np.ndarray:
        return self.gamma(self.alpha(px) + self.beta(qx))


# --- The paper's three validation functions (Sec. 4) -----------------------

# F1: f(x) = x^3 - 15 x^2 + 500   (one variable; paper Eq. 24, range ±2^12)
F1 = Problem(
    name="F1",
    alpha=lambda px: np.zeros_like(px, dtype=np.float64),
    beta=lambda qx: qx ** 3 - 15.0 * qx ** 2 + 500.0,
    gamma=lambda d: d,
    domain=(-4096.0, 4095.0),
    minimize=True,
    single_var=True,
)

# F2: f(x, y) = 8x - 4y + 1020   (paper Eq. 25)
F2 = Problem(
    name="F2",
    alpha=lambda px: 8.0 * px,
    beta=lambda qx: -4.0 * qx + 1020.0,
    gamma=lambda d: d,
    domain=(-128.0, 127.0),
    minimize=True,
)

# F3: f(x, y) = sqrt(x^2 + y^2)   (paper Eq. 26)
F3 = Problem(
    name="F3",
    alpha=lambda px: px.astype(np.float64) ** 2,
    beta=lambda qx: qx.astype(np.float64) ** 2,
    gamma=lambda d: np.sqrt(np.maximum(d, 0.0)),
    domain=(-128.0, 127.0),
    minimize=True,
)

PROBLEMS = {"F1": F1, "F2": F2, "F3": F3}


def decode(u: jax.Array, c: int, domain: tuple) -> jax.Array:
    """Decode a c-bit unsigned half-chromosome to its real value."""
    lo, hi = domain
    scale = (hi - lo) / float((1 << c) - 1)
    return lo + u.astype(jnp.float32) * jnp.float32(scale)


# ---------------------------------------------------------------------------
# LUT (faithful) mode
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LutTables:
    """Fixed-point ROM contents for one Problem at a given m.

    alpha_t, beta_t: int32[2^c] — α/β values scaled by 2^frac_bits.
    gamma_t: int32[2^g] or None (None == identity γ, paper's F1/F2 case where
             the third ROM is a pass-through).
    delta_min / delta_shift: the γ ROM is addressed by
             clip((δ - delta_min) >> delta_shift, 0, 2^g - 1).
    """

    c: int
    frac_bits: int
    alpha_t: np.ndarray
    beta_t: np.ndarray
    gamma_t: Optional[np.ndarray]
    delta_min: int
    delta_shift: int
    g: int


def build_tables(problem: Problem, m: int, frac_bits: Optional[int] = None,
                 g: int = 14) -> LutTables:
    """Quantize α/β/γ into ROM tables, the FFM's synthesis step.

    frac_bits may be negative (coarser-than-integer fixed point) — exactly
    what a hardware synthesis would do when the fitness range exceeds the
    ROM word width.  If None, the largest value keeping |α|+|β| within int31
    is chosen automatically (capped at 8 fractional bits).
    """
    c = m // 2
    u = np.arange(1 << c, dtype=np.float64)
    lo, hi = problem.domain
    v = lo + u * (hi - lo) / float((1 << c) - 1)

    if frac_bits is None:
        peak = (np.abs(problem.alpha(v)).max() + np.abs(problem.beta(v)).max())
        frac_bits = 8
        while frac_bits > -24 and peak * (2.0 ** frac_bits) >= 2 ** 30:
            frac_bits -= 1

    scale = float(2.0 ** frac_bits)
    a = np.round(problem.alpha(v) * scale).astype(np.int64)
    b = np.round(problem.beta(v) * scale).astype(np.int64)

    # int32 saturation (the ROM word width)
    i32 = lambda t: np.clip(t, -(2 ** 31), 2 ** 31 - 1).astype(np.int32)
    alpha_t, beta_t = i32(a), i32(b)

    is_identity = problem.gamma(np.array([0.0, 1.0, 2.0])).tolist() == [0.0, 1.0, 2.0]
    if is_identity:
        return LutTables(c, frac_bits, alpha_t, beta_t, None, 0, 0, 0)

    dmin = int(a.min() + b.min())
    dmax = int(a.max() + b.max())
    span = max(dmax - dmin, 1)
    shift = max(0, int(np.ceil(np.log2(span / ((1 << g) - 1) + 1e-12))) if span >= (1 << g) else 0)
    # γ table: value at address k represents δ = dmin + (k << shift)
    k = np.arange(1 << g, dtype=np.int64)
    delta = (dmin + (k << shift)).astype(np.float64) / scale
    gamma_t = i32(np.round(problem.gamma(delta) * scale))
    return LutTables(c, frac_bits, alpha_t, beta_t, gamma_t, dmin, shift, g)


def lut_fitness(px: jax.Array, qx: jax.Array, t: LutTables) -> jax.Array:
    """Faithful FFM: two ROM reads, an add, one more ROM read. int32 out."""
    a = jnp.asarray(t.alpha_t)[px]
    b = jnp.asarray(t.beta_t)[qx]
    d = a + b
    if t.gamma_t is None:
        return d
    addr = jnp.clip((d - jnp.int32(t.delta_min)) >> t.delta_shift, 0, (1 << t.g) - 1)
    return jnp.asarray(t.gamma_t)[addr]


# ---------------------------------------------------------------------------
# Arithmetic (TPU-native) mode
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArithSpec:
    """Closed-form fitness for the VPU: cubic α/β + {identity,sqrt} γ.

    α(v) = a3 v³ + a2 v² + a1 v + a0 (same for β); covers the paper's F1–F3
    and anything polynomial; γ ∈ {identity, sqrt}.
    """

    alpha_coef: tuple  # (a3, a2, a1, a0)
    beta_coef: tuple
    gamma_sqrt: bool
    domain: tuple

    @staticmethod
    def for_problem(problem: Problem) -> "ArithSpec":
        specs = {
            "F1": ((0.0, 0.0, 0.0, 0.0), (1.0, -15.0, 0.0, 500.0), False),
            "F2": ((0.0, 0.0, 8.0, 0.0), (0.0, 0.0, -4.0, 1020.0), False),
            "F3": ((0.0, 1.0, 0.0, 0.0), (0.0, 1.0, 0.0, 0.0), True),
        }
        if problem.name not in specs:
            raise ValueError(f"no ArithSpec for {problem.name}")
        a, b, s = specs[problem.name]
        return ArithSpec(a, b, s, problem.domain)


def _poly3(v: jax.Array, coef: tuple) -> jax.Array:
    a3, a2, a1, a0 = (jnp.float32(x) for x in coef)
    return ((a3 * v + a2) * v + a1) * v + a0


def arith_fitness(px: jax.Array, qx: jax.Array, c: int, spec: ArithSpec) -> jax.Array:
    """TPU-native FFM: decode + FMAs on the VPU, no memory traffic."""
    vp = decode(px, c, spec.domain)
    vq = decode(qx, c, spec.domain)
    d = _poly3(vp, spec.alpha_coef) + _poly3(vq, spec.beta_coef)
    if spec.gamma_sqrt:
        d = jnp.sqrt(jnp.maximum(d, 0.0))
    return d
