"""Production meshes.

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods × 256 chips as (pod=2, data=16, model=16) — the "pod"
axis carries pure data parallelism across the inter-pod (DCN) links, the
in-pod axes ride ICI.

Functions, not module constants: importing this module must never touch jax
device state (the dry-run sets XLA_FLAGS before any jax init).
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """Whatever devices exist, as a 1×N (data, model) mesh — for tests."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))


def make_island_mesh(n_devices: int | None = None) -> Mesh:
    """A 1-D ("islands",) mesh over the first `n_devices` devices (default
    all) — the natural layout for sharding a GA island axis."""
    devs = jax.devices()
    n = len(devs) if n_devices is None else n_devices
    if n > len(devs):
        raise ValueError(f"asked for {n} devices, have {len(devs)}")
    return Mesh(np.asarray(devs[:n]), ("islands",))


_MESH_AXIS_NAMES = {1: ("islands",), 2: ("data", "model"),
                    3: ("pod", "data", "model")}


def parse_mesh(spec: str) -> Mesh:
    """CLI mesh syntax -> Mesh.

    "auto"/"host"  all local devices as a 1-D ("islands",) mesh
    "4"            first 4 devices, 1-D ("islands",)
    "2x4"          (data=2, model=4);  "2x2x4" adds a leading "pod" axis
    """
    s = spec.strip().lower()
    if s in ("auto", "host"):
        return make_island_mesh()
    dims = tuple(int(d) for d in s.split("x"))
    if len(dims) == 1:
        return make_island_mesh(dims[0])
    if len(dims) not in _MESH_AXIS_NAMES:
        raise ValueError(f"mesh spec {spec!r}: want N, NxM or NxMxK")
    return jax.make_mesh(dims, _MESH_AXIS_NAMES[len(dims)])


# TPU v5e hardware model used by the roofline (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link (~3 links usable per axis hop)
