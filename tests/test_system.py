"""End-to-end behaviour tests for the paper's system: the full-parallel GA
reproduces the paper's optimisation results; the island model scales it; the
multi-device shard_map path works (spawned with fake devices).

All GA runs go through the unified `repro.ga` engine API (the old
`G.run` / `ISL.run_local` drivers were folded after their deprecation
cycle)."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ga
from repro.core import fitness as F
from repro.roofline import analyze_hlo


def test_f1_paper_reproduction_lut_mode():
    """Paper Fig. 11: minimise F1 with N=32, m=26 — global minimum within
    100 generations (LUT/fixed-point mode, the hardware-faithful path)."""
    spec = ga.paper_spec("F1", n=32, m=26, mode="lut", mutation_rate=0.05,
                         seed=7, generations=100)
    r = ga.solve(spec, backend="reference")
    target = float(F.F1.f(np.array([0.0, -4096.0])))
    assert r.best_fitness <= 0.98 * target   # real units (descaled)
    # decoded solution sits at the domain edge the paper reports
    assert r.best_params[1] == pytest.approx(-4096.0, abs=2.0)


def test_f3_paper_reproduction():
    """Paper Fig. 12: F3 with N=64, m=20 converges near zero in ~20 gens."""
    spec = ga.paper_spec("F3", n=64, m=20, mode="arith", mutation_rate=0.05,
                         seed=3, generations=100)
    r = ga.solve(spec, backend="reference")
    assert r.traj_best[40] < 3.0   # most of the way by gen 40
    assert r.best_fitness < 1.0


def test_islands_beat_single_population():
    """Island model with migration should match or beat one big population
    at equal total chromosome count (the multi-FPGA [19] claim)."""
    isl = ga.GASpec(problem="F3", n=32, bits_per_var=12, mode="arith",
                    mutation_rate=0.05, seed=1, generations=100,
                    n_islands=8, migrate_every=10)
    r_isl = ga.solve(isl, backend="islands")
    assert r_isl.telemetry.topology.migrations == 10

    big = ga.GASpec(problem="F3", n=256, bits_per_var=12, mode="arith",
                    mutation_rate=0.05, seed=1, generations=100)
    r_big = ga.solve(big, backend="reference")
    assert r_isl.best_fitness <= r_big.best_fitness * 1.5 + 0.2


def test_sharded_island_ga_on_multiple_devices():
    """Full shard_map island GA on 8 fake devices via the engine's
    reference×island_ring backend (subprocess so the forced device count
    doesn't leak into this process)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro import ga
mesh = jax.make_mesh((2, 4), ("data", "model"))
spec = ga.GASpec(problem="F3", n=32, bits_per_var=10, mode="arith",
                 mutation_rate=0.05, seed=2, generations=48,
                 n_islands=16, migrate_every=8)
r = ga.solve(spec, backend="islands", mesh=mesh)
assert r.backend == "islands"
assert r.telemetry.topology.sharded is True
assert r.telemetry.topology.migrations == 6
assert r.best_fitness < 2.0, r.best_fitness
print("SHARDED_OK", r.best_fitness)
"""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "SHARDED_OK" in r.stdout


def test_roofline_parser_on_known_program():
    def loss(ws, x):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(y ** 2)

    comp = jax.jit(jax.grad(loss)).lower(
        jax.ShapeDtypeStruct((10, 128, 128), jnp.float32),
        jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
    res = analyze_hlo(comp.as_text())
    # fwd + 2 bwd matmuls per scanned layer, times 10 layers
    assert res["flops"] == pytest.approx(10 * 3 * 2 * 128 ** 3, rel=0.05)
    assert res["collective_bytes"] == 0.0


def test_serving_engine_end_to_end():
    from repro.configs import get_config, reduced
    from repro.models import common as C
    from repro.models import lm as LM
    from repro.serve.engine import Engine, EngineConfig

    cfg = reduced(get_config("minitron-8b"))
    params = C.init_params(LM.model_defs(cfg, max_seq=128), jax.random.key(0))
    eng = Engine(cfg, params, EngineConfig(batch=2, max_len=128))
    prompts = np.ones((2, 16), np.int32)
    toks, stats = eng.generate(prompts, max_new_tokens=8)
    assert toks.shape == (2, 8)
    assert (toks >= 0).all() and (toks < cfg.vocab_).all()
    assert stats["decode_tok_per_s"] > 0
