"""`evolve` — the GA engine exposed as the framework's blackbox-tuning service.

This is how the paper's accelerator integrates with the LM stack as a
first-class feature: anything expressible as "minimize f(θ) over a box" —
learning-rate schedule coefficients, serving batch knobs, quantization
clip scales — can be handed to the full-parallel GA.  The evaluation function
receives a whole population matrix at once (N, V) and returns (N,) scores, so
model-based fitness (e.g. run 10 train steps per candidate) can itself be
vmapped/pmapped by the caller.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fitness as F
from repro.core import ga as G
from repro.core import islands as ISL


@dataclasses.dataclass
class EvolveResult:
    best_params: np.ndarray     # [V] decoded
    best_fitness: float
    traj_best: np.ndarray       # [K]
    traj_mean: np.ndarray       # [K]


def evolve(fn: Callable[[jax.Array], jax.Array],
           bounds: Sequence[Tuple[float, float]],
           *,
           population: int = 64,
           generations: int = 100,
           bits_per_var: int = 16,
           mutation_rate: float = 0.02,
           minimize: bool = True,
           seed: int = 0,
           n_islands: int = 1,
           migrate_every: int = 16,
           jit_fitness: bool = True,
           mesh=None) -> EvolveResult:
    """Minimize (or maximize) `fn` over box `bounds` with the parallel GA.

    fn: (N, V) float32 -> (N,) batch evaluator.  Set jit_fitness=False when
    fn is not traceable (e.g. it runs training trials) — the GA operators
    stay jitted, fitness runs eagerly.
    With n_islands > 1 the island model is used (sharded over `mesh` when
    given, vmapped locally otherwise).
    """
    v = len(bounds)
    cfg = G.GAConfig(n=population, c=bits_per_var, v=v,
                     mutation_rate=mutation_rate, minimize=minimize,
                     seed=seed, mode="arith")
    fit = G.make_blackbox_fitness(fn, bits_per_var, bounds)

    if n_islands <= 1:
        if jit_fitness:
            out = jax.jit(lambda: G.run(cfg, fit, generations))()
        else:
            out = G.run_unjitted(cfg, fit, generations)
        lo = np.array([b[0] for b in bounds])
        hi = np.array([b[1] for b in bounds])
        u = np.asarray(out.best_x) & cfg.var_mask
        params = lo + u.astype(np.float64) * (hi - lo) / ((1 << bits_per_var) - 1)
        return EvolveResult(params, float(out.best_y),
                            np.asarray(out.traj_best), np.asarray(out.traj_mean))

    icfg = ISL.IslandConfig(ga=cfg, n_islands=n_islands,
                            migrate_every=migrate_every)
    epochs = max(1, generations // migrate_every)
    if mesh is not None:
        states, best = ISL.run_sharded(icfg, fit, mesh, epochs)
    else:
        states, best = ISL.run_local(icfg, fit, epochs)
    # recover best chromosome across islands
    y = jax.vmap(fit)(states.x).astype(jnp.float32)
    flat = y.reshape(-1)
    idx = int(jnp.argmin(flat) if minimize else jnp.argmax(flat))
    xi = np.asarray(states.x.reshape(-1, v)[idx]) & cfg.var_mask
    lo = np.array([b[0] for b in bounds])
    hi = np.array([b[1] for b in bounds])
    params = lo + xi.astype(np.float64) * (hi - lo) / ((1 << bits_per_var) - 1)
    return EvolveResult(params, float(flat[idx]), np.array([best]), np.array([]))
