"""Stdlib HTTP surface for the GA serving telemetry.

`GA_METRICS` (repro.serve.engine) aggregates `Engine.run_chunked` telemetry
per job; this module makes that snapshot scrapeable AND streamable before a
full RPC stack lands: a `http.server` daemon thread rendering the registry
in Prometheus text exposition format plus JSON/SSE job endpoints.

    from repro.serve.metrics_http import start_metrics_server
    server = start_metrics_server(9100)          # or 0 for an ephemeral port
    ... run GA jobs (serve.engine.run_ga_job / serve.scheduler) ...
    server.shutdown()

Endpoints:
  /metrics               Prometheus text (version 0.0.4) — per-job gauges,
                         fleet totals, and (when a GAScheduler attached its
                         stats to the registry) queue-depth / jobs-running /
                         compile-cache gauges.
  /healthz               liveness probe.
  /jobs                  JSON registry snapshot.
  /jobs/<id>             JSON one job; `?after=N&timeout=S` long-polls until
                         the job has recorded more than N chunks (or ended).
  /jobs/<id>/stream      Server-Sent Events: one `data:` JSON line per
                         telemetry chunk while the job runs, closing with an
                         `event: end` message — live streaming for curl /
                         EventSource clients.

Opt-in from the CLI with `repro.launch.ga_run --metrics-port PORT` or
`repro.launch.ga_serve --port PORT`.
"""

from __future__ import annotations

import json
import queue as _queue
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlparse

_PREFIX = "repro_ga"

# per-job numeric gauges: (metrics()-dict key, prometheus suffix, help)
_JOB_GAUGES = (
    ("generations_done", "generations_done", "Generations completed"),
    ("generations_total", "generations_total", "Generations requested"),
    ("chunks", "chunks", "Telemetry chunks recorded"),
    ("generations_per_s", "generations_per_s", "Generations per second"),
    ("islands", "islands", "Concurrently evolving populations"),
    ("shards", "shards", "Mesh shards the island axis spans"),
    ("generations_per_s_per_shard", "generations_per_s_per_shard",
     "Island-generations per second per mesh shard"),
    ("best_fitness", "best_fitness", "Best fitness seen (real units)"),
    ("migration_count", "migrations", "Ring migrations performed"),
    ("n_vars", "n_vars", "Decoded variable count V"),
    ("wall_s", "wall_seconds", "Wall-clock seconds spent"),
    ("priority", "priority", "Scheduler priority (higher preempts)"),
    ("preemptions", "preemptions", "Times the scheduler parked this job"),
    ("retries", "retries", "Scheduler retry dispatches of this job"),
    ("pack_size", "pack_size", "Jobs sharing this job's launch"),
)

_FLEET_GAUGES = (
    ("job_count", "jobs", "GA jobs known to the registry"),
    ("jobs_done", "jobs_done", "GA jobs finished successfully"),
    ("jobs_running", "jobs_running", "GA jobs currently running"),
    ("jobs_queued", "jobs_queued", "GA jobs waiting in the scheduler queue"),
    ("jobs_preempted", "jobs_preempted", "GA jobs parked by preemption"),
    ("jobs_failed", "jobs_failed", "GA jobs that errored"),
    ("jobs_deadline_exceeded", "jobs_deadline_exceeded",
     "GA jobs that ran out of wall-clock budget"),
    ("generations_total", "fleet_generations", "Generations done, all jobs"),
    ("migrations_total", "fleet_migrations", "Migrations, all jobs"),
)

# scheduler gauges (snapshot["scheduler"], present when a GAScheduler is
# attached): queue depth / packing / compile-cache counters for the CI smoke
_SCHED_GAUGES = (
    ("queue_depth", "sched_queue_depth", "Jobs waiting for the mesh"),
    ("jobs_running", "sched_jobs_running", "Jobs in the running pack"),
    ("packs_launched", "sched_packs_launched", "Packed launches dispatched"),
    ("preemptions", "sched_preemptions", "Packs parked for priority work"),
    ("jobs_packed", "sched_jobs_packed", "Jobs that shared a launch"),
    ("cache_hits", "compile_cache_hits", "Compiled-runner cache hits"),
    ("cache_misses", "compile_cache_misses", "Compiled-runner cache misses"),
    ("cache_entries", "compile_cache_entries", "Compiled runners cached"),
    ("jobs_evicted", "sched_evicted_total",
     "Finished jobs TTL-evicted from the registry"),
    ("plans_measured", "plan_measured_total",
     "Launches planned from measured cost tables"),
    ("plans_heuristic", "plan_heuristic_total",
     "Launches planned by the static heuristic"),
    ("plan_table_entries", "plan_table_entries",
     "Cost-table points available to the planner"),
    ("retries", "sched_retries_total",
     "Job retry dispatches after transient failures"),
    ("quarantined", "sched_quarantined_total",
     "Poison jobs isolated from their pack and failed"),
    ("recovered", "sched_recovered_total",
     "Jobs re-enqueued by journal replay after a restart"),
    ("deadline_exceeded", "sched_deadline_exceeded_total",
     "Jobs terminated at their wall-clock deadline"),
    ("worker_alive", "sched_worker_alive",
     "1 while the scheduler worker thread is running"),
)


def _esc(v) -> str:
    return str(v).replace("\\", r"\\").replace('"', r'\"').replace("\n", r"\n")


def render_prometheus(snapshot: dict) -> str:
    """Serialize a `GAMetricsRegistry.metrics()` snapshot as Prometheus
    text exposition format (one gauge family per numeric job stat, the job
    identity carried in labels)."""
    lines = []
    jobs = snapshot.get("jobs", {})

    def label_str(j):
        return (f'job_id="{_esc(j["job_id"])}",backend="{_esc(j["backend"])}"'
                f',problem="{_esc(j["problem"])}"')

    for key, suffix, help_ in _JOB_GAUGES:
        name = f"{_PREFIX}_{suffix}"
        lines.append(f"# HELP {name} {help_}")
        lines.append(f"# TYPE {name} gauge")
        for j in jobs.values():
            val = j.get(key)
            if val is None:
                continue
            lines.append(f"{name}{{{label_str(j)}}} {float(val):g}")
    # job status as a one-hot info gauge
    name = f"{_PREFIX}_job_status"
    lines.append(f"# HELP {name} Job state (1 for the current status label)")
    lines.append(f"# TYPE {name} gauge")
    for j in jobs.values():
        lines.append(
            f'{name}{{{label_str(j)},status="{_esc(j["status"])}"}} 1')
    # the epoch-plan decision (mode × provenance × selection lane) as a
    # one-hot info gauge, so dashboards can see e.g. "auto" picking gather
    name = f"{_PREFIX}_plan_info"
    lines.append(f"# HELP {name} Epoch plan decision "
                 "(1 for the current mode/source/lane labels)")
    lines.append(f"# TYPE {name} gauge")
    for j in jobs.values():
        if j.get("epoch_mode", "-") == "-":
            continue
        lines.append(
            f'{name}{{{label_str(j)},mode="{_esc(j["epoch_mode"])}"'
            f',source="{_esc(j["plan_source"])}"'
            f',lane="{_esc(j.get("sel_lane", "-"))}"}} 1')
    for key, suffix, help_ in _FLEET_GAUGES:
        name = f"{_PREFIX}_{suffix}"
        lines.append(f"# HELP {name} {help_}")
        lines.append(f"# TYPE {name} gauge")
        lines.append(f"{name} {float(snapshot.get(key, 0)):g}")
    sched = snapshot.get("scheduler")
    if sched:
        for key, suffix, help_ in _SCHED_GAUGES:
            if key not in sched:
                continue
            name = f"{_PREFIX}_{suffix}"
            lines.append(f"# HELP {name} {help_}")
            lines.append(f"# TYPE {name} gauge")
            lines.append(f"{name} {float(sched[key]):g}")
    return "\n".join(lines) + "\n"


def _json_default(v):
    try:
        import numpy as np
        if isinstance(v, np.ndarray):
            return v.tolist()
        if isinstance(v, np.generic):
            return v.item()
    except Exception:
        pass
    return str(v)


def start_metrics_server(port: int = 0, registry=None,
                         host: str = "0.0.0.0") -> ThreadingHTTPServer:
    """Serve `registry` (default: the process-global GA_METRICS) at
    /metrics (+ /jobs JSON, /jobs/<id> long-poll, /jobs/<id>/stream SSE) on
    a daemon thread.  Returns the server; its bound port is
    `server.server_address[1]` (useful with port=0), stop with
    `server.shutdown()`."""
    if registry is None:
        from repro.serve.engine import GA_METRICS
        registry = GA_METRICS

    class Handler(BaseHTTPRequestHandler):
        def _send(self, body: bytes, ctype: str, code: int = 200):
            self.send_response(code)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def _send_json(self, obj, code: int = 200):
            self._send(json.dumps(obj, default=_json_default).encode(),
                       "application/json", code)

        def _job_snapshot(self, job_id):
            return registry.metrics()["jobs"].get(job_id)

        def _long_poll(self, job_id, qs):
            """Block until the job has recorded more chunks than `after`
            (or ended / `timeout` seconds passed), then return its dict."""
            after = int(qs.get("after", ["-1"])[0])
            timeout = min(float(qs.get("timeout", ["30"])[0]), 300.0)
            snap = self._job_snapshot(job_id)
            if snap is None:
                self.send_error(404, f"no such job {job_id}")
                return
            sub = registry.subscribe(job_id)
            try:
                import time as _t
                deadline = _t.monotonic() + timeout
                while (snap["chunks"] <= after
                       and snap["status"] in ("pending", "queued", "running",
                                              "preempted")):
                    left = deadline - _t.monotonic()
                    if left <= 0:
                        break
                    try:
                        sub.get(timeout=min(left, 1.0))
                    except _queue.Empty:
                        pass
                    snap = self._job_snapshot(job_id)
            finally:
                registry.unsubscribe(job_id, sub)
            self._send_json(snap)

        def _stream_sse(self, job_id):
            """Server-Sent Events: chunk telemetry as `data:` JSON lines."""
            snap = self._job_snapshot(job_id)
            if snap is None:
                self.send_error(404, f"no such job {job_id}")
                return
            sub = registry.subscribe(job_id)
            try:
                self.send_response(200)
                self.send_header("Content-Type", "text/event-stream")
                self.send_header("Cache-Control", "no-cache")
                self.end_headers()
                # prime with the current snapshot so late subscribers see
                # where the job stands before live chunks arrive
                self.wfile.write(b"event: snapshot\ndata: " + json.dumps(
                    snap, default=_json_default).encode() + b"\n\n")
                self.wfile.flush()
                if snap["status"] in ("done", "failed", "deadline_exceeded"):
                    return
                while True:
                    try:
                        event = sub.get(timeout=15.0)
                    except _queue.Empty:
                        self.wfile.write(b": keepalive\n\n")   # SSE comment
                        self.wfile.flush()
                        continue
                    name = event.get("event", "chunk")
                    self.wfile.write(
                        f"event: {name}\n".encode() + b"data: " + json.dumps(
                            event, default=_json_default).encode() + b"\n\n")
                    self.wfile.flush()
                    if name == "end":
                        return
            except (BrokenPipeError, ConnectionResetError):
                pass                                 # client went away
            finally:
                registry.unsubscribe(job_id, sub)

        def do_GET(self):  # noqa: N802  (http.server API)
            url = urlparse(self.path)
            path, qs = url.path.rstrip("/") or "/", parse_qs(url.query)
            if path in ("/", "/metrics"):
                self._send(render_prometheus(registry.metrics()).encode(),
                           "text/plain; version=0.0.4; charset=utf-8")
            elif path == "/healthz":
                self._send(b"ok\n", "text/plain")
            elif path == "/jobs":
                self._send_json(registry.metrics())
            elif path.startswith("/jobs/") and path.endswith("/stream"):
                self._stream_sse(path[len("/jobs/"):-len("/stream")])
            elif path.startswith("/jobs/"):
                job_id = path[len("/jobs/"):]
                if "after" in qs or "timeout" in qs:
                    self._long_poll(job_id, qs)
                else:
                    snap = self._job_snapshot(job_id)
                    if snap is None:
                        self.send_error(404, f"no such job {job_id}")
                    else:
                        self._send_json(snap)
            else:
                self.send_error(404)

        def log_message(self, *a):   # keep scrapes out of stdout
            pass

    server = ThreadingHTTPServer((host, port), Handler)
    thread = threading.Thread(target=server.serve_forever,
                              name="ga-metrics-http", daemon=True)
    thread.start()
    return server
