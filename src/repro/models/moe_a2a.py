"""Explicit expert-parallel MoE: shard_map + all-to-all token exchange.

EXPERIMENTS.md §Perf (deepseek iter 5) showed GSPMD cannot propagate
"experts sharded over data×model" without replicating tokens.  This module
is the hand-written fix: tokens are routed to expert-owning devices with
`jax.lax.all_to_all`, computed locally, and returned — the communication
pattern every large MoE system (GShard, DeepSeek, Switch) actually ships.

Layout inside one `shard_map` over the EP axis (default: "model"):
  * each device owns E_loc = E / ep experts and T_loc tokens;
  * send buffer  (ep, cap, d): token copies bucketed by destination device,
    positioned by a per-destination running count (capacity-dropped);
  * `all_to_all` swaps src↔dst: the receive buffer holds, per source device,
    its tokens for MY experts (+ int metadata: local expert id, src slot);
  * local compute buckets received rows per expert: (E_loc, ecap, d)
    batched-matmul against (E_loc, d, f) — the MXU-shaped expert FFN;
  * the inverse all_to_all returns outputs to their source slots, where the
    top-k combine weights them back into the token order.

Differentiable end-to-end (scatter-add/gather + all_to_all transpose), so it
drops into the training step; parity vs the einsum MoE is tested in
tests/test_moe_a2a.py.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from repro.sharding import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.models import common as C
from repro.models.moe import MoEConfig, route


def _bucket_positions(dst: jax.Array, n_dst: int, cap: int):
    """dst: (R,) destination id per row -> (pos within destination, keep)."""
    oh = jax.nn.one_hot(dst, n_dst, dtype=jnp.int32)          # (R, n_dst)
    pos = (jnp.cumsum(oh, axis=0) - 1)
    pos = jnp.sum(pos * oh, axis=1)                            # (R,)
    return pos, pos < cap


def moe_a2a_forward(p, x: jax.Array, cfg: MoEConfig, mesh: Mesh,
                    ep_axis: str = "model", dp_axis: str = "data",
                    ) -> jax.Array:
    """x: (B, S, D) with B sharded over dp_axis; experts over ep_axis.

    Weights: p["w_gate"|"w_up"|"w_down"] (E, d, f)/(E, f, d) sharded on the
    expert dim over ep_axis; p["router"] (d, E) replicated.
    Returns (B, S, D).  (Aux loss comes from `route` in the caller if
    needed; this path returns outputs only.)
    """
    ep = mesh.shape[ep_axis]
    e_loc = cfg.n_experts // ep
    assert e_loc * ep == cfg.n_experts

    def inner(xb, router_w, wg, wu, wd):
        # xb: (B_loc, S, d) — identical across the ep axis; each ep rank
        # takes its slice of tokens so work is disjoint.
        b_loc, s, d = xb.shape
        rank = jax.lax.axis_index(ep_axis)
        t_all = b_loc * s
        t_loc = t_all // ep
        toks = xb.reshape(t_all, d)
        my = jax.lax.dynamic_slice_in_dim(toks, rank * t_loc, t_loc, 0)

        weights, idx, _ = route(router_w, my[None], cfg)       # (1,T,k)
        weights, idx = weights[0], idx[0]                      # (T,k), (T,k)

        rows = t_loc * cfg.top_k
        flat_expert = idx.reshape(rows)                        # expert id
        flat_w = weights.reshape(rows)
        src_slot = jnp.arange(rows)
        dst = flat_expert // e_loc                             # device
        cap = int(np.ceil(t_loc * cfg.top_k / ep
                          * cfg.capacity_factor))
        pos, keep = _bucket_positions(dst, ep, cap)

        flat_idx = jnp.where(keep, dst * cap + pos, ep * cap)  # drop slot
        send = jnp.zeros((ep * cap + 1, d), my.dtype)
        send = send.at[flat_idx].add(
            jnp.repeat(my, cfg.top_k, axis=0) *
            keep[:, None].astype(my.dtype))[:-1]
        meta_e = jnp.full((ep * cap + 1,), -1, jnp.int32).at[flat_idx].max(
            jnp.where(keep, flat_expert % e_loc, -1))[:-1]
        meta_s = jnp.full((ep * cap + 1,), -1, jnp.int32).at[flat_idx].max(
            jnp.where(keep, src_slot, -1))[:-1]

        # exchange: (ep, cap, ...) split over axis -> gathered from all srcs
        recv = jax.lax.all_to_all(send.reshape(ep, cap, d), ep_axis, 0, 0,
                                  tiled=False).reshape(ep * cap, d)
        recv_e = jax.lax.all_to_all(meta_e.reshape(ep, cap), ep_axis, 0, 0,
                                    tiled=False).reshape(ep * cap)

        # local expert compute: bucket rows per local expert
        ecap = int(np.ceil(recv.shape[0] / e_loc * cfg.capacity_factor))
        valid = recv_e >= 0
        e_of_row = jnp.where(valid, recv_e, 0)
        pos2, keep2 = _bucket_positions(
            jnp.where(valid, e_of_row, e_loc), e_loc + 1, ecap)
        keep2 &= valid
        bidx = jnp.where(keep2, e_of_row * ecap + pos2, e_loc * ecap)
        buckets = jnp.zeros((e_loc * ecap + 1, d), recv.dtype)
        buckets = buckets.at[bidx].add(
            recv * keep2[:, None].astype(recv.dtype))[:-1]
        bx = buckets.reshape(e_loc, ecap, d)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", bx, wg)) * \
            jnp.einsum("ecd,edf->ecf", bx, wu)
        out_b = jnp.einsum("ecf,efd->ecd", h.astype(bx.dtype), wd)
        # un-bucket back to received-row order
        out_rows = out_b.reshape(e_loc * ecap, d)[
            jnp.clip(bidx, 0, e_loc * ecap - 1)] * \
            keep2[:, None].astype(out_b.dtype)

        # return to source devices and slots.  The remote preserved intra-
        # block row order, so back slot (i*cap + c) is the result of MY send
        # slot (i*cap + c): the LOCAL meta_s indexes it directly (a second
        # metadata exchange would pair results with the wrong slots).
        back = jax.lax.all_to_all(out_rows.reshape(ep, cap, d), ep_axis,
                                  0, 0, tiled=False).reshape(ep * cap, d)
        back_s = meta_s
        ok = back_s >= 0
        contrib = jnp.zeros((rows + 1, d), back.dtype)
        contrib = contrib.at[jnp.where(ok, back_s, rows)].add(
            back * ok[:, None].astype(back.dtype))[:-1]
        y_my = jnp.sum(contrib.reshape(t_loc, cfg.top_k, d) *
                       flat_w.reshape(t_loc, cfg.top_k)[..., None]
                       .astype(back.dtype), axis=1)

        # reassemble the full token block across the ep axis
        y_all = jax.lax.all_gather(y_my, ep_axis, axis=0,
                                   tiled=True)               # (T_all, d)
        return y_all.reshape(b_loc, s, d)

    e_spec = P(ep_axis, None, None)
    out = shard_map(
        inner, mesh,
        in_specs=(P(dp_axis, None, None), P(), e_spec, e_spec,
                  P(ep_axis, None, None)),
        out_specs=P(dp_axis, None, None),
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return out
