"""The assigned input-shape grid and abstract input specs (no allocation).

Four shapes per LM architecture:
    train_4k     seq 4096,    global_batch 256   -> train_step
    prefill_32k  seq 32768,   global_batch 32    -> prefill
    decode_32k   seq 32768,   global_batch 128   -> decode_step (KV @ 32k)
    long_500k    seq 524288,  global_batch 1     -> decode_step (KV @ 512k)

long_500k is only valid for sub-quadratic archs (ssm / hybrid / gemma3's
5:1 sliding-window pattern); `cell_supported` encodes the skip rules from
DESIGN.md §Arch-applicability.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import sharding as SH
from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def cell_supported(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention arch: 512k decode has no "
                       "sub-quadratic path (DESIGN.md §Arch-applicability)")
    return True, ""


def _sds(shape, dtype, *axes):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=SH.named_sharding(axes, shape))


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    For train: the batch dict. For prefill: prompt tokens (+modality stubs).
    For decode: the one-token batch (the KV cache is built separately).
    """
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        d = {"tokens": _sds((b, s), jnp.int32, "batch", None),
             "labels": _sds((b, s), jnp.int32, "batch", None)}
    elif shape.kind == "prefill":
        d = {"tokens": _sds((b, s), jnp.int32, "batch", None)}
    else:  # decode
        d = {"tokens": _sds((b, 1), jnp.int32, "batch", None)}
    if cfg.family == "audio" and shape.kind != "decode":
        d["frames"] = _sds((b, cfg.enc_seq, cfg.d_model), jnp.float32,
                           "batch", None, "act_embed")
    if cfg.family == "vlm" and shape.kind != "decode":
        d["patches"] = _sds((b, cfg.n_patches, cfg.d_model), jnp.float32,
                            "batch", None, "act_embed")
    return d
