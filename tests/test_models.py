"""Per-architecture smoke tests (deliverable f): every assigned arch, reduced
config, one forward + one train step on CPU; shapes + no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, get_config, list_archs, reduced
from repro.models import common as C
from repro.models import lm as LM
from repro.optim import adamw as OPT
from repro.train import step as TS

B, S = 2, 32


def _batch(cfg, key):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab),
    }
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.enc_seq, cfg.d_model), jnp.float32) * 0.1
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.n_patches, cfg.d_model), jnp.float32) * 0.1
    return batch


def test_registry_has_all_ten():
    assert len(list_archs()) == 10


@pytest.mark.parametrize("arch", sorted(REGISTRY))
def test_smoke_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    defs = LM.model_defs(cfg, max_seq=S)
    params = C.init_params(defs, jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))

    logits, aux = LM.forward(params, cfg, batch)
    expect_s = S + (cfg.n_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (B, expect_s, cfg.vocab_)
    assert not bool(jnp.isnan(logits).any()), "NaN logits"

    ts = jax.jit(TS.make_train_step(cfg))
    opt = OPT.init(params, OPT.AdamWConfig())
    p2, o2, m = ts(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    assert float(m["loss"]) < 2.0 * np.log(cfg.vocab_) + 5
    # params actually changed
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(p2)[0]
    assert not np.array_equal(np.asarray(l0), np.asarray(l1))


@pytest.mark.parametrize("arch", sorted(REGISTRY))
def test_full_config_param_counts_sane(arch):
    """Analytic parameter counts should be in the advertised ballpark."""
    cfg = get_config(arch)
    n = cfg.param_count()
    expected = {
        "minitron-8b": (7e9, 10.5e9),
        "yi-34b": (30e9, 40e9),
        "qwen1.5-32b": (29e9, 40e9),
        "gemma3-27b": (24e9, 32e9),
        # the ASSIGNED config (48L x 64e x 1408ff) is bigger than the
        # hf Moonlight (27L); we implement the assignment as specified
        "moonshot-v1-16b-a3b": (24e9, 32e9),
        "deepseek-v3-671b": (6.0e11, 7.4e11),
        "whisper-large-v3": (1.2e9, 2.2e9),
        "pixtral-12b": (10e9, 15e9),
        "mamba2-1.3b": (1.0e9, 1.8e9),
        "zamba2-2.7b": (2.2e9, 3.6e9),
    }[arch]
    assert expected[0] < n < expected[1], f"{arch}: {n:.3e}"


def test_moe_active_params_below_total():
    cfg = get_config("deepseek-v3-671b")
    assert cfg.active_param_count() < 0.1 * cfg.param_count()
