"""Engine backends — four executions of the same `GASpec` datapath.

  reference  pure-JAX `lax.scan` (the faithful port in repro.core.ga);
             supports every operator combination and vmapped `n_repeats`.
  fused      the Pallas one-kernel-per-generation path (repro.kernels);
             paper pipeline only, arith FFM, power-of-two N <= 1024.
             `n_repeats` replicas map onto the kernel's island grid axis.
  islands    vmapped island model with ring migration (repro.core.islands),
             shard_mapped over a mesh when one is provided.
  eager      python-loop driver for non-traceable fitness functions
             (operators stay jitted; fitness runs eagerly).

Each backend implements `supports(spec)` (capability check → reason string or
None), `init(spec)` (backend-native state pytree) and `segment(state, gens)`
(advance `gens` generations, returning the new state + telemetry).  The
Engine composes segments into full runs, chunked streaming and
checkpoint/resume — so every backend gets those features for free.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ga as G
from repro.core import islands as ISL
from repro.ga import operators as OPS
from repro.ga.spec import GASpec
from repro.kernels import ga_step as _ga_step


@dataclasses.dataclass
class Segment:
    """Telemetry for one contiguous block of generations (raw fitness units).

    traj arrays have one entry per generation, except the islands backend
    where the unit is one migration epoch (`migrate_every` generations).
    """

    state: Any
    best_y: float
    best_x: np.ndarray          # uint32[V]
    traj_best: np.ndarray
    traj_mean: np.ndarray
    gens: int
    extras: Dict[str, Any] = dataclasses.field(default_factory=dict)


def _better_f(minimize: bool):
    return min if minimize else max


def _arg_best(y: np.ndarray, minimize: bool) -> int:
    return int(np.argmin(y) if minimize else np.argmax(y))


def _stack_states(cfg: G.GAConfig, n_replicas: int):
    """Replica r is seeded `seed + r` — replica 0 reproduces the solo run
    bit-exactly (asserted in tests), and the splitmix seed hash decorrelates
    consecutive integers."""
    states = [G.init_state(dataclasses.replace(cfg, seed=cfg.seed + r))
              for r in range(n_replicas)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *states)


class Backend:
    """One execution strategy for a GASpec."""

    name = "?"

    def __init__(self, spec: GASpec, *, mesh=None, interpret=None):
        self.spec = spec
        self.cfg = spec.ga_config()
        self.mesh = mesh
        self.interpret = interpret
        self._cache: Dict[int, Any] = {}   # gens -> jitted segment runner

    @staticmethod
    def supports(spec: GASpec, mesh=None) -> Optional[str]:
        """None if the spec can run on this backend, else the reason why not."""
        raise NotImplementedError

    def init(self):
        raise NotImplementedError

    def segment(self, state, gens: int) -> Segment:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# reference — pure-JAX scan, any operators, vmapped repeats
# ---------------------------------------------------------------------------


class ReferenceBackend(Backend):
    name = "reference"

    def __init__(self, spec, **kw):
        super().__init__(spec, **kw)
        self.fit = spec.fitness_fn()
        self.gen_fn = OPS.make_generation(spec.selection, spec.crossover,
                                          spec.mutation)

    @staticmethod
    def supports(spec: GASpec, mesh=None) -> Optional[str]:
        if not spec.jit_fitness:
            return "fitness is not traceable (jit_fitness=False); use 'eager'"
        if spec.n_islands > 1:
            return "n_islands > 1; use the 'islands' backend"
        return None

    def init(self):
        if self.spec.n_repeats == 1:
            return G.init_state(self.cfg)
        return _stack_states(self.cfg, self.spec.n_repeats)

    def _runner(self, gens: int):
        if gens not in self._cache:
            one = lambda st: G.run(self.cfg, self.fit, gens, st, self.gen_fn)
            fn = one if self.spec.n_repeats == 1 else jax.vmap(one)
            self._cache[gens] = jax.jit(fn)
        return self._cache[gens]

    def segment(self, state, gens: int) -> Segment:
        out: G.GARun = self._runner(gens)(state)
        mini = self.spec.minimize
        if self.spec.n_repeats == 1:
            return Segment(state=out.state, best_y=float(out.best_y),
                           best_x=np.asarray(out.best_x),
                           traj_best=np.asarray(out.traj_best),
                           traj_mean=np.asarray(out.traj_mean), gens=gens)
        per_rep = np.asarray(out.best_y)                       # [R]
        r = _arg_best(per_rep, mini)
        tb = np.asarray(out.traj_best)                         # [R, gens]
        reduce = np.min if mini else np.max
        return Segment(state=out.state, best_y=float(per_rep[r]),
                       best_x=np.asarray(out.best_x)[r],
                       traj_best=reduce(tb, axis=0),
                       traj_mean=np.asarray(out.traj_mean).mean(axis=0),
                       gens=gens,
                       extras={"per_repeat_best": per_rep,
                               "per_repeat_traj_best": tb})


# ---------------------------------------------------------------------------
# fused — the Pallas kernel, scanned with best/trajectory tracking
# ---------------------------------------------------------------------------


class FusedBackend(Backend):
    name = "fused"

    def __init__(self, spec, **kw):
        super().__init__(spec, **kw)
        self.arith = spec.arith_spec()
        if self.interpret is None:
            self.interpret = jax.default_backend() != "tpu"

    @staticmethod
    def supports(spec: GASpec, mesh=None) -> Optional[str]:
        if not spec.jit_fitness:
            return "fitness is not traceable (jit_fitness=False); use 'eager'"
        if spec.mode != "arith":
            return ("Pallas kernel requires mode='arith' — LUT gathers stay "
                    "on the XLA path ('reference')")
        if spec.problem is None or spec.arith_spec() is None:
            return "fused FFM needs a closed-form paper problem (ArithSpec)"
        if spec.n & (spec.n - 1):
            return f"fused kernel requires power-of-two N (got {spec.n})"
        if spec.n > 1024:
            return (f"N={spec.n} > 1024: the (N, N) one-hot tournament "
                    "matrices must fit VMEM; use islands/reference")
        if not spec.uses_paper_pipeline:
            return ("fused kernel hardwires the paper pipeline "
                    "(tournament/single_point/xor); other operators run on "
                    "'reference'")
        if spec.n_islands > 1:
            return "migration is not fused; use the 'islands' backend"
        return None

    def init(self):
        # replicas ride the kernel's island grid axis (leading dim)
        return _stack_states(self.cfg, self.spec.n_repeats)

    def _runner(self, gens: int):
        if gens in self._cache:
            return self._cache[gens]
        cfg, arith, interp = self.cfg, self.arith, self.interpret
        mini = self.spec.minimize

        @jax.jit
        def go(states: G.GAState):
            neutral = jnp.full((states.x.shape[0],),
                               jnp.inf if mini else -jnp.inf, jnp.float32)

            def body(carry, _):
                x, sel, cross, mut, by, bx = carry
                x2, sel2, cross2, mut2, y = _ga_step.ga_generation_kernel(
                    x, sel, cross, mut, cfg=cfg, spec=arith,
                    interpret=interp)
                # y is the fitness of x (pre-update) — same convention as
                # the reference scan, so trajectories align bit-for-bit.
                idx = (jnp.argmin(y, axis=1) if mini
                       else jnp.argmax(y, axis=1))
                ii = jnp.arange(x.shape[0])
                gen_best = y[ii, idx]
                better = gen_best < by if mini else gen_best > by
                by2 = jnp.where(better, gen_best, by)
                bx2 = jnp.where(better[:, None], x[ii, idx], bx)
                carry = (x2, sel2, cross2, mut2, by2, bx2)
                tb = jnp.min(gen_best) if mini else jnp.max(gen_best)
                return carry, (tb, jnp.mean(y))

            init = (states.x, states.sel_lfsr, states.cross_lfsr,
                    states.mut_lfsr, neutral,
                    jnp.zeros((states.x.shape[0], cfg.v), jnp.uint32))
            (x, sel, cross, mut, by, bx), (tb, tm) = jax.lax.scan(
                body, init, None, length=gens)
            return G.GAState(x, sel, cross, mut, states.k + gens), by, bx, tb, tm

        self._cache[gens] = go
        return go

    def segment(self, state, gens: int) -> Segment:
        states, by, bx, tb, tm = self._runner(gens)(state)
        per_rep = np.asarray(by)
        r = _arg_best(per_rep, self.spec.minimize)
        return Segment(state=states, best_y=float(per_rep[r]),
                       best_x=np.asarray(bx)[r],
                       traj_best=np.asarray(tb), traj_mean=np.asarray(tm),
                       gens=gens,
                       extras={"per_repeat_best": per_rep})


# ---------------------------------------------------------------------------
# islands — vmapped / shard_mapped island model with ring migration
# ---------------------------------------------------------------------------


class IslandsBackend(Backend):
    name = "islands"

    def __init__(self, spec, **kw):
        super().__init__(spec, **kw)
        self.fit = spec.fitness_fn()
        self.gen_fn = OPS.make_generation(spec.selection, spec.crossover,
                                          spec.mutation)
        self.icfg = ISL.IslandConfig(ga=self.cfg,
                                     n_islands=spec.n_islands,
                                     migrate_every=spec.migrate_every)

    @staticmethod
    def supports(spec: GASpec, mesh=None) -> Optional[str]:
        if not spec.jit_fitness:
            return "fitness is not traceable (jit_fitness=False); use 'eager'"
        if spec.n_repeats > 1:
            return "n_repeats is redundant with islands; raise n_islands"
        return None

    def init(self):
        states = ISL.init_islands_fast(self.icfg)
        if self.mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            axes = self.icfg.axis_names
            states = jax.tree.map(
                lambda x: jax.device_put(x, NamedSharding(
                    self.mesh, P(axes, *([None] * (x.ndim - 1))))), states)
        return states

    def _epoch(self):
        if "epoch" in self._cache:
            return self._cache["epoch"]
        if self.mesh is not None:
            step = ISL.make_sharded_step(self.icfg, self.fit, self.mesh,
                                         self.gen_fn)
        else:
            step = ISL.make_local_step(self.icfg, self.fit, self.gen_fn)
        self._cache["epoch"] = step
        return step

    def segment(self, state, gens: int) -> Segment:
        epochs = max(1, math.ceil(gens / self.icfg.migrate_every))
        step = self._epoch()
        mini = self.spec.minimize
        better = _better_f(mini)
        best_y, best_x = None, None
        tb, tm = [], []
        for _ in range(epochs):
            state, elite_x, elite_y = step(state)
            ey = np.asarray(elite_y)
            i = _arg_best(ey, mini)
            if best_y is None or better(ey[i], best_y) == ey[i]:
                best_y, best_x = float(ey[i]), np.asarray(elite_x)[i]
            tb.append(float(ey[i]))
            tm.append(float(ey.mean()))
        return Segment(state=state, best_y=best_y, best_x=best_x,
                       traj_best=np.asarray(tb), traj_mean=np.asarray(tm),
                       gens=epochs * self.icfg.migrate_every,
                       extras={"telemetry_unit_gens": self.icfg.migrate_every,
                               "n_islands": self.icfg.n_islands})


# ---------------------------------------------------------------------------
# eager — python generation loop for non-traceable fitness
# ---------------------------------------------------------------------------


class EagerBackend(Backend):
    name = "eager"

    def __init__(self, spec, **kw):
        super().__init__(spec, **kw)
        self.fit = spec.fitness_fn()
        self.apply_ops = OPS.make_apply_ops(spec.selection, spec.crossover,
                                            spec.mutation)

    @staticmethod
    def supports(spec: GASpec, mesh=None) -> Optional[str]:
        if spec.n_islands > 1:
            return "eager driver has no migration; use 'islands'"
        return None

    def init(self):
        if self.spec.n_repeats == 1:
            return G.init_state(self.cfg)
        return _stack_states(self.cfg, self.spec.n_repeats)

    def segment(self, state, gens: int) -> Segment:
        R = self.spec.n_repeats
        mini = self.spec.minimize
        if R == 1:
            out = G.run_unjitted(self.cfg, self.fit, gens, state,
                                 apply_ops_fn=self.apply_ops)
            return Segment(state=out.state, best_y=float(out.best_y),
                           best_x=np.asarray(out.best_x),
                           traj_best=np.asarray(out.traj_best),
                           traj_mean=np.asarray(out.traj_mean), gens=gens)
        outs = []
        for r in range(R):
            st_r = jax.tree.map(lambda a: a[r], state)
            cfg_r = dataclasses.replace(self.cfg, seed=self.cfg.seed + r)
            outs.append(G.run_unjitted(cfg_r, self.fit, gens, st_r,
                                       apply_ops_fn=self.apply_ops))
        state = jax.tree.map(lambda *xs: jnp.stack(xs),
                             *[o.state for o in outs])
        per_rep = np.array([float(o.best_y) for o in outs])
        i = _arg_best(per_rep, mini)
        tb = np.stack([np.asarray(o.traj_best) for o in outs])
        reduce = np.min if mini else np.max
        return Segment(state=state, best_y=float(per_rep[i]),
                       best_x=np.asarray(outs[i].best_x),
                       traj_best=reduce(tb, axis=0),
                       traj_mean=np.stack([np.asarray(o.traj_mean)
                                           for o in outs]).mean(axis=0),
                       gens=gens, extras={"per_repeat_best": per_rep})


BACKENDS: Dict[str, type] = {
    ReferenceBackend.name: ReferenceBackend,
    FusedBackend.name: FusedBackend,
    IslandsBackend.name: IslandsBackend,
    EagerBackend.name: EagerBackend,
}
