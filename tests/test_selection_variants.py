"""Alternative selection methods (paper Sec. 2 survey) behave correctly."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fitness as F
from repro.core import ga as G
from repro.core import selection as SEL


def _setup(seed=0, n=64):
    cfg = G.GAConfig(n=n, c=10, v=2, mutation_rate=0.03, seed=seed,
                     mode="arith")
    fit = G.fitness_for_problem(F.F3, cfg)
    return cfg, fit, G.init_state(cfg)


@pytest.mark.parametrize("name", sorted(SEL.SELECTORS))
def test_selector_preserves_population_invariants(name):
    cfg, fit, st = _setup()
    sel = SEL.SELECTORS[name]
    y = fit(st.x)
    w, _ = sel(st.x, y, st.sel_lfsr, cfg)
    assert w.shape == st.x.shape
    # every selected chromosome exists in the source population
    xs = {tuple(r) for r in np.asarray(st.x)}
    for r in np.asarray(w):
        assert tuple(r) in xs


@pytest.mark.parametrize("name", sorted(SEL.SELECTORS))
def test_selector_biases_toward_better_fitness(name):
    cfg, fit, st = _setup(seed=3, n=128)
    sel = SEL.SELECTORS[name]
    y = fit(st.x).astype(jnp.float32)
    w, _ = sel(st.x, y, st.sel_lfsr, cfg)
    yw = fit(w).astype(jnp.float32)
    assert float(jnp.mean(yw)) <= float(jnp.mean(y)) + 1e-3, \
        f"{name}: selection should not worsen mean fitness (minimize)"


@pytest.mark.parametrize("name", sorted(SEL.SELECTORS))
def test_ga_converges_with_each_selector(name):
    cfg, fit, st = _setup(seed=5)
    sel = SEL.SELECTORS[name]

    @jax.jit
    def run(st):
        def body(carry, _):
            st, best = carry
            st2, y = SEL.generation_with(sel, st, cfg, fit)
            best = jnp.minimum(best, jnp.min(y.astype(jnp.float32)))
            return (st2, best), None
        (st, best), _ = jax.lax.scan(body, (st, jnp.float32(jnp.inf)),
                                     None, length=100)
        return best

    assert float(run(st)) < 5.0


def test_elitism_preserves_best():
    cfg, fit, st = _setup(seed=9)
    sel = SEL.with_elitism(SEL.tournament, n_elite=1)
    y = fit(st.x).astype(jnp.float32)
    w, _ = sel(st.x, y, st.sel_lfsr, cfg)
    best = st.x[jnp.argmin(y)]
    assert any(np.array_equal(np.asarray(best), r) for r in np.asarray(w))
