"""Repeat-until-stable replay timing.

One micro-benchmark sample is worthless on a shared host: the first call
pays compilation, the next few pay cache warmup, and any call can eat a
scheduler hiccup.  `replay_until_stable` runs the workload until the
coefficient of variation (std/mean) over a trailing window of repetitions
drops under a threshold — the replay-stability check from trace-replay
cost models — and reports the windowed mean plus whether stability was
actually reached before the repetition cap.

The clock is injectable (`timer=`), so tests drive the whole convergence
logic with a deterministic fake timer and zero real sleeping.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class Replay:
    """Outcome of one replay-until-stable run (times are per-rep seconds;
    mean_s/cov describe the trailing window, not all reps)."""
    times: Tuple[float, ...]
    mean_s: float
    cov: float
    reps: int
    stable: bool


def _window_stats(times, window: int) -> Tuple[float, float]:
    tail = times[-window:]
    mean = sum(tail) / len(tail)
    if mean <= 0.0:
        return mean, math.inf
    var = sum((t - mean) ** 2 for t in tail) / len(tail)
    return mean, math.sqrt(var) / mean


def replay_until_stable(fn: Callable[[], object], *,
                        warmup: int = 1,
                        min_reps: int = 3,
                        max_reps: int = 16,
                        cov_threshold: float = 0.10,
                        window: Optional[int] = None,
                        timer: Callable[[], float] = time.perf_counter,
                        ) -> Replay:
    """Time `fn()` until the trailing-window CoV is <= cov_threshold.

    Runs `warmup` untimed calls, then timed repetitions: from `min_reps`
    onward the CoV over the last `window` (default: min_reps) samples is
    checked after every rep, and the first window that meets the threshold
    ends the run.  Hitting `max_reps` without converging still returns the
    trailing-window stats, flagged `stable=False` — callers decide whether
    an unstable measurement is worth persisting.
    """
    if min_reps < 2:
        raise ValueError("min_reps must be >= 2 (CoV of one sample)")
    if max_reps < min_reps:
        raise ValueError("max_reps must be >= min_reps")
    window = min_reps if window is None else window
    if window < 2:
        raise ValueError("window must be >= 2")

    for _ in range(warmup):
        fn()

    times = []
    while len(times) < max_reps:
        t0 = timer()
        fn()
        times.append(timer() - t0)
        if len(times) >= min_reps:
            mean, cov = _window_stats(times, window)
            if cov <= cov_threshold:
                return Replay(tuple(times), mean, cov, len(times), True)
    mean, cov = _window_stats(times, window)
    return Replay(tuple(times), mean, cov, len(times), False)
