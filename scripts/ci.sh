#!/usr/bin/env bash
# Tier-1 verification + launch smokes of the unified GA engine + the
# benchmark regression gate.  Run by .github/workflows/ci.yml on every push.
#
#   bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== engine smoke (reference backend, ~5s) =="
timeout 120 python -m repro.launch.ga_run \
    --problem F1 --n 16 --k 20 --backend reference

echo "== n-variable smoke (rastrigin:4 through the fused kernel FFM stage) =="
timeout 120 python -m repro.launch.ga_run \
    --problem rastrigin:4 --n 16 --k 20 --backend fused --mode arith

echo "== distributed smoke (fused-islands on a mesh, RESIDENT epochs:"
echo "   gens_per_epoch > migrate_every, ring migration in VMEM) =="
timeout 180 python -m repro.launch.ga_run \
    --problem rastrigin:4 --n 16 --k 16 --islands 2 --migrate-every 4 \
    --backend fused-islands --mesh auto --gens-per-epoch 8

echo "== scheduler smoke (multi-tenant packing + preemption on an"
echo "   8-fake-device mesh; per-job bests bit-identical to solo runs) =="
timeout 420 python scripts/scheduler_smoke.py

echo "== chaos smoke (deterministic fault injection: crash retry, corrupt"
echo "   ckpt fallback, pack quarantine, preemption + journal recovery) =="
timeout 420 python scripts/chaos_smoke.py

echo "== autotune smoke (tiny sweep on the 8-fake-device host; table"
echo "   written, planner consumes it, snapshot still steers plans) =="
mkdir -p artifacts
timeout 420 python scripts/autotune_smoke.py \
    --out artifacts/autotune_table.json

echo "== streaming smoke (oversized island stack through the HBM-streaming"
echo "   epoch lane; bit-identical to the islands reference) =="
timeout 420 python scripts/streaming_smoke.py

echo "== backend-matrix smoke (1 tiny config per topology x executor x problem) =="
timeout 420 python -m benchmarks.engine_backends --smoke \
    --out artifacts/engine_backends.json \
    --cost-table artifacts/autotune_table.json
cat artifacts/engine_backends.json

echo "== serve-throughput smoke (K packed jobs vs K sequential) =="
timeout 420 python -m benchmarks.serve_throughput --smoke \
    --out artifacts/serve_throughput.json

echo "== bench regression gate (relative combo-vs-reference ratios) =="
# --append-trajectory extends the COMMITTED per-PR throughput history —
# commit the updated benchmarks/BENCH_trajectory.json with your PR
python scripts/check_bench.py artifacts/engine_backends.json \
    --append-trajectory
python scripts/check_bench.py artifacts/serve_throughput.json \
    --baseline benchmarks/baseline_serve_throughput.json

echo "CI OK"
