"""Serving throughput: K packed jobs vs K sequential solo runs.

The scheduler's replica-axis packing multiplexes shape-compatible jobs onto
one launch; this benchmark measures what that buys in AGGREGATE throughput
(sum of all jobs' generations / wall time) for K tenants submitting the
same spec shape with different seeds:

    engine_reference[F3]   the solo anchor every ratio divides by
    serve_seq[F3]          K solo Engine runs back to back (no packing)
    serve_packed[F3]       one PackedEngine launch, K slots down n_repeats

`serve_packed / engine_reference` is the regression gate for the packing
path (scripts/check_bench.py --baseline
benchmarks/baseline_serve_throughput.json): results stay bit-identical to
solo runs, so the packed row should approach K × the vectorization win and
must never fall below its committed ratio.  Like every bench here, ratios
(not absolutes) are gated — CPU numbers only rank compositions.

Standalone smoke mode for CI:

    PYTHONPATH=src python -m benchmarks.serve_throughput --smoke \
        --out artifacts/serve_throughput.json
"""

from __future__ import annotations

import argparse
import json
import os

from benchmarks.ga_common import time_call
from repro import ga

K_JOBS = 4
SMOKE = dict(n=16, m=16, generations=8)
FULL = dict(n=64, m=20, generations=100)
PROBLEM = "F3"


def _specs(problem: str, *, n: int, m: int, generations: int):
    return [ga.GASpec(problem=problem, n=n, bits_per_var=m // 2,
                      mode="arith", mutation_rate=0.02, seed=1 + i,
                      generations=generations) for i in range(K_JOBS)]


def _row(name: str, gens: int, dt: float, extra: dict):
    payload = json.dumps({"problem": PROBLEM.split(":")[0], "n_vars": 2,
                          "gens_per_s": round(gens / dt, 1),
                          "jobs": K_JOBS, "devices": 1, **extra},
                         separators=(",", ":"))
    return (name, dt / gens * 1e6, payload)


def run(smoke: bool = False):
    sizes = SMOKE if smoke else FULL
    specs = _specs(PROBLEM, **sizes)
    gens = sizes["generations"]
    rows = []

    # anchor: one solo reference run (the denominator of every ratio)
    solo = ga.Engine(specs[0], "reference")
    solo.run()                                     # compile + warm caches
    dt, out = time_call(solo.run, warmup=0, iters=3)
    rows.append(_row(f"engine_reference[{PROBLEM}]", gens, dt,
                     {"backend": "reference", "best":
                      round(out.best_fitness, 4)}))

    # K sequential solo runs: what K tenants cost without the scheduler
    engines = [ga.Engine(s, "reference") for s in specs]
    for e in engines:
        e.run()

    def seq():
        return [e.run() for e in engines]

    dt, outs = time_call(seq, warmup=0, iters=3)
    rows.append(_row(f"serve_seq[{PROBLEM}]", gens * K_JOBS, dt,
                     {"backend": "reference",
                      "best": round(outs[0].best_fitness, 4)}))

    # K jobs packed down the replica axis: one launch, bit-identical slots
    pe = ga.PackedEngine(specs, "reference")
    pe.run()

    dt, jobs = time_call(pe.run, warmup=0, iters=3)
    rows.append(_row(f"serve_packed[{PROBLEM}]", gens * K_JOBS, dt,
                     {"backend": "reference", "pack_size": K_JOBS,
                      "best": round(jobs[0]["best_fitness"], 4)}))
    # packing must not change results: packed slot 0 == solo job 0
    assert jobs[0]["best_fitness"] == outs[0].best_fitness, \
        "packed slot diverged from its solo run"
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config (CI regression gate; seconds)")
    ap.add_argument("--out", default=None,
                    help="write the rows as a JSON artifact here")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print("name,us_per_gen,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        artifact = [{"name": name, "us_per_gen": round(us, 2),
                     **json.loads(derived)} for name, us, derived in rows]
        with open(args.out, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
