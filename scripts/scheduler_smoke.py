#!/usr/bin/env python
"""CI smoke for GA-as-a-service: the multi-tenant scheduler on a mesh.

Forces an 8-device host-platform mesh, submits heterogeneous jobs —
two shape-compatible island jobs (packed down the replica axis), an
incompatible rastrigin job, and a late high-priority arrival that preempts
the running low-priority pack — then asserts:

  * every per-job best is bit-identical to its solo `ga.solve` run
    (packing and checkpoint/resume preemption change scheduling, never
    results);
  * at least one pack held >= 2 jobs and at least one preemption happened;
  * the resubmitted spec shape hit the compiled-runner cache;
  * /metrics serves the `repro_ga_sched_*` + compile-cache gauges.

    PYTHONPATH=src python scripts/scheduler_smoke.py
"""

import os
import re
import sys
import urllib.request

# must precede the first jax import: fake an 8-device host platform
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import ga                                    # noqa: E402
from repro.launch.mesh import make_island_mesh          # noqa: E402
from repro.serve.engine import GAMetricsRegistry        # noqa: E402
from repro.serve.metrics_http import start_metrics_server   # noqa: E402
from repro.serve.scheduler import GAScheduler           # noqa: E402


def _spec(**kw):
    base = dict(problem="F3", n=32, bits_per_var=10, mode="arith",
                mutation_rate=0.05, seed=11, generations=24,
                n_islands=8, migrate_every=4)
    base.update(kw)
    return ga.GASpec(**base)


def main():
    mesh = make_island_mesh(8)
    print(f"mesh: {dict(mesh.shape)}")
    reg = GAMetricsRegistry()
    sched = GAScheduler(mesh=mesh, registry=reg, backend="islands",
                        chunk_generations=8)
    server = start_metrics_server(0, registry=reg, host="127.0.0.1")
    port = server.server_address[1]
    try:
        # a long low-priority job the hot job will preempt mid-run
        lo_spec = _spec(seed=3, generations=96)
        lo = sched.submit(lo_spec, priority=0)
        # two shape-compatible jobs -> one packed launch (submitted while
        # lo runs, so they queue together and pack at dispatch)
        pa_spec, pb_spec = _spec(seed=11), _spec(seed=40)
        pa, pb = sched.submit(pa_spec), sched.submit(pb_spec)
        # heterogeneous: different problem/shape, cannot pack with the pair
        ra_spec = _spec(problem="rastrigin:4", seed=5)
        ra = sched.submit(ra_spec)
        # the preemptor: submitted only once lo has streamed a chunk (i.e.
        # is demonstrably mid-run), so the strictly higher priority must
        # park lo between chunks rather than just winning the initial race
        hot_spec = _spec(problem="ackley:4", seed=7)
        hot = None
        for event in sched.stream(lo, timeout=600):
            if event.get("event") == "chunk" and hot is None:
                hot = sched.submit(hot_spec, priority=10)
                break
        assert hot is not None, "lo ended before streaming a single chunk"

        results = {j: sched.result(j, timeout=600)
                   for j in (lo, pa, pb, ra, hot)}

        # 1) bit-identical to solo runs, packing and preemption included
        for job_id, spec in ((lo, lo_spec), (pa, pa_spec), (pb, pb_spec),
                             (ra, ra_spec), (hot, hot_spec)):
            solo = ga.solve(spec, backend="islands", mesh=mesh)
            got = results[job_id]["best_fitness"]
            assert got == solo.best_fitness, \
                f"{job_id}: packed/preempted best {got} != solo " \
                f"{solo.best_fitness}"
            print(f"{job_id}: best={got:.6f} "
                  f"pack={results[job_id]['pack_size']} (== solo)")

        # 2) packing + preemption actually exercised
        stats = sched.stats()
        print(f"stats: {stats}")
        assert stats["worker_alive"] is True, "worker thread died mid-run"
        assert max(r["pack_size"] for r in results.values()) >= 2, \
            "no pack held >= 2 jobs"
        assert stats["jobs_packed"] >= 2
        assert stats["preemptions"] >= 1, "no preemption happened"
        assert reg.metrics()["jobs"][lo]["preemptions"] >= 1

        # 3) identical spec shape resubmitted -> compiled-runner cache hit
        hits0 = stats["cache_hits"]
        again = sched.submit(_spec(seed=77))
        sched.result(again, timeout=600)
        assert sched.stats()["cache_hits"] > hits0, \
            "resubmitted spec shape missed the compile cache"

        # 4) the gauges are scrapeable
        text = urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics", timeout=10).read().decode()
        for gauge in ("repro_ga_sched_queue_depth",
                      "repro_ga_sched_jobs_running",
                      "repro_ga_sched_packs_launched",
                      "repro_ga_sched_preemptions",
                      "repro_ga_compile_cache_hits"):
            assert gauge in text, f"missing gauge {gauge}"
        hits = float(re.search(r"^repro_ga_compile_cache_hits (\S+)$",
                               text, re.M).group(1))
        assert hits > 0
        print(f"/metrics OK (compile_cache_hits={hits:g})")
        print("scheduler smoke OK")
    finally:
        server.shutdown()
        sched.shutdown()
        assert sched.stats()["worker_alive"] is False, \
            "worker thread survived shutdown"


if __name__ == "__main__":
    main()
