"""The HBM-streaming epoch lane + the typed engine API surface: planner
feasibility boundaries around the VMEM budget, bit-identity of the streamed
kernel to the `islands` reference (single device, pinned tiles, sharded
8-fake-device mesh), forced-override validation, the fused multi-bank LFSR
leap, `EngineOptions` resolution and the deprecated `.extras` views."""

import dataclasses
import os
import subprocess
import sys

import numpy as np
import pytest

from repro import ga
from repro.ga.options import resolve_options
from repro.kernels import ga_step as K


def _spec(**kw):
    base = dict(problem="F3", n=16, bits_per_var=8, mode="arith",
                mutation_rate=0.02, seed=1, generations=16, n_islands=8,
                migrate_every=4, gens_per_epoch=8)
    base.update(kw)
    return ga.GASpec(**base)


def _budget(spec, islands):
    """A planning budget sized to `islands` resident islands of this spec —
    under the full stack, so the streamed lane engages."""
    return K.resident_vmem_bytes(spec.ga_config(), islands)


# ---------------------------------------------------------------------------
# Planner boundaries: at / under / far-under the budget
# ---------------------------------------------------------------------------


def test_candidate_boundaries_around_the_budget():
    """`epoch_mode_candidates` at the exact byte boundaries: resident at the
    budget, streamed one byte under (largest double-buffered tile), gridded
    only when not even one double-buffered island fits."""
    cfg = _spec().ga_config()
    kw = dict(executor="fused", migration="ring", gens_per_epoch=8,
              migrate_every=4, sharded=False)
    fit = K.resident_vmem_bytes(cfg, 8)
    cands = K.epoch_mode_candidates(cfg, 8, budget=fit, **kw)
    assert [c["mode"] for c in cands] == ["resident", "gridded"]
    # one byte under: the streamed lane IS the heuristic, and the 4-island
    # tile (double-buffered = the full 8-island stack) is exactly too big
    cands = K.epoch_mode_candidates(cfg, 8, budget=fit - 1, **kw)
    assert [c["mode"] for c in cands] == ["streamed", "gridded"]
    s = cands[0]
    assert s["tile_islands"] == 2
    assert "VMEM" in s["fallback"]
    # streamed folds whole migration intervals, exactly like resident
    assert s["epochs_per_launch"] == 2 and s["gens_per_launch"] == 8
    # below a single double-buffered island: gridded only, reason attached
    floor = 2 * K.resident_vmem_bytes(cfg, 1)
    assert K.streamed_tile_islands(cfg, 8, budget=floor) == 1
    cands = K.epoch_mode_candidates(cfg, 8, budget=floor - 1, **kw)
    assert [c["mode"] for c in cands] == ["gridded"]
    assert "VMEM" in cands[0]["fallback"]


def test_migration_none_keeps_gridded_heuristic():
    """For migration='none' the streamed candidate is offered for the table
    or an override to pick, but gridded stays the silent default."""
    cfg = _spec().ga_config()
    cands = K.epoch_mode_candidates(
        cfg, 8, executor="fused", migration="none", gens_per_epoch=16,
        migrate_every=4, sharded=False, budget=_budget(_spec(), 5))
    assert [c["mode"] for c in cands] == ["gridded", "streamed"]


def test_plan_override_streamed_on_fitting_spec_errors():
    """Forcing the streamed lane onto a spec whose stack FITS residency is
    refused with the feasibility hint (streamed exists because of the
    budget, it is not a free-floating mode)."""
    with pytest.raises(ValueError, match="vmem_budget"):
        ga.Engine(_spec(), "fused-islands",
                  options=ga.EngineOptions(cost_table=False,
                                           plan_override="streamed"))


# ---------------------------------------------------------------------------
# Bit-identity: the streamed kernel is a launch-shape change, never a
# results change
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("problem", ["F1", "F2", "F3", "rastrigin:4"])
def test_streamed_bit_identical_to_islands_reference(problem):
    """Every paper problem + an n-variable one through the streamed lane:
    final population and all three LFSR banks bit-equal the `islands`
    reference backend after 16 generations (4 ring migrations)."""
    spec = _spec(problem=problem)
    opts = ga.EngineOptions(cost_table=False, vmem_budget=_budget(spec, 5))
    eng = ga.Engine(spec, "fused-islands", options=opts)
    plan = eng.backend.topology.plan
    assert plan["mode"] == "streamed" and plan["tile_islands"] == 2, plan
    seg_s = eng.backend.segment(eng.init_state(), 16)
    ref = ga.Engine(dataclasses.replace(spec, gens_per_epoch=1), "islands",
                    options=ga.EngineOptions(cost_table=False))
    seg_r = ref.backend.segment(ref.init_state(), 16)
    for field in ("x", "sel_lfsr", "cross_lfsr", "mut_lfsr"):
        np.testing.assert_array_equal(np.asarray(getattr(seg_s.state, field)),
                                      np.asarray(getattr(seg_r.state, field)),
                                      err_msg=field)
    assert seg_s.best_y == seg_r.best_y
    # the reported best chromosome must match the RESIDENT lane bit-for-bit
    # (the fused lanes fold per-island bests island-major, so on an exact
    # fitness tie they may surface a different equally-fit chromosome than
    # the gen-major reference fold — a pre-existing fused-lane property)
    res = ga.Engine(spec, "fused-islands",
                    options=ga.EngineOptions(cost_table=False))
    assert res.backend.topology.plan["mode"] == "resident"
    seg_res = res.backend.segment(res.init_state(), 16)
    np.testing.assert_array_equal(np.asarray(seg_s.best_x),
                                  np.asarray(seg_res.best_x))


def test_pinned_tile_is_a_launch_shape_knob_only():
    """Any feasible pinned tile gives bit-identical results; infeasible
    pins (non-divisor, too big to double-buffer) are rejected with the
    byte math."""
    spec = _spec()
    budget = _budget(spec, 5)
    base = ga.solve(spec, backend="fused-islands",
                    options=ga.EngineOptions(cost_table=False,
                                             vmem_budget=budget))
    assert base.telemetry.plan.mode == "streamed"
    for t in (1, 2):
        res = ga.solve(spec, backend="fused-islands",
                       options=ga.EngineOptions(cost_table=False,
                                                vmem_budget=budget,
                                                stream_tile_islands=t))
        assert res.telemetry.plan.tile_islands == t
        assert res.best_fitness == base.best_fitness
        np.testing.assert_array_equal(np.asarray(res.best_x),
                                      np.asarray(base.best_x))
    for bad in (3, 4):      # 3 does not divide 8; 4 won't double-buffer
        with pytest.raises(ValueError, match="feasible tile"):
            ga.Engine(spec, "fused-islands",
                      options=ga.EngineOptions(cost_table=False,
                                               vmem_budget=budget,
                                               stream_tile_islands=bad))


def test_streamed_migration_none_bit_identical_via_override():
    """The isolated-islands ablation through the streamed lane (forced —
    gridded is its heuristic) matches the gridded run bit-for-bit."""
    spec = _spec(migration="none", generations=16, gens_per_epoch=16)
    budget = _budget(spec, 5)
    res = ga.solve(spec, backend="fused-islands",
                   options=ga.EngineOptions(cost_table=False,
                                            vmem_budget=budget,
                                            plan_override="streamed"))
    assert res.telemetry.plan.mode == "streamed"
    assert res.telemetry.plan.source == "forced"
    assert res.telemetry.topology.migrations == 0
    grid = ga.solve(spec, backend="fused-islands",
                    options=ga.EngineOptions(cost_table=False,
                                             vmem_budget=budget))
    assert grid.telemetry.plan.mode == "gridded"
    assert res.best_fitness == grid.best_fitness
    np.testing.assert_array_equal(np.asarray(res.best_x),
                                  np.asarray(grid.best_x))


def test_streamed_sharded_on_eight_fake_devices():
    """The global ring across shards INSIDE the streamed scan body: 32
    islands over 8 fake devices (4 local islands, 1-island tiles), final
    state bit-equal the local `islands` reference (subprocess so the forced
    device count doesn't leak)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["REPRO_GA_COST_TABLE"] = "off"
import dataclasses, jax, numpy as np
from repro import ga
from repro.kernels import ga_step as K
mesh = jax.make_mesh((8,), ("islands",))
spec = ga.GASpec(problem="F3", n=16, bits_per_var=8, mode="arith",
                 mutation_rate=0.02, seed=2, generations=16,
                 n_islands=32, migrate_every=4, gens_per_epoch=8)
budget = K.resident_vmem_bytes(spec.ga_config(), 3)
eng = ga.Engine(spec, "fused-islands",
                options=ga.EngineOptions(mesh=mesh, cost_table=False,
                                         vmem_budget=budget))
plan = eng.backend.topology.plan
assert plan["mode"] == "streamed" and plan["tile_islands"] == 1, plan
seg_s = eng.backend.segment(eng.init_state(), 16)
ref = ga.Engine(dataclasses.replace(spec, gens_per_epoch=1), "islands",
                options=ga.EngineOptions(cost_table=False))
seg_r = ref.backend.segment(ref.init_state(), 16)
for f in ("x", "sel_lfsr", "cross_lfsr", "mut_lfsr"):
    np.testing.assert_array_equal(np.asarray(getattr(seg_s.state, f)),
                                  np.asarray(getattr(seg_r.state, f)),
                                  err_msg=f)
assert seg_s.best_y == seg_r.best_y
print("STREAMED_SHARDED_OK", seg_s.best_y)
"""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "STREAMED_SHARDED_OK" in r.stdout


# ---------------------------------------------------------------------------
# The fused multi-bank LFSR leap
# ---------------------------------------------------------------------------


def test_fused_bank_leap_matches_per_bank_leaps():
    """`_lfsr_draw_banks` (one GF(2) leap over the concatenated register
    file) is bit-identical per element to leaping each bank alone."""
    rng = np.random.default_rng(0)
    import jax.numpy as jnp
    banks = tuple(jnp.asarray(rng.integers(1, 1 << 32, size=s,
                                           dtype=np.uint32))
                  for s in ((2, 16), (3, 8), (5,)))
    for steps in (1, 3, 17, 45):
        fused = K._lfsr_draw_banks(banks, steps)
        for got, bank in zip(fused, banks):
            np.testing.assert_array_equal(
                np.asarray(got), np.asarray(K._lfsr_draw(bank, steps)),
                err_msg=f"steps={steps}")


# ---------------------------------------------------------------------------
# EngineOptions resolution + the deprecated extras views
# ---------------------------------------------------------------------------


def test_engine_options_validation_and_clash():
    with pytest.raises(ValueError, match="plan_override"):
        ga.EngineOptions(plan_override="warp")
    with pytest.raises(ValueError, match="vmem_budget"):
        ga.EngineOptions(vmem_budget=0)
    with pytest.raises(ValueError, match="stream_tile_islands"):
        ga.EngineOptions(stream_tile_islands=-1)
    opts = ga.EngineOptions(cost_table=False)
    assert resolve_options(opts) is opts
    # options= plus a non-default legacy kwarg: two sources of truth
    with pytest.raises(ValueError, match="legacy kwarg"):
        resolve_options(opts, cost_table=False)
    with pytest.raises(ValueError, match="legacy kwarg"):
        ga.Engine(_spec(), "fused-islands", options=opts,
                  plan_override="gridded")
    with pytest.raises(TypeError, match="EngineOptions"):
        resolve_options({"mesh": None})


def test_deprecated_extras_views_warn_and_match_typed_fields():
    spec = _spec(n_islands=2, generations=8)
    res = ga.solve(spec, backend="fused-islands",
                   options=ga.EngineOptions(cost_table=False))
    with pytest.warns(DeprecationWarning, match="EngineResult.extras"):
        legacy = res.extras
    assert legacy["epoch_mode"] == res.telemetry.plan.mode
    assert legacy["migrations"] == res.telemetry.topology.migrations
    eng = ga.Engine(spec, "fused-islands",
                    options=ga.EngineOptions(cost_table=False))
    seg = eng.backend.segment(eng.init_state(), 8)
    with pytest.warns(DeprecationWarning, match="Segment.extras"):
        legacy = seg.extras
    assert legacy["executor"] == seg.telemetry.topology.executor == "fused"
    # the job view strips the replica payload, keeps the plan
    rep = ga.solve(dataclasses.replace(spec, n_repeats=2),
                   backend="fused-islands",
                   options=ga.EngineOptions(cost_table=False))
    view = rep.telemetry.job_view()
    assert view.per_repeat is None
    assert view.plan.mode == rep.telemetry.plan.mode
