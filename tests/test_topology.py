"""The topology × executor decomposition: fused×island_ring is bit-identical
to reference×island_ring, replicas vmap outside the island axis, migration
math is shared with repro.core.islands, the mesh path (shard_map +
ppermute) is bit-identical to the single-device run, and serve-side GA job
telemetry."""

import dataclasses
import os
import subprocess
import sys
import warnings

import jax
import numpy as np
import pytest

from repro import ga
from repro.core import islands as ISL


def _spec(**kw):
    base = dict(problem="F3", n=32, bits_per_var=10, mode="arith",
                mutation_rate=0.05, seed=11, generations=15,
                n_islands=4, migrate_every=5)
    base.update(kw)
    return ga.GASpec(**base)


def _segment(spec, backend, gens):
    eng = ga.Engine(spec, backend)
    return eng.backend.segment(eng.init_state(), gens)


# ---------------------------------------------------------------------------
# Acceptance: the fused Pallas executor under the island ring is bit-identical
# to the reference executor under the island ring (same seeds, same migration)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("problem", ["F1", "F2", "F3"])
def test_fused_islands_bit_identical_to_reference_islands(problem):
    spec = _spec(problem=problem)
    seg_r = _segment(spec, "islands", 15)
    seg_f = _segment(spec, "fused-islands", 15)
    # island-stacked populations and every LFSR bank after 3 migration
    # epochs: bit-exact (migration runs between kernel launches on the
    # same elite/worst decisions)
    for field in ("x", "sel_lfsr", "cross_lfsr", "mut_lfsr"):
        np.testing.assert_array_equal(np.asarray(getattr(seg_f.state, field)),
                                      np.asarray(getattr(seg_r.state, field)),
                                      err_msg=field)
    np.testing.assert_array_equal(seg_f.traj_best, seg_r.traj_best)
    np.testing.assert_array_equal(seg_f.best_x, seg_r.best_x)
    assert seg_f.best_y == seg_r.best_y
    assert (seg_f.telemetry.topology.migrations
            == seg_r.telemetry.topology.migrations == 3)
    assert seg_f.telemetry.topology.executor == "fused"
    assert seg_r.telemetry.topology.executor == "reference"
    assert (seg_f.telemetry.topology.topology
            == seg_r.telemetry.topology.topology == "island_ring")


@pytest.mark.parametrize("problem", ["rastrigin:4", "ackley:6"])
def test_fused_islands_nvar_bit_identical(problem):
    """Acceptance: n-variable registry problems run fused-islands (the
    pluggable in-kernel FFM stage) bit-identical to reference islands."""
    spec = _spec(problem=problem)
    seg_r = _segment(spec, "islands", 15)
    seg_f = _segment(spec, "fused-islands", 15)
    for field in ("x", "sel_lfsr", "cross_lfsr", "mut_lfsr"):
        np.testing.assert_array_equal(np.asarray(getattr(seg_f.state, field)),
                                      np.asarray(getattr(seg_r.state, field)),
                                      err_msg=field)
    assert seg_f.best_y == seg_r.best_y
    np.testing.assert_array_equal(seg_f.best_x, seg_r.best_x)


def test_fused_islands_blackbox_bit_identical():
    """Acceptance: a blackbox fitness (captured arrays and all) runs
    fused-islands bit-identical to reference islands — the old
    'fused FFM needs a closed-form paper problem' gate is gone."""
    import jax.numpy as jnp
    t = jnp.asarray([1.0, -0.5, 0.25], jnp.float32)
    spec = ga.GASpec(fitness=lambda p: jnp.sum(jnp.abs(p - t), axis=-1),
                     bounds=((-2.0, 2.0),) * 3, n=32, bits_per_var=10,
                     mutation_rate=0.05, seed=11, generations=15,
                     n_islands=4, migrate_every=5)
    assert ga.capability_matrix(spec)["fused-islands"] is None
    seg_r = _segment(spec, "islands", 15)
    seg_f = _segment(spec, "fused-islands", 15)
    for field in ("x", "sel_lfsr", "cross_lfsr", "mut_lfsr"):
        np.testing.assert_array_equal(np.asarray(getattr(seg_f.state, field)),
                                      np.asarray(getattr(seg_r.state, field)),
                                      err_msg=field)
    assert seg_f.best_y == seg_r.best_y
    np.testing.assert_array_equal(seg_f.traj_best, seg_r.traj_best)


def test_fused_islands_end_to_end_solve():
    """`ga.solve(spec, backend="fused-islands")` runs the Pallas step kernel
    under an island ring with migration and converges on the paper problem."""
    spec = _spec(generations=40, migrate_every=8)
    r = ga.solve(spec, backend="fused-islands")
    assert r.backend == "fused-islands"
    assert r.telemetry.topology.migrations == 5
    assert np.isfinite(r.best_fitness) and r.best_fitness < 3.0
    assert r.generations == 40
    assert len(r.traj_best) == 5   # telemetry unit = migration epoch


# ---------------------------------------------------------------------------
# Replica axis outside the island axis (n_repeats × n_islands)
# ---------------------------------------------------------------------------


def test_islands_n_repeats_per_replica_bests():
    solo = ga.solve(_spec(), backend="islands")
    rep = ga.solve(_spec(n_repeats=3), backend="islands")
    per = rep.telemetry.per_repeat.best
    assert per.shape == (3,)
    # replica 0 re-runs the n_repeats=1 island stack bit-exactly
    assert float(per[0]) == solo.best_fitness
    assert rep.best_fitness == float(np.min(per))
    # replicas are seeded distinctly — not all identical
    assert len(np.unique(per)) > 1


def test_fused_islands_n_repeats_matches_reference():
    spec = _spec(n_repeats=2, generations=10)
    r_ref = ga.solve(spec, backend="islands")
    r_fus = ga.solve(spec, backend="fused-islands")
    np.testing.assert_array_equal(r_ref.telemetry.per_repeat.best,
                                  r_fus.telemetry.per_repeat.best)
    assert r_ref.best_fitness == r_fus.best_fitness


# ---------------------------------------------------------------------------
# Shared migration math: the engine's island_ring == core/islands.py
# ---------------------------------------------------------------------------


def test_islands_backend_state_matches_core_local_step():
    """The engine's island_ring epoch == repro.core.islands.make_local_step
    (the independent oracle), state bit-for-bit after 3 epochs."""
    spec = _spec()
    icfg = ISL.IslandConfig(ga=spec.ga_config(), n_islands=4, migrate_every=5)
    epoch = ISL.make_local_step(icfg, spec.fitness_fn())
    old_states = ISL.init_islands_fast(icfg)
    for _ in range(3):
        old_states, _ex, _ey = epoch(old_states)
    seg = _segment(spec, "islands", 15)
    for a, b in zip(old_states, seg.state):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_migration_none_ablation():
    """migration='none' evolves isolated islands: epochs still chunk the
    run but no elites are exchanged."""
    ring = ga.solve(_spec(), backend="islands")
    none = ga.solve(_spec(migration="none"), backend="islands")
    assert none.telemetry.topology.migrations == 0
    assert ring.telemetry.topology.migrations == 3
    assert np.isfinite(none.best_fitness)


# ---------------------------------------------------------------------------
# In-kernel epochs (gens_per_epoch): launch-overhead amortization that stays
# bit-identical in state and best tracking
# ---------------------------------------------------------------------------


def test_gens_per_epoch_bit_identical_state_and_best():
    """gens_per_epoch>1 folds generations inside one Pallas launch; the
    population/LFSR state AND the best individual (in-kernel fold) must be
    bit-identical to the reference islands run — only the trajectory
    coarsens to one sample per launch."""
    spec = _spec()
    seg_r = _segment(spec, "islands", 15)
    seg_g = _segment(dataclasses.replace(spec, gens_per_epoch=5),
                     "fused-islands", 15)
    for field in ("x", "sel_lfsr", "cross_lfsr", "mut_lfsr"):
        np.testing.assert_array_equal(np.asarray(getattr(seg_g.state, field)),
                                      np.asarray(getattr(seg_r.state, field)),
                                      err_msg=field)
    assert seg_g.best_y == seg_r.best_y
    np.testing.assert_array_equal(seg_g.best_x, seg_r.best_x)


def test_gens_per_epoch_remainder_launch_on_single_topology():
    """10 generations at gens_per_epoch=4 = two full launches + a remainder
    launch of 2; state/best equal to gens_per_epoch=1, one traj sample per
    launch."""
    spec = _spec(n_islands=1, generations=10)
    a = _segment(spec, "fused", 10)
    b = _segment(dataclasses.replace(spec, gens_per_epoch=4), "fused", 10)
    for field in ("x", "sel_lfsr", "cross_lfsr", "mut_lfsr"):
        np.testing.assert_array_equal(np.asarray(getattr(b.state, field)),
                                      np.asarray(getattr(a.state, field)),
                                      err_msg=field)
    assert a.best_y == b.best_y
    assert a.traj_best.shape[-1] == 10 and b.traj_best.shape[-1] == 3


# ---------------------------------------------------------------------------
# Resident-epoch kernel: gens_per_epoch beyond migrate_every folds the ring
# migration INTO the VMEM-resident launch — bit-identical to the
# between-launch ring at equal seeds
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("problem", ["F1", "F2", "F3", "rastrigin:4"])
def test_resident_epoch_bit_identical_to_reference_islands(problem):
    """gens_per_epoch=10 > migrate_every=5 engages the resident kernel (2
    migration intervals per launch, in-VMEM ring).  State AND best must be
    bit-identical to reference × island_ring; 15 generations = one 2-interval
    launch + one 1-interval remainder launch, so the trajectory coarsens to
    2 samples while migrations still count every in-kernel ring."""
    spec = _spec(problem=problem, gens_per_epoch=10)
    seg_r = _segment(dataclasses.replace(spec, gens_per_epoch=1),
                     "islands", 15)
    seg_f = _segment(spec, "fused-islands", 15)
    for field in ("x", "sel_lfsr", "cross_lfsr", "mut_lfsr"):
        np.testing.assert_array_equal(np.asarray(getattr(seg_f.state, field)),
                                      np.asarray(getattr(seg_r.state, field)),
                                      err_msg=field)
    assert seg_f.best_y == seg_r.best_y
    np.testing.assert_array_equal(seg_f.best_x, seg_r.best_x)
    assert seg_f.telemetry.plan.mode == "resident"
    assert seg_f.telemetry.topology.launches == 2
    assert (seg_f.telemetry.topology.migrations
            == seg_r.telemetry.topology.migrations == 3)
    assert seg_f.telemetry.topology.telemetry_unit_gens == 10
    assert seg_f.traj_best.shape == (2,)


def test_resident_epoch_n_repeats_matches_reference():
    """Replica groups ride the resident kernel's grid axis — each replica's
    in-VMEM ring stays independent and bit-identical to the reference run."""
    spec = _spec(n_repeats=3, generations=10, gens_per_epoch=10)
    r_ref = ga.solve(dataclasses.replace(spec, gens_per_epoch=1),
                     backend="islands")
    r_res = ga.solve(spec, backend="fused-islands")
    np.testing.assert_array_equal(r_ref.telemetry.per_repeat.best,
                                  r_res.telemetry.per_repeat.best)
    assert r_ref.best_fitness == r_res.best_fitness
    assert r_res.telemetry.plan.mode == "resident"


def test_resident_sharded_epoch_on_one_device_mesh():
    """On a mesh the resident plan keeps one migration interval per launch
    (the boundary elite must ppermute between launches) but runs the
    intra-shard migrations in VMEM — bit-identical to the local reference
    ring even on a 1-device mesh (where the ppermute ring is the wrap)."""
    spec = _spec(gens_per_epoch=10)
    ref = _segment(dataclasses.replace(spec, gens_per_epoch=1),
                   "islands", 15)
    eng = ga.Engine(spec, "fused-islands", mesh=_mesh1())
    shard = eng.backend.segment(eng.init_state(), 15)
    for field in ("x", "sel_lfsr", "cross_lfsr", "mut_lfsr"):
        np.testing.assert_array_equal(np.asarray(getattr(shard.state, field)),
                                      np.asarray(getattr(ref.state, field)),
                                      err_msg=field)
    assert shard.best_y == ref.best_y
    assert shard.telemetry.plan.mode == "resident-sharded"
    assert shard.telemetry.topology.sharded is True


def test_resident_vmem_budget_fallback_decision():
    """The VMEM-budget estimator drives the fallback: an island stack whose
    one-hot working set exceeds the budget reverts to the STREAMED lane when
    a double-buffered tile fits, and all the way to the gridded per-interval
    kernel when none does (still bit-identical), never errors."""
    from repro.kernels import ga_step as K

    cfg = _spec().ga_config()
    # unit decision: the same stack fits a large budget, not a small one
    assert K.resident_fit_reason(cfg, 4, 0, budget=1 << 30) is None
    reason = K.resident_fit_reason(cfg, 4, 0, budget=1 << 10)
    assert reason is not None and "VMEM" in reason
    # big captured consts count against the same budget
    assert K.resident_fit_reason(cfg, 4, 1 << 30) is not None
    # estimator scales with the one-hot term: N=512 x 4 islands > 16 MiB —
    # but a double-buffered 1-island tile fits, so the HBM-streaming lane
    # absorbs the oversize case instead of dropping kernel residency
    big = _spec(n=512, gens_per_epoch=10)
    eng = ga.Engine(big, "fused-islands", options=ga.EngineOptions(
        cost_table=False))
    plan = eng.backend.topology.plan
    assert plan["mode"] == "streamed" and "VMEM" in plan["fallback"]
    # with a budget too small for even a double-buffered 1-island tile the
    # planner still reverts to gridded
    eng_g = ga.Engine(big, "fused-islands", options=ga.EngineOptions(
        cost_table=False, vmem_budget=1 << 10))
    plan_g = eng_g.backend.topology.plan
    assert plan_g["mode"] == "gridded" and "VMEM" in plan_g["fallback"]
    # integration: the streamed fallback path still runs, matches reference
    seg_f = eng.backend.segment(eng.init_state(), 10)
    seg_r = _segment(dataclasses.replace(big, gens_per_epoch=1),
                     "islands", 10)
    np.testing.assert_array_equal(np.asarray(seg_f.state.x),
                                  np.asarray(seg_r.state.x))
    assert seg_f.telemetry.plan.fallback == plan["fallback"]


# ---------------------------------------------------------------------------
# Mesh path: shard_map over the island axis + ppermute ring migration,
# bit-identical to the single-device run (any executor, any n_repeats)
# ---------------------------------------------------------------------------


def _mesh1():
    """A 1-device mesh: exercises the whole shard_map/ppermute machinery on
    every host, so the sharded path is tier-1 everywhere."""
    return jax.make_mesh((1,), ("islands",))


def test_fused_islands_on_one_device_mesh_bit_identical():
    spec = _spec()
    local = _segment(spec, "fused-islands", 15)
    eng = ga.Engine(spec, "fused-islands", mesh=_mesh1())
    shard = eng.backend.segment(eng.init_state(), 15)
    for field in ("x", "sel_lfsr", "cross_lfsr", "mut_lfsr"):
        np.testing.assert_array_equal(np.asarray(getattr(shard.state, field)),
                                      np.asarray(getattr(local.state, field)),
                                      err_msg=field)
    assert shard.best_y == local.best_y
    np.testing.assert_array_equal(shard.traj_best, local.traj_best)
    assert shard.telemetry.topology.sharded is True
    assert shard.telemetry.topology.n_shards == 1


def test_mesh_capability_gates():
    mesh = _mesh1()
    caps = ga.capability_matrix(_spec(), mesh=mesh)
    # PR 2's mesh restrictions are lifted: both executors, n_repeats > 1
    # and migration='none' all compose with the mesh now
    assert caps["islands"] is None and caps["fused-islands"] is None
    assert ga.capability_matrix(_spec(n_repeats=3), mesh=mesh)["islands"] is None
    assert ga.capability_matrix(_spec(migration="none"),
                                mesh=mesh)["islands"] is None
    # 3 islands over 1 shard is fine; over 2 shards it must be rejected
    assert ga.BACKENDS["islands"].supports(_spec(n_islands=3),
                                           mesh=mesh) is None
    import types
    fake2 = types.SimpleNamespace(shape={"islands": 2},
                                  axis_names=("islands",))
    assert "divide evenly" in ga.BACKENDS["islands"].supports(
        _spec(n_islands=3), mesh=fake2)
    # a spec naming axes missing from the mesh is rejected with a reason
    bad = _spec(mesh_axes=("nope",))
    assert "not in the mesh" in ga.BACKENDS["islands"].supports(bad, mesh=mesh)


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >1 devices (CI runs this with "
                           "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
@pytest.mark.parametrize("backend", ["islands", "fused-islands"])
def test_mesh_multi_device_bit_identical_in_process(backend):
    """On a real multi-device host (or the forced-8-device CI job) the
    sharded epoch crosses device boundaries and must still be bit-identical
    to the single-device run."""
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("islands",))
    spec = _spec(n_islands=2 * n_dev)
    local = _segment(spec, backend, 15)
    eng = ga.Engine(spec, backend, mesh=mesh)
    shard = eng.backend.segment(eng.init_state(), 15)
    for field in ("x", "sel_lfsr", "cross_lfsr", "mut_lfsr"):
        np.testing.assert_array_equal(np.asarray(getattr(shard.state, field)),
                                      np.asarray(getattr(local.state, field)),
                                      err_msg=field)
    assert shard.best_y == local.best_y
    assert shard.telemetry.topology.n_shards == n_dev


def test_fused_islands_mesh_bit_identical_subprocess_8dev():
    """Acceptance: fused-islands on a host-platform mesh of 8 devices is
    bit-identical to the single-device run at equal seeds — F1–F3, an
    n-variable registry problem (rastrigin:4) and a blackbox through the
    in-kernel FFM stage, an n_repeats>1 on-mesh case, AND a mesh built
    with a custom (reversed) device permutation, which must form the SAME
    logical ring (ring_shift_sharded orders by logical mesh coordinates,
    not physical devices).  Spawned so the forced device count doesn't
    leak into this process."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import Mesh
from repro import ga
mesh = jax.make_mesh((2, 4), ("data", "model"))

def seg(spec, backend, gens, mesh=None):
    eng = ga.Engine(spec, backend, mesh=mesh)
    return eng.backend.segment(eng.init_state(), gens)

def check(spec, mesh, tag):
    local = seg(spec, "fused-islands", 15)
    shard = seg(spec, "fused-islands", 15, mesh=mesh)
    for f in ("x", "sel_lfsr", "cross_lfsr", "mut_lfsr"):
        np.testing.assert_array_equal(np.asarray(getattr(shard.state, f)),
                                      np.asarray(getattr(local.state, f)),
                                      err_msg=tag + " " + f)
    assert shard.best_y == local.best_y, tag
    np.testing.assert_array_equal(shard.traj_best, local.traj_best)
    ti = shard.telemetry.topology
    assert ti.sharded is True and ti.n_shards == 8

for problem in ("F1", "F2", "F3", "rastrigin:4"):
    spec = ga.GASpec(problem=problem, n=32, bits_per_var=10, mode="arith",
                     mutation_rate=0.05, seed=11, generations=15,
                     n_islands=8, migrate_every=5)
    check(spec, mesh, problem)

# blackbox (captured-array FFM stage) on the mesh
t = jnp.asarray([0.5, -1.0, 1.5], jnp.float32)
bb = ga.GASpec(fitness=lambda p: jnp.sum((p - t) ** 2, axis=-1),
               bounds=((-2.0, 2.0),) * 3, n=32, bits_per_var=10,
               mutation_rate=0.05, seed=11, generations=15,
               n_islands=8, migrate_every=5)
check(bb, mesh, "blackbox")

# custom device permutation: same LOGICAL ring, bit-identical run
perm_mesh = Mesh(np.asarray(jax.devices())[::-1].reshape(2, 4),
                 ("data", "model"))
spec = ga.GASpec(problem="F3", n=32, bits_per_var=10, mode="arith",
                 mutation_rate=0.05, seed=11, generations=15,
                 n_islands=8, migrate_every=5)
check(spec, perm_mesh, "permuted-devices")

spec = ga.GASpec(problem="F3", n=32, bits_per_var=10, mode="arith",
                 mutation_rate=0.05, seed=11, generations=10,
                 n_islands=8, migrate_every=5, n_repeats=2)
local = ga.solve(spec, backend="fused-islands")
shard = ga.solve(spec, backend="fused-islands", mesh=mesh)
np.testing.assert_array_equal(local.telemetry.per_repeat.best,
                              shard.telemetry.per_repeat.best)
assert local.best_fitness == shard.best_fitness

# RESIDENT epochs on the mesh: gens_per_epoch=10 > migrate_every=5 runs the
# boundary kernel (intra-shard migration in VMEM, elite ppermute between
# launches) — state/best bit-identical to the local reference ring, on the
# row-major mesh AND a reversed-device mesh (same logical ring), with
# n_repeats riding the kernel grid axis
def check_resident(tag, use_mesh, n_repeats=1):
    spec = ga.GASpec(problem="rastrigin:4", n=32, bits_per_var=10,
                     mode="arith", mutation_rate=0.05, seed=11,
                     generations=15, n_islands=8, migrate_every=5,
                     n_repeats=n_repeats, gens_per_epoch=10)
    ref = seg(dataclasses.replace(spec, gens_per_epoch=1), "islands", 15)
    res = seg(spec, "fused-islands", 15, mesh=use_mesh)
    assert res.telemetry.plan.mode == "resident-sharded", tag
    for f in ("x", "sel_lfsr", "cross_lfsr", "mut_lfsr"):
        np.testing.assert_array_equal(np.asarray(getattr(res.state, f)),
                                      np.asarray(getattr(ref.state, f)),
                                      err_msg=tag + " " + f)
    assert res.best_y == ref.best_y, tag
    np.testing.assert_array_equal(res.best_x, ref.best_x)

check_resident("resident", mesh)
check_resident("resident-permuted", perm_mesh)
check_resident("resident-repeats", mesh, n_repeats=2)
print("MESH_OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "MESH_OK" in r.stdout


# ---------------------------------------------------------------------------
# Spec-level topology plumbing
# ---------------------------------------------------------------------------


def test_topology_field_validation():
    assert _spec().effective_topology == "island_ring"
    assert _spec(n_islands=1).effective_topology == "single"
    assert _spec(n_islands=1, topology="auto").topology is None
    with pytest.raises(ValueError, match="inconsistent"):
        _spec(topology="single")           # n_islands=4
    with pytest.raises(ValueError, match="n_islands > 1"):
        _spec(n_islands=1, topology="island_ring")
    with pytest.raises(ValueError, match="topology must be"):
        _spec(topology="torus")
    with pytest.raises(ValueError, match="migration must be"):
        _spec(migration="broadcast")
    with pytest.raises(ValueError, match="gens_per_epoch must be"):
        _spec(gens_per_epoch=0)
    with pytest.raises(ValueError, match="mesh_axes must be"):
        _spec(mesh_axes=())


def test_gens_per_epoch_beyond_migrate_every_needs_whole_intervals():
    """The gens_per_epoch <= migrate_every cap is GONE (the resident kernel
    folds ring migrations in VMEM); what remains is the whole-interval rule:
    beyond migrate_every, gens_per_epoch must be a multiple of it so every
    launch folds complete migration intervals."""
    # multiples are valid now — this used to be a spec-build error
    assert _spec(migrate_every=4, gens_per_epoch=8).gens_per_epoch == 8
    assert _spec(migrate_every=4, gens_per_epoch=4).gens_per_epoch == 4
    with pytest.raises(ValueError) as ei:
        _spec(migrate_every=4, gens_per_epoch=7)
    msg = str(ei.value)
    assert "gens_per_epoch=7" in msg and "migrate_every=4" in msg
    assert "multiple" in msg
    # single topology is uncapped and rule-free
    solo = _spec(n_islands=1, gens_per_epoch=63)
    assert solo.effective_topology == "single"
    # migration='none' has no interval boundary — no multiple rule either
    none = _spec(migrate_every=4, gens_per_epoch=7, migration="none")
    assert none.gens_per_epoch == 7


def test_auto_and_fallback_routing():
    # auto on CPU routes island specs to the reference×island_ring composition
    assert ga.resolve_backend(_spec()) == "islands"
    # fused-islands falls back to islands when the kernel can't run (lut FFM)
    lut = _spec(mode="lut")
    assert ga.capability_matrix(lut)["fused-islands"] is not None
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        r = ga.solve(lut, backend="fused-islands")
    assert r.backend == "islands"
    assert any("falling back" in str(x.message) for x in w)
    # pinned single topology keeps island backends off the table
    single = _spec(n_islands=1)
    caps = ga.capability_matrix(single)
    assert caps["reference"] is None
    assert caps["islands"] is None        # permissive: 1-island ring runs
    pinned = _spec(n_islands=1, topology="single")
    assert ga.capability_matrix(pinned)["islands"] is not None


def test_chunked_checkpoint_resume_on_islands(tmp_path):
    spec = _spec(generations=20, migrate_every=5)
    ckpt = str(tmp_path / "isl_ck")
    full = list(ga.Engine(spec, "islands").run_chunked(chunk_generations=5))
    assert [t["gens_done"] for t in full] == [5, 10, 15, 20]
    assert full[-1]["migrations"] == 4

    it = ga.Engine(spec, "islands").run_chunked(chunk_generations=5,
                                                ckpt_dir=ckpt)
    next(it), next(it)     # 2 epochs, then "crash"
    del it
    resumed = list(ga.Engine(spec, "islands").run_chunked(
        chunk_generations=5, ckpt_dir=ckpt))
    assert [t["gens_done"] for t in resumed] == [15, 20]
    assert resumed[-1]["best_fitness"] == full[-1]["best_fitness"]
    assert resumed[-1]["migrations"] == 4


# ---------------------------------------------------------------------------
# Serve-side GA job telemetry
# ---------------------------------------------------------------------------


def test_serve_ga_job_metrics():
    from repro.serve.engine import GAMetricsRegistry, run_ga_job

    reg = GAMetricsRegistry()
    spec = _spec(generations=10, migrate_every=5)
    out = run_ga_job(spec, backend="islands", job_id="job-a",
                     chunk_generations=5, registry=reg)
    assert out["status"] == "done"
    assert out["backend"] == "islands"
    assert out["problem"] == "F3" and out["n_vars"] == 2
    assert out["generations_done"] == 10
    assert out["migration_count"] == 2
    assert out["generations_per_s"] > 0
    # per-shard throughput: 4 islands on 1 shard -> islands x gens/s
    assert out["islands"] == 4 and out["shards"] == 1
    assert out["generations_per_s_per_shard"] == pytest.approx(
        4 * out["generations_per_s"], rel=0.01)
    assert len(out["best_fitness_trajectory"]) == 2
    assert out["best_fitness"] == min(out["best_fitness_trajectory"])

    snap = reg.metrics()
    assert snap["job_count"] == 1 and snap["jobs_done"] == 1
    assert snap["migrations_total"] == 2
    assert snap["generations_total"] == 10
    assert "job-a" in snap["jobs"]


def test_metrics_http_endpoint_scrapes_prometheus_text():
    """The stdlib /metrics endpoint serves the registry snapshot in
    Prometheus text format (and /healthz answers) while jobs run."""
    import urllib.request

    from repro.serve.engine import GAMetricsRegistry, run_ga_job
    from repro.serve.metrics_http import render_prometheus, start_metrics_server

    reg = GAMetricsRegistry()
    server = start_metrics_server(0, registry=reg, host="127.0.0.1")
    try:
        port = server.server_address[1]
        spec = _spec(problem="rastrigin:4", generations=10, migrate_every=5)
        run_ga_job(spec, backend="islands", job_id="job-m",
                   chunk_generations=5, registry=reg)
        url = f"http://127.0.0.1:{port}"
        with urllib.request.urlopen(f"{url}/metrics") as resp:
            assert resp.status == 200
            assert resp.headers["Content-Type"].startswith("text/plain")
            txt = resp.read().decode()
        line = ('repro_ga_generations_done{job_id="job-m",'
                'backend="islands",problem="rastrigin"} 10')
        assert line in txt, txt[:500]
        assert 'status="done"' in txt
        assert "repro_ga_jobs 1" in txt
        assert 'repro_ga_n_vars{job_id="job-m"' in txt
        with urllib.request.urlopen(f"{url}/healthz") as resp:
            assert resp.read() == b"ok\n"
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(f"{url}/nope")
        # the renderer is pure: re-rendering the snapshot reproduces the scrape
        assert render_prometheus(reg.metrics()) == txt
    finally:
        server.shutdown()
