"""Mixture-of-Experts: GShard-style capacity routing with expert parallelism.

Experts live on the "expert" logical axis (→ TP/"model" mesh axis), tokens on
the batch/DP axes; the dispatch/combine einsums contract across both, which
XLA lowers to the all-to-all / all-gather pattern of classic GShard EP.

Routing: softmax-over-logits top-k with probability renormalization
(DeepSeek-V3's sigmoid+group-bias routing is approximated by softmax top-k;
MoE capacity semantics, shared experts and expert parallelism are faithful —
the deviation is noted in DESIGN.md).

Group dimension: tokens route within their own sequence (G = batch dim), the
standard way to bound the dispatch tensor and keep routing local.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding as SH
from repro.models import common as C
from repro.models import mlp as MLP


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    n_experts: int            # routed experts
    top_k: int
    expert_ff: int            # per-expert hidden dim
    n_shared: int = 0         # shared (always-on) experts
    shared_ff: Optional[int] = None
    capacity_factor: float = 1.25

    @property
    def shared_dim(self) -> int:
        return (self.shared_ff or self.expert_ff) * max(self.n_shared, 0)


def moe_defs(cfg: MoEConfig) -> Dict[str, C.ParamDef]:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.expert_ff
    defs = {
        "router": C.ParamDef((d, e), ("embed", None), dtype=jnp.float32),
        # EP (model axis) + FSDP (data axis on d): measured best of three
        # layouts — EP-only replication doesn't fit deepseek's 656B expert
        # params; full-EP (experts over data x model) makes GSPMD replicate
        # tokens (862s of collectives).  See EXPERIMENTS.md §Perf iters 3-6.
        "w_gate": C.ParamDef((e, d, f), ("expert", "embed", None)),
        "w_up": C.ParamDef((e, d, f), ("expert", "embed", None)),
        "w_down": C.ParamDef((e, f, d), ("expert", None, "embed")),
    }
    if cfg.n_shared > 0:
        defs["shared"] = MLP.gated_defs(d, cfg.shared_dim)
    return defs


def _capacity(tokens_per_group: int, cfg: MoEConfig) -> int:
    c = int(np.ceil(tokens_per_group * cfg.top_k * cfg.capacity_factor
                    / cfg.n_experts))
    return max(c, cfg.top_k)


def route(router_w: jax.Array, x: jax.Array, cfg: MoEConfig
          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (G, S, D) -> (weights (G,S,k), idx (G,S,k), aux_loss scalar)."""
    # bf16 operands + f32 accumulation: materializing x in f32 promotes the
    # whole residual stream's collectives to f32 (EXPERIMENTS.md §Perf iter 7)
    logits = jnp.einsum("gsd,de->gse", x, router_w.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, cfg.top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # load-balance auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(
        jax.nn.one_hot(idx[..., 0], cfg.n_experts, dtype=jnp.float32), axis=(0, 1))
    aux = cfg.n_experts * jnp.sum(me * ce)
    return weights.astype(x.dtype), idx, aux


def forward(p, x: jax.Array, cfg: MoEConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D). Returns (out, aux_loss). B is the routing group dim."""
    g, s, d = x.shape
    cap = _capacity(s, cfg)
    weights, idx, aux = route(p["router"], x, cfg)

    # position of each (token, choice) within its expert's capacity buffer
    onehot = jax.nn.one_hot(idx, cfg.n_experts, dtype=jnp.int32)  # (G,S,k,E)
    flat = onehot.reshape(g, s * cfg.top_k, cfg.n_experts)
    pos_in_expert = jnp.cumsum(flat, axis=1) - 1                  # (G,S*k,E)
    pos = jnp.sum(pos_in_expert * flat, axis=-1).reshape(g, s, cfg.top_k)
    keep = pos < cap

    # dispatch (G, S, E, C) — sharded: G on batch axes, E on "expert".
    # Every contraction below is strictly 2-operand: a 3-operand einsum here
    # lets XLA materialize a (G,S,E,C,k) intermediate — observed as a
    # multi-TiB temp in the deepseek train_4k dry-run (EXPERIMENTS.md §Perf).
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                            dtype=x.dtype)[..., :cap]             # (G,S,k,C)
    oh = onehot.astype(x.dtype)
    disp = jnp.einsum("gske,gskc->gsec", oh, pos_oh)              # (G,S,E,C)
    disp = SH.constrain(disp, "batch", None, "expert", None)

    expert_in = jnp.einsum("gsec,gsd->gecd", disp, x)
    expert_in = SH.constrain(expert_in, "batch", "expert", None, None)

    gate = jnp.einsum("gecd,edf->gecf", expert_in, p["w_gate"])
    up = jnp.einsum("gecd,edf->gecf", expert_in, p["w_up"])
    act = (jax.nn.silu(gate) * up).astype(x.dtype)
    expert_out = jnp.einsum("gecf,efd->gecd", act, p["w_down"])
    expert_out = SH.constrain(expert_out, "batch", "expert", None, None)

    w_oh = oh * weights[..., None]                                # (G,S,k,E)
    combine = jnp.einsum("gske,gskc->gsec", w_oh, pos_oh)         # (G,S,E,C)
    combine = SH.constrain(combine, "batch", None, "expert", None)
    out = jnp.einsum("gsec,gecd->gsd", combine, expert_out)
    out = SH.constrain(out, "batch", "act_seq", "act_embed")

    if cfg.n_shared > 0:
        out = out + MLP.gated_forward(p["shared"], x)
    return out, aux
