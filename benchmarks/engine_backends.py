"""`repro.ga` backend matrix: generations/sec per (topology × executor).

Canonical specs run through every registered backend for EACH problem in
the sweep — the paper's F3 (V=2, closed form) and an n-variable registry
problem (rastrigin:4) so the generalized in-kernel FFM stage is always
covered; the derived column is a JSON object (with `problem`/`n_vars`
fields) so downstream tooling can scrape per-backend throughput.
Island-topology rows use 8 islands (total chromosome throughput is
islands × gens/s); on CPU the fused rows run the Pallas kernel in interpret
mode, so their absolute numbers only mean something on TPU — which is why
`scripts/check_bench.py` gates combo-vs-combo RATIOS, not absolutes.
The `fused-islands` rows run with `gens_per_epoch = 2 * migrate_every`,
i.e. the RESIDENT epoch kernel (ring migration folded into the VMEM-resident
launch; the intra-shard part on mesh rows) — their ratio row is the
regression gate for that optimization.  The `+streamed` /
`+streamed-gridded` pair runs an island stack that exceeds a (forced)
VMEM budget through the HBM-streaming lane and through the gridded
fallback respectively; `check_bench.streamed_gate` requires the streamed
row to actually stream and to be no slower than its gridded twin.  The
`+onehot` / `+gather` pair pins the fused tournament's selection lane on
an N=512 spec; `check_bench.lane_gate` requires the gather row to run the
gather lane and keep up with its onehot twin.

The island backends additionally run as mesh combos (`...@mesh{D}`): the
island axis shard_mapped over D devices with `ppermute` ring migration —
the `devices` column is the scaling sweep (full mode sweeps powers of two
up to the host's device count; point it at a TPU pod slice and the
`gens_per_s` column is the paper's speedup-vs-replication headline).

Standalone smoke mode for CI (1 tiny config per backend × problem combo,
JSON artifact so a composition regression fails fast):

    PYTHONPATH=src python -m benchmarks.engine_backends --smoke \
        --out artifacts/engine_backends.json
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

from benchmarks.ga_common import planned_peak_vmem, time_call
from repro import ga

K = 100
N_ISLANDS = 8

SMOKE = dict(n=16, m=16, generations=8, n_islands=2, migrate_every=4)

PROBLEM_SWEEP = ("F3", "rastrigin:4")
MESH_BACKENDS = ("islands", "fused-islands")


def _spec_for(backend: str, problem: str, *, n: int, m: int,
              generations: int, n_islands: int,
              migrate_every: int) -> ga.GASpec:
    base = ga.GASpec(problem=problem, n=n, bits_per_var=m // 2, mode="arith",
                     mutation_rate=0.02, seed=1, generations=generations,
                     migrate_every=migrate_every)
    if backend.split("@")[0] == "fused-islands":
        # fold 2 migration intervals per launch: the resident-epoch kernel
        # keeps the island stack + ring migration in VMEM (falls back to
        # gridded per-interval launches if the VMEM budget says no), so this
        # row gates the resident path's gens/s-vs-reference ratio
        return dataclasses.replace(base, n_islands=n_islands,
                                   gens_per_epoch=2 * migrate_every)
    if backend.split("@")[0] == "islands":
        return dataclasses.replace(base, n_islands=n_islands)
    return base


def _mesh_device_counts(smoke: bool):
    """Device counts the mesh combos sweep: all devices in smoke mode,
    powers of two up to the device count in full mode."""
    import jax
    n = len(jax.devices())
    if smoke:
        return [n]
    counts, d = [], 1
    while d <= n:
        counts.append(d)
        d *= 2
    return counts


def _one_row(name: str, backend: str, spec: ga.GASpec, *, smoke: bool,
             mesh=None, devices: int = 1, cost_table=False, options=None):
    # cost_table=False by default: benchmark rows must not silently flip
    # epoch plans because the host happens to have an ambient autotune
    # table — only the explicit `+measured` rows consume one
    if options is None:
        options = ga.EngineOptions(mesh=mesh, cost_table=cost_table)
    eng = ga.Engine(spec, backend, options=options)
    out = eng.run()           # compile + warm caches
    # interpret-mode Pallas and the eager loop are slow; fewer iters.  The
    # cheap XLA backends keep 3 timed iters even in smoke mode — the
    # reference row is the anchor every ratio divides by, so its noise
    # multiplies into every gated combo.
    slow = backend in ("fused", "fused-islands", "eager")
    iters = 1 if slow else 3
    dt, out = time_call(eng.run, warmup=0, iters=iters)
    gens = out.generations * max(spec.n_islands, spec.n_repeats)
    tele = out.telemetry
    payload = json.dumps({"backend": out.backend,
                          "executor": tele.topology.executor,
                          "topology": tele.topology.topology,
                          "problem": tele.problem or spec.problem,
                          "n_vars": spec.v,
                          "gens_per_s": round(gens / dt, 1),
                          "best": round(out.best_fitness, 4),
                          "n": spec.n,
                          "islands": spec.n_islands,
                          "devices": devices,
                          "epoch_mode": tele.plan.mode,
                          "plan_source": tele.plan.source,
                          "tile_islands": tele.plan.tile_islands,
                          "sel_lane": tele.plan.lane,
                          "planned_vmem_bytes": planned_peak_vmem(eng),
                          "migrations": tele.topology.migrations},
                         separators=(",", ":"))
    # island epochs round K up to whole migration epochs — divide by
    # what actually ran
    return (name, dt / out.generations * 1e6, payload)


def _streamed_rows(problem: str, sizes: dict, *, smoke: bool):
    """The oversized-stack pair: an island stack past a (forced) VMEM
    budget, once through the HBM-streaming lane (the planner's heuristic
    pick for oversized ring specs) and once forced through the gridded
    per-interval fallback.  The kernels still validate tiles against the
    REAL budget, so the forced budget only steers the plan."""
    from repro.kernels import ga_step as KS
    isl = max(8, sizes["n_islands"])
    spec = dataclasses.replace(
        _spec_for("fused-islands", problem, **sizes), n_islands=isl)
    probe = ga.Engine(spec, "fused-islands",
                      options=ga.EngineOptions(cost_table=False))
    cfg = probe.backend.topology.cfg
    # below the full stack, but a double-buffered 2-island tile fits:
    # the heuristic plans streamed with tile_islands=2
    budget = KS.resident_vmem_bytes(cfg, isl - 3)
    return [
        _one_row(f"engine_fused-islands[{problem}]+streamed",
                 "fused-islands", spec, smoke=smoke,
                 options=ga.EngineOptions(cost_table=False,
                                          vmem_budget=budget)),
        _one_row(f"engine_fused-islands[{problem}]+streamed-gridded",
                 "fused-islands", spec, smoke=smoke,
                 options=ga.EngineOptions(cost_table=False,
                                          vmem_budget=budget,
                                          plan_override="gridded")),
    ]


LANE_N = 512     # the lane pair's population: large enough that the onehot
                 # lane's (N, N) working set dominates and gather should win


def _lane_rows(problem: str, sizes: dict, *, smoke: bool):
    """The selection-lane pair: one fused-islands spec at N=512 pinned to
    each tournament lane.  `check_bench.lane_gate` requires the gather row
    to actually run the gather lane and to keep up with (noise margin) or
    beat its onehot twin — the O(N·V) working set must not cost speed."""
    spec = dataclasses.replace(
        _spec_for("fused-islands", problem, **sizes),
        n=LANE_N, n_islands=2)
    rows = []
    for lane in ("onehot", "gather"):
        rows.append(_one_row(
            f"engine_fused-islands[{problem}]+{lane}", "fused-islands",
            dataclasses.replace(spec, sel_lane=lane), smoke=smoke))
    return rows


def run(smoke: bool = False, cost_table=None):
    sizes = SMOKE if smoke else dict(n=64, m=20, generations=K,
                                     n_islands=N_ISLANDS, migrate_every=16)
    rows = []
    for problem in PROBLEM_SWEEP:
        for backend in sorted(ga.BACKENDS):
            spec = _spec_for(backend, problem, **sizes)
            rows.append(_one_row(f"engine_{backend}[{problem}]", backend,
                                 spec, smoke=smoke))
        if cost_table is not None:
            # the measured-planner row: same spec as the static
            # fused-islands row, epoch plan chosen from the cost table —
            # check_bench gates its gens/s against the static row's
            spec = _spec_for("fused-islands", problem, **sizes)
            rows.append(_one_row(
                f"engine_fused-islands[{problem}]+measured", "fused-islands",
                spec, smoke=smoke, cost_table=cost_table))
        if problem == "F3":
            # one oversized-stack pair is enough to gate the streamed lane
            rows.extend(_streamed_rows(problem, sizes, smoke=smoke))
            # one N=512 pinned-lane pair gates the gather selection lane
            rows.extend(_lane_rows(problem, sizes, smoke=smoke))
        # mesh combos: island axis sharded over devices (device-count sweep)
        from repro.launch.mesh import make_island_mesh
        for backend in MESH_BACKENDS:
            for d in _mesh_device_counts(smoke):
                isl = sizes["n_islands"]
                isl = isl if isl % d == 0 else d * -(-isl // d)  # ceil mult
                spec = _spec_for(backend, problem,
                                 **{**sizes, "n_islands": isl})
                rows.append(_one_row(
                    f"engine_{backend}[{problem}]@mesh{d}", backend, spec,
                    smoke=smoke, mesh=make_island_mesh(d), devices=d))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="1 tiny config per backend x problem combo (CI "
                         "regression gate; seconds, not minutes)")
    ap.add_argument("--out", default=None,
                    help="write the rows as a JSON artifact here")
    ap.add_argument("--cost-table", default=None,
                    help="autotune cost table path: adds '+measured' "
                         "fused-islands rows planned from measurements")
    args = ap.parse_args()
    rows = run(smoke=args.smoke, cost_table=args.cost_table)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        artifact = [{"name": name, "us_per_gen": round(us, 2),
                     **json.loads(derived)} for name, us, derived in rows]
        with open(args.out, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
