"""Paper Table 1: generations/s vs population size N (m=20).

The FPGA reports ~16.8k gens/s at N=4 falling to ~11.5k at N=64 (50 MHz
clock / 3).  We report the JAX engine's CPU wall-clock generations/s (a
relative measure on this container) and the TPU roofline-bound generations/s
from the dry-run (the deployable number).
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.ga_common import time_call
from repro.core import fitness as F
from repro.core import ga as G

K = 200


def run():
    rows = []
    for n in (4, 8, 16, 32, 64):
        cfg = G.GAConfig(n=n, c=10, v=2, mutation_rate=0.02, seed=1,
                         mode="lut")
        fit = G.fitness_for_problem(F.F3, cfg)
        runner = jax.jit(lambda: G.run(cfg, fit, K))
        dt, out = time_call(runner, iters=3)
        gens_per_s = K / dt
        rows.append((f"table1_N{n}", dt / K * 1e6,
                     f"gens_per_s={gens_per_s:.0f}"))
    return rows
