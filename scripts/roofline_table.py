#!/usr/bin/env python3
"""Render a dryrun results directory as the EXPERIMENTS.md roofline table.

    python scripts/roofline_table.py dryrun_results_v2 [pod1|pod2]

Or render a GA autotune cost table as a measured-plan table (each epoch
mode's gens/s as a fraction of the best plan measured for its spec):

    python scripts/roofline_table.py --ga-cost-table path/to/cost_table.json
"""
import glob
import json
import os
import sys


def render_ga(path):
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.autotune import CostTable
    from repro.roofline import ga_measured_points
    table = CostTable.load(path)
    if table is None:
        print(f"no usable cost table at {path}")
        return 1
    print("| stage | migration | mode | N | I/shard | shards | E |"
          " gens/launch | gens/s | % of best | reps | cov |")
    print("|---|---|---|---|---|---|---|---|---|---|---|---|")
    for r in ga_measured_points(table):
        print(f"| {r['stage']} | {r['migration']} | {r['mode']} | {r['n']} |"
              f" {r['i_local']} | {r['shards']} | {r['E']} |"
              f" {r['gens_per_launch']} |"
              f" {r['gens_per_s']:.1f} | {r['frac_of_best']*100:.1f} |"
              f" {r['reps']} | {r['cov']:.3f} |")
    return 0


def main():
    if len(sys.argv) > 1 and sys.argv[1] == "--ga-cost-table":
        sys.exit(render_ga(sys.argv[2]))
    dirname = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results"
    mesh = sys.argv[2] if len(sys.argv) > 2 else "pod1"
    print("| arch | shape | compute (ms) | memory (ms) | collective (ms) |"
          " dominant | MODEL/HLO | roofline % | temp GiB/dev |")
    print("|---|---|---|---|---|---|---|---|---|")
    for f in sorted(glob.glob(f"{dirname}/*.json")):
        d = json.load(open(f))
        if d.get("mesh") != mesh:
            continue
        if d["status"] != "ok":
            print(f"| {d['arch']} | {d['shape']} | — | — | — | *skipped* |"
                  " — | — | — |")
            continue
        print(f"| {d['arch']} | {d['shape']} | {d['t_compute']*1e3:.1f} |"
              f" {d['t_memory']*1e3:.1f} | {d['t_collective']*1e3:.1f} |"
              f" {d['dominant']} | {d['useful_flops_ratio']:.2f} |"
              f" {d['roofline_fraction']*100:.1f} |"
              f" {d['memory_analysis']['temp_size_in_bytes']/2**30:.1f} |")


if __name__ == "__main__":
    main()
