"""Architecture registry: --arch <id> resolves here."""
from repro.configs.base import ModelConfig, reduced

from repro.configs.minitron_8b import CONFIG as MINITRON_8B
from repro.configs.yi_34b import CONFIG as YI_34B
from repro.configs.qwen15_32b import CONFIG as QWEN15_32B
from repro.configs.gemma3_27b import CONFIG as GEMMA3_27B
from repro.configs.moonshot_v1_16b_a3b import CONFIG as MOONSHOT
from repro.configs.deepseek_v3_671b import CONFIG as DEEPSEEK_V3
from repro.configs.whisper_large_v3 import CONFIG as WHISPER_LARGE_V3
from repro.configs.pixtral_12b import CONFIG as PIXTRAL_12B
from repro.configs.mamba2_1_3b import CONFIG as MAMBA2_13B
from repro.configs.zamba2_2_7b import CONFIG as ZAMBA2_27B

REGISTRY = {c.name: c for c in [
    MINITRON_8B, YI_34B, QWEN15_32B, GEMMA3_27B, MOONSHOT, DEEPSEEK_V3,
    WHISPER_LARGE_V3, PIXTRAL_12B, MAMBA2_13B, ZAMBA2_27B,
]}


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]


def list_archs():
    return sorted(REGISTRY)
