#!/usr/bin/env python
"""CI smoke for the HBM-streaming epoch lane: plan, stream, bit-match.

Runs an 8-island F3 spec whose resident stack exceeds a forced VMEM
budget (`EngineOptions.vmem_budget`), so the planner's heuristic picks the
STREAMED epoch mode — the double-buffered HBM→VMEM pipeline that tiles
the island stack through VMEM instead of falling back to gridded
per-interval launches.  Asserts:

  * the plan really is streamed (mode, tile size, double-buffered VMEM
    estimate within the forced budget);
  * the result is bit-identical to the `islands` reference backend —
    best fitness, best chromosome, and the best-trajectory at launch
    boundaries (streamed launches fold several migration intervals, so
    the trajectory is one sample per launch, same as resident);
  * a pinned `stream_tile_islands=1` override also bit-matches (tile
    size is a launch-shape knob, never a results knob);
  * `plan_override="streamed"` on a spec that FITS the budget raises
    with the planner's feasibility reason.

    PYTHONPATH=src python scripts/streaming_smoke.py
"""

import os
import sys

# this smoke pins every plan explicitly; never consume an ambient table
os.environ["REPRO_GA_COST_TABLE"] = "off"

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np                                      # noqa: E402

from repro import ga                                    # noqa: E402
from repro.kernels import ga_step as K                  # noqa: E402

SPEC = ga.GASpec(problem="F3", n=16, bits_per_var=8, mode="arith",
                 mutation_rate=0.02, seed=1, generations=16, n_islands=8,
                 migrate_every=4, gens_per_epoch=8)


def main():
    ref = ga.solve(SPEC, backend="islands")

    probe = ga.Engine(SPEC, "fused-islands", cost_table=False)
    cfg = probe.backend.topology.cfg
    # below the 8-island stack, but a double-buffered 2-island tile fits
    budget = K.resident_vmem_bytes(cfg, 5)
    opts = ga.EngineOptions(cost_table=False, vmem_budget=budget)
    res = ga.solve(SPEC, backend="fused-islands", options=opts)

    plan = res.telemetry.plan
    assert plan.mode == "streamed", plan
    assert plan.tile_islands == 2, plan
    assert plan.vmem_estimate_bytes <= budget, plan
    print(f"streamed plan: tile={plan.tile_islands}, "
          f"~{plan.vmem_estimate_bytes} B double-buffered "
          f"(budget {budget} B); fallback: {plan.fallback}")

    assert res.best_fitness == ref.best_fitness, \
        (res.best_fitness, ref.best_fitness)
    assert np.array_equal(res.best_x, ref.best_x)
    # islands samples once per interval, streamed once per (multi-interval)
    # launch: compare at the launch boundaries
    stride = (res.telemetry.topology.telemetry_unit_gens
              // ref.telemetry.topology.telemetry_unit_gens)
    assert np.array_equal(res.traj_best,
                          ref.traj_best[stride - 1::stride]), \
        (res.traj_best, ref.traj_best)
    print(f"bit-identical to islands reference: best={res.best_fitness}")

    pinned = ga.solve(SPEC, backend="fused-islands",
                      options=ga.EngineOptions(cost_table=False,
                                               vmem_budget=budget,
                                               stream_tile_islands=1))
    assert pinned.telemetry.plan.tile_islands == 1, pinned.telemetry.plan
    assert pinned.best_fitness == ref.best_fitness
    assert np.array_equal(pinned.best_x, ref.best_x)
    print("pinned tile=1 bit-identical too")

    try:
        ga.solve(SPEC, backend="fused-islands",
                 options=ga.EngineOptions(cost_table=False,
                                          plan_override="streamed"))
    except ValueError as e:
        print(f"fitting spec refuses forced streaming: {e}")
    else:
        raise AssertionError("plan_override='streamed' on a fitting spec "
                             "should raise")
    print("streaming smoke OK")


if __name__ == "__main__":
    main()
