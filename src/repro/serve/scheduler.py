"""GA-as-a-service: async multi-tenant job scheduler over one device mesh.

`run_ga_job` made the engine a telemetered *single-job* service; this module
makes it multi-tenant.  A `GAScheduler` owns the mesh and a worker thread;
clients `submit(spec)` and get a job id back immediately:

    sched = GAScheduler(mesh=mesh)
    a = sched.submit(spec_a)                  # QUEUED
    b = sched.submit(spec_b)                  # shape-compatible with a
    hot = sched.submit(urgent, priority=10)   # preempts the running pack
    for event in sched.stream(a):             # live per-chunk telemetry
        print(event["gens_done"], event["best_fitness"])
    print(sched.result(a)["best_fitness"])    # blocks until DONE

Three mechanisms carry the multiplexing:

* **Packing** — queued jobs whose specs share `GASpec.compile_key()` (and
  `generations`) are packed down the engine's `n_repeats` replica axis into
  ONE `PackedEngine` launch, up to `max_pack` slots.  Slot seeding follows
  the solo convention exactly, so per-job results are bit-identical to
  running each job alone (asserted in tests).
* **Compile cache** — runners live in the process-global
  `repro.ga.compile_cache.RUNNER_CACHE`, keyed by spec shape: the second
  submission of an identical spec shape skips tracing/compilation entirely
  (the hit/miss counters are exported through `stats()` → /metrics).
* **Preemption** — the worker drives `PackedEngine.run_chunked` with a
  checkpoint directory; between chunks it checks for strictly
  higher-priority queued work, and if present parks the pack (jobs →
  PREEMPTED, state already on disk) and requeues it.  Resume restores the
  packed state bit-identically — `run_chunked`'s checkpoint/resume path IS
  the preemption primitive, no new state format.

Job states: QUEUED → RUNNING → DONE, with RUNNING → PREEMPTED → QUEUED
loops and any state → FAILED on error.  Telemetry flows through a
`GAMetricsRegistry` (per-chunk pub/sub feeds the metrics_http SSE and
long-poll endpoints; `attach_scheduler_stats` adds queue-depth /
jobs-running / cache-hit gauges to every /metrics scrape).

Two trace-driven extensions ride on top:

* **Cost-table ordering** — when a `cost_table` (see `repro.autotune`) is
  attached, every submission gets a measured gens/s estimate for its
  planned launch shape; within a priority level the dispatcher runs
  shortest-estimated-wall first.  The table also flows into every
  `PackedEngine` so each launch uses the measured epoch plan.  With no
  table the ordering is bit-identical to plain priority/FIFO.
* **TTL GC** — `job_ttl_s` bounds how long DONE/FAILED jobs linger in the
  scheduler and registry; the worker sweeps them out between dispatches
  (`repro_ga_sched_evicted_total` counts evictions).
"""

from __future__ import annotations

import dataclasses
import itertools
import os
import tempfile
import threading
from typing import Any, Dict, Iterator, List, Optional

from repro.serve.engine import GA_METRICS, GAMetricsRegistry

QUEUED = "queued"
RUNNING = "running"
PREEMPTED = "preempted"
DONE = "done"
FAILED = "failed"


@dataclasses.dataclass
class Job:
    """One submitted GASpec and its scheduler-side lifecycle."""

    job_id: str
    spec: Any
    backend: str = "auto"
    priority: int = 0
    state: str = QUEUED
    result: Optional[Dict[str, Any]] = None
    error: Optional[str] = None
    done: threading.Event = dataclasses.field(default_factory=threading.Event)
    est_gens_per_s: Optional[float] = None   # cost-table throughput estimate
    finished_at: Optional[float] = None      # monotonic DONE/FAILED stamp


@dataclasses.dataclass
class _Unit:
    """One schedulable queue entry: fresh single jobs (packable at dispatch)
    or a preempted pack (membership frozen — its checkpoint holds the whole
    packed state, so it must resume with the same jobs in the same order)."""

    seq: int
    jobs: List[Job]
    packable: bool = True
    ckpt_dir: Optional[str] = None

    @property
    def priority(self) -> int:
        return max(j.priority for j in self.jobs)


class GAScheduler:
    """Async multi-tenant GA job scheduler (one worker thread owns the mesh).

    Parameters: `mesh` is handed to every engine build; `backend` is the
    default backend request; `max_pack` caps slots per launch;
    `chunk_generations` sets the telemetry/preemption granularity;
    `ckpt_root` is where pack checkpoints live (a temp dir by default);
    `job_ttl_s` evicts DONE/FAILED jobs that many seconds after they
    finish (None keeps them forever); `cost_table` follows
    `repro.autotune.table.resolve_table` semantics — None discovers the
    ambient table, False disables, a path or CostTable pins one.
    Engine knobs can also arrive as one `ga.EngineOptions` via `options=`
    (mesh/cost_table then live there; mixing both is an error) — that is
    how the streamed lane's vmem_budget / stream_tile_islands reach every
    packed launch.
    """

    def __init__(self, *, mesh=None, registry: Optional[GAMetricsRegistry]
                 = None, backend: str = "auto", max_pack: int = 8,
                 chunk_generations: Optional[int] = None,
                 ckpt_root: Optional[str] = None,
                 job_ttl_s: Optional[float] = None,
                 cost_table=None, options=None):
        from repro.autotune import resolve_table   # import-light (no jax)
        from repro.ga.options import resolve_options   # import-light too

        self.options = resolve_options(options, mesh=mesh,
                                       cost_table=cost_table)
        self.mesh = self.options.mesh
        self.registry = registry if registry is not None else GA_METRICS
        self.backend = backend
        self.max_pack = max(1, int(max_pack))
        self.chunk_generations = chunk_generations
        self.ckpt_root = ckpt_root or tempfile.mkdtemp(prefix="ga-sched-")
        self.job_ttl_s = None if job_ttl_s is None else float(job_ttl_s)
        # resolve once: every engine build + submit estimate reuses it
        self.cost_table = resolve_table(self.options.cost_table)
        self._cv = threading.Condition()
        self._queue: List[_Unit] = []
        self._jobs: Dict[str, Job] = {}
        self._seq = itertools.count()
        self._stop = False
        self._running: List[Job] = []
        self.packs_launched = 0
        self.preemptions = 0
        self.jobs_packed = 0        # jobs that shared a launch with >=1 other
        self.jobs_evicted = 0       # finished jobs TTL-swept from registry
        self.plans_measured = 0     # launches planned from the cost table
        self.plans_heuristic = 0    # launches planned by the static heuristic
        self.registry.attach_scheduler_stats(self.stats)
        self._worker = threading.Thread(target=self._run, name="ga-scheduler",
                                        daemon=True)
        self._worker.start()

    # ---- client API -----------------------------------------------------

    def submit(self, spec, *, backend: Optional[str] = None,
               priority: int = 0) -> str:
        """Enqueue a GASpec; returns its job id immediately (state QUEUED)."""
        with self._cv:
            if self._stop:
                raise RuntimeError("scheduler is shut down")
        job_id = self.registry.allocate_job_id(spec.problem or "blackbox")
        job = Job(job_id=job_id, spec=spec,
                  backend=backend if backend is not None else self.backend,
                  priority=int(priority))
        if self.cost_table is not None:
            from repro.autotune import estimate_gens_per_s
            try:   # an estimate is a scheduling hint, never a submit error
                job.est_gens_per_s = estimate_gens_per_s(
                    spec, self.cost_table, backend=job.backend,
                    mesh=self.mesh)
            except Exception:
                job.est_gens_per_s = None
        self.registry.queue_job(job_id, problem=spec.problem or "blackbox",
                                gens_total=spec.generations, n_vars=spec.v,
                                priority=job.priority)
        with self._cv:
            self._jobs[job_id] = job
            self._queue.append(_Unit(seq=next(self._seq), jobs=[job]))
            self._cv.notify_all()
        return job_id

    def job(self, job_id: str) -> Job:
        with self._cv:
            return self._jobs[job_id]

    def result(self, job_id: str, timeout: Optional[float] = None
               ) -> Dict[str, Any]:
        """Block until the job finishes; returns its final telemetry dict.
        Raises RuntimeError if it FAILED, TimeoutError on timeout."""
        job = self.job(job_id)
        if not job.done.wait(timeout):
            raise TimeoutError(f"job {job_id} still {job.state} "
                               f"after {timeout}s")
        if job.state == FAILED:
            raise RuntimeError(f"job {job_id} failed: {job.error}")
        return job.result

    def stream(self, job_id: str, timeout: Optional[float] = None
               ) -> Iterator[Dict[str, Any]]:
        """Yield per-chunk telemetry events live until the job ends (the
        same feed the metrics_http SSE endpoint serves)."""
        job = self.job(job_id)
        q = self.registry.subscribe(job_id)
        try:
            # subscribed after the job ended -> the end event predates the
            # subscription and will never arrive; don't block on it
            st = self.registry.metrics()["jobs"].get(job_id, {}).get("status")
            if job.done.is_set() or st in (DONE, FAILED):
                return
            while True:
                event = q.get(timeout=timeout)
                yield event
                if event.get("event") == "end":
                    return
        finally:
            self.registry.unsubscribe(job_id, q)

    def wait_all(self, timeout: Optional[float] = None) -> None:
        """Block until every submitted job is DONE or FAILED."""
        import time as _t
        deadline = None if timeout is None else _t.monotonic() + timeout
        for job in list(self._jobs.values()):
            left = None if deadline is None else deadline - _t.monotonic()
            if left is not None and left <= 0:
                raise TimeoutError("jobs still pending")
            if not job.done.wait(left):
                raise TimeoutError(f"job {job.job_id} still {job.state}")

    def stats(self) -> Dict[str, Any]:
        """Scheduler gauges for /metrics (queue depth, running, packing and
        compile-cache counters)."""
        from repro.ga.compile_cache import RUNNER_CACHE
        with self._cv:
            depth = sum(len(u.jobs) for u in self._queue)
            running = len(self._running)
        cache = RUNNER_CACHE.stats()
        return {"queue_depth": depth, "jobs_running": running,
                "packs_launched": self.packs_launched,
                "preemptions": self.preemptions,
                "jobs_packed": self.jobs_packed,
                "max_pack": self.max_pack,
                "cache_hits": cache["hits"],
                "cache_misses": cache["misses"],
                "cache_entries": cache["entries"],
                "jobs_evicted": self.jobs_evicted,
                "plans_measured": self.plans_measured,
                "plans_heuristic": self.plans_heuristic,
                "plan_table_entries": (len(self.cost_table)
                                       if self.cost_table is not None else 0)}

    def gc_now(self, now: Optional[float] = None) -> int:
        """Evict DONE/FAILED jobs older than `job_ttl_s`; returns the count.
        The worker calls this between dispatches; tests call it directly.
        Registry eviction happens outside `_cv` (its Condition lock is not
        reentrant and the registry takes its own lock)."""
        if self.job_ttl_s is None:
            return 0
        import time as _t
        now = _t.monotonic() if now is None else now
        with self._cv:
            stale = [j for j in self._jobs.values()
                     if j.state in (DONE, FAILED) and j.finished_at is not None
                     and now - j.finished_at >= self.job_ttl_s]
            for j in stale:
                del self._jobs[j.job_id]
        for j in stale:
            self.registry.evict_job(j.job_id)
        self.jobs_evicted += len(stale)
        return len(stale)

    def shutdown(self, wait: bool = True, timeout: float = 30.0) -> None:
        """Stop the worker after the unit in flight; queued jobs stay QUEUED."""
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        if wait:
            self._worker.join(timeout)

    # ---- worker ---------------------------------------------------------

    def _pack_sig(self, job: Job):
        return (job.spec.compile_key(), job.spec.generations, job.backend)

    def _unit_order_key(self, u: _Unit):
        """Dispatch order: priority first, then (with a cost table) shortest
        estimated wall, then FIFO.  Estimated units outrank unestimated ones
        within a level; with no table every unit gets the same middle terms,
        so the order is bit-identical to plain priority/FIFO."""
        ests = [j.spec.generations / j.est_gens_per_s for j in u.jobs
                if j.est_gens_per_s]
        if not ests:
            return (u.priority, 0, 0.0, -u.seq)
        return (u.priority, 1, -min(ests), -u.seq)

    def _take_unit(self) -> Optional[_Unit]:
        """Pop the best-priority unit; pack compatible fresh jobs onto it.
        FIFO within a priority level (seq breaks ties)."""
        best = max(self._queue, key=self._unit_order_key)
        self._queue.remove(best)
        if best.packable:
            sig = self._pack_sig(best.jobs[0])
            room = self.max_pack - best.jobs[0].spec.n_repeats
            for u in sorted([u for u in self._queue if u.packable],
                            key=lambda u: u.seq):
                if room <= 0:
                    break
                cand = u.jobs[0]
                if (self._pack_sig(cand) == sig
                        and cand.spec.n_repeats <= room):
                    self._queue.remove(u)
                    best.jobs.append(cand)
                    room -= cand.spec.n_repeats
        return best

    def _higher_priority_waiting(self, priority: int) -> bool:
        with self._cv:
            return any(u.priority > priority for u in self._queue)

    def _run(self) -> None:
        import time as _t
        # with a TTL, wake periodically so finished jobs age out even while
        # the queue is idle; gc runs OUTSIDE _cv (it takes _cv itself plus
        # the registry lock)
        wait_s = None if self.job_ttl_s is None else min(1.0, self.job_ttl_s)
        while True:
            with self._cv:
                if not self._queue and not self._stop:
                    self._cv.wait(timeout=wait_s)
                if self._stop:
                    return
                unit = self._take_unit() if self._queue else None
                if unit is not None:
                    for j in unit.jobs:
                        j.state = RUNNING
                    self._running = list(unit.jobs)
            if unit is None:
                self.gc_now()
                continue
            try:
                self._run_unit(unit)
            except Exception as e:     # noqa: BLE001 — job-level failure wall
                err = repr(e)
                now = _t.monotonic()
                for j in unit.jobs:
                    j.state = FAILED
                    j.error = err
                    j.finished_at = now
                    self.registry.finish_job(j.job_id, error=err)
                    j.done.set()
            finally:
                with self._cv:
                    self._running = []
                self.gc_now()

    def _run_unit(self, unit: _Unit) -> None:
        from repro.ga.engine import PackedEngine   # lazy: jax import cost

        jobs = unit.jobs
        if unit.ckpt_dir is None:
            unit.ckpt_dir = os.path.join(self.ckpt_root, f"pack-{unit.seq}")
        pe = PackedEngine(
            [j.spec for j in jobs], jobs[0].backend,
            options=dataclasses.replace(self.options,
                                        cost_table=self.cost_table))
        self.packs_launched += 1
        if len(jobs) > 1:
            self.jobs_packed += len(jobs)
        for j in jobs:
            self.registry.start_job(j.job_id, backend=pe.backend_name,
                                    gens_total=j.spec.generations,
                                    problem=j.spec.problem or "blackbox",
                                    n_vars=j.spec.v)
        priority = unit.priority
        last: Optional[Dict[str, Any]] = None
        for tele in pe.run_chunked(chunk_generations=self.chunk_generations,
                                   ckpt_dir=unit.ckpt_dir, resume=True):
            if last is None:   # count the plan once per dispatch
                tj = tele["jobs"][0].get("telemetry")
                ps = tj.plan.source if tj is not None else None
                if ps == "measured":
                    self.plans_measured += 1
                elif ps is not None and ps != "-":
                    self.plans_heuristic += 1
            last = tele
            for j, jt in zip(jobs, tele["jobs"]):
                self.registry.record_chunk(j.job_id, jt)
            if (tele["gens_done"] < tele["gens_total"]
                    and self._higher_priority_waiting(priority)):
                # park the pack: state is already checkpointed; membership
                # freezes so the packed checkpoint resumes with these jobs
                for j in jobs:
                    j.state = PREEMPTED
                    self.registry.set_status(j.job_id, PREEMPTED)
                self.preemptions += 1
                with self._cv:
                    # jobs stay PREEMPTED while waiting (the informative
                    # state); the unit re-enters the queue and flips them
                    # back to RUNNING when re-dispatched
                    self._queue.append(_Unit(seq=unit.seq, jobs=jobs,
                                             packable=False,
                                             ckpt_dir=unit.ckpt_dir))
                    self._cv.notify_all()
                return
        import time as _t
        now = _t.monotonic()
        for j, jt in zip(jobs, last["jobs"]):
            j.result = dict(jt)
            j.result["best_params"] = [float(v) for v in jt["best_params"]]
            j.state = DONE
            j.finished_at = now
            self.registry.finish_job(j.job_id)
            j.done.set()
