"""Core: the paper's full-parallel GA as a composable JAX module.

Submodules: lfsr (paper's PRNG), fitness (FFM), ga (FFM+SM+CM+MM datapath),
islands (multi-pod scaling), evolve (blackbox-tuning service).
"""

from repro.core.fitness import (F1, F2, F3, PROBLEMS, FitnessProgram,
                                ProblemDef, build_tables, compile_program,
                                register_problem, resolve_problem)
from repro.core.ga import GAConfig, GAState, GARun, generation, init_state, run_scan
from repro.core.islands import IslandConfig, init_islands_fast, migrate_ring
from repro.core.evolve import evolve, EvolveResult
