"""FFM tests: LUT (faithful ROM) vs arithmetic (TPU-native) fitness modes."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fitness as F
from repro.core import ga as G


@pytest.mark.parametrize("name", ["F1", "F2", "F3"])
@pytest.mark.parametrize("m", [20, 26])
def test_lut_matches_arith_within_quantization(name, m):
    problem = F.PROBLEMS[name]
    c = m // 2
    t = F.build_tables(problem, m)
    spec = F.ArithSpec.for_problem(problem)
    rng = np.random.default_rng(0)
    px = jnp.asarray(rng.integers(0, 1 << c, 256), jnp.int32)
    qx = jnp.asarray(rng.integers(0, 1 << c, 256), jnp.int32)
    y_lut = np.asarray(F.lut_fitness(px, qx, t)).astype(np.float64) / 2.0 ** t.frac_bits
    y_ari = np.asarray(F.arith_fitness(px.astype(jnp.uint32),
                                       qx.astype(jnp.uint32), c, spec))
    scale = np.maximum(np.abs(y_ari), 1.0)
    # quantization: frac_bits rounding + γ table addressing granularity
    tol = (2.0 ** -t.frac_bits) * 4 + (2.0 ** t.delta_shift) * 2.0 ** -t.frac_bits
    assert np.max(np.abs(y_lut - y_ari) / scale) < max(tol, 1e-2)


def test_tables_fixed_point_autoscale():
    t1 = F.build_tables(F.F1, 26)   # F1 spans ±6.9e10 -> negative frac bits
    assert t1.frac_bits < 0
    t3 = F.build_tables(F.F3, 20)   # F3 small range -> fractional precision
    assert t3.frac_bits > 0
    assert t3.gamma_t is not None   # sqrt needs the third ROM
    t2 = F.build_tables(F.F2, 20)
    assert t2.gamma_t is None       # identity γ -> ROM elided (paper's F1/F2)


def test_decode_domain_mapping():
    v = F.decode(jnp.asarray([0, (1 << 10) - 1]), 10, (-128.0, 127.0))
    np.testing.assert_allclose(np.asarray(v), [-128.0, 127.0], rtol=1e-6)


@pytest.mark.parametrize("name,n,m,k", [("F1", 32, 26, 100),
                                        ("F3", 64, 20, 100)])
def test_paper_convergence_claims(name, n, m, k):
    """Paper Figs. 11–12: F1 (N=32, m=26) reaches its global minimum within
    100 generations; F3 (N=64, m=20) gets near zero."""
    problem = F.PROBLEMS[name]
    best = np.inf
    for seed in (1, 2, 3):
        cfg = G.GAConfig(n=n, c=m // 2, v=2, mutation_rate=0.05, seed=seed,
                         mode="lut")
        t = F.build_tables(problem, m)
        out = G.run_scan(cfg, G.make_lut_fitness(t), k)
        best = min(best, float(out.best_y) / 2.0 ** t.frac_bits)
    if name == "F1":
        target = float(problem.f(np.array(0.0), np.array(-4096.0)))
        assert best <= target * 0.98  # within 2% of the global minimum
    else:
        assert best < 2.0             # near zero (grid-limited)
