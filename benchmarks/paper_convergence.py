"""Paper Figs. 11-12: convergence curves for F1 (N=32, m=26) and F3
(N=64, m=20), averaged over seeds; derived value = generations to reach the
paper's reported convergence point."""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import fitness as F
from repro.core import ga as G


def _gens_to(traj, target):
    hit = np.nonzero(traj <= target)[0]
    return int(hit[0]) if len(hit) else -1


def run():
    rows = []
    t0 = time.perf_counter()
    # F1: global min at x=-4096
    target1 = float(F.F1.f(np.array(0.0), np.array(-4096.0))) * 0.98
    gens = []
    for seed in range(10):
        cfg = G.GAConfig(n=32, c=13, v=2, mutation_rate=0.05, seed=seed,
                         mode="lut")
        t = F.build_tables(F.F1, 26)
        out = G.run(cfg, G.make_lut_fitness(t), 100)
        traj = np.asarray(out.traj_best) / 2.0 ** t.frac_bits
        gens.append(_gens_to(traj, target1))
    ok = [g for g in gens if g >= 0]
    rows.append(("convergence_F1_N32_m26",
                 (time.perf_counter() - t0) * 1e5,
                 f"median_gens_to_min={int(np.median(ok)) if ok else -1},"
                 f"hit_rate={len(ok)}/10"))
    # F3
    t0 = time.perf_counter()
    gens = []
    for seed in range(10):
        cfg = G.GAConfig(n=64, c=10, v=2, mutation_rate=0.05, seed=seed,
                         mode="arith")
        out = G.run(cfg, G.fitness_for_problem(F.F3, cfg), 100)
        gens.append(_gens_to(np.asarray(out.traj_best), 1.0))
    ok = [g for g in gens if g >= 0]
    rows.append(("convergence_F3_N64_m20",
                 (time.perf_counter() - t0) * 1e5,
                 f"median_gens_to_near_zero={int(np.median(ok)) if ok else -1},"
                 f"hit_rate={len(ok)}/10"))
    return rows
