"""The paper's own experiment grid: F1/F2/F3 × N ∈ {4..64} × m ∈ {20..28}."""
from repro.core.ga import GAConfig

POPULATIONS = (4, 8, 16, 32, 64)
BIT_WIDTHS = (20, 22, 24, 26, 28)
K_GENERATIONS = 100          # paper's default
MUTATION_RATE = 0.02         # paper: 0.1%–2%


def paper_config(n: int = 32, m: int = 20, mode: str = "lut",
                 seed: int = 1) -> GAConfig:
    return GAConfig(n=n, c=m // 2, v=2, mutation_rate=MUTATION_RATE,
                    minimize=True, seed=seed, mode=mode)
