"""Attention: GQA/MHA with RoPE, sliding windows, QKV bias, QK-norm,
cross-attention, and a decode KV cache.  Heads are TP-sharded ("heads" /
"kv_heads" logical axes); batch stays on the DP axes.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding as SH
from repro.models import common as C

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False       # qwen1.5
    qk_norm: bool = False        # gemma3
    rope_theta: Optional[float] = 10_000.0   # None = no rope (whisper)
    causal: bool = True
    window: Optional[int] = None  # sliding-window size (gemma3 locals)
    softmax_scale: Optional[float] = None

    @property
    def q_groups(self) -> int:
        assert self.n_heads % self.n_kv_heads == 0
        return self.n_heads // self.n_kv_heads

    @property
    def scale(self) -> float:
        return self.softmax_scale or self.head_dim ** -0.5


def attn_defs(cfg: AttnConfig) -> Dict[str, C.ParamDef]:
    d, h, kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    defs = {
        "wq": C.ParamDef((d, h, hd), ("embed", "heads", None)),
        "wk": C.ParamDef((d, kh, hd), ("embed", "kv_heads", None)),
        "wv": C.ParamDef((d, kh, hd), ("embed", "kv_heads", None)),
        "wo": C.ParamDef((h, hd, d), ("heads", None, "embed")),
    }
    if cfg.qkv_bias:
        defs["bq"] = C.ParamDef((h, hd), ("heads", None), init="zeros")
        defs["bk"] = C.ParamDef((kh, hd), ("kv_heads", None), init="zeros")
        defs["bv"] = C.ParamDef((kh, hd), ("kv_heads", None), init="zeros")
    if cfg.qk_norm:
        defs["q_norm"] = C.ParamDef((hd,), (None,), init="zeros")
        defs["k_norm"] = C.ParamDef((hd,), (None,), init="zeros")
    return defs


def _project_qkv(p, x, cfg: AttnConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    if cfg.qk_norm:
        q = C.rmsnorm(q, p["q_norm"])
        k = C.rmsnorm(k, p["k_norm"])
    return q, k, v


def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, cfg: AttnConfig,
               k_valid: Optional[jax.Array] = None,
               window=None) -> jax.Array:
    """(..., Sq, Sk) additive f32 mask from positions.

    `window` may be a traced scalar (gemma3 selects local/global per layer
    inside the layer scan); falls back to the static cfg.window.
    """
    d = q_pos[..., :, None] - k_pos[..., None, :]
    ok = jnp.ones(d.shape, bool)
    if cfg.causal:
        ok &= d >= 0
    if window is not None:
        ok &= d < window
    elif cfg.window is not None:
        ok &= d < cfg.window
    if k_valid is not None:
        ok &= k_valid[..., None, :]
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _sdpa(q, k, v, bias, cfg: AttnConfig):
    """q: (B,Sq,H,hd)  k/v: (B,Sk,KH,hd)  bias: broadcastable (B,1,Sq,Sk).

    KV heads are broadcast up to the Q-head count BEFORE the score einsum so
    the (Sq, Sk) score tensor shards on "heads" (always TP-divisible, unlike
    kv_heads, e.g. 8 KV heads on a 16-way model axis would replicate a
    B×H×S×S f32 tensor — catastrophic at 4k+ context).
    """
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    g = h // kh
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
        k = SH.constrain(k, "batch", None, "heads", None)
        v = SH.constrain(v, "batch", None, "heads", None)
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32)
    scores = scores * cfg.scale + bias
    scores = SH.constrain(scores, "batch", "heads", None, None)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhst,bthd->bshd", probs, v)
    return out


def forward(p, x: jax.Array, cfg: AttnConfig,
            positions: Optional[jax.Array] = None,
            rope_cs: Optional[Tuple[jax.Array, jax.Array]] = None,
            window=None) -> jax.Array:
    """Full-sequence (train / prefill) self-attention.

    rope_cs: optional precomputed (cos, sin) tables — lets a layer scan pick
    between local/global RoPE bases (gemma3) without retracing.
    window: optional traced sliding-window size.
    """
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg)
    q = SH.constrain(q, "batch", None, "heads", None)
    if positions is None:
        positions = jnp.arange(s)[None, :]
    if rope_cs is not None:
        q = C.apply_rope(q, *rope_cs)
        k = C.apply_rope(k, *rope_cs)
    elif cfg.rope_theta is not None:
        cos, sin = C.rope_tables(positions, cfg.head_dim, cfg.rope_theta)
        q = C.apply_rope(q, cos, sin)
        k = C.apply_rope(k, cos, sin)
    bias = _mask_bias(positions, positions, cfg, window=window)[:, None]
    out = _sdpa(q, k, v, bias, cfg)
    out = SH.constrain(out, "batch", None, "heads", None)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# ---------------------------------------------------------------------------
# Flash-style chunked attention (prefill): online softmax over KV blocks —
# the (Sq, Sk) score matrix never exists in HBM.  At 32k context the naive
# form costs ≈50 GiB of score traffic per layer; this form reads K/V once.
# Inference-only (prefill/serving): the train path keeps the einsum form
# (its backward is handled by remat; a custom flash VJP is future work).
# ---------------------------------------------------------------------------

FLASH_MIN_SEQ = 8192
FLASH_CHUNK = 1024


def _flash_sdpa(q, k, v, cfg: AttnConfig, q_pos, k_pos, window=None):
    """q: (B,Sq,H,hd); k/v: (B,Sk,H,hd) (already head-expanded).
    q_pos: (B,Sq); k_pos: (Sk,). Returns (B,Sq,H,hd)."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    n_chunks = -(-sk // FLASH_CHUNK)
    pad = n_chunks * FLASH_CHUNK - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=-(10 ** 9))
    kc = k.reshape(b, n_chunks, FLASH_CHUNK, h, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, FLASH_CHUNK, h, hd).transpose(1, 0, 2, 3, 4)
    pc = k_pos.reshape(n_chunks, FLASH_CHUNK)

    qf = q.astype(jnp.float32) * cfg.scale

    def body(carry, xs):
        m, l, acc = carry                     # (B,H,Sq), (B,H,Sq), (B,Sq,H,hd)
        k_i, v_i, p_i = xs
        s = jnp.einsum("bshd,bthd->bhst", qf,
                       k_i.astype(jnp.float32))          # (B,H,Sq,Ck)
        d = q_pos[:, None, :, None] - p_i[None, None, None, :]
        ok = jnp.ones(d.shape, bool)
        if cfg.causal:
            ok &= d >= 0
        if window is not None:
            ok &= d < window
        elif cfg.window is not None:
            ok &= d < cfg.window
        ok &= (p_i >= 0)[None, None, None, :]
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhst,bthd->bshd", p.astype(v_i.dtype), v_i)
        acc_new = acc * alpha.transpose(0, 2, 1)[..., None] + \
            pv.astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    acc0 = jnp.zeros((b, sq, h, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype)


def _sdpa_infer(q, k, v, cfg: AttnConfig, q_pos, k_pos, window=None):
    """Inference SDPA: flash path for long sequences, einsum otherwise."""
    kh = k.shape[2]
    g = q.shape[2] // kh
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
        k = SH.constrain(k, "batch", None, "heads", None)
        v = SH.constrain(v, "batch", None, "heads", None)
    if q.shape[1] >= FLASH_MIN_SEQ:
        return _flash_sdpa(q, k, v, cfg, q_pos, k_pos, window=window)
    bias = _mask_bias(q_pos, k_pos[None, :], cfg, window=window)[:, None]
    return _sdpa(q, k, v, bias, cfg)


# ---------------------------------------------------------------------------
# KV cache (decode)
# ---------------------------------------------------------------------------


def cache_defs(cfg: AttnConfig, batch: int, max_len: int) -> Dict[str, C.ParamDef]:
    """KV cache sharded over batch AND sequence ("act_seq" -> model axis):
    flash-decoding layout — each model-shard attends its sequence slice and
    GSPMD combines the partial softmaxes (tiny AR), instead of replicating a
    multi-GiB cache when kv_heads < TP ways."""
    kh, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": C.ParamDef((batch, max_len, kh, hd), ("batch", "act_seq", "kv_heads", None), init="zeros"),
        "v": C.ParamDef((batch, max_len, kh, hd), ("batch", "act_seq", "kv_heads", None), init="zeros"),
    }


def prefill(p, x: jax.Array, cfg: AttnConfig, cache: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Run full attention over the prompt and fill the cache at [0, S)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg)
    positions = jnp.arange(s)[None, :]
    if cfg.rope_theta is not None:
        cos, sin = C.rope_tables(positions, cfg.head_dim, cfg.rope_theta)
        q = C.apply_rope(q, cos, sin)
        k = C.apply_rope(k, cos, sin)
    out = _sdpa_infer(q, k, v, cfg, positions, jnp.arange(s))
    cache = {
        "k": jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)),
    }
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache


def decode_step(p, x: jax.Array, cfg: AttnConfig, cache: Dict[str, jax.Array],
                pos: jax.Array) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode. x: (B,1,D); pos: scalar int32 (current length)."""
    b = x.shape[0]
    q, k, v = _project_qkv(p, x, cfg)
    positions = jnp.full((b, 1), pos, jnp.int32)
    if cfg.rope_theta is not None:
        cos, sin = C.rope_tables(positions, cfg.head_dim, cfg.rope_theta)
        q = C.apply_rope(q, cos, sin)
        k = C.apply_rope(k, cos, sin)
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
    s_max = ck.shape[1]
    k_pos = jnp.arange(s_max)[None, :]
    k_valid = k_pos[0] <= pos
    bias = _mask_bias(positions, jnp.broadcast_to(k_pos, (b, s_max)), cfg,
                      k_valid=k_valid[None, :])[:, None]
    out = _sdpa(q, ck, cv, bias, cfg)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# Ring-buffer KV cache for sliding-window layers (gemma3 locals).
# Slot i of the ring holds position p ≡ i (mod W); at decode position `pos`
# the live positions are (pos-W, pos], recoverable in closed form — no extra
# position storage.  This is what makes a 500k-token decode hold a 1k cache
# in 52 of gemma3's 62 layers.
# ---------------------------------------------------------------------------


def ring_cache_defs(cfg: AttnConfig, batch: int, window: int) -> Dict[str, C.ParamDef]:
    kh, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": C.ParamDef((batch, window, kh, hd), ("batch", "act_seq", "kv_heads", None), init="zeros"),
        "v": C.ParamDef((batch, window, kh, hd), ("batch", "act_seq", "kv_heads", None), init="zeros"),
    }


def ring_prefill(p, x: jax.Array, cfg: AttnConfig, cache, window: int,
                 rope_cs=None):
    """Windowed attention over the prompt; keep the last `window` KVs.

    Requires window | S (checked) so ring slots line up with positions.
    """
    b, s, _ = x.shape
    assert s % window == 0, f"ring prefill needs window|S ({window},{s})"
    q, k, v = _project_qkv(p, x, cfg)
    positions = jnp.arange(s)[None, :]
    if rope_cs is not None:
        q = C.apply_rope(q, *rope_cs)
        k = C.apply_rope(k, *rope_cs)
    elif cfg.rope_theta is not None:
        cos, sin = C.rope_tables(positions, cfg.head_dim, cfg.rope_theta)
        q = C.apply_rope(q, cos, sin)
        k = C.apply_rope(k, cos, sin)
    bias = _mask_bias(positions, positions, cfg, window=window)[:, None]
    out = _sdpa(q, k, v, bias, cfg)
    cache = {"k": k[:, -window:].astype(cache["k"].dtype),
             "v": v[:, -window:].astype(cache["v"].dtype)}
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache


def ring_decode_step(p, x: jax.Array, cfg: AttnConfig, cache, pos: jax.Array,
                     window: int, rope_cs=None):
    """One-token decode against a ring cache. x: (B,1,D)."""
    b = x.shape[0]
    q, k, v = _project_qkv(p, x, cfg)
    positions = jnp.full((b, 1), pos, jnp.int32)
    if rope_cs is not None:
        q = C.apply_rope(q, *rope_cs)
        k = C.apply_rope(k, *rope_cs)
    elif cfg.rope_theta is not None:
        cos, sin = C.rope_tables(positions, cfg.head_dim, cfg.rope_theta)
        q = C.apply_rope(q, cos, sin)
        k = C.apply_rope(k, cos, sin)
    slot = jnp.mod(pos, window)
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, slot, 0, 0))
    # position held by ring slot i:  pos - ((pos - i) mod W)
    i = jnp.arange(window)[None, :]
    k_pos = pos - jnp.mod(pos - i, window)
    k_valid = (k_pos[0] >= 0)
    bias = _mask_bias(positions, jnp.broadcast_to(k_pos, (b, window)), cfg,
                      k_valid=k_valid[None, :], window=window)[:, None]
    out = _sdpa(q, ck, cv, bias, cfg)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder)
# ---------------------------------------------------------------------------


def cross_defs(cfg: AttnConfig) -> Dict[str, C.ParamDef]:
    return attn_defs(dataclasses.replace(cfg, qkv_bias=False, qk_norm=False))


def cross_forward(p, x: jax.Array, kv_src: jax.Array, cfg: AttnConfig) -> jax.Array:
    """x attends over kv_src (encoder states); no mask, no rope."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", kv_src, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", kv_src, p["wv"])
    bias = jnp.zeros((x.shape[0], 1, x.shape[1], kv_src.shape[1]), jnp.float32)
    out = _sdpa(q, k, v, bias, cfg)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def cross_cache_defs(cfg: AttnConfig, batch: int, enc_seq: int) -> Dict[str, C.ParamDef]:
    kh, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": C.ParamDef((batch, enc_seq, kh, hd), ("batch", "act_seq", "kv_heads", None), init="zeros"),
        "v": C.ParamDef((batch, enc_seq, kh, hd), ("batch", "act_seq", "kv_heads", None), init="zeros"),
    }


def cross_fill(p, kv_src: jax.Array, cfg: AttnConfig):
    """Project encoder states to cross K/V once (at prefill)."""
    k = jnp.einsum("btd,dhk->bthk", kv_src, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", kv_src, p["wv"])
    return {"k": k, "v": v}


def cross_decode(p, x: jax.Array, cfg: AttnConfig, cache) -> jax.Array:
    """Decode-time cross-attention against cached encoder K/V."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k, v = cache["k"].astype(q.dtype), cache["v"].astype(q.dtype)
    bias = jnp.zeros((x.shape[0], 1, x.shape[1], k.shape[1]), jnp.float32)
    out = _sdpa(q, k, v, bias, cfg)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])
