"""`evolve` — the GA engine exposed as the framework's blackbox-tuning service.

This is how the paper's accelerator integrates with the LM stack as a
first-class feature: anything expressible as "minimize f(θ) over a box" —
learning-rate schedule coefficients, serving batch knobs, quantization
clip scales — can be handed to the full-parallel GA.  The evaluation function
receives a whole population matrix at once (N, V) and returns (N,) scores, so
model-based fitness (e.g. run 10 train steps per candidate) can itself be
vmapped/pmapped by the caller.

Since the `repro.ga` engine redesign this is a thin shim: the keyword
surface is unchanged, but the run is a `GASpec` handed to `ga.solve`, which
auto-routes to the eager backend when `jit_fitness=False`, the island
backend when `n_islands > 1` (shard_mapped over `mesh` when given), and the
reference scan otherwise.  Prefer building a `GASpec` directly in new code.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence, Tuple

import jax
import numpy as np


@dataclasses.dataclass
class EvolveResult:
    best_params: np.ndarray     # [V] decoded
    best_fitness: float
    traj_best: np.ndarray       # [K] (island runs: one entry per epoch)
    traj_mean: np.ndarray       # [K]


def evolve(fn: Callable[[jax.Array], jax.Array],
           bounds: Sequence[Tuple[float, float]],
           *,
           population: int = 64,
           generations: int = 100,
           bits_per_var: int = 16,
           mutation_rate: float = 0.02,
           minimize: bool = True,
           seed: int = 0,
           n_islands: int = 1,
           migrate_every: int = 16,
           jit_fitness: bool = True,
           selection: str = "tournament",
           mesh=None) -> EvolveResult:
    """Minimize (or maximize) `fn` over box `bounds` with the parallel GA.

    fn: (N, V) float32 -> (N,) batch evaluator.  Set jit_fitness=False when
    fn is not traceable (e.g. it runs training trials) — the GA operators
    stay jitted, fitness runs eagerly.
    With n_islands > 1 the island model is used (sharded over `mesh` when
    given, vmapped locally otherwise).  `selection` picks any registered
    selection scheme (see repro.ga.SELECTION).
    """
    from repro import ga

    # the island model always traces fitness (as it did pre-engine):
    # jit_fitness=False only selects the eager driver for single-population
    # runs, where a python loop is possible at all
    spec = ga.GASpec(fitness=fn, bounds=tuple(tuple(b) for b in bounds),
                     n=population, bits_per_var=bits_per_var,
                     mutation_rate=mutation_rate, minimize=minimize,
                     seed=seed, generations=generations,
                     n_islands=n_islands, migrate_every=migrate_every,
                     jit_fitness=jit_fitness or n_islands > 1,
                     selection=selection)
    res = ga.solve(spec, mesh=mesh)
    return EvolveResult(best_params=res.best_params,
                        best_fitness=res.best_fitness,
                        traj_best=res.traj_best,
                        traj_mean=res.traj_mean)
