"""Pallas TPU kernel: one fused GA generation per island.

This is the TPU re-expression of the paper's full-parallel datapath: on the
FPGA, FFM/SM/CM/MM are N physically parallel circuits clocked as one 3-cycle
pipeline; here the whole generation is ONE kernel launch whose working set
(population, fitness vector, LFSR banks, one-hot tournament matrices) lives
entirely in VMEM — no HBM round-trips between GA stages.

Key adaptation — MUX trees → MXU matmuls:
  the paper gathers tournament contestants through N-input multiplexer trees
  (SMMUX1..3, the source of its O(N²) LUT growth).  A TPU has no per-lane
  dynamic gather, but the systolic array contracts a one-hot matrix against
  the population in O(N²) MACs — the exact same asymptotics as the MUX-tree
  area, now in hardware we do have.  Bit-exactness is preserved by splitting
  each uint32 word into two 16-bit halves before the f32 matmul (≤ 2^16 is
  exactly representable; each one-hot row has a single nonzero so the
  accumulation is exact), then recombining.

Grid: one program instance per island.  VMEM budget per instance is dominated
by the (N, N) one-hot f32 matrices → N ≤ 1024 keeps it ≤ 4 MiB (checked).
The FPGA paper tops out at N=64; larger populations use more islands or the
pure-JAX path in repro.core.ga.

The FFM stage is PLUGGABLE: the kernel takes a traceable ``ffm`` function
``uint32[N, V] bits -> f32[N]`` (normally ``FitnessProgram.stage`` from
repro.core.fitness — decode + the problem's jnp expression on the VPU) and
traces it into the kernel body, so any n-variable registry problem or user
blackbox runs fused, not just the paper's two-variable polynomials.  Because
the reference executor evaluates the SAME function, fused stays bit-identical
to reference for every program.  LUT-mode (HBM gather tables) stays in the
pure-JAX path — gathers inside a TPU kernel would defeat the fusion.
"""

from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.ga import GAConfig

# The kernel-facing FFM stage: uint32 bits (N, V) -> f32 fitness (N,).
FfmStage = Callable[[jax.Array], jax.Array]


def _lfsr_draw(state, steps: int):
    """In-kernel LFSR-32 advance (paper polynomial r^32+r^22+r^2+1)."""
    s = state
    for _ in range(steps):
        fb = ((s >> 31) ^ (s >> 21) ^ (s >> 1) ^ s) & jnp.uint32(1)
        s = (s << 1) | fb
    return s


def _onehot_gather_u32(oh: jax.Array, x: jax.Array) -> jax.Array:
    """Exact uint32 gather via two 16-bit-half f32 matmuls on the MXU."""
    hi = (x >> 16).astype(jnp.float32)
    lo = (x & jnp.uint32(0xFFFF)).astype(jnp.float32)
    ghi = jax.lax.dot(oh, hi, precision=jax.lax.Precision.HIGHEST)
    glo = jax.lax.dot(oh, lo, precision=jax.lax.Precision.HIGHEST)
    return (ghi.astype(jnp.uint32) << 16) | glo.astype(jnp.uint32)


def _gen_best(x, y, cfg: GAConfig):
    """First-occurrence generation best — the reference scan's argmin/argmax
    tie rule, expressed MXU-style: the index is a min-reduction over a masked
    iota (no dynamic gather), the chromosome a one-hot matmul gather."""
    m = jnp.min(y) if cfg.minimize else jnp.max(y)
    iota = jax.lax.broadcasted_iota(jnp.int32, (cfg.n,), 0)
    idx = jnp.min(jnp.where(y == m, iota, cfg.n))
    oh = (iota == idx).astype(jnp.float32)[None, :]          # (1, N)
    return m, _onehot_gather_u32(oh, x)[0]                   # (V,)


def _kernel(x_ref, sel_ref, cross_ref, mut_ref,              # inputs
            *rest,                                           # consts + outputs
            cfg: GAConfig, ffm, const_shapes=(), gens: int = 1,
            track_best: bool = False):
    """One or MANY generations per launch.

    gens > 1 is the VMEM-residency optimization (EXPERIMENTS.md §Perf GA
    iter 2): the FPGA keeps population + LFSRs in registers between clock
    beats; we keep them in VMEM between generations, so HBM sees one state
    read + one write per `gens` generations instead of per generation.

    `rest` leads with one VMEM ref per FFM closure constant (arrays the
    user's fitness captured, hoisted by `jax.closure_convert` in
    `ga_generation_kernel` — Pallas kernels cannot capture array constants
    directly); `const_shapes` restores their original shapes.

    track_best=True adds two outputs (best_y, best_x) folding the running
    best individual *inside* the launch with the reference scan's strict
    improvement + first-occurrence tie rule — so a gens>1 launch loses no
    best-tracking fidelity, only per-generation trajectory resolution
    (y_out is the fitness of the LAST pre-update population)."""
    n_consts = len(const_shapes)
    const_refs, out_refs = rest[:n_consts], rest[n_consts:]
    if n_consts:
        consts = [r[0].reshape(s) for r, s in zip(const_refs, const_shapes)]
        ffm_stage = lambda x: ffm(x, *consts)
    else:
        ffm_stage = ffm
    if track_best:
        x_out, sel_out, cross_out, mut_out, y_out, by_out, bx_out = out_refs
    else:
        x_out, sel_out, cross_out, mut_out, y_out = out_refs

    def step(carry):
        x, sel, cross, mut, y = carry[:5]
        out = _one_generation(x, sel, cross, mut, y, cfg=cfg, ffm=ffm_stage)
        if track_best:
            by, bx = carry[5], carry[6]
            y2 = out[4]
            gb, gx = _gen_best(x, y2, cfg)   # y2 scores x (pre-update)
            better = gb < by if cfg.minimize else gb > by
            out = out + (jnp.where(better, gb, by),
                         jnp.where(better, gx, bx))
        return out

    init = (x_ref[0], sel_ref[0], cross_ref[0], mut_ref[0],
            jnp.zeros((cfg.n,), jnp.float32))
    if track_best:
        init = init + (jnp.float32(jnp.inf if cfg.minimize else -jnp.inf),
                       jnp.zeros((cfg.v,), jnp.uint32))
    if gens > 1:
        final = jax.lax.fori_loop(0, gens, lambda _, c: step(c), init)
    else:
        final = step(init)
    x_out[0], sel_out[0], cross_out[0], mut_out[0], y_out[0] = final[:5]
    if track_best:
        by_out[0], bx_out[0] = final[5], final[6]


def _one_generation(x, sel_in, cross_in, mut_in, _y_prev,
                    *, cfg: GAConfig, ffm: FfmStage):
    n, v, c = cfg.n, cfg.v, cfg.c
    var_mask = jnp.uint32((1 << c) - 1)

    # ---- FFM (pluggable traced stage: decode + problem expression, VPU) --
    y = jnp.asarray(ffm(x), jnp.float32)                  # (N,)

    # ---- SM: tournaments via one-hot MXU gathers --------------------------
    sel = _lfsr_draw(sel_in, cfg.steps_per_draw)          # (2, N)
    i1 = (sel[0] >> jnp.uint32(32 - cfg.idx_bits)).astype(jnp.int32)
    i2 = (sel[1] >> jnp.uint32(32 - cfg.idx_bits)).astype(jnp.int32)
    iota = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    oh1 = (iota == i1[:, None]).astype(jnp.float32)
    oh2 = (iota == i2[:, None]).astype(jnp.float32)
    y1 = jax.lax.dot(oh1, y[:, None], precision=jax.lax.Precision.HIGHEST)[:, 0]
    y2 = jax.lax.dot(oh2, y[:, None], precision=jax.lax.Precision.HIGHEST)[:, 0]
    first_wins = (y1 <= y2) if cfg.minimize else (y1 >= y2)
    ohw = jnp.where(first_wins[:, None], oh1, oh2)        # winner one-hot
    w = _onehot_gather_u32(ohw, x)                        # (N, V)

    # ---- CM: mask-shift single-point crossover ----------------------------
    cross = _lfsr_draw(cross_in, cfg.steps_per_draw)      # (V, N/2)
    cut = (cross >> jnp.uint32(32 - cfg.cut_bits)).astype(jnp.uint32)
    cut = jnp.minimum(cut, jnp.uint32(c))
    s = (var_mask >> cut).T                               # (N/2, V)
    wp = w.reshape(n // 2, 2, v)
    w1, w2 = wp[:, 0], wp[:, 1]
    z1 = (w1 & ~s) | (w2 & s)
    z2 = (w2 & ~s) | (w1 & s)
    z = jnp.stack([z1, z2], axis=1).reshape(n, v)

    # ---- MM: XOR-mutate the first P --------------------------------------
    mut = _lfsr_draw(mut_in, cfg.steps_per_draw)          # (V, N)
    rbits = (mut >> jnp.uint32(32 - c)).T                 # (N, V)
    mut_row = (jax.lax.broadcasted_iota(jnp.int32, (n, 1), 0) < cfg.p)
    x_new = jnp.where(mut_row, z ^ rbits, z)
    return x_new, sel, cross, mut, y


def ga_generation_kernel(x, sel, cross, mut, *, cfg: GAConfig,
                         ffm: FfmStage, interpret: bool = False,
                         gens: int = 1, track_best: bool = False
                         ) -> Tuple[jax.Array, ...]:
    """Launch the fused generation(s) over a stack of islands.

    x: uint32[I, N, V]; sel: uint32[I, 2, N]; cross: uint32[I, V, N//2];
    mut: uint32[I, V, N].  Returns (x', sel', cross', mut', y[I, N]).
    ffm: the traced FFM stage — uint32[N, V] -> f32[N] (normally
    `FitnessProgram.stage`; any traceable n-variable/blackbox objective).
    gens: generations per launch (VMEM-resident state between them).
    track_best appends (best_y[I], best_x[I, V]) — the running best over all
    `gens` in-kernel generations, reference tie rule (see `_kernel`).
    """
    assert cfg.n & (cfg.n - 1) == 0, "kernel path requires power-of-two N"
    assert cfg.n <= 1024, "one-hot (N,N) must fit VMEM; use islands for more"
    i_islands, n, v = x.shape
    assert (n, v) == (cfg.n, cfg.v)

    # Hoist any array constants the FFM stage closed over (decode bounds,
    # blackbox targets, ...) into explicit kernel inputs — Pallas kernels
    # cannot capture non-scalar constants.  `jax.closure_convert` only
    # hoists autodiff-perturbed consts, so we lower the stage to a jaxpr
    # ourselves and replay it inside the kernel with the consts re-read from
    # refs.  Every const rides in replicated (block index 0 on every grid
    # step), flattened to one 2-D (1, size) lane row for TPU friendliness
    # and reshaped back inside the kernel.
    closed = jax.make_jaxpr(lambda xx: jnp.asarray(ffm(xx), jnp.float32))(
        jax.ShapeDtypeStruct((n, v), jnp.uint32))
    ffm_consts = closed.consts
    ffm_conv = lambda xx, *cs: jax.core.eval_jaxpr(closed.jaxpr, cs, xx)[0]
    const_shapes = tuple(np.shape(c) for c in ffm_consts)
    flat_consts = [jnp.reshape(jnp.asarray(c), (1, max(int(np.size(c)), 1)))
                   for c in ffm_consts]

    blk = lambda *shape: pl.BlockSpec((1,) + shape, lambda i: (i,) + (0,) * len(shape))
    cblk = lambda k: pl.BlockSpec((1, k), lambda i: (0, 0))
    grid = (i_islands,)
    kernel = functools.partial(_kernel, cfg=cfg, ffm=ffm_conv,
                               const_shapes=const_shapes, gens=gens,
                               track_best=track_best)
    out_specs = [blk(n, v), blk(2, n), blk(v, n // 2), blk(v, n), blk(n)]
    out_shape = [
        jax.ShapeDtypeStruct((i_islands, n, v), jnp.uint32),
        jax.ShapeDtypeStruct((i_islands, 2, n), jnp.uint32),
        jax.ShapeDtypeStruct((i_islands, v, n // 2), jnp.uint32),
        jax.ShapeDtypeStruct((i_islands, v, n), jnp.uint32),
        jax.ShapeDtypeStruct((i_islands, n), jnp.float32),
    ]
    if track_best:
        out_specs += [blk(), blk(v)]
        out_shape += [jax.ShapeDtypeStruct((i_islands,), jnp.float32),
                      jax.ShapeDtypeStruct((i_islands, v), jnp.uint32)]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[blk(n, v), blk(2, n), blk(v, n // 2), blk(v, n)]
                 + [cblk(c.shape[1]) for c in flat_consts],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(x, sel, cross, mut, *flat_consts)
