"""Paper Figs. 11-12: convergence curves for F1 (N=32, m=26) and F3
(N=64, m=20), averaged over seeds; derived value = generations to reach the
paper's reported convergence point.

The per-seed replication is the engine's `n_repeats` batch mode — the
paper's Table 3 accuracy-study methodology (repeat the run R times, report
hit statistics) in ONE vmapped launch per problem."""

from __future__ import annotations

import time

import numpy as np

from repro import ga
from repro.core import fitness as F

R = 10   # seeds, vmapped into one scan


def _gens_to(traj, target):
    hit = np.nonzero(traj <= target)[0]
    return int(hit[0]) if len(hit) else -1


def run():
    rows = []
    # F1: global min at x=-4096
    t0 = time.perf_counter()
    target1 = float(F.F1.f(np.array([0.0, -4096.0]))) * 0.98
    spec1 = ga.paper_spec("F1", n=32, m=26, mode="lut", mutation_rate=0.05,
                          seed=0, generations=100, n_repeats=R)
    out1 = ga.solve(spec1, backend="reference")
    per_rep = out1.telemetry.per_repeat.traj_best / spec1.fitness_scale()
    gens = [_gens_to(per_rep[r], target1) for r in range(R)]
    ok = [g for g in gens if g >= 0]
    rows.append(("convergence_F1_N32_m26",
                 (time.perf_counter() - t0) * 1e5,
                 f"median_gens_to_min={int(np.median(ok)) if ok else -1},"
                 f"hit_rate={len(ok)}/{R}"))
    # F3
    t0 = time.perf_counter()
    spec3 = ga.paper_spec("F3", n=64, m=20, mode="arith", mutation_rate=0.05,
                          seed=0, generations=100, n_repeats=R)
    out3 = ga.solve(spec3, backend="reference")
    per_rep = out3.telemetry.per_repeat.traj_best
    gens = [_gens_to(per_rep[r], 1.0) for r in range(R)]
    ok = [g for g in gens if g >= 0]
    rows.append(("convergence_F3_N64_m20",
                 (time.perf_counter() - t0) * 1e5,
                 f"median_gens_to_near_zero={int(np.median(ok)) if ok else -1},"
                 f"hit_rate={len(ok)}/{R}"))
    return rows
