#!/usr/bin/env python
"""Benchmark regression gate for the engine backend matrix.

Compares a fresh `benchmarks.engine_backends --smoke` artifact against the
committed baseline and fails (exit 1) when any (topology × executor) combo
regressed by more than the tolerance:

    PYTHONPATH=src python -m benchmarks.engine_backends --smoke \
        --out artifacts/engine_backends.json
    python scripts/check_bench.py artifacts/engine_backends.json

A combo missing from the current artifact also fails — a silently dropped
backend is a coverage regression, not a speedup.  Combos are only compared
when their `devices` count matches (mesh rows scale with the host).

The committed baseline is seeded CONSERVATIVELY: pass SEVERAL artifacts
(collected across repeated runs, ideally including one on a loaded
machine) and --write-baseline keeps the per-combo MINIMUM gens/s scaled by
`SEED_MARGIN` — so machine-to-machine and run-to-run variance does not
trip the 30% gate.  Regenerate when a deliberate change shifts throughput:

    python scripts/check_bench.py run1.json run2.json run3.json \
        --write-baseline

Env overrides: CHECK_BENCH_TOLERANCE (float, default 0.30) and
CHECK_BENCH_SKIP=1 (escape hatch for pathological machines — prints a
warning, exits 0).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks", "baseline_engine_backends.json")
SEED_MARGIN = 0.5    # baseline = observed_min * SEED_MARGIN at --write-baseline


def load_rows(path: str) -> dict:
    with open(path) as f:
        rows = json.load(f)
    return {r["name"]: r for r in rows}


def _base_name(name: str) -> str:
    """Mesh rows embed the host's device count ('engine_islands@mesh8');
    strip it so rows recorded on differently-sized hosts still pair up."""
    return name.split("@mesh")[0] + ("@mesh" if "@mesh" in name else "")


def compare(current: dict, baseline: dict, tolerance: float):
    """Returns (failures, notes): failures are regressions/missing combos.

    gens/s is only compared between rows with equal `devices`; a combo
    whose device count differs from the baseline host's (mesh rows on a
    bigger machine) is noted and skipped, not failed — absolute throughput
    does not transfer across device counts.
    """
    failures, notes = [], []
    cur_bases = {_base_name(n) for n in current}
    for name, base in sorted(baseline.items()):
        cur = current.get(name)
        if cur is None:
            if _base_name(name) in cur_bases:
                notes.append(f"{name}: no row at {base.get('devices')} "
                             "device(s) on this host; skipping")
            else:
                failures.append(f"{name}: combo missing from current "
                                "artifact (was it dropped from the "
                                "registry?)")
            continue
        if cur.get("devices") != base.get("devices"):
            notes.append(f"{name}: device count changed "
                         f"({base.get('devices')} -> {cur.get('devices')}); "
                         "skipping gens/s comparison")
            continue
        floor = base["gens_per_s"] * (1.0 - tolerance)
        if cur["gens_per_s"] < floor:
            failures.append(
                f"{name}: {cur['gens_per_s']:.1f} gens/s < floor "
                f"{floor:.1f} (baseline {base['gens_per_s']:.1f}, "
                f"tolerance {tolerance:.0%})")
    for name in sorted(set(current) - set(baseline)):
        notes.append(f"{name}: new combo (no baseline yet)")
    return failures, notes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("artifacts", nargs="+",
                    help="engine_backends --smoke --out JSON(s); several "
                         "are min-merged per combo (use with "
                         "--write-baseline to seed from repeated runs)")
    ap.add_argument("--baseline", default=os.path.normpath(DEFAULT_BASELINE))
    ap.add_argument("--tolerance", type=float,
                    default=float(os.environ.get("CHECK_BENCH_TOLERANCE",
                                                 "0.30")),
                    help="allowed fractional gens/s drop per combo")
    ap.add_argument("--write-baseline", action="store_true",
                    help="(re)seed the baseline from the artifact "
                         f"(gens/s scaled by {SEED_MARGIN})")
    args = ap.parse_args()

    current: dict = {}
    for path in args.artifacts:
        for name, r in load_rows(path).items():
            if (name not in current
                    or r["gens_per_s"] < current[name]["gens_per_s"]):
                current[name] = r
    if args.write_baseline:
        rows = []
        for name, r in sorted(current.items()):
            rows.append({"name": name,
                         "gens_per_s": round(r["gens_per_s"] * SEED_MARGIN, 1),
                         "devices": r.get("devices", 1)})
        with open(args.baseline, "w") as f:
            json.dump(rows, f, indent=2)
            f.write("\n")
        print(f"wrote {args.baseline} ({len(rows)} combos, "
              f"margin {SEED_MARGIN})")
        return 0

    if os.environ.get("CHECK_BENCH_SKIP") == "1":
        print("check_bench: CHECK_BENCH_SKIP=1 — skipping regression gate")
        return 0

    baseline = load_rows(args.baseline)
    failures, notes = compare(current, baseline, args.tolerance)
    for n in notes:
        print(f"note: {n}")
    if failures:
        print(f"check_bench: {len(failures)} regression(s) vs "
              f"{args.baseline}:")
        for f_ in failures:
            print(f"  FAIL {f_}")
        return 1
    print(f"check_bench: OK — {len(baseline)} combos within "
          f"{args.tolerance:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
