"""Quickstart: the paper's parallel GA in five lines, then the same engine
as the framework's blackbox tuner.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import F1, F3, GAConfig, build_tables, evolve, run
from repro.core import ga as G


def main():
    # --- 1. Reproduce the paper's F1 experiment (Fig. 11): N=32, m=26 ----
    cfg = GAConfig(n=32, c=13, v=2, mutation_rate=0.05, seed=7, mode="lut")
    tables = build_tables(F1, m=26)
    out = run(cfg, G.make_lut_fitness(tables), k_generations=100)
    best = float(out.best_y) / 2.0 ** tables.frac_bits
    print(f"F1 best fitness after 100 generations: {best:.4g} "
          f"(global minimum ≈ -6.897e10)")
    print(f"decoded solution: {G.decode_best(out, cfg, F1.domain)}")

    # --- 2. F3 with the TPU-native arithmetic fitness (Fig. 12) ----------
    cfg3 = GAConfig(n=64, c=10, v=2, mutation_rate=0.05, seed=3, mode="arith")
    out3 = run(cfg3, G.fitness_for_problem(F3, cfg3), 100)
    print(f"F3 best: {float(out3.best_y):.4f} (optimum 0)")

    # --- 3. The GA as a tuning service: minimize a 4-var blackbox --------
    target = jnp.array([0.5, -1.0, 2.0, 0.0])

    def objective(p):          # (N, 4) -> (N,)
        return jnp.sum((p - target) ** 2, axis=-1)

    r = evolve(objective, bounds=[(-4, 4)] * 4, population=128,
               generations=200, mutation_rate=0.05, seed=0)
    print(f"evolve() found {np.round(r.best_params, 3)} "
          f"(target {np.asarray(target)}) fitness={r.best_fitness:.2e}")


if __name__ == "__main__":
    main()
