"""`repro.ga` backend matrix: generations/sec per backend on one spec.

One canonical spec (F3, N=64, m=20, arith) runs through every registered
backend; the derived column is a JSON object so downstream tooling can
scrape per-backend throughput.  The islands row uses 8 islands (total
chromosome throughput is islands × gens/s); on CPU the fused row runs the
Pallas kernel in interpret mode, so its absolute number only means something
on TPU.
"""

from __future__ import annotations

import dataclasses
import json

from benchmarks.ga_common import time_call
from repro import ga

K = 100
N_ISLANDS = 8


def run():
    base = ga.paper_spec("F3", n=64, m=20, mode="arith", mutation_rate=0.02,
                         seed=1, generations=K)
    rows = []
    for backend in sorted(ga.BACKENDS):
        spec = base if backend != "islands" else \
            dataclasses.replace(base, n_islands=N_ISLANDS)
        eng = ga.Engine(spec, backend)
        out = eng.run()           # compile + warm caches
        iters = 1 if backend in ("fused", "eager") else 3  # interpret is slow
        dt, out = time_call(eng.run, warmup=0, iters=iters)
        gens = out.generations * max(spec.n_islands, spec.n_repeats)
        payload = json.dumps({"backend": out.backend,
                              "gens_per_s": round(gens / dt, 1),
                              "best": round(out.best_fitness, 4),
                              "n": spec.n,
                              "islands": spec.n_islands},
                             separators=(",", ":"))
        # islands rounds K up to whole migration epochs — divide by what ran
        rows.append((f"engine_{backend}", dt / out.generations * 1e6, payload))
    return rows
