"""Batched serving engine: prefill + decode steps with slot-based batching.

A fixed batch of `slots` runs lock-step decode (the shape the decode_32k /
long_500k dry-run cells lower).  A light continuous-batching layer refills
finished slots from a request queue between decode bursts — enough to drive
realistic serving benchmarks without an RPC stack.

The serving stack also fronts the GA engine as a tuning service: `run_ga_job`
drives `repro.ga.Engine.run_chunked` under a job id and aggregates its
per-chunk telemetry (generations/s, best-fitness trajectory, migration
count) into `GA_METRICS`, whose `metrics()` snapshot is the /metrics-style
dict a scrape endpoint would serialize.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import common as C
from repro.models import lm as LM


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 32
    out_tokens: Optional[List[int]] = None


@dataclasses.dataclass
class EngineConfig:
    batch: int = 8
    max_len: int = 512
    greedy: bool = True
    temperature: float = 1.0


class Engine:
    """Slot-based batched generation over (prefill, decode_step)."""

    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig,
                 max_seq: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self._prefill = jax.jit(
            lambda p, t, c, **kw: LM.prefill(p, cfg, t, c, **kw))
        self._decode = jax.jit(
            lambda p, t, c: LM.decode_step(p, cfg, t, c))
        self._cache_defs = LM.cache_defs(cfg, ecfg.batch, ecfg.max_len)

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.ecfg.greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.ecfg.temperature).astype(jnp.int32)

    def generate(self, prompts: np.ndarray, max_new_tokens: int = 32,
                 frames=None, patches=None, seed: int = 0
                 ) -> Tuple[np.ndarray, Dict[str, float]]:
        """Lock-step generation. prompts: (B, S) int32. Returns tokens + stats."""
        b, s = prompts.shape
        assert b == self.ecfg.batch
        cache = C.init_params(self._cache_defs, jax.random.key(0))
        t0 = time.perf_counter()
        kw = {}
        if frames is not None:
            kw["frames"] = frames
        if patches is not None:
            kw["patches"] = patches
        logits, cache = self._prefill(self.params, jnp.asarray(prompts),
                                      cache, **kw)
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0

        key = jax.random.key(seed)
        tok = self._sample(logits, key)[:, None]
        out = [tok]
        t1 = time.perf_counter()
        for i in range(max_new_tokens - 1):
            logits, cache = self._decode(self.params, tok, cache)
            key, sub = jax.random.split(key)
            tok = self._sample(logits, sub)[:, None]
            out.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.perf_counter() - t1
        tokens = np.asarray(jnp.concatenate(out, axis=1))
        return tokens, {
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "decode_tok_per_s": b * (max_new_tokens - 1) / max(t_decode, 1e-9),
        }


def serve_queue(engine: Engine, requests: List[Request],
                max_new_tokens: int = 16) -> Dict[int, np.ndarray]:
    """Minimal continuous batching: group requests into engine-sized batches,
    refilling from the queue as batches finish."""
    q: "queue.Queue[Request]" = queue.Queue()
    for r in requests:
        q.put(r)
    results: Dict[int, np.ndarray] = {}
    bsz = engine.ecfg.batch
    while not q.empty():
        batch: List[Request] = []
        while len(batch) < bsz and not q.empty():
            batch.append(q.get())
        while len(batch) < bsz:           # pad with a copy of the last req
            batch.append(batch[-1])
        slen = max(len(r.prompt) for r in batch)
        prompts = np.zeros((bsz, slen), np.int32)
        for i, r in enumerate(batch):
            prompts[i, -len(r.prompt):] = r.prompt
        toks, _ = engine.generate(prompts, max_new_tokens)
        for i, r in enumerate(batch):
            if r.uid not in results:
                results[r.uid] = toks[i]
    return results


# ---------------------------------------------------------------------------
# GA job telemetry (Engine.run_chunked -> /metrics-style dicts)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GAJobStats:
    """Aggregated `repro.ga.Engine.run_chunked` telemetry for one job."""

    job_id: str
    backend: str = "?"
    problem: str = "?"               # registry name or "blackbox"
    n_vars: int = 0                  # decoded variable count V
    # pending | queued | running | preempted | done | failed
    status: str = "pending"
    gens_done: int = 0
    gens_total: int = 0
    chunks: int = 0
    best_fitness: Optional[float] = None
    best_trajectory: List[float] = dataclasses.field(default_factory=list)
    migrations: int = 0
    islands: int = 1                 # populations evolving concurrently
    shards: int = 1                  # mesh shards the island axis spans
    wall_s: float = 0.0
    error: Optional[str] = None
    priority: int = 0                # scheduler priority (higher preempts)
    preemptions: int = 0             # times the scheduler parked this job
    retries: int = 0                 # scheduler retry dispatches of this job
    deadline_s: Optional[float] = None   # wall budget (None = unbounded)
    quarantined: bool = False        # failed as the isolated poison job
    pack_size: int = 1               # jobs sharing the launch it ran in
    epoch_mode: str = "-"            # resident | streamed | gridded | ...
    plan_source: str = "-"           # heuristic | measured | forced
    plan_fallback: Optional[str] = None   # why resident modes were infeasible
    tile_islands: Optional[int] = None    # streamed mode's island tile size
    sel_lane: str = "-"              # fused tournament lane: onehot | gather

    @property
    def gens_per_s(self) -> float:
        return self.gens_done / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def gens_per_s_per_shard(self) -> float:
        """Island-generations/s each mesh shard contributes (the scaling
        headline: flat per-shard throughput == linear total speedup)."""
        return self.gens_per_s * self.islands / max(self.shards, 1)

    def as_metrics(self) -> Dict[str, Any]:
        """Flat dict the /metrics endpoint of a GA job would serialize."""
        return {
            "job_id": self.job_id,
            "backend": self.backend,
            "problem": self.problem,
            "n_vars": self.n_vars,
            "status": self.status,
            "generations_done": self.gens_done,
            "generations_total": self.gens_total,
            "chunks": self.chunks,
            "generations_per_s": round(self.gens_per_s, 2),
            "islands": self.islands,
            "shards": self.shards,
            "generations_per_s_per_shard": round(self.gens_per_s_per_shard, 2),
            "best_fitness": self.best_fitness,
            "best_fitness_trajectory": list(self.best_trajectory),
            "migration_count": self.migrations,
            "wall_s": round(self.wall_s, 4),
            "error": self.error,
            "priority": self.priority,
            "preemptions": self.preemptions,
            "retries": self.retries,
            "deadline_s": self.deadline_s,
            "quarantined": self.quarantined,
            "pack_size": self.pack_size,
            "epoch_mode": self.epoch_mode,
            "plan_source": self.plan_source,
            "plan_fallback": self.plan_fallback,
            "tile_islands": self.tile_islands,
            "sel_lane": self.sel_lane,
        }


class GAMetricsRegistry:
    """Thread-safe per-job telemetry aggregation for GA runs.

    Feed it `run_chunked` telemetry dicts via `record_chunk`; scrape the
    whole registry with `metrics()` (every job keyed by id, plus fleet
    totals), the shape a /metrics handler returns as JSON.  Every mutation
    and snapshot holds the registry lock — the scheduler records chunks
    from its worker thread while HTTP handler threads scrape and stream.

    Streaming: `subscribe(job_id)` returns a Queue that receives every
    subsequent `record_chunk` telemetry dict for that job plus a final
    `{"event": "end", ...}` marker from `finish_job` — the feed the
    metrics_http SSE/long-poll endpoints drain.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._jobs: Dict[str, GAJobStats] = {}
        self._next_id = 0
        self._subs: Dict[str, List["queue.Queue"]] = {}
        self._scheduler_stats: Optional[Any] = None   # callable -> dict

    def allocate_job_id(self, suffix: str = "job") -> str:
        """A unique job id, safe under concurrent `run_ga_job` calls."""
        with self._lock:
            jid = f"ga-{self._next_id}-{suffix}"
            self._next_id += 1
            return jid

    def ensure_next_id(self, n: int) -> None:
        """Bump the id counter to at least `n` — a recovering scheduler
        calls this so fresh ids never collide with journaled ones."""
        with self._lock:
            self._next_id = max(self._next_id, int(n))

    def start_job(self, job_id: str, backend: str = "?",
                  gens_total: int = 0, problem: str = "?",
                  n_vars: int = 0) -> GAJobStats:
        """Mark a job running.  Upserts: a job the scheduler queued (or
        preempted and re-dispatched) keeps its accumulated stats."""
        with self._lock:
            job = self._jobs.get(job_id)
            if job is None:
                job = GAJobStats(job_id=job_id)
                self._jobs[job_id] = job
            job.backend = backend if backend != "?" else job.backend
            job.problem = problem if problem != "?" else job.problem
            job.n_vars = n_vars or job.n_vars
            job.gens_total = gens_total or job.gens_total
            job.status = "running"
            return job

    def queue_job(self, job_id: str, problem: str = "?", gens_total: int = 0,
                  n_vars: int = 0, priority: int = 0,
                  deadline_s: Optional[float] = None) -> GAJobStats:
        """Register a scheduler-owned job in the QUEUED state."""
        with self._lock:
            job = GAJobStats(job_id=job_id, problem=problem, n_vars=n_vars,
                             gens_total=gens_total, status="queued",
                             priority=priority, deadline_s=deadline_s)
            self._jobs[job_id] = job
            return job

    def set_status(self, job_id: str, status: str) -> None:
        """Move a job between scheduler states (queued/running/preempted)."""
        with self._lock:
            job = self._jobs[job_id]
            if status == "preempted" and job.status != "preempted":
                job.preemptions += 1
            job.status = status

    def note_retry(self, job_id: str) -> None:
        """Count one scheduler retry dispatch against the job."""
        with self._lock:
            self._jobs[job_id].retries += 1

    def record_chunk(self, job_id: str, tele: Dict[str, Any]) -> None:
        """Fold one `Engine.run_chunked` telemetry dict into the job."""
        with self._lock:
            job = self._jobs[job_id]
            job.backend = tele.get("backend", job.backend)
            job.problem = tele.get("problem", job.problem)
            job.n_vars = int(tele.get("n_vars", job.n_vars))
            job.gens_done = int(tele.get("gens_done", job.gens_done))
            job.gens_total = int(tele.get("gens_total", job.gens_total))
            job.chunks += 1
            job.wall_s += float(tele.get("wall_s", 0.0))
            job.migrations = int(tele.get("migrations", job.migrations))
            job.pack_size = int(tele.get("pack_size", job.pack_size))
            rt = tele.get("telemetry")
            if rt is not None:
                job.islands = rt.topology.n_islands
                job.shards = rt.topology.n_shards
                if rt.plan.mode != "-":
                    job.epoch_mode = rt.plan.mode
                    job.plan_source = rt.plan.source
                    job.tile_islands = rt.plan.tile_islands
                    job.sel_lane = rt.plan.lane
                    job.plan_fallback = rt.plan.fallback or job.plan_fallback
            bf = tele.get("best_fitness")
            if bf is not None:
                job.best_fitness = float(bf)
                job.best_trajectory.append(float(bf))
            subs = list(self._subs.get(job_id, ()))
        event = {"event": "chunk", "job_id": job_id}
        event.update({k: v for k, v in tele.items()
                      if k not in ("telemetry", "extras", "best_params",
                                   "traj_best")})
        for q in subs:
            q.put(event)

    def finish_job(self, job_id: str, error: Optional[str] = None,
                   status: Optional[str] = None,
                   quarantined: bool = False) -> None:
        """Terminal transition.  `status` overrides the default
        failed/done mapping (the scheduler passes "deadline_exceeded");
        `quarantined` marks a poison job isolated by pack splitting."""
        with self._lock:
            job = self._jobs[job_id]
            job.status = status or ("failed" if error else "done")
            job.error = error
            job.quarantined = job.quarantined or quarantined
            subs = list(self._subs.get(job_id, ()))
            end = {"event": "end", "job_id": job_id, "status": job.status,
                   "best_fitness": job.best_fitness, "error": error}
        for q in subs:
            q.put(end)

    def abort_streams(self, reason: str) -> None:
        """Push an aborted end-sentinel to every subscriber of a
        non-terminal job — the worker thread died or the scheduler shut
        down, so those chunk feeds will never produce an organic end event
        and blocked `stream()` / SSE clients must be released."""
        with self._lock:
            targets = []
            for jid, subs in self._subs.items():
                job = self._jobs.get(jid)
                if job is not None and job.status in (
                        "done", "failed", "deadline_exceeded"):
                    continue
                targets.extend((q, jid) for q in subs)
        for q, jid in targets:
            q.put({"event": "end", "job_id": jid, "status": "aborted",
                   "error": reason})

    def evict_job(self, job_id: str) -> bool:
        """Drop a finished job's stats and any stale subscriber queues (the
        scheduler's TTL GC calls this).  Returns False if already gone."""
        with self._lock:
            gone = self._jobs.pop(job_id, None)
            self._subs.pop(job_id, None)
            return gone is not None

    # ---- streaming ------------------------------------------------------

    def subscribe(self, job_id: str) -> "queue.Queue":
        """A Queue fed every future chunk event (and the end marker) for
        `job_id`.  Pair with `unsubscribe` when the client disconnects."""
        q: "queue.Queue" = queue.Queue()
        with self._lock:
            self._subs.setdefault(job_id, []).append(q)
        return q

    def unsubscribe(self, job_id: str, q: "queue.Queue") -> None:
        with self._lock:
            subs = self._subs.get(job_id)
            if subs and q in subs:
                subs.remove(q)
                if not subs:
                    del self._subs[job_id]

    # ---- scheduler gauges ----------------------------------------------

    def attach_scheduler_stats(self, stats_fn) -> None:
        """Register a zero-arg callable returning scheduler gauges
        (queue depth, jobs running, compile-cache counters); its dict rides
        into every `metrics()` snapshot under "scheduler"."""
        with self._lock:
            self._scheduler_stats = stats_fn

    def metrics(self) -> Dict[str, Any]:
        """The /metrics snapshot: every job + fleet aggregates."""
        with self._lock:
            jobs = {jid: j.as_metrics() for jid, j in self._jobs.items()}
            stats_fn = self._scheduler_stats
        by_status = {}
        for j in jobs.values():
            by_status[j["status"]] = by_status.get(j["status"], 0) + 1
        snap = {
            "jobs": jobs,
            "job_count": len(jobs),
            "jobs_done": by_status.get("done", 0),
            "jobs_running": by_status.get("running", 0),
            "jobs_queued": by_status.get("queued", 0),
            "jobs_preempted": by_status.get("preempted", 0),
            "jobs_failed": by_status.get("failed", 0),
            "jobs_deadline_exceeded": by_status.get("deadline_exceeded", 0),
            "generations_total": sum(j["generations_done"]
                                     for j in jobs.values()),
            "migrations_total": sum(j["migration_count"]
                                    for j in jobs.values()),
        }
        if stats_fn is not None:
            try:
                snap["scheduler"] = dict(stats_fn())
            except Exception:      # a dying scheduler must not kill scrapes
                pass
        return snap

    def reset(self) -> None:
        with self._lock:
            self._jobs.clear()
            self._subs.clear()
            self._scheduler_stats = None


GA_METRICS = GAMetricsRegistry()


def run_ga_job(spec, backend: str = "auto", *, job_id: Optional[str] = None,
               chunk_generations: Optional[int] = None,
               ckpt_dir: Optional[str] = None,
               registry: Optional[GAMetricsRegistry] = None,
               mesh=None, options=None) -> Dict[str, Any]:
    """Run a GASpec as a telemetered serving job.

    Streams `Engine.run_chunked` into the registry so a concurrent /metrics
    scrape sees live generations/s, the best-fitness trajectory and the
    migration count.  Returns the job's final metrics dict.
    """
    from repro import ga   # lazy: LM-only servers never pay the import

    registry = registry if registry is not None else GA_METRICS
    if job_id is None:
        job_id = registry.allocate_job_id(spec.problem or "blackbox")
    if options is not None:
        eng = ga.Engine(spec, backend, options=options)
    else:
        eng = ga.Engine(spec, backend, mesh=mesh)
    registry.start_job(job_id, backend=eng.backend_name,
                       gens_total=spec.generations,
                       problem=spec.problem or "blackbox", n_vars=spec.v)
    try:
        for tele in eng.run_chunked(chunk_generations=chunk_generations,
                                    ckpt_dir=ckpt_dir):
            registry.record_chunk(job_id, tele)
    except Exception as e:   # surface the failure in /metrics, then re-raise
        registry.finish_job(job_id, error=repr(e))
        raise
    registry.finish_job(job_id)
    return registry.metrics()["jobs"][job_id]
