"""pixtral-12b backbone — mistral-nemo-style decoder + ViT patch prefix
[hf:mistralai/Pixtral-12B-2409; unverified].  The vision tower is a STUB:
input_specs() provides precomputed (B, 1024, 5120) patch embeddings that are
prepended to the token sequence."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=131072, rope_theta=1_000_000.0, n_patches=1024,
)
