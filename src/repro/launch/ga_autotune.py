"""Autotune launcher — measure epoch-plan costs and persist the table.

    # sweep the default shapes into the per-host cache
    PYTHONPATH=src python -m repro.launch.ga_autotune

    # a wider sweep, written to an explicit file for CI / sharing
    PYTHONPATH=src python -m repro.launch.ga_autotune \
        --problems F3,rastrigin:4 --islands 8 --gens-per-epoch 16,32,64 \
        --out artifacts/cost_table.json

For every (problem, gens_per_epoch, migration) shape this times each
feasible epoch mode — gridded, resident, resident-sharded (with --mesh),
resident-free (migration=none), streamed (when the resident stack exceeds
the VMEM budget; `--vmem-budget` forces that on small shapes) — by forcing
it with `plan_override` and
replaying segments until the timing is stable.  The resulting
`repro.autotune.CostTable` is what `Engine(..., cost_table=...)`, the
serving scheduler and the benchmarks consume: among VMEM-feasible modes
the planner then picks best *measured* gens/s instead of the static
heuristic.  By default the table lands in the per-host cache
(`repro.autotune.default_table_path()`), where every later engine in this
environment discovers it automatically; `--merge` folds the new points
into an existing table instead of replacing it.
"""

from __future__ import annotations

import argparse


def build_specs(problems, *, n, bits_per_var, n_islands, migrate_every,
                gens_per_epoch, migrations, seed=1):
    """The sweep grid: one GASpec per (problem, gpe, migration) point."""
    from repro import ga
    specs = []
    for prob in problems:
        for gpe in gens_per_epoch:
            for migration in migrations:
                specs.append(ga.GASpec(
                    problem=prob, n=n, bits_per_var=bits_per_var,
                    mode="arith", seed=seed, generations=gpe,
                    n_islands=n_islands, migrate_every=migrate_every,
                    gens_per_epoch=gpe, migration=migration))
    return specs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--problems", default="F3,rastrigin:4",
                    help="comma list of registered problems to sweep")
    ap.add_argument("--n", type=int, default=32, help="population per island")
    ap.add_argument("--m", type=int, default=20,
                    help="chromosome bits (c = m/2 bits per variable)")
    ap.add_argument("--islands", type=int, default=8)
    ap.add_argument("--migrate-every", type=int, default=16)
    ap.add_argument("--gens-per-epoch", default="16,32",
                    help="comma list of epoch folds to measure")
    ap.add_argument("--migration", default="both",
                    choices=["ring", "none", "both"],
                    help="which migration regimes to cover (none adds the "
                         "resident-free mode to the sweep)")
    ap.add_argument("--backend", default="fused-islands")
    ap.add_argument("--mesh", default=None,
                    help="also measure sharded plans: 'auto', '4', '2x4', ...")
    ap.add_argument("--reps", type=int, default=8,
                    help="max replay repetitions per candidate")
    ap.add_argument("--cov", type=float, default=0.25,
                    help="coefficient-of-variation stability threshold")
    ap.add_argument("--out", default=None,
                    help="table path (default: the per-host cache file)")
    ap.add_argument("--merge", action="store_true",
                    help="fold new points into an existing table at --out "
                         "instead of replacing it")
    ap.add_argument("--seed", type=int, default=1)
    from repro.ga.options import EngineOptions
    EngineOptions.add_cli_args(ap)   # --vmem-budget etc. (the sweep itself
    args = ap.parse_args()           # forces cost_table/plan_override)

    from repro.autotune import (CostTable, default_table_path,
                                host_fingerprint, sweep)

    problems = [p for p in args.problems.split(",") if p]
    gpes = [int(g) for g in args.gens_per_epoch.split(",")]
    migrations = (["ring", "none"] if args.migration == "both"
                  else [args.migration])
    specs = build_specs(problems, n=args.n, bits_per_var=args.m // 2,
                        n_islands=args.islands,
                        migrate_every=args.migrate_every,
                        gens_per_epoch=gpes, migrations=migrations,
                        seed=args.seed)

    mesh = None
    if args.mesh:
        from repro.launch.mesh import parse_mesh
        mesh = parse_mesh(args.mesh)
        print(f"mesh: {dict(mesh.shape)} ({mesh.devices.size} device(s))")

    out = args.out or default_table_path()
    table = None
    if args.merge:
        table = CostTable.load(out)
        if table is not None:
            print(f"merging into {len(table)} existing point(s) from {out}")
    if table is None:
        table = CostTable(host=host_fingerprint())

    options = EngineOptions.from_args(args, mesh=mesh)
    print(f"sweeping {len(specs)} spec(s) x feasible modes "
          f"(backend={args.backend})")
    sweep(specs, backend=args.backend, options=options, table=table,
          max_reps=args.reps, cov_threshold=args.cov, log=print)
    table.save(out)
    print(f"wrote {len(table)} measured point(s) -> {out}")
    print("engines discover it automatically when this is the per-host "
          "cache; otherwise set REPRO_GA_COST_TABLE or pass cost_table=.")


if __name__ == "__main__":
    main()
