"""The autotune sweep: measure each feasible epoch-plan candidate.

For every spec in a sweep, the runner builds a probe engine (cost table
DISABLED, so measurement never depends on prior measurements), asks the
island topology for its feasible plan candidates — the exact list the
planner itself enumerates, so table points and planner queries can never
drift apart — then times each candidate by forcing it with
`plan_override` and replaying one `segment` worth of generations until
the timing is stable (`stability.replay_until_stable`).  Results land in
a `table.CostTable` keyed by `compile_cache.plan_point`.

This module imports `repro.ga` (and through it jax) lazily inside
functions: `repro.autotune.table` must stay importable from
`ga/backends.py` without a cycle.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterable, List, Optional

from repro.autotune.stability import Replay, replay_until_stable
from repro.autotune.table import CostTable, host_fingerprint


def _probe_options(options, *, mesh, interpret, plan_override=None,
                   sel_lane=None):
    """The engine options a probe runs under: the caller's base options
    (or legacy mesh/interpret kwargs) with the cost table DISABLED — a
    measurement must never depend on prior measurements — and optionally
    one mode and/or selection lane forced."""
    from repro.ga.options import resolve_options
    base = resolve_options(options, mesh=mesh, interpret=interpret)
    if sel_lane is None:
        sel_lane = base.sel_lane
    return dataclasses.replace(base, cost_table=False,
                               plan_override=plan_override,
                               sel_lane=sel_lane)


def sweep_lanes(spec) -> List[str]:
    """The selection lanes an autotune sweep should measure for `spec`: a
    pinned lane measures alone; "auto" measures every lane the fused
    kernels could legally run (onehot under its N cap, gather on any
    power-of-two N), so the planner's cross-lane argmax has real data on
    both sides."""
    from repro.core.ga import ONEHOT_MAX_N
    if spec.sel_lane != "auto":
        return [spec.sel_lane]
    lanes = []
    if spec.n <= ONEHOT_MAX_N:
        lanes.append("onehot")
    if spec.n & (spec.n - 1) == 0:
        lanes.append("gather")
    return lanes or [spec.resolved_sel_lane]


def plan_candidates(spec, *, backend: str = "auto", mesh=None,
                    interpret: Optional[bool] = None,
                    options=None, sel_lane=None) -> List[Dict[str, Any]]:
    """The feasible epoch-plan candidates an engine for `spec` would weigh
    (heuristic choice first), or [] for backends with no island planner.
    `sel_lane` forces the probe's selection lane (the candidates carry it
    in their "lane" field)."""
    from repro import ga
    eng = ga.Engine(spec, backend,
                    options=_probe_options(options, mesh=mesh,
                                           interpret=interpret,
                                           sel_lane=sel_lane))
    topo = getattr(eng.backend, "topology", None)
    if topo is None or not hasattr(topo, "epoch_candidates"):
        return []
    return topo.epoch_candidates()


def measure_candidate(spec, mode: str, *, backend: str = "auto", mesh=None,
                      interpret: Optional[bool] = None, options=None,
                      sel_lane: Optional[str] = None,
                      warmup: int = 1, min_reps: int = 3, max_reps: int = 8,
                      cov_threshold: float = 0.25,
                      timer: Callable[[], float] = time.perf_counter,
                      ) -> Dict[str, Any]:
    """Force one epoch mode via plan_override (and optionally one selection
    lane) and time a segment of `gens_per_epoch` generations until
    replay-stable.  Returns the table row: {"point", "gens_per_launch",
    "gens_per_s", "replay"}."""
    import jax
    from repro import ga
    from repro.ga import compile_cache as CC

    eng = ga.Engine(spec, backend,
                    options=_probe_options(options, mesh=mesh,
                                           interpret=interpret,
                                           plan_override=mode,
                                           sel_lane=sel_lane))
    topo = eng.backend.topology
    state = eng.init_state()
    seg_gens = max(spec.gens_per_epoch, spec.migrate_every)

    def once():
        seg = eng.backend.segment(state, seg_gens)
        jax.block_until_ready(jax.tree_util.tree_leaves(seg.state))
        return seg

    first = once()          # also the compile warmup for replay's counter
    replay = replay_until_stable(
        once, warmup=max(0, warmup - 1), min_reps=min_reps,
        max_reps=max_reps, cov_threshold=cov_threshold, timer=timer)
    point = CC.plan_point(spec, executor=topo.executor.name,
                          mode=topo.plan["mode"], n_shards=topo.n_shards,
                          lane=topo.plan.get("lane"))
    return {"point": point,
            "gens_per_launch": topo.plan["gens_per_launch"],
            "gens_per_s": first.gens / replay.mean_s,
            "replay": replay}


def sweep(specs: Iterable, *, backend: str = "auto", mesh=None,
          interpret: Optional[bool] = None, options=None,
          table: Optional[CostTable] = None,
          warmup: int = 1, min_reps: int = 3, max_reps: int = 8,
          cov_threshold: float = 0.25,
          timer: Callable[[], float] = time.perf_counter,
          log: Optional[Callable[[str], None]] = None) -> CostTable:
    """Measure every feasible candidate of every spec into one CostTable
    (reuses `table` when given, so sweeps accumulate across invocations).
    An `options` carrying vmem_budget makes the streamed lane feasible on
    small shapes, so its cost gets measured too."""
    table = CostTable(host=host_fingerprint()) if table is None else table
    for spec in specs:
        measured_keys = set()
        for lane in sweep_lanes(spec):
            cands = plan_candidates(spec, backend=backend, mesh=mesh,
                                    interpret=interpret, options=options,
                                    sel_lane=lane)
            if not cands:
                if log:
                    log(f"skip {spec.problem or 'blackbox'}: no island "
                        f"planner for backend {backend!r}")
                continue
            for cand in cands:
                row = measure_candidate(
                    spec, cand["mode"], backend=backend, mesh=mesh,
                    interpret=interpret, options=options, sel_lane=lane,
                    warmup=warmup, min_reps=min_reps, max_reps=max_reps,
                    cov_threshold=cov_threshold, timer=timer)
                # a lane-forced probe that fell back to a non-fused executor
                # produces the same point for every lane — measure it once
                key = (tuple(sorted(row["point"].items())),
                       row["gens_per_launch"])
                if key in measured_keys:
                    continue
                measured_keys.add(key)
                rep: Replay = row["replay"]
                table.add(row["point"], row["gens_per_launch"],
                          row["gens_per_s"], reps=rep.reps, cov=rep.cov)
                if log:
                    stable = "stable" if rep.stable else "UNSTABLE"
                    log(f"  {spec.problem or 'blackbox'} n={spec.n} "
                        f"I={spec.n_islands} gpe={spec.gens_per_epoch} "
                        f"{cand['mode']:>16}/{cand.get('lane', '?')}: "
                        f"{row['gens_per_s']:9.1f} gens/s "
                        f"({rep.reps} reps, cov={rep.cov:.3f}, {stable})")
    return table


def estimate_gens_per_s(spec, table: Optional[CostTable], *,
                        backend: str = "auto", mesh=None,
                        interpret: Optional[bool] = None,
                        options=None) -> Optional[float]:
    """What the measured planner expects for `spec` under `table` — the
    chosen plan's measured gens/s, or None when the table does not cover
    the spec (scheduler ordering treats those jobs as unknown-length)."""
    if table is None:
        return None
    from repro import ga
    from repro.ga.options import resolve_options
    try:
        opts = resolve_options(options, mesh=mesh, interpret=interpret)
        eng = ga.Engine(spec, backend,
                        options=dataclasses.replace(opts, cost_table=table))
    except Exception:
        return None
    plan = getattr(getattr(eng.backend, "topology", None), "plan", None)
    if not plan:
        return None
    return plan.get("plan_gens_per_s")
