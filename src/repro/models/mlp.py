"""Feed-forward blocks: gated SiLU (llama family) and plain GELU (whisper)."""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro import sharding as SH
from repro.models import common as C


def gated_defs(d_model: int, d_ff: int) -> Dict[str, C.ParamDef]:
    return {
        "w_gate": C.ParamDef((d_model, d_ff), ("embed", "mlp")),
        "w_up": C.ParamDef((d_model, d_ff), ("embed", "mlp")),
        "w_down": C.ParamDef((d_ff, d_model), ("mlp", "embed")),
    }


def gated_forward(p, x: jax.Array, act: str = "silu") -> jax.Array:
    g = C.dense(x, p["w_gate"])
    u = C.dense(x, p["w_up"])
    g = SH.constrain(g, "batch", None, "mlp")
    a = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g, approximate=True)
    return C.dense((a * u).astype(x.dtype), p["w_down"])


def plain_defs(d_model: int, d_ff: int) -> Dict[str, C.ParamDef]:
    return {
        "w_in": C.ParamDef((d_model, d_ff), ("embed", "mlp")),
        "b_in": C.ParamDef((d_ff,), ("mlp",), init="zeros"),
        "w_out": C.ParamDef((d_ff, d_model), ("mlp", "embed")),
        "b_out": C.ParamDef((d_model,), ("embed",), init="zeros"),
    }


def plain_forward(p, x: jax.Array) -> jax.Array:
    h = C.dense(x, p["w_in"], p["b_in"])
    h = SH.constrain(h, "batch", None, "mlp")
    h = jax.nn.gelu(h, approximate=True).astype(x.dtype)
    return C.dense(h, p["w_out"], p["b_out"])
