"""Fault-tolerant training loop.

Production behaviors implemented (and exercised by tests/examples):
  * auto-resume: on start, restore the latest valid checkpoint (params,
    optimizer state, data step) and continue bit-exactly (data pipeline is
    step-indexed, so batch k after restart == batch k before the crash);
  * async checkpointing every `ckpt_every` steps (serialization off-thread);
  * preemption safety: SIGTERM/SIGINT triggers a final synchronous save;
  * straggler watchdog: an EMA of step time flags steps slower than
    `watchdog_factor`× the average — on a real pod this feeds the controller
    that evicts/replaces the slow host; here it logs + counts;
  * elastic restart: restore() re-shards to the active mesh, so the same
    checkpoint resumes on a different device count (see tests/test_ckpt.py).
"""

from __future__ import annotations

import dataclasses
import os
import signal
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding as SH
from repro.ckpt import checkpoint as CKPT
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, DataIterator
from repro.models import common as C
from repro.models import lm as LM
from repro.optim import adamw as OPT
from repro.train import step as TS


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    resume: bool = True
    watchdog_factor: float = 3.0
    seed: int = 0


class Watchdog:
    """EMA step-time straggler detector."""

    def __init__(self, factor: float):
        self.factor = factor
        self.ema: Optional[float] = None
        self.events = 0

    def observe(self, dt: float) -> bool:
        slow = self.ema is not None and dt > self.factor * self.ema
        if slow:
            self.events += 1
        self.ema = dt if self.ema is None else 0.9 * self.ema + 0.1 * dt
        return slow


def train(model_cfg: ModelConfig, tcfg: TrainConfig,
          data_cfg: Optional[DataConfig] = None,
          opt_cfg: Optional[OPT.AdamWConfig] = None,
          mesh=None,
          log_fn: Callable[[str], None] = print) -> Dict[str, Any]:
    """Run (or resume) a training job. Returns final metrics + history."""
    opt_cfg = opt_cfg or OPT.AdamWConfig()
    data_cfg = data_cfg or DataConfig(
        vocab=model_cfg.vocab_, seq_len=128, global_batch=8)

    with SH.use_mesh(mesh):
        defs = LM.model_defs(model_cfg, max_seq=data_cfg.seq_len)
        params = C.init_params(defs, jax.random.key(tcfg.seed))
        opt_state = OPT.init(params, opt_cfg)
        start_step = 0

        if tcfg.resume and tcfg.ckpt_dir:
            latest = CKPT.latest_step(tcfg.ckpt_dir)
            if latest is not None:
                state_like = {"params": params, "opt": opt_state}
                restored, extra = CKPT.restore(tcfg.ckpt_dir, latest, state_like)
                params, opt_state = restored["params"], restored["opt"]
                start_step = int(extra.get("data_step", latest))
                log_fn(f"[resume] restored step {latest}")

        train_step = jax.jit(TS.make_train_step(model_cfg, opt_cfg))
        it = DataIterator(data_cfg, start_step=start_step)
        ckpt = CKPT.AsyncCheckpointer()
        wd = Watchdog(tcfg.watchdog_factor)

        stop = {"now": False}

        def handle(sig, frame):
            stop["now"] = True

        old_handlers = {}
        for s in (signal.SIGTERM, signal.SIGINT):
            try:
                old_handlers[s] = signal.signal(s, handle)
            except ValueError:
                pass  # not on main thread

        history = []
        metrics = {}
        step = start_step
        try:
            for step in range(start_step, tcfg.steps):
                batch_np = it.batch_at(step)
                batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
                t0 = time.perf_counter()
                params, opt_state, metrics = train_step(params, opt_state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                if wd.observe(dt):
                    log_fn(f"[watchdog] step {step} took {dt:.3f}s "
                           f"(ema {wd.ema:.3f}s) — straggler event")
                if step % tcfg.log_every == 0:
                    log_fn(f"step {step}: loss={float(metrics['loss']):.4f} "
                           f"({dt*1e3:.0f} ms)")
                history.append(float(metrics["loss"]))
                if tcfg.ckpt_dir and (step + 1) % tcfg.ckpt_every == 0:
                    ckpt.save(tcfg.ckpt_dir, step + 1,
                              {"params": params, "opt": opt_state},
                              extra={"data_step": step + 1})
                if stop["now"]:
                    log_fn(f"[preempt] signal at step {step}; saving")
                    break
        finally:
            it.close()
            if tcfg.ckpt_dir:
                ckpt.wait()
                CKPT.save(tcfg.ckpt_dir, step + 1,
                          {"params": params, "opt": opt_state},
                          extra={"data_step": step + 1})
            for s, h in old_handlers.items():
                signal.signal(s, h)

        return {"loss": float(metrics.get("loss", float("nan"))),
                "history": history,
                "straggler_events": wd.events,
                "final_step": step + 1,
                "params": params}
