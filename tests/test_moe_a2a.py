"""Parity: the explicit all-to-all EP MoE matches a direct per-token
reference (same router), on a multi-device mesh via subprocess."""

import os
import subprocess
import sys


def test_a2a_moe_matches_reference():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.models import common as C
from repro.models.moe import MoEConfig, moe_defs, route
from repro.models.moe_a2a import moe_a2a_forward

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = MoEConfig(d_model=32, n_experts=8, top_k=2, expert_ff=16,
                n_shared=0, capacity_factor=8.0)  # high cap: no drops
defs = moe_defs(cfg)
params = C.init_params(defs, jax.random.key(0))
params = jax.tree.map(lambda a: a.astype(jnp.float32), params)
x = jax.random.normal(jax.random.key(1), (4, 16, 32), jnp.float32) * 0.5

y = moe_a2a_forward(params, x, cfg, mesh)

# reference: direct per-token computation with the same router outputs
w_, idx_, _ = route(params["router"], x.reshape(4, 16, 32), cfg)
wg, wu, wd = params["w_gate"], params["w_up"], params["w_down"]
ref = np.zeros((4, 16, 32), np.float32)
xn = np.asarray(x); wn = np.asarray(w_); idxn = np.asarray(idx_)
for b in range(4):
    for s in range(16):
        acc = np.zeros(32, np.float32)
        for k in range(cfg.top_k):
            e = int(idxn[b, s, k])
            t = xn[b, s]
            h = (t @ np.asarray(wg[e]))
            h = h / (1 + np.exp(-h)) * (t @ np.asarray(wu[e]))
            acc += wn[b, s, k] * (h @ np.asarray(wd[e]))
        ref[b, s] = acc
err = float(np.max(np.abs(np.asarray(y) - ref)))
assert err < 2e-3, err
print("A2A_MOE_OK", err)

# gradients flow
def loss(params):
    return jnp.sum(moe_a2a_forward(params, x, cfg, mesh) ** 2)
g = jax.grad(loss)(params)
gn = float(jnp.sqrt(sum(jnp.sum(t.astype(jnp.float32)**2)
                        for t in jax.tree.leaves(g))))
assert np.isfinite(gn) and gn > 0
print("A2A_GRAD_OK", gn)
"""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, (r.stderr[-3000:], r.stdout[-500:])
    assert "A2A_MOE_OK" in r.stdout and "A2A_GRAD_OK" in r.stdout
