"""Integration: the training loop learns, checkpoints, resumes, and the
GA-evolve service plugs into the same framework."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig, DataIterator
from repro.models import common as C
from repro.models import lm as LM
from repro.optim import adamw as OPT
from repro.train import step as TS
from repro.train.loop import TrainConfig, train


def test_loss_decreases_on_learnable_data():
    cfg = reduced(get_config("minitron-8b"))
    out = train(cfg, TrainConfig(steps=60, log_every=1000),
                DataConfig(vocab=cfg.vocab_, seq_len=64, global_batch=8),
                OPT.AdamWConfig(lr=1e-3))
    h = out["history"]
    assert np.mean(h[-10:]) < np.mean(h[:10]) - 0.5, \
        f"loss did not drop: {np.mean(h[:10]):.3f} -> {np.mean(h[-10:]):.3f}"


def test_checkpoint_resume_is_exact(tmp_path):
    """Crash/restart fault-tolerance: training 30 steps straight equals
    training 20, 'crashing', and resuming for 10 (bit-identical loss)."""
    cfg = reduced(get_config("mamba2-1.3b"))
    data = DataConfig(vocab=cfg.vocab_, seq_len=32, global_batch=4)
    opt = OPT.AdamWConfig(lr=5e-4)

    d1 = os.path.join(tmp_path, "straight")
    a = train(cfg, TrainConfig(steps=30, ckpt_dir=d1, ckpt_every=1000,
                               log_every=1000), data, opt)

    d2 = os.path.join(tmp_path, "resumed")
    train(cfg, TrainConfig(steps=20, ckpt_dir=d2, ckpt_every=10,
                           log_every=1000), data, opt)
    b = train(cfg, TrainConfig(steps=30, ckpt_dir=d2, ckpt_every=1000,
                               log_every=1000, resume=True), data, opt)
    assert abs(a["loss"] - b["loss"]) < 1e-5, (a["loss"], b["loss"])


def test_8bit_optimizer_trains():
    cfg = reduced(get_config("minitron-8b"))
    defs = LM.model_defs(cfg)
    params = C.init_params(defs, jax.random.key(0))
    ocfg = OPT.AdamWConfig(lr=1e-3, state_bits=8)
    opt = OPT.init(params, ocfg)
    ts = jax.jit(TS.make_train_step(cfg, ocfg))
    it = DataIterator(DataConfig(vocab=cfg.vocab_, seq_len=32, global_batch=4))
    losses = []
    for step in range(30):
        b = {k: jnp.asarray(v) for k, v in it.batch_at(step).items()}
        params, opt, m = ts(params, opt, b)
        losses.append(float(m["loss"]))
    it.close()
    assert losses[-1] < losses[0] - 0.3
    # 8-bit states really are int8
    leaf = jax.tree.leaves(opt.m, is_leaf=lambda x: isinstance(x, OPT.QTensor))[0]
    assert leaf.q.dtype == jnp.int8


def test_watchdog_counts_stragglers():
    from repro.train.loop import Watchdog
    wd = Watchdog(factor=3.0)
    assert not wd.observe(0.1)
    for _ in range(5):
        wd.observe(0.1)
    assert wd.observe(1.0)      # 10x slower -> flagged
    assert wd.events == 1


def test_evolve_tunes_lr_for_quadratic():
    """The paper's GA as the framework's tuning service: evolve the LR of a
    toy quadratic optimisation — GA should find a near-optimal step size."""
    from repro.core import evolve

    def run_sgd(lrs):  # (N,1) -> (N,) final loss of 20 GD steps on x^2
        def one(lr):
            x = jnp.float32(5.0)
            for _ in range(20):
                x = x - lr * 2 * x
            return x * x
        return jax.vmap(one)(lrs[:, 0])

    r = evolve(run_sgd, [(0.001, 1.2)], population=32, generations=60,
               bits_per_var=12, mutation_rate=0.05, seed=4)
    assert r.best_fitness < 1e-3
    assert 0.05 < r.best_params[0] < 1.0


def test_data_pipeline_determinism_and_host_sharding():
    cfg = DataConfig(vocab=512, seq_len=16, global_batch=8, n_hosts=2,
                     host_id=0, seed=9)
    it = DataIterator(cfg)
    b1 = it.batch_at(5)
    b2 = it.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    it.close()
    other = DataIterator(DataConfig(vocab=512, seq_len=16, global_batch=8,
                                    n_hosts=2, host_id=1, seed=9))
    b3 = other.batch_at(5)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert b1["tokens"].shape == (4, 16)  # host batch = global/2
    other.close()
