"""Fitness Function Module (FFM) — paper Sec. 3.1, generalized to V variables.

The paper computes  y = γ(α(px) + β(qx))  with three ROMs per individual and
notes the architecture extends "to more variables from some adjustments on
hardware architecture".  This module is that adjustment: a registered
:class:`ProblemDef` (or a user blackbox) is *compiled* into a
:class:`FitnessProgram`, one object that lowers the same problem to every
evaluation mode the engine's executors consume:

  * ``lut``   — faithful: per-variable int32 fixed-point ROMs stacked into
    one [V, 2^c] table (the paper's α/β ROMs are the V=2 rows), one δ add
    tree and an optional γ ROM.  Available for separable problems
    ``f(x) = γ(Σ_i φ(x_i))`` — exactly the family the FFM synthesizes.
  * ``arith`` — TPU-native: the problem's jnp expression evaluated in f32 on
    the VPU (HBM gathers are far more expensive than FMAs on TPU).
  * in-kernel stage — ``FitnessProgram.stage`` is a traceable
    ``uint32[(..., V)] bits -> f32[...]`` function the Pallas ``ga_step``
    kernel calls as its FFM stage, so *any* traceable problem — n-variable
    benchmarks and user blackboxes included — runs fused.  The reference
    executor evaluates the SAME traced function, which is what makes
    reference × fused bit-identity hold for every registered problem.

All modes share the domain mapping: a c-bit unsigned gene u decodes to
v = lo + u * (hi - lo) / (2^c - 1), per variable.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Problem registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ProblemDef:
    """A registered n-variable optimisation problem.

    ``fn`` is the batch evaluator ``(..., V) f32 -> (...,) f32`` in jnp —
    traceable, so it lowers to XLA *and* into the Pallas kernel.  The
    optional separable form ``f(x) = gamma(Σ_i term(v, i))`` (``term`` in
    numpy, evaluated at ROM-synthesis time) enables the LUT lowering; leave
    it None for non-separable problems (rosenbrock, ackley, blackboxes),
    which then run mode='arith' only.
    """

    name: str
    fn: Callable[[jax.Array], jax.Array]
    domain: Tuple[float, float]          # per-variable decode box
    fixed_vars: Optional[int] = None     # paper problems pin V
    default_vars: int = 2
    min_vars: int = 1
    minimize: bool = True
    term: Optional[Callable[[np.ndarray, int], np.ndarray]] = None
    gamma: Optional[Callable[[np.ndarray], np.ndarray]] = None  # None = id

    @property
    def separable(self) -> bool:
        """Whether the LUT (stacked per-variable ROM) lowering exists."""
        return self.term is not None

    def f(self, vals) -> jax.Array:
        """Convenience single/batch evaluation over a trailing V axis."""
        return self.fn(jnp.asarray(vals, jnp.float32))


PROBLEMS: Dict[str, ProblemDef] = {}


def register_problem(pdef: ProblemDef) -> ProblemDef:
    """Add a problem to the registry (user problems welcome — see
    examples/custom_fitness.py)."""
    PROBLEMS[pdef.name] = pdef
    return pdef


def resolve_problem(problem: str) -> Tuple[ProblemDef, Optional[int]]:
    """Look up ``"name"`` or ``"name:V"`` -> (ProblemDef, requested V or
    None).  The ``:V`` suffix is the CLI/spec shorthand for n_vars."""
    name, sep, vs = problem.partition(":")
    n_vars = None
    if sep:
        try:
            n_vars = int(vs)
        except ValueError:
            raise ValueError(f"bad problem spec {problem!r}: the :V suffix "
                             "must be an integer, e.g. 'rastrigin:8'")
    if name not in PROBLEMS:
        raise ValueError(f"unknown problem {name!r}; "
                         f"choose from {sorted(PROBLEMS)}")
    return PROBLEMS[name], n_vars


def resolve_vars(pdef: ProblemDef, n_vars: Optional[int]) -> int:
    """Validate a requested variable count against a problem's shape rules
    (fixed paper layout, minimum V) and return the effective V.  THE shared
    rule set — `GASpec` validation and `compile_program` both call this."""
    if pdef.fixed_vars is not None:
        if n_vars is not None and n_vars != pdef.fixed_vars:
            raise ValueError(f"problem {pdef.name!r} is defined at "
                             f"V={pdef.fixed_vars} (paper layout); "
                             f"got n_vars={n_vars}")
        return pdef.fixed_vars
    v = n_vars if n_vars is not None else pdef.default_vars
    if v < pdef.min_vars:
        raise ValueError(f"problem {pdef.name!r} needs at least "
                         f"{pdef.min_vars} variables; got n_vars={v}")
    return v


def check_mode(pdef: ProblemDef, mode: str) -> None:
    """Reject FFM modes the problem cannot lower to (shared by `GASpec`
    validation and `compile_program`)."""
    if mode not in ("lut", "arith"):
        raise ValueError(f"mode must be 'lut' or 'arith', got {mode!r}")
    if mode == "lut" and not pdef.separable:
        raise ValueError(f"problem {pdef.name!r} has no separable form for "
                         "the LUT ROMs (mode='lut'); run mode='arith'")


# --- The paper's three validation functions (Sec. 4), fixed at V=2 ---------

# F1: f(x) = x^3 - 15 x^2 + 500   (one variable; paper Eq. 24, range ±2^12).
# The paper still lays it out as px ‖ qx with α(px) = 0, so V stays 2.
F1 = register_problem(ProblemDef(
    name="F1",
    fn=lambda v: v[..., 1] ** 3 - 15.0 * v[..., 1] ** 2 + 500.0,
    domain=(-4096.0, 4095.0),
    fixed_vars=2,
    term=lambda v, i: (np.zeros_like(v) if i == 0
                       else v ** 3 - 15.0 * v ** 2 + 500.0),
))

# F2: f(x, y) = 8x - 4y + 1020   (paper Eq. 25)
F2 = register_problem(ProblemDef(
    name="F2",
    fn=lambda v: 8.0 * v[..., 0] + (-4.0 * v[..., 1] + 1020.0),
    domain=(-128.0, 127.0),
    fixed_vars=2,
    term=lambda v, i: 8.0 * v if i == 0 else -4.0 * v + 1020.0,
))

# F3: f(x, y) = sqrt(x^2 + y^2)   (paper Eq. 26)
F3 = register_problem(ProblemDef(
    name="F3",
    fn=lambda v: jnp.sqrt(jnp.maximum(
        v[..., 0] * v[..., 0] + v[..., 1] * v[..., 1], 0.0)),
    domain=(-128.0, 127.0),
    fixed_vars=2,
    term=lambda v, i: v.astype(np.float64) ** 2,
    gamma=lambda d: np.sqrt(np.maximum(d, 0.0)),
))


# --- The standard n-variable GA benchmark suite (configurable V) -----------

register_problem(ProblemDef(
    name="sphere",
    fn=lambda v: jnp.sum(v * v, axis=-1),
    domain=(-5.12, 5.12),
    term=lambda v, i: v.astype(np.float64) ** 2,
))

register_problem(ProblemDef(
    name="rastrigin",
    # 10V + Σ x² - 10 cos(2πx), folded as Σ (x² - 10 cos(2πx) + 10)
    fn=lambda v: jnp.sum(
        v * v - 10.0 * jnp.cos(2.0 * np.pi * v) + 10.0, axis=-1),
    domain=(-5.12, 5.12),
    term=lambda v, i: (v.astype(np.float64) ** 2
                       - 10.0 * np.cos(2.0 * np.pi * v) + 10.0),
))

register_problem(ProblemDef(
    name="rosenbrock",
    # coupled terms -> not separable -> arith/kernel modes only
    fn=lambda v: jnp.sum(
        100.0 * (v[..., 1:] - v[..., :-1] * v[..., :-1]) ** 2
        + (1.0 - v[..., :-1]) ** 2, axis=-1),
    domain=(-2.048, 2.048),
    min_vars=2,
))

register_problem(ProblemDef(
    name="ackley",
    # two coupled reductions -> not γ(Σφ)-separable -> arith/kernel only
    fn=lambda v: (-20.0 * jnp.exp(
        -0.2 * jnp.sqrt(jnp.mean(v * v, axis=-1)))
        - jnp.exp(jnp.mean(jnp.cos(2.0 * np.pi * v), axis=-1))
        + 20.0 + np.e),
    domain=(-32.768, 32.768),
))


def decode(u: jax.Array, c: int, domain: tuple) -> jax.Array:
    """Decode a c-bit unsigned gene to its real value (single shared box)."""
    lo, hi = domain
    scale = (hi - lo) / float((1 << c) - 1)
    return lo + u.astype(jnp.float32) * jnp.float32(scale)


# ---------------------------------------------------------------------------
# LUT (faithful) mode — per-variable ROMs stacked into one table
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LutTables:
    """Fixed-point ROM contents for one separable problem at width c.

    var_t: int32[V, 2^c] — per-variable term ROMs scaled by 2^frac_bits
           (the paper's α/β ROMs are rows 0 and 1 of the V=2 case).
    gamma_t: int32[2^g] or None (None == identity γ, paper's F1/F2 case where
             the third ROM is a pass-through).
    delta_min / delta_shift: the γ ROM is addressed by
             clip((δ - delta_min) >> delta_shift, 0, 2^g - 1).
    """

    c: int
    frac_bits: int
    var_t: np.ndarray
    gamma_t: Optional[np.ndarray]
    delta_min: int
    delta_shift: int
    g: int


def build_tables(pdef: ProblemDef, c: int, n_vars: int,
                 frac_bits: Optional[int] = None, g: int = 14) -> LutTables:
    """Quantize the per-variable terms + γ into ROM tables (FFM synthesis).

    frac_bits may be negative (coarser-than-integer fixed point) — exactly
    what a hardware synthesis would do when the fitness range exceeds the
    ROM word width.  If None, the largest value keeping |Σ terms| within
    int31 is chosen automatically (capped at 8 fractional bits).
    """
    if not pdef.separable:
        raise ValueError(f"problem {pdef.name!r} has no separable form — "
                         "the LUT ROMs cannot be synthesized; run "
                         "mode='arith'")
    u = np.arange(1 << c, dtype=np.float64)
    lo, hi = pdef.domain
    v = lo + u * (hi - lo) / float((1 << c) - 1)
    terms = [np.asarray(pdef.term(v, i), np.float64) for i in range(n_vars)]

    if frac_bits is None:
        peak = sum(np.abs(t).max() for t in terms)
        frac_bits = 8
        while frac_bits > -24 and peak * (2.0 ** frac_bits) >= 2 ** 30:
            frac_bits -= 1

    scale = float(2.0 ** frac_bits)
    fixed = [np.round(t * scale).astype(np.int64) for t in terms]

    # int32 saturation (the ROM word width)
    i32 = lambda t: np.clip(t, -(2 ** 31), 2 ** 31 - 1).astype(np.int32)
    var_t = np.stack([i32(t) for t in fixed])

    if pdef.gamma is None:
        return LutTables(c, frac_bits, var_t, None, 0, 0, 0)

    dmin = int(sum(t.min() for t in fixed))
    dmax = int(sum(t.max() for t in fixed))
    span = max(dmax - dmin, 1)
    shift = max(0, int(np.ceil(np.log2(span / ((1 << g) - 1) + 1e-12))) if span >= (1 << g) else 0)
    # γ table: value at address k represents δ = dmin + (k << shift)
    k = np.arange(1 << g, dtype=np.int64)
    delta = (dmin + (k << shift)).astype(np.float64) / scale
    gamma_t = i32(np.round(pdef.gamma(delta) * scale))
    return LutTables(c, frac_bits, var_t, gamma_t, dmin, shift, g)


def lut_fitness(x: jax.Array, t: LutTables) -> jax.Array:
    """Faithful FFM: V ROM reads, a δ add tree, one more ROM read.

    x: uint32/int32 (..., V) chromosome matrix; int32 fitness out."""
    mask = np.uint32((1 << t.c) - 1)
    idx = (x.astype(jnp.uint32) & mask).astype(jnp.int32)
    tabs = jnp.asarray(t.var_t)
    d = tabs[0][idx[..., 0]]
    for i in range(1, t.var_t.shape[0]):
        d = d + tabs[i][idx[..., i]]
    if t.gamma_t is None:
        return d
    addr = jnp.clip((d - jnp.int32(t.delta_min)) >> t.delta_shift, 0, (1 << t.g) - 1)
    return jnp.asarray(t.gamma_t)[addr]


# ---------------------------------------------------------------------------
# FitnessProgram — one problem compiled for every executor
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FitnessProgram:
    """A problem (or blackbox) lowered to the engine's evaluation modes.

    ``stage`` is THE arith lowering: a traceable bits -> fitness function
    shared verbatim by the XLA executors and the Pallas kernel's FFM stage,
    so reference × fused bit-identity holds by construction for every
    program.  ``lut_stage`` is the faithful ROM pipeline (separable
    problems only).  ``fitness(mode)`` dispatches for the executors.
    """

    name: str
    n_vars: int
    bits_per_var: int
    domains: Tuple[Tuple[float, float], ...]   # per-variable (lo, hi)
    minimize: bool
    fn: Callable[[jax.Array], jax.Array]
    supports_lut: bool
    tables: Optional[LutTables] = None   # synthesized only for mode='lut'

    @property
    def modes(self) -> Tuple[str, ...]:
        return ("lut", "arith") if self.supports_lut else ("arith",)

    def scale(self, mode: str) -> float:
        """Raw-fitness units per real unit (lut mode is fixed-point)."""
        if mode == "lut":
            return 2.0 ** self._tables().frac_bits
        return 1.0

    def _tables(self) -> LutTables:
        if self.tables is None:
            raise ValueError(
                f"program for {self.name!r} was not compiled with "
                "mode='lut'" if self.supports_lut else
                f"problem {self.name!r} has no LUT lowering (not "
                "separable); run mode='arith'")
        return self.tables

    # ---- lowerings ------------------------------------------------------

    def decode(self, x: jax.Array) -> jax.Array:
        """uint32 bits (..., V) -> f32 values (..., V), per-variable box."""
        c = self.bits_per_var
        lo = np.asarray([d[0] for d in self.domains], np.float32)
        span = np.asarray([(d[1] - d[0]) / ((1 << c) - 1)
                           for d in self.domains], np.float32)
        mask = np.uint32((1 << c) - 1)
        return jnp.asarray(lo) + (x & mask).astype(jnp.float32) * jnp.asarray(span)

    def stage(self, x: jax.Array) -> jax.Array:
        """The arith/in-kernel FFM stage: uint32 bits (..., V) -> f32 (...,).

        Traceable under XLA jit AND inside a Pallas kernel body — this exact
        function is what `kernels.ga_step` runs in place of the paper's
        hardwired two-variable polynomial pipeline."""
        return jnp.asarray(self.fn(self.decode(x)), jnp.float32)

    def lut_stage(self, x: jax.Array) -> jax.Array:
        """The faithful ROM pipeline: uint32 bits (..., V) -> int32 (...,)."""
        return lut_fitness(x, self._tables())

    def fitness(self, mode: str) -> Callable[[jax.Array], jax.Array]:
        """The executor-facing fitness function for one FFM mode."""
        if mode == "lut":
            self._tables()          # fail loudly before tracing
            return self.lut_stage
        if mode != "arith":
            raise ValueError(f"mode must be 'lut' or 'arith', got {mode!r}")
        return self.stage


def compile_program(problem: Optional[str] = None,
                    fitness: Optional[Callable] = None,
                    bounds=None, *,
                    n_vars: Optional[int] = None,
                    bits_per_var: int,
                    mode: str = "arith",
                    minimize: bool = True) -> FitnessProgram:
    """Lower a registered problem name (``"F3"``, ``"rastrigin:8"``) or a
    blackbox ``(N, V) -> (N,)`` + bounds into a :class:`FitnessProgram`.

    LUT ROMs are synthesized only when mode='lut' (they can be 2^c-entry
    tables); ``supports_lut`` still reports availability either way.
    """
    if (problem is None) == (fitness is None):
        raise ValueError("pass exactly one of problem= or fitness=")
    if mode not in ("lut", "arith"):
        raise ValueError(f"mode must be 'lut' or 'arith', got {mode!r}")

    if problem is not None:
        pdef, v_suffix = resolve_problem(problem)
        if v_suffix is not None and n_vars is not None and v_suffix != n_vars:
            raise ValueError(f"problem {problem!r} pins V={v_suffix} but "
                             f"n_vars={n_vars} was also given")
        v = resolve_vars(pdef, v_suffix if v_suffix is not None else n_vars)
        check_mode(pdef, mode)
        tables = (build_tables(pdef, bits_per_var, v)
                  if mode == "lut" else None)
        return FitnessProgram(name=pdef.name, n_vars=v,
                              bits_per_var=bits_per_var,
                              domains=(pdef.domain,) * v,
                              minimize=minimize, fn=pdef.fn,
                              supports_lut=pdef.separable, tables=tables)

    if bounds is None:
        raise ValueError("blackbox fitness requires bounds=")
    domains = tuple((float(lo), float(hi)) for lo, hi in bounds)
    if n_vars is not None and n_vars != len(domains):
        raise ValueError(f"n_vars={n_vars} does not match "
                         f"len(bounds)={len(domains)}")
    if mode == "lut":
        raise ValueError("blackbox fitness has no LUT lowering; "
                         "run mode='arith'")
    return FitnessProgram(name="blackbox", n_vars=len(domains),
                          bits_per_var=bits_per_var, domains=domains,
                          minimize=minimize, fn=fitness, supports_lut=False)
