"""LFSR unit + property tests (the paper's randomness source)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing.hypothesis_fallback import given, settings, st

from repro.core import lfsr


@given(st.lists(st.integers(1, 2**32 - 1), min_size=1, max_size=32),
       st.integers(1, 64))
@settings(max_examples=30, deadline=None)
def test_matches_numpy_reference(seeds, n):
    s = jnp.asarray(np.array(seeds, np.uint32))
    got = np.asarray(lfsr.steps(s, n))
    want = lfsr.np_steps(np.array(seeds, np.uint32), n)
    np.testing.assert_array_equal(got, want)


@given(st.integers(1, 2**32 - 1))
@settings(max_examples=50, deadline=None)
def test_nonzero_state_stays_nonzero(seed):
    s = jnp.asarray(np.array([seed], np.uint32))
    out = np.asarray(lfsr.steps(s, 128))
    assert out[0] != 0


def test_zero_is_absorbing():
    # degenerate all-zero register never escapes — why seeds must be nonzero
    s = jnp.zeros((1,), jnp.uint32)
    assert int(lfsr.steps(s, 10)[0]) == 0


@given(st.lists(st.integers(1, 2**32 - 1), min_size=1, max_size=8),
       st.integers(1, 200))
@settings(max_examples=20, deadline=None)
def test_leap_equals_iterated_steps(seeds, t):
    s = jnp.asarray(np.array(seeds, np.uint32))
    np.testing.assert_array_equal(
        np.asarray(lfsr.leap(s, t)), np.asarray(lfsr.steps(s, t)))


@pytest.mark.parametrize("t", [1, 2, 3, 5, 13, 31])
def test_leap_feedback_masks_shift_parity_form(t):
    """The precomputed GF(2) masks reproduce t sequential clocks exactly in
    the shift+parity form  (s << t) | Σ_j parity(s & M_j) << j  — the
    kernel's `_lfsr_draw` replacement for the unrolled shift loop."""
    masks = lfsr.leap_feedback_masks(t)
    assert len(masks) == t
    s = np.asarray(lfsr.seeds(41, 64), np.uint32)
    out = (s << np.uint32(t)).astype(np.uint32)
    for j, m in enumerate(masks):
        par = np.zeros_like(s)
        for b in range(32):
            if (m >> b) & 1:
                par ^= s >> np.uint32(b)
        out |= (par & np.uint32(1)) << np.uint32(j)
    np.testing.assert_array_equal(out, lfsr.np_steps(s, t))


def test_leap_feedback_masks_range_checked():
    for bad in (0, 32, -1):
        with pytest.raises(ValueError):
            lfsr.leap_feedback_masks(bad)


@given(st.integers(1, 2**32 - 1), st.integers(1, 31))
@settings(max_examples=30, deadline=None)
def test_truncate_keeps_msbs(seed, bits):
    s = jnp.asarray(np.array([seed], np.uint32))
    r = int(lfsr.truncate(s, bits)[0])
    assert r == seed >> (32 - bits)
    assert r < (1 << bits)


def test_seeds_distinct_and_nonzero():
    s = np.asarray(lfsr.seeds(42, 4096))
    assert (s != 0).all()
    assert len(np.unique(s)) == 4096


def test_long_period_no_short_cycle():
    # the polynomial is primitive-ish: no cycle within 2^12 steps
    s0 = np.array([0xACE1], np.uint32)
    seen = set()
    s = s0.copy()
    for _ in range(4096):
        key = int(s[0])
        assert key not in seen
        seen.add(key)
        s = lfsr.np_step(s)
