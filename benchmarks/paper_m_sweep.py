"""Paper Figs. 15-16: effect of chromosome width m on speed (N=32).

On the FPGA, clock falls ~linearly with m (LUT depth) and LUT count rises.
Here m changes the fixed-point tables and bit widths; the vectorized engine
should be nearly m-invariant — which is itself a finding we record."""

from __future__ import annotations

import jax

from benchmarks.ga_common import time_call
from repro.core import fitness as F
from repro.core import ga as G

K = 200


def run():
    rows = []
    for m in (20, 22, 24, 26, 28):
        cfg = G.GAConfig(n=32, c=m // 2, v=2, mutation_rate=0.02, seed=1,
                         mode="lut")
        fit = G.fitness_for_problem(F.F3, cfg)
        runner = jax.jit(lambda: G.run(cfg, fit, K))
        dt, _ = time_call(runner, iters=3)
        rows.append((f"m_sweep_m{m}", dt / K * 1e6,
                     f"gens_per_s={K/dt:.0f}"))
    return rows
