"""Jitted public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute with interpret=True — the kernel
body runs in Python/XLA for correctness validation; on TPU they compile to
Mosaic.  `interpret` is auto-detected from the backend unless forced.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.core.ga import GAConfig
from repro.kernels.ga_step import FfmStage
from repro.kernels import ga_step as _ga_step
from repro.kernels import lfsr_kernel as _lfsr


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


@functools.partial(jax.jit, static_argnames=("steps", "interpret"))
def lfsr_advance(state: jax.Array, steps: int,
                 interpret: Optional[bool] = None) -> jax.Array:
    return _lfsr.lfsr_advance_kernel(state, steps,
                                     interpret=_auto_interpret(interpret))


def ga_generation(x, sel, cross, mut, *, cfg: GAConfig, ffm: FfmStage,
                  interpret: Optional[bool] = None, gens: int = 1,
                  track_best: bool = False):
    """Fused GA generation(s) over islands. See kernels/ga_step.py.
    ffm: the traced FFM stage (uint32[N, V] -> f32[N], e.g.
    `FitnessProgram.stage`); gens > 1 keeps the GA state VMEM-resident
    between generations; track_best=True appends in-kernel
    (best_y[I], best_x[I, V]) outputs."""
    fn = functools.partial(_ga_step.ga_generation_kernel, cfg=cfg, ffm=ffm,
                           interpret=_auto_interpret(interpret), gens=gens,
                           track_best=track_best)
    return jax.jit(fn)(x, sel, cross, mut)


def ga_epoch(x, sel, cross, mut, *, cfg: GAConfig, ffm: FfmStage,
             migrate_every: int, intervals: int = 1, boundary: bool = False,
             interpret: Optional[bool] = None):
    """Resident-epoch launch over replica-stacked island shards
    ([G, I, ...]): `intervals x migrate_every` generations with the ring
    migration folded into the in-VMEM loop.  See kernels/ga_step.py
    (`ga_epoch_kernel`) for the contract and the VMEM budget."""
    fn = functools.partial(_ga_step.ga_epoch_kernel, cfg=cfg, ffm=ffm,
                           migrate_every=migrate_every, intervals=intervals,
                           boundary=boundary,
                           interpret=_auto_interpret(interpret))
    return jax.jit(fn)(x, sel, cross, mut)


