"""train_step factory: CE loss, remat'd layer scans, AdamW, aux losses.

The returned step is a pure function
    (params, opt_state, batch) -> (params, opt_state, metrics)
suitable for jax.jit with explicit in/out shardings (launch/dryrun.py) or
plain jit on one host (tests/examples).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import sharding as SH
from repro.configs.base import ModelConfig
from repro.models import lm as LM
from repro.optim import adamw as OPT

IGNORE = -1  # label value that is masked out of the loss (vlm patch prefix)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean CE over valid positions. logits (B,S,V); labels (B,S) int32."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(
        lf, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    mask = (labels != IGNORE).astype(jnp.float32)
    nll = (lse - ll) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)


def make_loss_fn(cfg: ModelConfig, remat: bool = True,
                 aux_weight: float = 0.01):
    def loss_fn(params, batch):
        logits, aux = LM.forward(params, cfg, batch, remat=remat)
        labels = batch["labels"]
        if cfg.family == "vlm":
            # patch prefix positions carry no next-token target
            pad = jnp.full(
                (labels.shape[0], cfg.n_patches), IGNORE, labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        ce = cross_entropy(logits, labels)
        return ce + aux_weight * aux, {"ce": ce, "aux": aux}
    return loss_fn


def make_train_step(cfg: ModelConfig, opt_cfg: Optional[OPT.AdamWConfig] = None,
                    remat: bool = True) -> Callable:
    opt_cfg = opt_cfg or OPT.AdamWConfig()
    loss_fn = make_loss_fn(cfg, remat=remat)

    def train_step(params, opt_state, batch):
        (loss, extras), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        params, opt_state, om = OPT.update(params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, **extras, **om}
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig) -> Callable:
    loss_fn = make_loss_fn(cfg, remat=False)

    def eval_step(params, batch):
        loss, extras = loss_fn(params, batch)
        return {"loss": loss, **extras}

    return eval_step
