"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table1]

Prints ``name,us_per_call,derived`` CSV rows.
"""

from __future__ import annotations

import argparse
import sys

MODULES = [
    "benchmarks.paper_table1",       # Table 1: gens/s vs N
    "benchmarks.paper_m_sweep",      # Figs 15-16: m sweep
    "benchmarks.paper_table2",       # Table 2: speedup vs sequential GA
    "benchmarks.paper_convergence",  # Figs 11-12: convergence
    "benchmarks.kernel_bench",       # fused kernel vs pure JAX
    "benchmarks.engine_backends",    # repro.ga backend matrix (JSON rows)
    "benchmarks.lm_bench",           # LM substrate sanity
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    import importlib
    print("name,us_per_call,derived")
    failed = []
    for modname in MODULES:
        if args.only and args.only not in modname:
            continue
        try:
            mod = importlib.import_module(modname)
            for name, us, derived in mod.run():
                print(f"{name},{us:.2f},{derived}")
                sys.stdout.flush()
        except Exception as e:  # keep the harness going
            failed.append((modname, repr(e)))
            print(f"{modname},ERROR,{e!r}")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
