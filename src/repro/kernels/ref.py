"""Pure-jnp oracles for the Pallas kernels.

These mirror the kernels op-for-op using plain indexing instead of one-hot
matmuls; the kernels must match them BIT-EXACTLY (uint32) / exactly (f32
fitness, since every one-hot contraction has a single nonzero and 16-bit
halves are exact in f32).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lfsr
from repro.core.fitness import decode  # noqa: F401  (re-export for tests)
from repro.core.ga import GAConfig


def lfsr_advance_ref(state: jax.Array, steps: int) -> jax.Array:
    return lfsr.steps(state, steps)


def ga_generation_ref(x, sel, cross, mut, *, cfg: GAConfig, ffm
                      ) -> Tuple[jax.Array, ...]:
    """Oracle for ga_step: operates on stacked islands via vmap.
    `ffm` is the same traced FFM stage the kernel consumes
    (uint32[N, V] -> f32[N])."""

    def one(x, sel, cross, mut):
        n, v, c = cfg.n, cfg.v, cfg.c
        var_mask = jnp.uint32((1 << c) - 1)
        y = jnp.asarray(ffm(x), jnp.float32)

        sel2 = lfsr.steps(sel, cfg.steps_per_draw)
        i1 = (sel2[0] >> jnp.uint32(32 - cfg.idx_bits)).astype(jnp.int32)
        i2 = (sel2[1] >> jnp.uint32(32 - cfg.idx_bits)).astype(jnp.int32)
        y1, y2 = y[i1], y[i2]
        first = (y1 <= y2) if cfg.minimize else (y1 >= y2)
        w = jnp.where(first[:, None], x[i1], x[i2])

        cross2 = lfsr.steps(cross, cfg.steps_per_draw)
        cut = (cross2 >> jnp.uint32(32 - cfg.cut_bits)).astype(jnp.uint32)
        cut = jnp.minimum(cut, jnp.uint32(c))
        s = (var_mask >> cut).T
        w1, w2 = w[0::2], w[1::2]
        z1 = (w1 & ~s) | (w2 & s)
        z2 = (w2 & ~s) | (w1 & s)
        z = jnp.stack([z1, z2], axis=1).reshape(n, v)

        mut2 = lfsr.steps(mut, cfg.steps_per_draw)
        rbits = (mut2 >> jnp.uint32(32 - c)).T
        mrow = (jnp.arange(n) < cfg.p)[:, None]
        x_new = jnp.where(mrow, z ^ rbits, z)
        return x_new, sel2, cross2, mut2, y

    return jax.vmap(one)(x, sel, cross, mut)
