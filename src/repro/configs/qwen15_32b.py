"""qwen1.5-32b — GQA with QKV bias [hf:Qwen/Qwen1.5-0.5B family; hf].

40 heads (MHA-style kv=40) padded to 48 for even 16-way TP.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40, head_dim=128,
    d_ff=27392, vocab=152064, qkv_bias=True, rope_theta=1_000_000.0,
    pad_heads_to=16,
)
