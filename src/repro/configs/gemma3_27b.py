"""gemma3-27b — 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt scaled; unverified].

62 layers = 10 × (5 local + 1 global) + 2 trailing local.  Local layers use a
1024-token sliding window (ring KV cache at decode) + 10k RoPE; globals use
1M RoPE.  QK-norm, tied embeddings, head_dim fixed at 128.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=21504, vocab=262144, qk_norm=True, tie_embeddings=True,
    global_every=6, window_size=1024,
    rope_theta=1_000_000.0, rope_theta_local=10_000.0,
)
