"""GA serving launcher — run the multi-tenant scheduler from the CLI.

    # four demo jobs (two packable pairs) with live streaming + metrics
    PYTHONPATH=src python -m repro.launch.ga_serve --demo 4 --port 9100

    # jobs from a JSON file, packed onto an 8-way mesh
    PYTHONPATH=src python -m repro.launch.ga_serve --jobs jobs.json \
        --mesh auto --max-pack 8 --chunk 16

The jobs file is a JSON list of objects; each object's keys are GASpec
fields plus optional "backend", "priority", "deadline_s" (wall-clock
budget → DEADLINE_EXCEEDED) and "max_retries" (per-job retry budget):

    [{"problem": "F3", "n": 32, "bits_per_var": 10, "generations": 100},
     {"problem": "F3", "n": 32, "bits_per_var": 10, "generations": 100,
      "seed": 7},
     {"problem": "rastrigin:4", "n": 64, "generations": 200, "priority": 5}]

Jobs sharing a spec shape (same `GASpec.compile_key()` and generations) are
packed down the replica axis into one launch — results stay bit-identical
to solo runs — and repeat shapes hit the process-global compile cache.
`--port` serves /metrics, /jobs, /jobs/<id> (long-poll) and
/jobs/<id>/stream (SSE) while jobs run; `--demo K` submits K F3 jobs with
distinct seeds (and, for K >= 3, one higher-priority rastrigin job that
preempts them) without needing a file.
"""

from __future__ import annotations

import argparse
import json


def _spec_from(obj: dict):
    from repro import ga
    obj = dict(obj)
    backend = obj.pop("backend", None)
    priority = int(obj.pop("priority", 0))
    deadline_s = obj.pop("deadline_s", None)
    max_retries = obj.pop("max_retries", None)
    return (ga.GASpec(**obj), backend, priority,
            None if deadline_s is None else float(deadline_s),
            None if max_retries is None else int(max_retries))


def _demo_jobs(k: int):
    base = dict(problem="F3", n=32, bits_per_var=10, generations=64)
    jobs = [dict(base, seed=11 + i) for i in range(k)]
    if k >= 3:
        # a later high-priority arrival that preempts the running pack
        jobs[-1] = dict(problem="rastrigin:4", n=32, bits_per_var=10,
                        generations=64, seed=5, priority=10)
    return jobs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", default=None,
                    help="JSON file: list of GASpec-field objects "
                         "(+ optional 'backend'/'priority' keys)")
    ap.add_argument("--demo", type=int, default=0, metavar="K",
                    help="submit K built-in demo jobs instead of --jobs")
    ap.add_argument("--backend", default="auto",
                    help="default backend for jobs that don't name one")
    ap.add_argument("--mesh", default=None,
                    help="shard islands over devices: 'auto', '4', '2x4', ...")
    ap.add_argument("--max-pack", type=int, default=8,
                    help="max replica slots per packed launch")
    ap.add_argument("--chunk", type=int, default=None,
                    help="telemetry/preemption granularity in generations")
    ap.add_argument("--ckpt-root", default=None,
                    help="pack checkpoint directory (temp dir by default)")
    ap.add_argument("--port", type=int, default=None,
                    help="serve /metrics, /jobs and SSE streams at PORT "
                         "(0 picks an ephemeral port)")
    ap.add_argument("--job-ttl", type=float, default=None, metavar="S",
                    help="evict DONE/FAILED jobs S seconds after they "
                         "finish (default: keep forever)")
    ap.add_argument("--recover", action="store_true",
                    help="replay the scheduler journal under --ckpt-root: "
                         "re-enqueue pending jobs (packs resume from their "
                         "checkpoints) and restore finished results")
    ap.add_argument("--max-retries", type=int, default=3, metavar="N",
                    help="per-job retry budget for transient failures")
    ap.add_argument("--retry-backoff", type=float, default=0.05, metavar="S",
                    help="base of the exponential retry backoff")
    ap.add_argument("--stream", default="first",
                    choices=["first", "none"],
                    help="print the first job's live telemetry feed")
    from repro.ga.options import EngineOptions
    EngineOptions.add_cli_args(ap)   # --cost-table/--plan-override/--vmem-...
    args = ap.parse_args()

    if args.jobs is not None and args.demo > 0:
        ap.error("use only one of --jobs FILE or --demo K")
    if args.jobs is None and args.demo <= 0 and not args.recover:
        ap.error("one of --jobs FILE or --demo K is required "
                 "(or --recover alone to only resume journaled jobs)")
    job_dicts = (_demo_jobs(args.demo) if args.demo > 0
                 else json.load(open(args.jobs)) if args.jobs is not None
                 else [])
    if not job_dicts and not args.recover:
        ap.error("no jobs to run")

    mesh = None
    if args.mesh:
        from repro.launch.mesh import parse_mesh
        mesh = parse_mesh(args.mesh)
        print(f"mesh: {dict(mesh.shape)} ({mesh.devices.size} device(s))")

    options = EngineOptions.from_args(args, mesh=mesh)

    from repro.serve.scheduler import GAScheduler
    if args.recover and args.ckpt_root is None:
        ap.error("--recover needs --ckpt-root (the journal lives there)")
    sched = GAScheduler(backend=args.backend,
                        max_pack=args.max_pack,
                        chunk_generations=args.chunk,
                        ckpt_root=args.ckpt_root,
                        job_ttl_s=args.job_ttl,
                        max_retries=args.max_retries,
                        retry_backoff_s=args.retry_backoff,
                        recover=args.recover,
                        options=options)
    if args.recover:
        print(f"recovered {sched.recovered_total} pending job(s) "
              "from the journal")
    if sched.cost_table is not None:
        print(f"cost table: {len(sched.cost_table)} measured point(s)")

    server = None
    if args.port is not None:
        from repro.serve.metrics_http import start_metrics_server
        server = start_metrics_server(args.port, registry=sched.registry)
        port = server.server_address[1]
        print(f"metrics:  http://0.0.0.0:{port}/metrics")
        print(f"jobs:     http://0.0.0.0:{port}/jobs")
        print(f"streams:  http://0.0.0.0:{port}/jobs/<id>/stream  (SSE)")

    ids = []
    for obj in job_dicts:
        spec, backend, priority, deadline_s, max_retries = _spec_from(obj)
        job_id = sched.submit(spec, backend=backend, priority=priority,
                              deadline_s=deadline_s, max_retries=max_retries)
        ids.append(job_id)
        print(f"submitted {job_id}: {spec.problem or 'blackbox'} "
              f"gens={spec.generations} priority={priority}"
              + (f" deadline={deadline_s}s" if deadline_s else ""))

    try:
        if args.stream == "first" and ids:
            for event in sched.stream(ids[0]):
                if event.get("event") != "chunk":
                    continue
                print(f"[{event['job_id']}] chunk {event['chunk']}: "
                      f"{event['gens_done']}/{event['gens_total']} gens, "
                      f"best={event['best_fitness']:.4f}, "
                      f"pack={event.get('pack_size', 1)}")
        sched.wait_all(timeout=600)
        for job_id in ids:
            res = sched.result(job_id)
            print(f"{job_id}: best={res['best_fitness']:.6f} "
                  f"backend={res['backend']} pack={res.get('pack_size', 1)} "
                  f"({res['gens_per_s']:.0f} gens/s)")
        stats = sched.stats()
        print(f"packs={stats['packs_launched']} "
              f"packed_jobs={stats['jobs_packed']} "
              f"preemptions={stats['preemptions']} "
              f"cache: {stats['cache_hits']} hit(s) / "
              f"{stats['cache_misses']} miss(es), "
              f"{stats['cache_entries']} entries")
        print(f"plans: {stats['plans_measured']} measured / "
              f"{stats['plans_heuristic']} heuristic "
              f"(table points={stats['plan_table_entries']}, "
              f"evicted jobs={stats['jobs_evicted']})")
        print(f"faults: retries={stats['retries']} "
              f"quarantined={stats['quarantined']} "
              f"recovered={stats['recovered']} "
              f"deadline_exceeded={stats['deadline_exceeded']}")
    finally:
        sched.shutdown()
        if server is not None:
            server.shutdown()


if __name__ == "__main__":
    main()
