"""Production meshes.

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods × 256 chips as (pod=2, data=16, model=16) — the "pod"
axis carries pure data parallelism across the inter-pod (DCN) links, the
in-pod axes ride ICI.

Functions, not module constants: importing this module must never touch jax
device state (the dry-run sets XLA_FLAGS before any jax init).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """Whatever devices exist, as a 1×N (data, model) mesh — for tests."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))


# TPU v5e hardware model used by the roofline (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link (~3 links usable per axis hop)
