"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch minitron-8b --reduced \
        --steps 200 --ckpt-dir /tmp/run1

On the production pods this binary is what every host runs (jax.distributed
initializes from the cluster env); on this container it runs the reduced
configs end-to-end with the same code path: data pipeline -> sharded
train_step -> async checkpoints -> watchdog -> auto-resume.
"""

from __future__ import annotations

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced (CPU-sized) config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--mesh", choices=["none", "host", "pod1", "pod2"],
                    default="none")
    ap.add_argument("--fake-devices", type=int, default=0,
                    help="force host platform device count (dry-run style)")
    args = ap.parse_args()

    if args.fake_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.fake_devices} "
            + os.environ.get("XLA_FLAGS", ""))

    from repro.configs import get_config
    from repro.configs.base import reduced as make_reduced
    from repro.data.pipeline import DataConfig
    from repro.optim.adamw import AdamWConfig
    from repro.train.loop import TrainConfig, train

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)

    mesh = None
    if args.mesh != "none":
        from repro.launch.mesh import make_host_mesh, make_production_mesh
        mesh = (make_host_mesh() if args.mesh == "host" else
                make_production_mesh(multi_pod=(args.mesh == "pod2")))

    out = train(
        cfg,
        TrainConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                    ckpt_every=args.ckpt_every, resume=not args.no_resume),
        DataConfig(vocab=cfg.vocab_, seq_len=args.seq_len,
                   global_batch=args.global_batch),
        AdamWConfig(lr=args.lr),
        mesh=mesh,
    )
    print(f"final loss {out['loss']:.4f} after {out['final_step']} steps "
          f"({out['straggler_events']} straggler events)")


if __name__ == "__main__":
    main()
