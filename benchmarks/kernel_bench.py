"""Fused Pallas executor vs pure-JAX reference executor at equal island
count (interpret mode on CPU — the relative number is architecture-bound on
TPU; see EXPERIMENTS.md §Perf).

Both rows run through `repro.ga.Engine` as (executor × island_ring)
compositions over 8 islands of 256; `migration="none"` with
`migrate_every=K` makes each run one uninterrupted stepping block, so this
isolates raw generation throughput (engine_backends.py covers the
migrating compositions).
"""

from __future__ import annotations

from benchmarks.ga_common import time_call
from repro import ga

K = 50
N_ISLANDS = 8
N = 256


def _engine(backend: str) -> ga.Engine:
    spec = ga.paper_spec("F3", n=N, m=20, mode="arith", mutation_rate=0.02,
                         seed=1, generations=K, n_islands=N_ISLANDS,
                         migrate_every=K, migration="none")
    eng = ga.Engine(spec, backend)
    eng.run()    # compile + warm caches
    return eng


def run():
    rows = []
    fused = _engine("fused-islands")
    dt_k, _ = time_call(fused.run, warmup=0, iters=2)
    rows.append((f"kernel_fused_{N_ISLANDS}x{N}", dt_k / K * 1e6,
                 f"island_gens_per_s={N_ISLANDS*K/dt_k:.0f}"))

    ref = _engine("islands")
    dt_p, _ = time_call(ref.run, warmup=0, iters=2)
    rows.append((f"pure_jax_{N_ISLANDS}x{N}", dt_p / K * 1e6,
                 f"island_gens_per_s={N_ISLANDS*K/dt_p:.0f},"
                 f"kernel_speedup={dt_p/dt_k:.2f}x(cpu-interpret)"))
    return rows
