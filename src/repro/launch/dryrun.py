"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell with
512 placeholder devices, print memory/cost analysis, save roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch minitron-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all          # sweep (subprocess/cell)
    PYTHONPATH=src python -m repro.launch.dryrun --ga           # the GA mega-cell

Results land in dryrun_results/<arch>__<shape>__<mesh>.json and feed
EXPERIMENTS.md §Dry-run / §Roofline.
"""

# The VERY FIRST lines — before ANY other import — jax locks the device
# count on first init:
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import roofline as RL
from repro import sharding as SH
from repro.configs import REGISTRY, get_config
from repro.configs.base import ModelConfig
from repro.launch import shapes as SHAPES
from repro.launch.mesh import make_production_mesh
from repro.models import common as C
from repro.models import lm as LM
from repro.optim import adamw as OPT
from repro.train import step as TS

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "dryrun_results")

MESHES = {"pod1": dict(multi_pod=False), "pod2": dict(multi_pod=True)}


def abstract_opt_state(defs, opt_cfg: OPT.AdamWConfig):
    """ShapeDtypeStruct tree for the optimizer state (sharded like params)."""
    def moment(d: C.ParamDef):
        if opt_cfg.state_bits == 8 and OPT.quantizable(d.shape, opt_cfg.block):
            shp_s = d.shape[:-1] + (d.shape[-1] // opt_cfg.block,)
            return OPT.QTensor(
                q=jax.ShapeDtypeStruct(d.shape, jnp.int8,
                                       sharding=SH.named_sharding(d.axes, d.shape)),
                scale=jax.ShapeDtypeStruct(shp_s, jnp.float32,
                                           sharding=SH.named_sharding(d.axes, shp_s)),
                shape=d.shape, npad=0)
        return jax.ShapeDtypeStruct(d.shape, jnp.float32,
                                    sharding=SH.named_sharding(d.axes, d.shape))

    is_def = C.is_def
    return OPT.AdamState(
        step=jax.ShapeDtypeStruct((), jnp.int32,
                                  sharding=SH.named_sharding(())),
        m=jax.tree.map(moment, defs, is_leaf=is_def),
        v=jax.tree.map(moment, defs, is_leaf=is_def))


def model_flops_total(cfg: ModelConfig, shape: SHAPES.ShapeSpec) -> float:
    """Useful-FLOP convention: 6·N_active·D train, 2·N_active·D forward."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def build_cell(cfg: ModelConfig, shape: SHAPES.ShapeSpec,
               opt_bits: Optional[int] = None):
    """Returns (fn, args) ready to lower under the active mesh."""
    max_seq = shape.seq_len
    defs = LM.model_defs(cfg, max_seq=max_seq)
    params = C.abstract_params(defs)
    inputs = SHAPES.input_specs(cfg, shape)

    if shape.kind == "train":
        bits = opt_bits or (8 if cfg.name.startswith("deepseek") else 32)
        opt_cfg = OPT.AdamWConfig(state_bits=bits)
        opt_state = abstract_opt_state(defs, opt_cfg)
        fn = TS.make_train_step(cfg, opt_cfg, remat=True)
        return fn, (params, opt_state, inputs)

    if cfg.family == "vlm":
        max_seq += cfg.n_patches  # the patch prefix occupies cache slots
    cache_defs = LM.cache_defs(cfg, shape.global_batch, max_seq)
    cache = C.abstract_params(cache_defs)
    if shape.kind == "prefill":
        def fn(p, tokens, cache, frames=None, patches=None):
            return LM.prefill(p, cfg, tokens, cache, frames=frames,
                              patches=patches)
        kw = {k: v for k, v in inputs.items() if k != "tokens"}
        return fn, (params, inputs["tokens"], cache), kw
    # decode
    def fn(p, tokens, cache):
        return LM.decode_step(p, cfg, tokens, cache)
    return fn, (params, inputs["tokens"], cache)


def run_cell(arch: str, shape_name: str, mesh_name: str,
             out_dir: str = RESULTS_DIR, verbose: bool = True) -> Dict:
    cfg = get_config(arch)
    shape = SHAPES.SHAPES[shape_name]
    ok, why = SHAPES.cell_supported(cfg, shape)
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped", "reason": why}
        _save(rec, out_dir)
        return rec

    mesh = make_production_mesh(**MESHES[mesh_name])
    t0 = time.time()
    with SH.use_mesh(mesh, fsdp=True):
        built = build_cell(cfg, shape)
        fn, args = built[0], built[1]
        kw = built[2] if len(built) > 2 else {}
        lowered = jax.jit(fn).lower(*args, **kw)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    mem_d = {k: float(getattr(mem, k, 0) or 0) for k in
             ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes")}
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    hlo = compiled.as_text()
    n_dev = int(np.prod(list(mesh.shape.values())))
    report = RL.analyze_cell(arch, shape_name, mesh_name, n_dev, hlo,
                             dict(cost), mem_d,
                             model_flops_total(cfg, shape))
    rec = {"status": "ok", "t_lower_s": t_lower, "t_compile_s": t_compile,
           **report.to_dict()}
    _save(rec, out_dir)
    if verbose:
        print(f"[{arch} × {shape_name} × {mesh_name}] OK "
              f"(lower {t_lower:.1f}s, compile {t_compile:.1f}s)")
        print(f"  memory/device: args {mem_d['argument_size_in_bytes']/2**30:.2f} GiB, "
              f"temp {mem_d['temp_size_in_bytes']/2**30:.2f} GiB")
        print(f"  terms: compute {report.t_compute*1e3:.2f} ms | "
              f"memory {report.t_memory*1e3:.2f} ms | "
              f"collective {report.t_collective*1e3:.2f} ms "
              f"-> {report.dominant}-bound, "
              f"roofline {report.roofline_fraction*100:.1f}%")
    return rec


def _save(rec: Dict, out_dir: str):
    os.makedirs(out_dir, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1)


# ---------------------------------------------------------------------------
# GA mega-cell: the paper's engine at production scale
# ---------------------------------------------------------------------------


def run_ga_cell(mesh_name: str, out_dir: str = RESULTS_DIR,
                islands_per_device: int = 8, n: int = 256) -> Dict:
    from repro.core import ga as G
    from repro import ga as engine_api

    mesh = make_production_mesh(**MESHES[mesh_name])
    n_dev = int(np.prod(list(mesh.shape.values())))
    axes = tuple(mesh.axis_names)
    spec = engine_api.GASpec(
        problem="F3", n=n, bits_per_var=14, n_vars=2, mode="arith",
        mutation_rate=0.02, seed=1, migrate_every=16,
        n_islands=islands_per_device * n_dev)
    eng = engine_api.Engine(spec, "islands", mesh=mesh)
    cfg = spec.ga_config()
    icfg = eng.backend.topology.icfg

    t0 = time.time()
    step = eng.backend.topology._epoch()

    def sds(shape, dtype=jnp.uint32):
        return jax.ShapeDtypeStruct(
            shape, dtype, sharding=jax.NamedSharding(
                mesh, jax.sharding.PartitionSpec(axes, *([None] * (len(shape) - 1)))))

    I = icfg.n_islands
    states = G.GAState(
        x=sds((I, cfg.n, cfg.v)), sel_lfsr=sds((I, 2, cfg.n)),
        cross_lfsr=sds((I, cfg.v, cfg.n // 2)), mut_lfsr=sds((I, cfg.v, cfg.n)),
        k=jax.ShapeDtypeStruct((I,), jnp.int32, sharding=jax.NamedSharding(
            mesh, jax.sharding.PartitionSpec(axes))))
    lowered = step.lower(states)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    mem_d = {k: float(getattr(mem, k, 0) or 0) for k in
             ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes")}
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    report = RL.analyze_cell("ga-islands", f"I{I}_N{n}", mesh_name, n_dev,
                             compiled.as_text(), dict(cost), mem_d,
                             model_flops_total_ga(cfg, icfg))
    t_dom = max(report.t_compute, report.t_memory, report.t_collective)
    gens_per_s = icfg.migrate_every / t_dom if t_dom > 0 else 0
    rec = {"status": "ok", "t_lower_s": t_lower, "t_compile_s": t_compile,
           "generations_per_s_bound": gens_per_s,
           "total_chromosomes": I * n,
           **report.to_dict()}
    rec["arch"], rec["shape"] = "ga-islands", f"I{I}_N{n}"
    _save(rec, out_dir)
    print(f"[GA × {mesh_name}] {I} islands × N={n} "
          f"({I*n/1e6:.1f}M chromosomes): compile {t_compile:.1f}s, "
          f"bound {gens_per_s/1e3:.0f}k gens/s/epoch-step, "
          f"dominant={report.dominant}")
    return rec


def model_flops_total_ga(cfg, icfg) -> float:
    """Useful FLOPs per sharded epoch step: fitness evals dominate."""
    per_gen = icfg.n_islands * cfg.n * 20.0     # ~20 flops per fitness eval
    return per_gen * icfg.migrate_every


# ---------------------------------------------------------------------------


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default=None, choices=[None, "pod1", "pod2"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--ga", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    if args.ga:
        for mesh_name in ([args.mesh] if args.mesh else ["pod1", "pod2"]):
            run_ga_cell(mesh_name, args.out)
        return

    if args.all:
        # spawn one subprocess per cell: isolates XLA state + failures
        cells = []
        for arch in sorted(REGISTRY):
            for shape in SHAPES.SHAPES:
                for mesh_name in MESHES:
                    out = os.path.join(
                        args.out, f"{arch}__{shape}__{mesh_name}.json")
                    if os.path.exists(out) and not args.force:
                        continue
                    cells.append((arch, shape, mesh_name))
        print(f"{len(cells)} cells to run")
        failures = []
        for arch, shape, mesh_name in cells:
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh_name,
                   "--out", args.out]
            r = subprocess.run(cmd, capture_output=True, text=True)
            tail = r.stdout.strip().splitlines()[-3:]
            print(f"== {arch} × {shape} × {mesh_name}: rc={r.returncode}")
            for l in tail:
                print("   " + l)
            if r.returncode != 0:
                failures.append((arch, shape, mesh_name,
                                 r.stderr.strip().splitlines()[-5:]))
        if failures:
            print(f"\n{len(failures)} FAILURES:")
            for f_ in failures:
                print(f_)
            sys.exit(1)
        return

    assert args.arch and args.shape, "--arch and --shape (or --all / --ga)"
    meshes = [args.mesh] if args.mesh else list(MESHES)
    for mesh_name in meshes:
        try:
            run_cell(args.arch, args.shape, mesh_name, args.out)
        except Exception:
            traceback.print_exc()
            sys.exit(1)


if __name__ == "__main__":
    main()
