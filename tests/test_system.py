"""End-to-end behaviour tests for the paper's system: the full-parallel GA
reproduces the paper's optimisation results; the island model scales it; the
multi-device shard_map path works (spawned with fake devices)."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fitness as F
from repro.core import ga as G
from repro.core import islands as ISL
from repro.roofline import analyze_hlo


def test_f1_paper_reproduction_lut_mode():
    """Paper Fig. 11: minimise F1 with N=32, m=26 — global minimum within
    100 generations (LUT/fixed-point mode, the hardware-faithful path)."""
    cfg = G.GAConfig(n=32, c=13, v=2, mutation_rate=0.05, seed=7, mode="lut")
    t = F.build_tables(F.F1, 26)
    out = G.run(cfg, G.make_lut_fitness(t), 100)
    best = float(out.best_y) / 2.0 ** t.frac_bits
    target = float(F.F1.f(np.array(0.0), np.array(-4096.0)))
    assert best <= 0.98 * target
    # decoded solution sits at the domain edge the paper reports
    sol = G.decode_best(out, cfg, F.F1.domain)
    assert sol[1] == pytest.approx(-4096.0, abs=2.0)


def test_f3_paper_reproduction():
    """Paper Fig. 12: F3 with N=64, m=20 converges near zero in ~20 gens."""
    cfg = G.GAConfig(n=64, c=10, v=2, mutation_rate=0.05, seed=3, mode="arith")
    out = G.run(cfg, G.fitness_for_problem(F.F3, cfg), 100)
    traj = np.asarray(out.traj_best)
    assert traj[40] < 3.0          # most of the way by gen 40
    assert float(out.best_y) < 1.0


def test_islands_beat_single_population():
    """Island model with migration should match or beat one big population
    at equal total chromosome count (the multi-FPGA [19] claim)."""
    fit_cfg = G.GAConfig(n=32, c=12, v=2, mutation_rate=0.05, seed=1,
                         mode="arith")
    fit = G.fitness_for_problem(F.F3, fit_cfg)
    icfg = ISL.IslandConfig(ga=fit_cfg, n_islands=8, migrate_every=10)
    _, best_isl = ISL.run_local(icfg, fit, epochs=10)

    big = G.GAConfig(n=256, c=12, v=2, mutation_rate=0.05, seed=1, mode="arith")
    out = G.run(big, G.fitness_for_problem(F.F3, big), 100)
    assert best_isl <= float(out.best_y) * 1.5 + 0.2


def test_sharded_island_ga_on_multiple_devices():
    """Full shard_map island GA on 8 fake devices (subprocess so the forced
    device count doesn't leak into this process)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from jax.sharding import Mesh
from repro.core import fitness as F, ga as G, islands as ISL
mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = G.GAConfig(n=32, c=10, v=2, mutation_rate=0.05, seed=2, mode="arith")
icfg = ISL.IslandConfig(ga=cfg, n_islands=16, migrate_every=8,
                        axis_names=("data", "model"))
fit = G.fitness_for_problem(F.F3, cfg)
states, best = ISL.run_sharded(icfg, fit, mesh, epochs=6)
assert best < 2.0, best
print("SHARDED_OK", best)
"""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "SHARDED_OK" in r.stdout


def test_roofline_parser_on_known_program():
    def loss(ws, x):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(y ** 2)

    comp = jax.jit(jax.grad(loss)).lower(
        jax.ShapeDtypeStruct((10, 128, 128), jnp.float32),
        jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
    res = analyze_hlo(comp.as_text())
    # fwd + 2 bwd matmuls per scanned layer, times 10 layers
    assert res["flops"] == pytest.approx(10 * 3 * 2 * 128 ** 3, rel=0.05)
    assert res["collective_bytes"] == 0.0


def test_serving_engine_end_to_end():
    from repro.configs import get_config, reduced
    from repro.models import common as C
    from repro.models import lm as LM
    from repro.serve.engine import Engine, EngineConfig

    cfg = reduced(get_config("minitron-8b"))
    params = C.init_params(LM.model_defs(cfg, max_seq=128), jax.random.key(0))
    eng = Engine(cfg, params, EngineConfig(batch=2, max_len=128))
    prompts = np.ones((2, 16), np.int32)
    toks, stats = eng.generate(prompts, max_new_tokens=8)
    assert toks.shape == (2, 8)
    assert (toks >= 0).all() and (toks < cfg.vocab_).all()
    assert stats["decode_tok_per_s"] > 0
