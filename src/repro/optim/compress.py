"""Gradient compression for the DP all-reduce: int8 quantization with error
feedback (EF-SGD style residual carrying).

With pjit, gradient reduction is implicit in the backward pass; to compress
it we expose an explicit variant: `shard_map` the loss/grad computation over
the DP axes with per-device local grads, quantize, psum the int8 payload in
f32 (exact — values ≤ 127·count), dequantize, and carry the quantization
residual into the next step.  ~4× less DP traffic for bf16 grads.

Used by train/loop.py when `grad_compression=int8`; correctness is covered by
tests/test_compress.py (error feedback keeps the long-run average unbiased).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0
    q = jnp.round(g / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_psum(grads, residual, axis_names) -> Tuple[Any, Any]:
    """Inside shard_map: all-reduce int8-compressed grads with error feedback.

    grads/residual: local f32 pytrees. Returns (mean grads, new residual).
    """
    def one(g, r):
        g = g.astype(jnp.float32) + r
        q, scale = quantize_int8(g)
        new_r = g - dequantize_int8(q, scale)
        # psum the int8 payload in f32 (sum of ≤127-magnitude ints is exact),
        # and the scales alongside; scales differ per device so reduce value.
        deq = dequantize_int8(q, scale)
        total = deq
        count = jnp.float32(1.0)
        for ax in axis_names:
            total = jax.lax.psum(total, ax)
            count = jax.lax.psum(count, ax)
        return total / count, new_r

    flat_g, td = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree.unflatten(td, [o[0] for o in out]),
            jax.tree.unflatten(td, [o[1] for o in out]))


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
