"""mamba2-1.3b — SSD state-space model, attention-free [arXiv:2405.21060;
unverified].  d_state 128, headdim 64 (64 SSM heads), chunked SSD scan.
Vocab 50280 padded to 50432."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, vocab=50280,
    d_state=128, ssm_headdim=64, tie_embeddings=True,
    # ssm_chunk stays 256: chunk 64 was tried (predicted 4x less decay-
    # tensor traffic) and REFUTED — with sequence-sharded activations the
    # decay tensor is no longer dominant, and smaller chunks add inter-chunk
    # state traffic (6.25 s -> 6.57 s memory term; EXPERIMENTS.md §Perf).
)
