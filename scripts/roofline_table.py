#!/usr/bin/env python3
"""Render a dryrun results directory as the EXPERIMENTS.md roofline table.

    python scripts/roofline_table.py dryrun_results_v2 [pod1|pod2]
"""
import glob
import json
import sys


def main():
    dirname = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results"
    mesh = sys.argv[2] if len(sys.argv) > 2 else "pod1"
    print("| arch | shape | compute (ms) | memory (ms) | collective (ms) |"
          " dominant | MODEL/HLO | roofline % | temp GiB/dev |")
    print("|---|---|---|---|---|---|---|---|---|")
    for f in sorted(glob.glob(f"{dirname}/*.json")):
        d = json.load(open(f))
        if d.get("mesh") != mesh:
            continue
        if d["status"] != "ok":
            print(f"| {d['arch']} | {d['shape']} | — | — | — | *skipped* |"
                  " — | — | — |")
            continue
        print(f"| {d['arch']} | {d['shape']} | {d['t_compute']*1e3:.1f} |"
              f" {d['t_memory']*1e3:.1f} | {d['t_collective']*1e3:.1f} |"
              f" {d['dominant']} | {d['useful_flops_ratio']:.2f} |"
              f" {d['roofline_fraction']*100:.1f} |"
              f" {d['memory_analysis']['temp_size_in_bytes']/2**30:.1f} |")


if __name__ == "__main__":
    main()
