"""Multi-head Latent Attention (DeepSeek-V2/V3).

Queries and KV are projected through low-rank latents; only the compressed KV
latent (kv_lora_rank) + the shared decoupled RoPE key (rope_dim) are cached at
decode time — the memory win that makes 128-head attention serveable.

Shapes (V3): d=7168, H=128, q_lora=1536, kv_lora=512, qk_nope=128, rope=64,
v_head=128.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import sharding as SH
from repro.models import common as C

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 10_000.0

    @property
    def qk_dim(self) -> int:
        return self.qk_nope_dim + self.qk_rope_dim

    @property
    def scale(self) -> float:
        return self.qk_dim ** -0.5


def mla_defs(cfg: MLAConfig) -> Dict[str, C.ParamDef]:
    d, h = cfg.d_model, cfg.n_heads
    return {
        "w_dq": C.ParamDef((d, cfg.q_lora_rank), ("embed", None)),
        "q_norm": C.ParamDef((cfg.q_lora_rank,), (None,), init="zeros"),
        "w_uq": C.ParamDef((cfg.q_lora_rank, h, cfg.qk_dim), (None, "heads", None)),
        "w_dkv": C.ParamDef((d, cfg.kv_lora_rank), ("embed", None)),
        "kv_norm": C.ParamDef((cfg.kv_lora_rank,), (None,), init="zeros"),
        "w_uk": C.ParamDef((cfg.kv_lora_rank, h, cfg.qk_nope_dim), (None, "heads", None)),
        "w_uv": C.ParamDef((cfg.kv_lora_rank, h, cfg.v_head_dim), (None, "heads", None)),
        "w_kr": C.ParamDef((d, cfg.qk_rope_dim), ("embed", None)),
        "wo": C.ParamDef((h, cfg.v_head_dim, d), ("heads", None, "embed")),
    }


def _queries(p, x, cfg: MLAConfig, positions):
    cq = C.rmsnorm(C.dense(x, p["w_dq"]), p["q_norm"])
    q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"])
    q_nope, q_rope = q[..., :cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]
    cos, sin = C.rope_tables(positions, cfg.qk_rope_dim, cfg.rope_theta)
    q_rope = C.apply_rope(q_rope, cos, sin)
    return jnp.concatenate([q_nope, q_rope], axis=-1)


def _latent_kv(p, x, cfg: MLAConfig, positions):
    """Compressed latent c_kv (B,S,R) + decoupled rope key (B,S,rope)."""
    c_kv = C.rmsnorm(C.dense(x, p["w_dkv"]), p["kv_norm"])
    k_rope = C.dense(x, p["w_kr"])[:, :, None, :]  # (B,S,1,rope)
    cos, sin = C.rope_tables(positions, cfg.qk_rope_dim, cfg.rope_theta)
    k_rope = C.apply_rope(k_rope, cos, sin)[:, :, 0, :]
    return c_kv, k_rope


def _attend(q, c_kv, k_rope, p, cfg: MLAConfig, bias):
    """q: (B,Sq,H,qk); c_kv: (B,Sk,R); k_rope: (B,Sk,rope)."""
    k_nope = jnp.einsum("btr,rhk->bthk", c_kv, p["w_uk"])
    v = jnp.einsum("btr,rhv->bthv", c_kv, p["w_uv"])
    q_nope, q_rope = q[..., :cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]
    s_nope = jnp.einsum("bshk,bthk->bhst", q_nope, k_nope)
    s_rope = jnp.einsum("bshk,btk->bhst", q_rope, k_rope)
    scores = (s_nope + s_rope).astype(jnp.float32) * cfg.scale + bias
    scores = SH.constrain(scores, "batch", "heads", None, None)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhst,bthv->bshv", probs, v)
    return jnp.einsum("bshv,hvd->bsd", out, p["wo"])


def forward(p, x: jax.Array, cfg: MLAConfig,
            positions: Optional[jax.Array] = None) -> jax.Array:
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q = _queries(p, x, cfg, positions)
    q = SH.constrain(q, "batch", None, "heads", None)
    c_kv, k_rope = _latent_kv(p, x, cfg, positions)
    causal = (positions[:, :, None] >= positions[:, None, :])
    bias = jnp.where(causal, 0.0, NEG_INF).astype(jnp.float32)[:, None]
    return _attend(q, c_kv, k_rope, p, cfg, bias)


def cache_defs(cfg: MLAConfig, batch: int, max_len: int) -> Dict[str, C.ParamDef]:
    return {
        "c_kv": C.ParamDef((batch, max_len, cfg.kv_lora_rank),
                           ("batch", "act_seq", None), init="zeros"),
        "k_rope": C.ParamDef((batch, max_len, cfg.qk_rope_dim),
                             ("batch", "act_seq", None), init="zeros"),
    }


def prefill(p, x, cfg: MLAConfig, cache):
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q = _queries(p, x, cfg, positions)
    c_kv, k_rope = _latent_kv(p, x, cfg, positions)
    causal = (positions[:, :, None] >= positions[:, None, :])
    bias = jnp.where(causal, 0.0, NEG_INF).astype(jnp.float32)[:, None]
    out = _attend(q, c_kv, k_rope, p, cfg, bias)
    cache = {
        "c_kv": jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, 0, 0)),
        "k_rope": jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, 0, 0)),
    }
    return out, cache


def decode_step(p, x, cfg: MLAConfig, cache, pos):
    """x: (B,1,D); caches only the 512+64-dim latents (the MLA win)."""
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q = _queries(p, x, cfg, positions)
    c_kv_new, k_rope_new = _latent_kv(p, x, cfg, positions)
    c_kv = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_kv_new.astype(cache["c_kv"].dtype), (0, pos, 0))
    k_rope = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype), (0, pos, 0))
    s_max = c_kv.shape[1]
    valid = (jnp.arange(s_max)[None, :] <= pos)
    bias = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)[:, None, None, :]
    out = _attend(q, c_kv, k_rope, p, cfg, bias)
    return out, {"c_kv": c_kv, "k_rope": k_rope}
