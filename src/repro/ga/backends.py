"""Engine backends — orthogonal **topology × executor** compositions.

The paper's headline result comes from running many full GA pipelines side
by side, not one fast pipeline — so "fast step" and "parallel populations"
must compose.  The engine therefore splits every backend into two
orthogonal pieces:

An **executor** advances a stack of populations a block of generations:

  reference  pure-JAX `lax.scan` over the operator pipeline
             (repro.core.ga.run_scan); any registered operators.
  fused      the Pallas `ga_step` kernel — one launch per
             `spec.gens_per_epoch` generations (default 1), the stack rides
             the kernel grid axis; paper pipeline, arith FFM (ANY traceable
             problem: the spec's FitnessProgram.stage is traced into the
             kernel as its FFM stage, so n-variable registry problems and
             blackboxes run fused), power-of-two N <= 1024.  Bit-identical
             to `reference` (state and best; the trajectory coarsens to one
             sample per launch when gens_per_epoch > 1).

A **topology** owns population layout, the epoch loop and migration:

  single       one population (or `n_repeats` vmapped replicas), no
               migration; a segment is one executor block.
  island_ring  `n_islands` populations; every `migrate_every` generations
               the best individual of each island ring-shifts to the next
               (`repro.core.islands.migrate_ring`, `lax.ppermute` on a
               mesh), replacing the recipient's worst.  By default
               migration runs *between* executor blocks — i.e. between
               Pallas kernel launches on the fused executor — so any
               executor composes; with the fused executor, ring migration
               and `gens_per_epoch >= migrate_every` the epoch planner
               instead folds the migration INTO the VMEM-resident launch
               (see IslandRingTopology's docstring — resident /
               resident-sharded / gridded modes, all bit-identical).
               `n_repeats` replicas are vmapped OUTSIDE the island axis.
               Given a mesh, the island axis is `shard_map`ped over the
               mesh axes (`spec.mesh_axes`, default all) with EITHER
               executor — one kernel launch per shard on fused — and the
               ring crosses shards via a boundary-elite `ppermute`
               (`islands.migrate_ring_sharded`), bit-identical to the
               single-device run; replicas vmap inside each shard.

The registry exposes the compositions under the familiar names:

  reference     = reference × single
  fused         = fused     × single
  islands       = reference × island_ring  (shard_mapped when mesh given)
  fused-islands = fused     × island_ring  (ring migration between
                                            launches, or in-VMEM on the
                                            resident epoch plan;
                                            shard_mapped when mesh given)
  eager         = python-loop driver for non-traceable fitness (no
                  composition — fitness cannot be traced into a block)

Each backend implements `supports(spec)` (capability check → reason string
or None), `init(spec)` (backend-native state pytree) and `segment(state,
gens)` (advance `gens` generations, returning the new state + telemetry).
The Engine composes segments into full runs, chunked streaming and
checkpoint/resume — so every composition gets those features for free.
"""

from __future__ import annotations

import dataclasses
import math
import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.autotune import table as _cost
from repro.core import ga as G
from repro.core import islands as ISL
from repro.ga import compile_cache as CC
from repro.ga import operators as OPS
from repro.ga import telemetry as RT
from repro.ga.options import resolve_options
from repro.ga.spec import GASpec
from repro.kernels import ga_step as _ga_step


@dataclasses.dataclass
class Segment:
    """One contiguous block of generations (raw fitness units).

    traj arrays have one entry per generation, except island_ring topologies
    where the unit is one migration epoch (`migrate_every` generations —
    see telemetry.topology.telemetry_unit_gens).  `telemetry` is the typed
    run telemetry (ga.RunTelemetry); `.extras` is its deprecated dict view.
    """

    state: Any
    best_y: float
    best_x: np.ndarray          # uint32[V]
    traj_best: np.ndarray
    traj_mean: np.ndarray
    gens: int
    telemetry: RT.RunTelemetry = dataclasses.field(
        default_factory=RT.RunTelemetry)

    @property
    def extras(self) -> Dict[str, Any]:
        """Deprecated legacy dict view of `telemetry`."""
        return RT.deprecated_extras(self.telemetry, "Segment")


def _arg_best(y: np.ndarray, minimize: bool) -> int:
    return int(np.argmin(y) if minimize else np.argmax(y))


def _stack_states_seeded(cfg: G.GAConfig, seeds):
    """One replica per entry of `seeds`, stacked on a new leading axis.
    Replica i is bit-identical to a solo run seeded `seeds[i]` — the
    contract job packing relies on: a packed slot reproduces the job it
    came from exactly."""
    states = [G.init_state(dataclasses.replace(cfg, seed=s)) for s in seeds]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *states)


def _stack_states(cfg: G.GAConfig, n_replicas: int):
    """Replica r is seeded `seed + r` — replica 0 reproduces the solo run
    bit-exactly (asserted in tests), and the splitmix seed hash decorrelates
    consecutive integers."""
    return _stack_states_seeded(cfg, [cfg.seed + r for r in range(n_replicas)])


def _stack_island_replicas_seeded(icfg: ISL.IslandConfig, seeds):
    """[R, I, ...] stack with one island set per seed (see
    `_stack_states_seeded` for the per-slot bit-identity contract)."""
    reps = []
    for s in seeds:
        ga_r = dataclasses.replace(icfg.ga, seed=s)
        reps.append(ISL.init_islands_fast(dataclasses.replace(icfg, ga=ga_r)))
    return jax.tree.map(lambda *xs: jnp.stack(xs), *reps)


def _stack_island_replicas(icfg: ISL.IslandConfig, n_replicas: int):
    """[R, I, ...] stack: replica r re-seeds the island seed stream with
    `seed + r` (same convention as `_stack_states`, so replica 0 reproduces
    the n_repeats=1 island run bit-exactly)."""
    return _stack_island_replicas_seeded(
        icfg, [icfg.ga.seed + r for r in range(n_replicas)])


class Backend:
    """One execution strategy for a GASpec.

    Execution knobs arrive as one frozen `ga.EngineOptions` (`options=`);
    the legacy `mesh=/interpret=/cost_table=/plan_override=` kwargs still
    work (folded into an EngineOptions via `resolve_options`, which rejects
    mixing the two styles).  cost_table feeds the measured tier of the
    epoch planner (see `repro.autotune.table.resolve_table` for accepted
    values — None discovers the ambient per-host table, False disables
    measurement and pins the pure heuristic).  plan_override forces one
    epoch mode by name ("resident" / "streamed" / "gridded" / ...; the
    autotune runner uses it to measure non-default candidates) and raises
    if the spec cannot feasibly run that mode.  vmem_budget overrides the
    PLANNER's feasibility budget (the kernels still validate against the
    real one) and stream_tile_islands pins the streamed tile.  sel_lane
    overrides the spec's fused-kernel selection lane (the spec is re-built
    with the override, so validation/compile keys stay consistent).
    Options only influence launch shapes, never results — every plan is
    bit-identical in state and best tracking.
    """

    name = "?"

    def __init__(self, spec: GASpec, *, options=None, mesh=None,
                 interpret=None, cost_table=None, plan_override=None):
        self.options = resolve_options(options, mesh=mesh,
                                       interpret=interpret,
                                       cost_table=cost_table,
                                       plan_override=plan_override)
        if (self.options.sel_lane is not None
                and self.options.sel_lane != spec.sel_lane):
            # rebuild the spec so the override flows through validation,
            # ga_config() and compile_key() like a spec-level pin would
            spec = dataclasses.replace(spec, sel_lane=self.options.sel_lane)
        self.spec = spec
        self.cfg = spec.ga_config()
        self.mesh = self.options.mesh
        self.interpret = self.options.interpret
        self.cost_table = _cost.resolve_table(self.options.cost_table)
        self.plan_override = self.options.plan_override
        self._cache: Dict[Any, Any] = {}   # gens -> jitted segment runner

    @staticmethod
    def supports(spec: GASpec, mesh=None) -> Optional[str]:
        """None if the spec can run on this backend, else the reason why not."""
        raise NotImplementedError

    def init(self):
        raise NotImplementedError

    def init_packed(self, seeds):
        """Stacked state with one replica SLOT per seed — the layout job
        packing (repro.ga.engine.PackedEngine) runs many tenants through:
        slot i is bit-identical to a solo run seeded `seeds[i]`.  Backends
        whose replica axis is a host loop (eager) cannot pack."""
        raise NotImplementedError(
            f"backend {self.name!r} does not support packed (multi-job) "
            "state initialization")

    def segment(self, state, gens: int) -> Segment:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Executors — advance a stack of populations one block of generations
# ---------------------------------------------------------------------------


class Executor:
    """Steps a leading-axis stack of populations `gens` generations.

    `block(gens)` returns a traceable function
        states[L, ...] -> (states', best_y[L], best_x[L, V],
                           traj_best[L, T], traj_mean[L, T])
    where best_* track the best individual seen across the block and traj_*
    are population best/mean per trajectory sample (fitness of the
    pre-update population, so both executors' trajectories align
    bit-for-bit).  T is one entry per generation, except the fused executor
    with `gens_per_epoch > 1` where it is one entry per kernel launch
    (best_* still fold every generation via the in-kernel best).
    `final_fitness(states)` evaluates the *current* populations ([L, N]) —
    both executors use the same XLA fitness function here, so migration
    decisions are identical whichever executor produced the states.
    """

    name = "?"
    stacked_only = True    # False -> also offers an unstacked solo path

    def __init__(self, spec: GASpec, *, interpret=None):
        self.spec = spec
        self.cfg = spec.ga_config()
        self.fit = spec.fitness_fn()

    @staticmethod
    def supports(spec: GASpec) -> Optional[str]:
        raise NotImplementedError

    def final_fitness(self, states: G.GAState) -> jax.Array:
        return jax.vmap(self.fit)(states.x)

    def block(self, gens: int):
        raise NotImplementedError


class ReferenceExecutor(Executor):
    name = "reference"
    stacked_only = False

    def __init__(self, spec: GASpec, *, interpret=None):
        super().__init__(spec, interpret=interpret)
        self.gen_fn = OPS.make_generation(spec.selection, spec.crossover,
                                          spec.mutation)

    @staticmethod
    def supports(spec: GASpec) -> Optional[str]:
        if not spec.jit_fitness:
            return "fitness is not traceable (jit_fitness=False); use 'eager'"
        return None

    def solo(self, gens: int):
        """Unstacked single-population runner (GARun) — the layout the
        reference×single backend has always exposed for n_repeats=1."""
        return lambda st: G.run_scan(self.cfg, self.fit, gens, st,
                                     self.gen_fn)

    def block(self, gens: int):
        one = self.solo(gens)

        def run_block(states: G.GAState):
            out: G.GARun = jax.vmap(one)(states)
            return (out.state, out.best_y, out.best_x,
                    out.traj_best, out.traj_mean)

        return run_block


class FusedExecutor(Executor):
    name = "fused"
    stacked_only = True

    def __init__(self, spec: GASpec, *, interpret=None):
        super().__init__(spec, interpret=interpret)
        self.gens_per_epoch = spec.gens_per_epoch
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self.interpret = interpret

    @staticmethod
    def supports(spec: GASpec) -> Optional[str]:
        if not spec.jit_fitness:
            return "fitness is not traceable (jit_fitness=False); use 'eager'"
        if spec.mode != "arith":
            return ("Pallas kernel requires mode='arith' — LUT gathers stay "
                    "on the XLA path ('reference')")
        if spec.n & (spec.n - 1):
            return f"fused kernel requires power-of-two N (got {spec.n})"
        if (spec.resolved_sel_lane == "onehot"
                and spec.n > G.ONEHOT_MAX_N):
            # only reachable through a lane pin that bypassed GASpec
            # validation; sel_lane="auto" resolves to gather past the cap
            return (f"N={spec.n} > {G.ONEHOT_MAX_N} on the 'onehot' "
                    "selection lane: the (N, N) one-hot tournament matrices "
                    "must fit VMEM; use islands/reference or "
                    "sel_lane='gather'")
        if not spec.uses_paper_pipeline:
            return ("fused kernel hardwires the paper pipeline "
                    "(tournament/single_point/xor); other operators run on "
                    "'reference'")
        # size-gate hoisted FFM closure constants: the kernel replicates
        # them into VMEM on every grid step, so a fitness capturing a large
        # array (e.g. a dataset) must stream on the reference path instead
        # of silently blowing the VMEM budget
        try:
            const_bytes = _ga_step.ffm_const_bytes(spec.program().stage,
                                                   spec.ga_config())
        except Exception as e:                   # pragma: no cover — defensive
            return f"FFM stage failed to trace for the kernel ({e!r})"
        limit = _ga_step.ffm_const_limit()
        if const_bytes > limit:
            return (f"FFM stage captures {const_bytes} bytes of array "
                    f"constants (> the {limit}-byte VMEM gate): hoisted "
                    "consts replicate into VMEM per grid step — run "
                    "'reference' (REPRO_FFM_CONST_LIMIT overrides)")
        return None

    def block(self, gens: int):
        # the FFM stage traced into the kernel is the SAME function the
        # reference executor evaluates (Executor.__init__ sets self.fit =
        # spec.fitness_fn() = FitnessProgram.stage in arith mode), so any
        # registered n-variable problem or traceable blackbox runs fused and
        # stays bit-identical to reference by construction.
        cfg, ffm, interp = self.cfg, self.fit, self.interpret
        mini = self.spec.minimize
        # generations folded inside one launch: the in-kernel best fold
        # (track_best) keeps best_y/best_x bit-identical to gens_per_epoch=1;
        # trajectories coarsen to one sample per launch.
        gpe = max(1, min(self.gens_per_epoch, gens))
        n_full, rem = divmod(gens, gpe)

        def launch(g):
            def body(carry, _):
                x, sel, cross, mut, by, bx = carry
                x2, sel2, cross2, mut2, y, lby, lbx = \
                    _ga_step.ga_generation_kernel(
                        x, sel, cross, mut, cfg=cfg, ffm=ffm,
                        interpret=interp, gens=g, track_best=True)
                # lby/lbx fold the best over all g in-kernel generations
                # with the reference tie rule; the trajectory samples both
                # come from y — the launch's LAST pre-update population —
                # so traj_best and traj_mean describe the same window.
                better = lby < by if mini else lby > by
                by2 = jnp.where(better, lby, by)
                bx2 = jnp.where(better[:, None], lbx, bx)
                carry = (x2, sel2, cross2, mut2, by2, bx2)
                gen_best = (jnp.min(y, axis=1) if mini
                            else jnp.max(y, axis=1))
                return carry, (gen_best, jnp.mean(y, axis=1))
            return body

        def run_block(states: G.GAState):
            L = states.x.shape[0]
            neutral = jnp.full((L,), jnp.inf if mini else -jnp.inf,
                               jnp.float32)
            carry = (states.x, states.sel_lfsr, states.cross_lfsr,
                     states.mut_lfsr, neutral,
                     jnp.zeros((L, cfg.v), jnp.uint32))
            tbs, tms = [], []
            if n_full:
                carry, (tb, tm) = jax.lax.scan(launch(gpe), carry, None,
                                               length=n_full)
                tbs.append(tb)
                tms.append(tm)
            if rem:
                carry, (tb1, tm1) = launch(rem)(carry, None)
                tbs.append(tb1[None])
                tms.append(tm1[None])
            x, sel, cross, mut, by, bx = carry
            tb = jnp.concatenate(tbs, axis=0)    # [launches, L]
            tm = jnp.concatenate(tms, axis=0)
            state = G.GAState(x, sel, cross, mut, states.k + gens)
            return state, by, bx, tb.T, tm.T     # traj -> [L, launches]

        return run_block


EXECUTORS: Dict[str, type] = {
    ReferenceExecutor.name: ReferenceExecutor,
    FusedExecutor.name: FusedExecutor,
}


# ---------------------------------------------------------------------------
# Topologies — population layout, epoch loop, migration
# ---------------------------------------------------------------------------


def _mesh_axes(spec: GASpec, mesh) -> tuple:
    """Mesh axes the island axis shards over: `spec.mesh_axes` or all axes
    of the given mesh (IslandConfig's default names when there is no mesh)."""
    if spec.mesh_axes is not None:
        return tuple(spec.mesh_axes)
    if mesh is not None:
        return tuple(mesh.axis_names)
    return ("data", "model")


class Topology:
    name = "?"

    def __init__(self, spec: GASpec, executor: Executor, *, mesh=None,
                 cost_table=None, plan_override=None, vmem_budget=None,
                 stream_tile_islands=None):
        self.spec = spec
        self.cfg = spec.ga_config()
        self.executor = executor
        self.mesh = mesh
        # already-resolved CostTable (or None) + forced mode + planner
        # VMEM-budget override + pinned streamed tile; only the island_ring
        # planner consults them — single has one launch shape
        self.cost_table = cost_table
        self.plan_override = plan_override
        self.vmem_budget = vmem_budget
        self.stream_tile_islands = stream_tile_islands
        self._cache: Dict[Any, Any] = {}   # instance memo over RUNNER_CACHE

    def _cached_runner(self, key, builder):
        """Instance memo in front of the process-global RUNNER_CACHE, so the
        global hit/miss counters record one resolution per topology instance
        (i.e. per Engine build) instead of one per segment launch."""
        fn = self._cache.get(key)
        if fn is None:
            fn = CC.RUNNER_CACHE.get_or_build(key, builder)
            self._cache[key] = fn
        return fn

    @staticmethod
    def supports(spec: GASpec, mesh, executor_cls) -> Optional[str]:
        raise NotImplementedError

    def init(self):
        raise NotImplementedError

    def segment(self, state, gens: int) -> Segment:
        raise NotImplementedError


class SingleTopology(Topology):
    """One population; `n_repeats` independent replicas ride the executor's
    stack axis.  A segment is exactly one executor block."""

    name = "single"

    @staticmethod
    def supports(spec: GASpec, mesh, executor_cls) -> Optional[str]:
        if spec.effective_topology != "single":
            return ("n_islands > 1; use an island_ring backend "
                    "('islands' / 'fused-islands')")
        if mesh is not None:
            return ("single topology would silently ignore the mesh; "
                    "shard over devices with an island_ring backend "
                    "(n_islands > 1)")
        return None

    def init(self):
        if self.spec.n_repeats == 1 and not self.executor.stacked_only:
            return G.init_state(self.cfg)
        return _stack_states(self.cfg, self.spec.n_repeats)

    def init_packed(self, seeds):
        if len(seeds) != self.spec.n_repeats:
            raise ValueError(f"{len(seeds)} seeds packed into a spec with "
                             f"n_repeats={self.spec.n_repeats}")
        return _stack_states_seeded(self.cfg, seeds)

    def _runner(self, gens: int, solo: bool):
        key = CC.runner_key(self.spec, self.name, self.executor.name,
                            getattr(self.executor, "interpret", None),
                            self.mesh, "block", gens, solo)
        return self._cached_runner(
            key, lambda: jax.jit(self.executor.solo(gens) if solo
                                 else self.executor.block(gens)))

    def segment(self, state, gens: int) -> Segment:
        mini = self.spec.minimize
        solo = self.spec.n_repeats == 1 and not self.executor.stacked_only
        if solo:
            out: G.GARun = self._runner(gens, True)(state)
            return Segment(state=out.state, best_y=float(out.best_y),
                           best_x=np.asarray(out.best_x),
                           traj_best=np.asarray(out.traj_best),
                           traj_mean=np.asarray(out.traj_mean), gens=gens)
        state, by, bx, tb, tm = self._runner(gens, False)(state)
        per_rep = np.asarray(by)                               # [R]
        r = _arg_best(per_rep, mini)
        tb = np.asarray(tb)                                    # [R, gens]
        reduce = np.min if mini else np.max
        return Segment(state=state, best_y=float(per_rep[r]),
                       best_x=np.asarray(bx)[r],
                       traj_best=reduce(tb, axis=0),
                       traj_mean=np.asarray(tm).mean(axis=0),
                       gens=gens,
                       telemetry=RT.RunTelemetry(per_repeat=RT.ReplicaStats(
                           best=per_rep, best_x=np.asarray(bx),
                           traj_best=tb, traj_mean=np.asarray(tm))))


class IslandRingTopology(Topology):
    """`n_islands` populations with ring migration every `migrate_every`
    generations.  The epoch is [executor block → final fitness → ring
    migration] in one jit; `n_repeats` replicas are stacked OUTSIDE the
    island axis ([R, I, ...]) and flattened to the executor's single stack
    axis, so every executor (including the Pallas kernel, whose grid is that
    axis) composes.

    With a mesh, the SAME epoch is `shard_map`ped: the island axis is
    sharded over the mesh axes (`spec.mesh_axes`, default all), each shard
    runs its executor block — one Pallas kernel launch per shard on the
    fused executor — and migration becomes `islands.migrate_ring_sharded`
    (boundary-elite `lax.ppermute` between launches), which is bit-identical
    to the single-device `jnp.roll` ring.  Replicas vmap inside each shard,
    so `n_repeats > 1` and `migration='none'` compose with the mesh too.

    Epoch planning is TWO-TIER (see `kernels.ga_step`'s module docstring).
    Tier 1, feasibility: `epoch_candidates` asks
    `ga_step.epoch_mode_candidates` which launch shapes this spec can run,
    gated by the VMEM byte estimator:

      resident          (fused, ring, no mesh)  one launch folds
                        gens_per_epoch // migrate_every whole migration
                        intervals, full in-VMEM ring (`ring_migrate_stack`).
      resident-sharded  (fused, ring, mesh)  one launch per interval; the
                        intra-shard migrations run in VMEM and only the
                        boundary elite crosses shards via `ppermute`
                        between launches.
      resident-free     (fused, migration="none", no mesh)  no ring to run,
                        so ONE launch folds the whole gens_per_epoch (any
                        value — the whole-multiple rule is ring-only).
      streamed          (fused, resident does NOT fit)  the HBM-streaming
                        lane: `ga_streamed_epoch_kernel` tiles the island
                        axis through VMEM (`plan["tile_islands"]` islands
                        per grid step, double-buffered by the Pallas grid
                        pipeline) and the ring splice runs in XLA between
                        kernel passes inside one jitted scan over
                        gens_per_epoch // migrate_every intervals — on a
                        mesh the boundary elite `ppermute`s inside that
                        same scan, so k > 1 intervals fold per launch
                        (unlike resident-sharded).
      gridded           always feasible — the per-grid-step kernel with
                        migration between launches (the last-resort
                        fallback when not even one double-buffered streamed
                        tile fits; the estimator's reason rides in
                        plan["fallback"] either way).

    Tier 2, selection: candidates[0] is the heuristic (resident when it
    fits, else streamed with ring migration, else gridded — for
    migration="none" gridded stays the default and resident-free/streamed
    are measured choices).  When a measured cost table covers the
    spec — including the heuristic's own mode, so "measured beats
    heuristic" is provable rather than assumed — the planner instead picks
    the candidate with the best measured gens/s (`plan_source: "measured"`,
    expected rate in plan["plan_gens_per_s"]).  No table, a stale table or
    uncovered points leave the heuristic choice untouched
    (`plan_source: "heuristic"`), bit-identical to the pre-measurement
    planner.  A `plan_override` mode skips tier 2 entirely
    (`plan_source: "forced"`).

    Every plan is bit-identical in state and best tracking; resident modes
    coarsen the trajectory to one sample per launch."""

    name = "island_ring"

    def __init__(self, spec: GASpec, executor: Executor, *, mesh=None,
                 cost_table=None, plan_override=None, vmem_budget=None,
                 stream_tile_islands=None):
        super().__init__(spec, executor, mesh=mesh, cost_table=cost_table,
                         plan_override=plan_override,
                         vmem_budget=vmem_budget,
                         stream_tile_islands=stream_tile_islands)
        axis_names = _mesh_axes(spec, mesh)
        self.n_shards = (int(np.prod([mesh.shape[a] for a in axis_names]))
                         if mesh is not None else 1)
        self.icfg = ISL.IslandConfig(ga=self.cfg,
                                     n_islands=spec.n_islands,
                                     migrate_every=spec.migrate_every,
                                     axis_names=axis_names)
        self.i_local = max(1, spec.n_islands // max(1, self.n_shards))
        self.plan = self._epoch_plan()
        # the measured tier can move an "auto" spec to the OTHER selection
        # lane (cross-lane argmax); rebuild the configs every runner closes
        # over so the kernels actually run the chosen lane
        lane = self.plan.get("lane", self.cfg.sel_lane)
        if lane != self.cfg.sel_lane:
            self.cfg = dataclasses.replace(self.cfg, sel_lane=lane)
            self.icfg = dataclasses.replace(self.icfg, ga=self.cfg)
            self.executor.cfg = self.cfg

    def epoch_candidates(self) -> list:
        """Tier-1 feasible plan candidates, heuristic first (the autotune
        runner measures exactly this list, so table points and planner
        queries can never drift apart).  All candidates carry the spec's
        own resolved selection lane — the other lane's candidates are a
        separate, measured-only grid (`_lane_candidates`)."""
        return self._lane_candidates(self.cfg.sel_lane)

    def _lane_candidates(self, lane: str) -> list:
        """Feasible candidates with the selection lane forced to `lane`
        (the measured tier's (mode × lane) grid for sel_lane='auto')."""
        spec = self.spec
        cfg = (self.cfg if lane == self.cfg.sel_lane
               else dataclasses.replace(self.cfg, sel_lane=lane))
        const_bytes = (_ga_step.ffm_const_bytes(self.executor.fit, cfg)
                       if self.executor.name == "fused" else 0)
        return _ga_step.epoch_mode_candidates(
            cfg, self.i_local, const_bytes,
            executor=self.executor.name, migration=spec.migration,
            gens_per_epoch=spec.gens_per_epoch,
            migrate_every=spec.migrate_every,
            sharded=self.mesh is not None, budget=self.vmem_budget)

    def _plan_point(self, cand: Dict[str, Any]) -> Dict[str, Any]:
        return CC.plan_point(self.spec, executor=self.executor.name,
                             mode=cand["mode"], n_shards=self.n_shards,
                             lane=cand.get("lane"))

    def _epoch_plan(self) -> Dict[str, Any]:
        """Two-tier plan decision (see class docstring)."""
        cands = self.epoch_candidates()
        if self.plan_override is not None:
            want = (self.plan_override.get("mode")
                    if isinstance(self.plan_override, dict)
                    else self.plan_override)
            for c in cands:
                if c["mode"] == want:
                    plan = dict(c, plan_source="forced")
                    break
            else:
                hint = (" — streamed is only offered when the resident "
                        "stack exceeds the VMEM budget (this spec fits "
                        "resident; lower vmem_budget to force streaming)"
                        if want == "streamed" else "")
                raise ValueError(
                    f"plan_override mode {want!r} is not feasible for this "
                    f"spec (candidates: {[c['mode'] for c in cands]})"
                    + hint)
        else:
            plan = dict(cands[0], plan_source="heuristic")
            table = self.cost_table
            if table is not None:
                rated = [(c, table.lookup(self._plan_point(c),
                                          c["gens_per_launch"]))
                         for c in cands]
                # sel_lane="auto": the OTHER lane's feasible shapes join the
                # argmax as measured-only candidates — the heuristic never
                # switches lane on its own, measurement does
                if self.spec.sel_lane == "auto":
                    twin = ("gather" if self.cfg.sel_lane == "onehot"
                            else "onehot")
                    if (twin != "onehot"
                            or self.spec.n <= G.ONEHOT_MAX_N):
                        rated += [(c, table.lookup(self._plan_point(c),
                                                   c["gens_per_launch"]))
                                  for c in self._lane_candidates(twin)]
                # refine only when the heuristic's own point is measured:
                # the argmax is then provably >= the heuristic's measured
                # rate, and an uncovered spec stays bit-identical heuristic
                if len(rated) > 1 and rated[0][1] is not None:
                    best_c, best_v = rated[0]
                    for c, v in rated[1:]:
                        if v is not None and v > best_v:
                            best_c, best_v = c, v
                    plan = dict(best_c, plan_source="measured",
                                plan_gens_per_s=round(best_v, 3))
        # VMEM accounting below must price the lane the plan actually runs
        # (a measured cross-lane pick differs from self.cfg until __init__
        # re-resolves it)
        plan_cfg = self.cfg
        if plan.get("lane", plan_cfg.sel_lane) != plan_cfg.sel_lane:
            plan_cfg = dataclasses.replace(plan_cfg, sel_lane=plan["lane"])
        if plan["mode"] == "streamed":
            const_bytes = _ga_step.ffm_const_bytes(self.executor.fit,
                                                   plan_cfg)
            if self.stream_tile_islands is not None:
                t = int(self.stream_tile_islands)
                budget = (self.vmem_budget if self.vmem_budget is not None
                          else _ga_step.resident_vmem_budget())
                need = 2 * _ga_step.resident_vmem_bytes(plan_cfg, t,
                                                        const_bytes)
                if self.i_local % t or need > budget:
                    raise ValueError(
                        f"stream_tile_islands={t} is not a feasible tile: "
                        f"it must divide the local island count "
                        f"{self.i_local} and fit double-buffered "
                        f"(~{need} B vs budget {budget} B)")
                plan["tile_islands"] = t
            # the double-buffered working set of one tile — what actually
            # occupies VMEM while the grid pipeline streams the stack
            plan["vmem_estimate_bytes"] = 2 * _ga_step.resident_vmem_bytes(
                plan_cfg, plan["tile_islands"], const_bytes)
        elif plan["mode"].startswith("resident"):
            const_bytes = _ga_step.ffm_const_bytes(self.executor.fit,
                                                   plan_cfg)
            plan["vmem_estimate_bytes"] = _ga_step.resident_vmem_bytes(
                plan_cfg, self.i_local, const_bytes)
            if os.environ.get("REPRO_VMEM_COMPILER_CHECK") == "1":
                plan["vmem_compiler_check"] = _ga_step.resident_compiler_check(
                    plan_cfg, self.executor.fit, self.i_local,
                    interpret=getattr(self.executor, "interpret", None))
        elif self.executor.name == "fused":
            # gridded fused launches hold ONE island per program instance —
            # report its lane-aware working set so benches can show the
            # selection lane's VMEM drop, not just gens/s
            const_bytes = _ga_step.ffm_const_bytes(self.executor.fit,
                                                   plan_cfg)
            plan["vmem_estimate_bytes"] = _ga_step.resident_vmem_bytes(
                plan_cfg, 1, const_bytes)
        return plan

    @staticmethod
    def supports(spec: GASpec, mesh, executor_cls) -> Optional[str]:
        if spec.topology == "single":
            return "spec pins topology='single'; use a single backend"
        if mesh is not None:
            axes = _mesh_axes(spec, mesh)
            missing = [a for a in axes if a not in mesh.shape]
            if missing:
                return (f"mesh_axes {missing} not in the mesh "
                        f"(axes: {tuple(mesh.axis_names)})")
            n_shards = int(np.prod([mesh.shape[a] for a in axes]))
            if spec.n_islands % n_shards:
                return (f"n_islands={spec.n_islands} must divide evenly over "
                        f"the {n_shards} mesh shard(s)")
        return None

    def _place(self, states, lead: int):
        """Shard the island axis of a fresh state stack over the mesh."""
        if self.mesh is None:
            return states
        from jax.sharding import NamedSharding, PartitionSpec as P
        axes = self.icfg.axis_names
        return jax.tree.map(
            lambda x: jax.device_put(x, NamedSharding(
                self.mesh, P(*([None] * lead), axes,
                             *([None] * (x.ndim - 1 - lead))))), states)

    def init(self):
        if self.spec.n_repeats > 1:
            states = _stack_island_replicas(self.icfg, self.spec.n_repeats)
            lead = 1
        else:
            states = ISL.init_islands_fast(self.icfg)
            lead = 0
        return self._place(states, lead)

    def init_packed(self, seeds):
        if len(seeds) != self.spec.n_repeats:
            raise ValueError(f"{len(seeds)} seeds packed into a spec with "
                             f"n_repeats={self.spec.n_repeats}")
        lead = 1 if self.spec.n_repeats > 1 else 0
        if lead == 0:
            ga_s = dataclasses.replace(self.icfg.ga, seed=seeds[0])
            states = ISL.init_islands_fast(
                dataclasses.replace(self.icfg, ga=ga_s))
        else:
            states = _stack_island_replicas_seeded(self.icfg, seeds)
        return self._place(states, lead)

    def _runner_key(self, *parts):
        # self.cfg.sel_lane rides along explicitly: a measured plan can move
        # an "auto" spec to the other lane without changing compile_key()
        return CC.runner_key(self.spec, self.name, self.executor.name,
                             getattr(self.executor, "interpret", None),
                             self.mesh, self.cfg.sel_lane, *parts)

    def _resident_runner(self, k: int):
        """Jitted resident launch (no mesh): ONE `ga_epoch_kernel` call
        folding k whole migration intervals (k*migrate_every generations,
        ring migration in VMEM).  Returns the same (state', by, bx, tb, tm)
        contract as `_epoch`, with one trajectory sample per launch."""
        key = self._runner_key("resident", k)
        E = self.icfg.migrate_every
        R = self.spec.n_repeats
        mini = self.spec.minimize
        cfg, ffm = self.cfg, self.executor.fit
        interp = self.executor.interpret
        g4 = (lambda a: a) if R > 1 else (lambda a: a[None])
        sq = (lambda a: a) if R > 1 else (lambda a: a[0])

        def launch(states):                    # states: [R?, I, ...]
            x, sel, cross, mut, y, by, bx = _ga_step.ga_epoch_kernel(
                g4(states.x), g4(states.sel_lfsr), g4(states.cross_lfsr),
                g4(states.mut_lfsr), cfg=cfg, ffm=ffm, migrate_every=E,
                intervals=k, interpret=interp)
            state = G.GAState(sq(x), sq(sel), sq(cross), sq(mut),
                              states.k + k * E)
            tb = jnp.min(y, axis=-1) if mini else jnp.max(y, axis=-1)
            return (state, sq(by), sq(bx), sq(tb)[..., None],
                    sq(jnp.mean(y, axis=-1))[..., None])

        return self._cached_runner(key, lambda: jax.jit(launch))

    def _resident_free_runner(self, g: int):
        """Jitted migration-free resident launch (`migration="none"`, no
        mesh): ONE `ga_epoch_kernel(migrate=False)` call folding g
        generations — no ring, so g is unconstrained by `migrate_every`.
        Same (state', by, bx, tb, tm) contract as `_resident_runner`."""
        key = self._runner_key("resident-free", g)
        R = self.spec.n_repeats
        mini = self.spec.minimize
        cfg, ffm = self.cfg, self.executor.fit
        interp = self.executor.interpret
        g4 = (lambda a: a) if R > 1 else (lambda a: a[None])
        sq = (lambda a: a) if R > 1 else (lambda a: a[0])

        def launch(states):                    # states: [R?, I, ...]
            x, sel, cross, mut, y, by, bx = _ga_step.ga_epoch_kernel(
                g4(states.x), g4(states.sel_lfsr), g4(states.cross_lfsr),
                g4(states.mut_lfsr), cfg=cfg, ffm=ffm, migrate_every=g,
                intervals=1, migrate=False, interpret=interp)
            state = G.GAState(sq(x), sq(sel), sq(cross), sq(mut),
                              states.k + g)
            tb = jnp.min(y, axis=-1) if mini else jnp.max(y, axis=-1)
            return (state, sq(by), sq(bx), sq(tb)[..., None],
                    sq(jnp.mean(y, axis=-1))[..., None])

        return self._cached_runner(key, lambda: jax.jit(launch))

    def _streamed_runner(self, k: int):
        """Jitted HBM-streaming launch: k migration intervals, each ONE
        `ga_streamed_epoch_kernel` pass tiling the island stack through
        VMEM (`plan["tile_islands"]` islands per grid step; the Pallas grid
        pipeline double-buffers the tile loads), with the ring splice
        running in XLA between passes — all inside one jitted `lax.scan`.
        The kernel emits PRE-splice elites + worst slots and the scan body
        applies the same shift-by-one/`splice_at` rule set as
        `ring_migrate_stack`, so state stays bit-identical to the resident
        and gridded plans.  On a mesh the launch is shard_mapped and the
        boundary elite crosses shards via the `ppermute` ring INSIDE the
        scan body — which is why, unlike resident-sharded, k > 1 intervals
        fold per launch.  Same (state', by, bx, tb, tm) contract as
        `_resident_runner` (one trajectory sample per launch)."""
        tile = self.plan["tile_islands"]
        key = self._runner_key("streamed", k, tile)
        E = self.icfg.migrate_every
        R = self.spec.n_repeats
        mini = self.spec.minimize
        migrate = self.spec.migration == "ring"
        cfg, ffm = self.cfg, self.executor.fit
        interp = self.executor.interpret
        mesh, axes = self.mesh, self.icfg.axis_names
        g4 = (lambda a: a) if R > 1 else (lambda a: a[None])
        sq = (lambda a: a) if R > 1 else (lambda a: a[0])

        def launch(states):                    # states: [R?, I(_loc), ...]
            x0 = g4(states.x)
            n_groups, i_loc = x0.shape[0], x0.shape[1]
            init = (x0, g4(states.sel_lfsr), g4(states.cross_lfsr),
                    g4(states.mut_lfsr),
                    jnp.full((n_groups, i_loc),
                             jnp.inf if mini else -jnp.inf, jnp.float32),
                    jnp.zeros((n_groups, i_loc, cfg.v), jnp.uint32))

            def interval(carry, _):
                x, sel, cross, mut, by, bx = carry
                outs = _ga_step.ga_streamed_epoch_kernel(
                    x, sel, cross, mut, cfg=cfg, ffm=ffm, migrate_every=E,
                    tile_islands=tile, migrate=migrate, interpret=interp)
                if migrate:
                    x, sel, cross, mut, ymig, lby, lbx, elite, widx = outs
                    if mesh is None:
                        # island 0 receives island I-1's elite — the same
                        # roll `ring_migrate_stack` writes as a concat
                        incoming = jnp.concatenate(
                            [elite[:, -1:], elite[:, :-1]], axis=1)
                    else:
                        # one global ring: the last LOCAL island's elite
                        # crosses to the next shard, whose island 0 takes it
                        recv = ISL.ring_shift_sharded(elite[:, -1], mesh,
                                                      axes)
                        incoming = jnp.concatenate(
                            [recv[:, None], elite[:, :-1]], axis=1)
                    x = jax.vmap(ISL.splice_at)(x, widx, incoming)
                else:
                    x, sel, cross, mut, ymig, lby, lbx = outs
                # fold the interval's in-kernel best into the launch best
                # (strict improvement: earlier intervals win ties, matching
                # the resident kernel's sequential per-generation fold)
                better = lby < by if mini else lby > by
                by = jnp.where(better, lby, by)
                bx = jnp.where(better[..., None], lbx, bx)
                return (x, sel, cross, mut, by, bx), ymig

            carry, ys = jax.lax.scan(interval, init, None, length=k)
            x, sel, cross, mut, by, bx = carry
            ymig = ys[-1]                      # final interval, pre-splice
            state = G.GAState(sq(x), sq(sel), sq(cross), sq(mut),
                              states.k + k * E)
            tb = jnp.min(ymig, axis=-1) if mini else jnp.max(ymig, axis=-1)
            return (state, sq(by), sq(bx), sq(tb)[..., None],
                    sq(jnp.mean(ymig, axis=-1))[..., None])

        fn = launch
        if mesh is not None:
            from jax.sharding import PartitionSpec as P
            from repro.sharding import shard_map
            lead = () if R == 1 else (None,)

            def pfor(extra):
                return P(*lead, axes, *([None] * extra))

            state_specs = G.GAState(x=pfor(2), sel_lfsr=pfor(2),
                                    cross_lfsr=pfor(2), mut_lfsr=pfor(2),
                                    k=pfor(0))
            fn = shard_map(
                launch, mesh, in_specs=(state_specs,),
                out_specs=(state_specs, pfor(0), pfor(1), pfor(1), pfor(1)))

        return self._cached_runner(key, lambda: jax.jit(fn))

    def _resident_sharded_epoch(self):
        """Shard-local epoch body for the resident-sharded plan: one
        `ga_epoch_kernel(boundary=True)` launch runs `migrate_every`
        generations + the INTRA-shard migrations in VMEM, then the boundary
        elite crosses to the next shard via the `ppermute` ring and lands in
        the first island's (in-kernel decided) worst slot.  Globally
        bit-identical to `migrate_ring_sharded` — same elite/worst rules,
        same logical-coordinate ring."""
        E = self.icfg.migrate_every
        R = self.spec.n_repeats
        cfg, ffm = self.cfg, self.executor.fit
        interp = self.executor.interpret
        mesh, axes = self.mesh, self.icfg.axis_names
        mini = self.spec.minimize
        g4 = (lambda a: a) if R > 1 else (lambda a: a[None])
        sq = (lambda a: a) if R > 1 else (lambda a: a[0])

        def epoch(states):                     # states: [R?, I_loc, ...]
            x, sel, cross, mut, y, by, bx, send, w0 = \
                _ga_step.ga_epoch_kernel(
                    g4(states.x), g4(states.sel_lfsr),
                    g4(states.cross_lfsr), g4(states.mut_lfsr), cfg=cfg,
                    ffm=ffm, migrate_every=E, intervals=1, boundary=True,
                    interpret=interp)
            # send: [G, V] boundary elites (one ring per replica group);
            # ppermute moves the whole block to the next shard at once, and
            # the received elite lands in island 0's in-kernel-decided worst
            # slot through the same splice rule set as every other splice
            recv = ISL.ring_shift_sharded(send, mesh, axes)
            x = x.at[:, 0].set(ISL.splice_at(x[:, 0], w0, recv))
            state = G.GAState(sq(x), sq(sel), sq(cross), sq(mut),
                              states.k + E)
            tb = jnp.min(y, axis=-1) if mini else jnp.max(y, axis=-1)
            return (state, sq(by), sq(bx), sq(tb)[..., None],
                    sq(jnp.mean(y, axis=-1))[..., None])

        return epoch

    def _epoch(self):
        """Jitted epoch over the canonical state layout ([I,...] or
        [R, I, ...]); returns (state', by, bx, tb, tm) with by/bx/tb/tm in
        [R, I, ...] layout (leading R axis only when n_repeats > 1).  On a
        mesh the epoch body is shard_mapped over the island axis — the body
        sees [R?, I/n_shards, ...] blocks and the ring crosses shards via
        `ppermute`; telemetry comes back as the same global arrays."""
        key = self._runner_key("epoch", self.plan["mode"])
        if key in self._cache:
            return self._cache[key]
        E = self.icfg.migrate_every
        R = self.spec.n_repeats
        mini = self.spec.minimize
        migrate = self.spec.migration == "ring"
        mesh, axes = self.mesh, self.icfg.axis_names
        if self.plan["mode"] == "resident-sharded":
            epoch = self._resident_sharded_epoch()
        else:
            blk = self.executor.block(E)
            fit_stack = self.executor.final_fitness

            if mesh is None:
                mig = lambda s, yy: ISL.migrate_ring(s, yy, minimize=mini)
            else:
                mig = lambda s, yy: ISL.migrate_ring_sharded(
                    s, yy, minimize=mini, mesh=mesh, axis_names=axes)

            def one(states):                   # states: [I(_loc), ...]
                states, by, bx, tb, tm = blk(states)
                if migrate:
                    y = fit_stack(states)      # [I(_loc), N]
                    states, _ex, _ey = mig(states, y)
                return states, by, bx, tb, tm

            if R == 1:
                epoch = one
            else:
                def epoch(states):             # states: [R, I(_loc), ...]
                    il = states.x.shape[1]
                    flat = jax.tree.map(
                        lambda a: a.reshape((R * il,) + a.shape[2:]), states)
                    flat, by, bx, tb, tm = blk(flat)
                    states = jax.tree.map(
                        lambda a: a.reshape((R, il) + a.shape[1:]), flat)
                    if migrate:
                        y = jax.vmap(fit_stack)(states)    # [R, I_loc, N]
                        states, _ex, _ey = jax.vmap(mig)(states, y)
                    return (states, by.reshape(R, il),
                            bx.reshape((R, il) + bx.shape[1:]),
                            tb.reshape((R, il) + tb.shape[1:]),
                            tm.reshape((R, il) + tm.shape[1:]))

        if mesh is not None:
            from jax.sharding import PartitionSpec as P
            from repro.sharding import shard_map
            lead = () if R == 1 else (None,)

            def pfor(extra):   # island axis sharded, `extra` trailing dims
                return P(*lead, axes, *([None] * extra))

            state_specs = G.GAState(x=pfor(2), sel_lfsr=pfor(2),
                                    cross_lfsr=pfor(2), mut_lfsr=pfor(2),
                                    k=pfor(0))
            epoch = shard_map(
                epoch, mesh, in_specs=(state_specs,),
                out_specs=(state_specs, pfor(0), pfor(1), pfor(1), pfor(1)))

        return self._cached_runner(key, lambda: jax.jit(epoch))

    def segment(self, state, gens: int) -> Segment:
        E = self.icfg.migrate_every
        epochs = max(1, math.ceil(gens / E))
        mode = self.plan["mode"]
        per_launch = self.plan["epochs_per_launch"]
        R = self.spec.n_repeats
        mini = self.spec.minimize
        reduce = np.min if mini else np.max
        # launch schedule: every plan covers the SAME epochs * E total
        # generations (the rounding contract all modes share), but
        # resident-free paces in raw generations — no ring means no
        # interval boundary to respect — while resident/streamed cover
        # `per_launch` whole migration intervals per launch and the rest
        # one epoch at a time
        if mode == "resident-free":
            g_max = self.plan["gens_per_launch"]
            sched, left = [], epochs * E
            while left:
                g = min(g_max, left)
                sched.append(self._resident_free_runner(g))
                left -= g
            unit = g_max
        else:
            sched, left = [], epochs
            while left:
                k = min(per_launch, left)
                if mode == "resident":
                    sched.append(self._resident_runner(k))
                elif mode == "streamed":
                    sched.append(self._streamed_runner(k))
                else:
                    sched.append(self._epoch())
                left -= k
            unit = E * per_launch
        # running per-replica best across launches (telemetry arrays get
        # one sample per launch)
        rep_y = np.full((R,), np.inf if mini else -np.inf, np.float32)
        rep_x = np.zeros((R, self.cfg.v), np.uint32)
        tb_ep, tm_ep = [], []          # per-launch, per-replica ([R] each)
        launches = 0
        for runner in sched:
            state, by, bx, tb, tm = runner(state)
            by = np.asarray(by).reshape(R, -1)              # [R, I]
            bx = np.asarray(bx).reshape(R, -1, self.cfg.v)  # [R, I, V]
            i = np.argmin(by, axis=1) if mini else np.argmax(by, axis=1)
            ep_y = by[np.arange(R), i]                      # [R]
            ep_x = bx[np.arange(R), i]
            better = ep_y < rep_y if mini else ep_y > rep_y
            rep_y = np.where(better, ep_y, rep_y)
            rep_x = np.where(better[:, None], ep_x, rep_x)
            tb_ep.append(reduce(by, axis=1))                           # [R]
            tm_ep.append(np.asarray(tm).reshape(R, -1).mean(axis=1))   # [R]
            launches += 1
        r = _arg_best(rep_y, mini)
        tb_rep = np.stack(tb_ep, axis=1)                    # [R, launches]
        tm_rep = np.stack(tm_ep, axis=1)
        tele = RT.RunTelemetry(
            plan=RT.PlanInfo.from_plan(self.plan),
            topology=RT.TopologyInfo(
                n_islands=self.icfg.n_islands,
                n_shards=self.n_shards,
                sharded=self.mesh is not None,
                launches=launches,
                migrations=(epochs if self.spec.migration == "ring" else 0),
                telemetry_unit_gens=unit),
            # per-replica views: job packing (PackedEngine) unpacks each
            # tenant's best/trajectory from its slot range here
            per_repeat=RT.ReplicaStats(best=rep_y, best_x=rep_x,
                                       traj_best=tb_rep, traj_mean=tm_rep))
        return Segment(state=state, best_y=float(rep_y[r]),
                       best_x=rep_x[r],
                       traj_best=reduce(tb_rep, axis=0),
                       traj_mean=tm_rep.mean(axis=0),
                       gens=epochs * E, telemetry=tele)


TOPOLOGIES: Dict[str, type] = {
    SingleTopology.name: SingleTopology,
    IslandRingTopology.name: IslandRingTopology,
}


# ---------------------------------------------------------------------------
# Composed backends (the registry entries)
# ---------------------------------------------------------------------------


class ComposedBackend(Backend):
    """A (topology × executor) pair behind the uniform Backend interface."""

    executor_cls: type = None
    topology_cls: type = None

    def __init__(self, spec: GASpec, *, options=None, mesh=None,
                 interpret=None, cost_table=None, plan_override=None):
        super().__init__(spec, options=options, mesh=mesh,
                         interpret=interpret, cost_table=cost_table,
                         plan_override=plan_override)
        opts = self.options
        # self.spec, not the constructor arg: Backend.__init__ may have
        # rebuilt the spec to apply an options-level sel_lane override
        self.executor: Executor = self.executor_cls(
            self.spec, interpret=opts.interpret)
        self.topology: Topology = self.topology_cls(
            self.spec, self.executor, mesh=opts.mesh,
            cost_table=self.cost_table, plan_override=opts.plan_override,
            vmem_budget=opts.vmem_budget,
            stream_tile_islands=opts.stream_tile_islands)

    @classmethod
    def supports(cls, spec: GASpec, mesh=None) -> Optional[str]:
        reason = cls.executor_cls.supports(spec)
        if reason is not None:
            return reason
        return cls.topology_cls.supports(spec, mesh, cls.executor_cls)

    def init(self):
        return self.topology.init()

    def init_packed(self, seeds):
        return self.topology.init_packed(seeds)

    def segment(self, state, gens: int) -> Segment:
        seg = self.topology.segment(state, gens)
        info = seg.telemetry.topology
        if info.executor == "-":
            info.executor = self.executor_cls.name
            info.topology = self.topology_cls.name
        return seg


def _compose(backend_name: str, executor: type, topology: type) -> type:
    cls = type(f"{backend_name.title().replace('-', '')}Backend",
               (ComposedBackend,),
               {"name": backend_name, "executor_cls": executor,
                "topology_cls": topology})
    return cls


ReferenceBackend = _compose("reference", ReferenceExecutor, SingleTopology)
FusedBackend = _compose("fused", FusedExecutor, SingleTopology)
IslandsBackend = _compose("islands", ReferenceExecutor, IslandRingTopology)
FusedIslandsBackend = _compose("fused-islands", FusedExecutor,
                               IslandRingTopology)


# ---------------------------------------------------------------------------
# eager — python generation loop for non-traceable fitness
# ---------------------------------------------------------------------------


def _pooled_fitness(fit, workers: int):
    """Population-parallel host fitness: split the (N, V) batch into
    `workers` contiguous row chunks and evaluate them on a bounded thread
    pool.  Chunks come back in submission order and are concatenated, so
    the result is bitwise identical to the serial batch call — the pool
    only overlaps the (GIL-releasing or I/O-bound) fitness work."""
    from concurrent.futures import ThreadPoolExecutor
    pool = ThreadPoolExecutor(max_workers=workers)

    def pooled(x):
        x = np.asarray(x)
        n = x.shape[0]
        chunk = max(1, -(-n // workers))
        parts = [x[i:i + chunk] for i in range(0, n, chunk)]
        outs = list(pool.map(
            lambda p: np.asarray(fit(p), np.float32), parts))
        return np.concatenate(outs, axis=0)

    return pooled


class EagerBackend(Backend):
    name = "eager"

    def __init__(self, spec, **kw):
        super().__init__(spec, **kw)
        spec = self.spec
        self.fit = spec.fitness_fn()
        if self.options.fitness_workers > 1:
            self.fit = _pooled_fitness(self.fit,
                                       self.options.fitness_workers)
        self.apply_ops = OPS.make_apply_ops(spec.selection, spec.crossover,
                                            spec.mutation)

    @staticmethod
    def supports(spec: GASpec, mesh=None) -> Optional[str]:
        if spec.effective_topology != "single":
            return "eager driver has no migration; use an island_ring backend"
        if mesh is not None:
            return ("eager driver is host-local and would silently ignore "
                    "the mesh; use an island_ring backend (n_islands > 1)")
        return None

    def init(self):
        if self.spec.n_repeats == 1:
            return G.init_state(self.cfg)
        return _stack_states(self.cfg, self.spec.n_repeats)

    def segment(self, state, gens: int) -> Segment:
        R = self.spec.n_repeats
        mini = self.spec.minimize
        if R == 1:
            out = G.run_eager(self.cfg, self.fit, gens, state,
                              apply_ops_fn=self.apply_ops)
            return Segment(state=out.state, best_y=float(out.best_y),
                           best_x=np.asarray(out.best_x),
                           traj_best=np.asarray(out.traj_best),
                           traj_mean=np.asarray(out.traj_mean), gens=gens)
        outs = []
        for r in range(R):
            st_r = jax.tree.map(lambda a: a[r], state)
            cfg_r = dataclasses.replace(self.cfg, seed=self.cfg.seed + r)
            outs.append(G.run_eager(cfg_r, self.fit, gens, st_r,
                                    apply_ops_fn=self.apply_ops))
        state = jax.tree.map(lambda *xs: jnp.stack(xs),
                             *[o.state for o in outs])
        per_rep = np.array([float(o.best_y) for o in outs])
        i = _arg_best(per_rep, mini)
        tb = np.stack([np.asarray(o.traj_best) for o in outs])
        reduce = np.min if mini else np.max
        return Segment(state=state, best_y=float(per_rep[i]),
                       best_x=np.asarray(outs[i].best_x),
                       traj_best=reduce(tb, axis=0),
                       traj_mean=np.stack([np.asarray(o.traj_mean)
                                           for o in outs]).mean(axis=0),
                       gens=gens,
                       telemetry=RT.RunTelemetry(
                           per_repeat=RT.ReplicaStats(best=per_rep)))


BACKENDS: Dict[str, type] = {
    ReferenceBackend.name: ReferenceBackend,
    FusedBackend.name: FusedBackend,
    IslandsBackend.name: IslandsBackend,
    FusedIslandsBackend.name: FusedIslandsBackend,
    EagerBackend.name: EagerBackend,
}
