"""Deterministic, seed-addressable fault injection for the serving stack.

A fault-tolerant scheduler is only as trustworthy as the failures it has
actually been exercised against.  This module is the repo's failure
*source*: a registry of named injection points threaded through the hot
paths of the serving stack, armed by a compact rule grammar and completely
inert (a dict lookup returning None) when disarmed.

Injection sites (`SITES`):

  * ``chunk_crash``  — raised between a chunk's compute and its checkpoint
    in `Engine.run_chunked` / `PackedEngine.run_chunked` (a worker dying
    mid-run; the chunk's work is lost but the previous checkpoint is not);
  * ``compile_fail`` — raised in the scheduler worker before the packed
    engine is built (a trace/compile blow-up, e.g. a transient OOM);
  * ``ckpt_corrupt`` — not an exception: `repro.ckpt.checkpoint.save`
    flips bytes in the just-written shard AFTER its checksum was recorded
    (bit-rot / torn write; the manifest checksum then catches it on read);
  * ``slow_chunk``   — sleeps `delay` seconds before a chunk's compute
    (a straggler; drives deadline enforcement without wall-clock flake).

Rule grammar — rules separated by ``;``, fields by ``:``::

    site[@match][:at=N[,M...]][:after=K][:times=T][:p=P][:seed=S][:delay=D]

  * ``match``   substring that must appear in the site invocation's tag
    (the engine tags chunk sites with the scheduler's job ids, checkpoint
    saves with job ids + path, so ``chunk_crash@ga-3-F3`` targets one job);
  * ``at``      fire exactly on these 1-based matching occurrences;
  * ``after``/``times``  fire on occurrences ``after+1 .. after+times``
    (defaults: after=0, times=1; ``times=inf`` never stops firing);
  * ``p``/``seed``  fire when the deterministic hash of
    ``(seed, site, occurrence)`` lands under probability ``p`` — the
    seed-addressable mode: same seed, same decision sequence, every run;
  * ``delay``   seconds ``slow_chunk`` sleeps (default 0.05).

Arming: pass a rule string / `FaultInjector` through
``ga.EngineOptions(faults=...)`` (shared by `Engine`, `PackedEngine`,
`GAScheduler` and the ``--faults`` CLI flag), or set the ambient
``REPRO_GA_FAULTS`` environment variable.  `resolve_faults(None)` reads
the env (memoized per rule string so occurrence counters persist across
call sites); ``False`` disarms even against the env.

Everything here is deterministic — occurrence counters plus a seeded
hash, never `random` — so a chaos run that found a bug replays the exact
same fault sequence (`scripts/chaos_smoke.py` relies on this).

Import-light on purpose (stdlib only): the scheduler and checkpoint code
consult it on every chunk/save.
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time
import zlib
from typing import Dict, Optional, Tuple

SITES = ("chunk_crash", "compile_fail", "ckpt_corrupt", "slow_chunk")


class FaultError(RuntimeError):
    """Base class of every injected failure.  `site` names the injection
    point, `transient` steers the scheduler's retry classification."""

    site = "?"
    transient = True

    def __init__(self, msg: str, tag: str = ""):
        super().__init__(msg)
        self.tag = tag


class ChunkCrash(FaultError):
    """Injected mid-run crash between a chunk's compute and its checkpoint."""

    site = "chunk_crash"
    transient = True


class CompileFail(FaultError):
    """Injected engine-build failure (trace/compile blow-up)."""

    site = "compile_fail"
    transient = True


@dataclasses.dataclass(frozen=True)
class FaultRule:
    """One armed rule: which site fires, on which occurrences, for tags
    containing `match`.  Decision order: `at` if set, else `p` (seeded
    hash), else the `after`/`times` window."""

    site: str
    match: str = ""
    at: Tuple[int, ...] = ()
    after: int = 0
    times: float = 1.0           # float so "inf" parses
    p: Optional[float] = None
    seed: int = 0
    delay_s: float = 0.05

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"known sites: {SITES}")
        if self.p is not None and not (0.0 <= self.p <= 1.0):
            raise ValueError(f"p must be in [0, 1], got {self.p!r}")

    def decides(self, n: int) -> bool:
        """Does this rule fire on its n-th (1-based) matching occurrence?"""
        if self.at:
            return n in self.at
        if self.p is not None:
            return _hash01(self.seed, self.site, n) < self.p
        return self.after < n <= self.after + self.times


def _hash01(seed: int, site: str, n: int) -> float:
    """Deterministic hash of (seed, site, occurrence) onto [0, 1)."""
    return (zlib.crc32(f"{seed}:{site}:{n}".encode()) % 1_000_000) / 1_000_000


def parse_rule(text: str) -> FaultRule:
    """Parse one ``site[@match][:key=value...]`` rule."""
    fields = text.strip().split(":")
    head = fields[0]
    site, _, match = head.partition("@")
    kw: Dict[str, object] = {"site": site.strip(), "match": match.strip()}
    for field in fields[1:]:
        if not field:
            continue
        key, _, val = field.partition("=")
        key = key.strip()
        if key == "at":
            kw["at"] = tuple(int(v) for v in val.split(",") if v)
        elif key == "after":
            kw["after"] = int(val)
        elif key == "times":
            kw["times"] = float("inf") if val == "inf" else float(val)
        elif key == "p":
            kw["p"] = float(val)
        elif key == "seed":
            kw["seed"] = int(val)
        elif key == "delay":
            kw["delay_s"] = float(val)
        else:
            raise ValueError(f"unknown fault rule field {key!r} in {text!r}")
    return FaultRule(**kw)


def parse_faults(text: str) -> "FaultInjector":
    """Parse a ``;``-separated rule list into an armed injector."""
    rules = [parse_rule(r) for r in text.split(";") if r.strip()]
    return FaultInjector(rules)


class FaultInjector:
    """Thread-safe registry of armed `FaultRule`s with per-rule occurrence
    counters.  `inject(site, tag)` is the one call threaded through the
    serving stack: it counts the occurrence against every matching rule
    and, if one fires, performs the site's action (raise / sleep / signal
    the caller to corrupt).  Share ONE instance across the components of a
    run — the occurrence counters are the determinism contract."""

    def __init__(self, rules=()):
        self._lock = threading.Lock()
        self._rules = [r if isinstance(r, FaultRule) else parse_rule(r)
                       for r in rules]
        self._counts: Dict[int, int] = {}
        self.fired: Dict[str, int] = {}

    def add_rule(self, rule) -> FaultRule:
        """Arm one more rule (a `FaultRule` or rule string) — lets a chaos
        harness target job ids it only learns after submission."""
        rule = rule if isinstance(rule, FaultRule) else parse_rule(rule)
        with self._lock:
            self._rules.append(rule)
        return rule

    def fires(self, site: str, tag: str = "") -> Optional[FaultRule]:
        """Count this occurrence; return the first rule that fires (and
        bump the site's `fired` counter), or None."""
        hit = None
        with self._lock:
            for i, rule in enumerate(self._rules):
                if rule.site != site:
                    continue
                if rule.match and rule.match not in tag:
                    continue
                n = self._counts[i] = self._counts.get(i, 0) + 1
                if hit is None and rule.decides(n):
                    hit = rule
            if hit is not None:
                self.fired[site] = self.fired.get(site, 0) + 1
        return hit

    def inject(self, site: str, tag: str = "") -> Optional[FaultRule]:
        """The injection point: no-op unless a matching rule fires, then
        perform the site's action.  ``chunk_crash``/``compile_fail`` raise,
        ``slow_chunk`` sleeps, ``ckpt_corrupt`` returns the rule so the
        checkpoint writer corrupts the shard itself."""
        rule = self.fires(site, tag)
        if rule is None:
            return None
        if site == "chunk_crash":
            raise ChunkCrash(f"injected chunk crash (tag={tag!r})", tag)
        if site == "compile_fail":
            raise CompileFail(f"injected compile failure (tag={tag!r})", tag)
        if site == "slow_chunk":
            time.sleep(rule.delay_s)
        return rule

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return dict(self.fired)

    def __repr__(self):
        return f"FaultInjector({len(self._rules)} rule(s), fired={self.fired})"


# ---------------------------------------------------------------------------
# Arming resolution (EngineOptions.faults / REPRO_GA_FAULTS)
# ---------------------------------------------------------------------------

ENV_VAR = "REPRO_GA_FAULTS"
_AMBIENT: Dict[str, FaultInjector] = {}
_AMBIENT_LOCK = threading.Lock()


def ambient() -> Optional[FaultInjector]:
    """The env-armed injector, memoized per rule string so occurrence
    counters persist across every call site in the process."""
    text = os.environ.get(ENV_VAR)
    if not text:
        return None
    with _AMBIENT_LOCK:
        inj = _AMBIENT.get(text)
        if inj is None:
            inj = _AMBIENT[text] = parse_faults(text)
        return inj


def resolve_faults(spec) -> Optional[FaultInjector]:
    """`EngineOptions.faults` semantics: None discovers the ambient env
    injector, False disarms, a rule string parses (resolve ONCE and share
    the instance — counters live on it), an injector passes through."""
    if spec is False:
        return None
    if spec is None:
        return ambient()
    if isinstance(spec, FaultInjector):
        return spec
    if isinstance(spec, str):
        return parse_faults(spec)
    raise TypeError(f"faults must be None, False, a rule string or a "
                    f"FaultInjector, got {type(spec).__name__}")


# ---------------------------------------------------------------------------
# Helpers for fault consumers
# ---------------------------------------------------------------------------


def corrupt_file(path: str, seed: int = 0, nbytes: int = 8) -> None:
    """Deterministically flip `nbytes` bytes of `path` in place (XOR 0xFF
    at seeded positions) — the ckpt_corrupt action, also usable directly
    by tests simulating bit-rot."""
    with open(path, "r+b") as f:
        f.seek(0, os.SEEK_END)
        size = f.tell()
        if size == 0:
            return
        for i in range(nbytes):
            pos = zlib.crc32(f"{seed}:{i}".encode()) % size
            f.seek(pos)
            byte = f.read(1)
            f.seek(pos)
            f.write(bytes([byte[0] ^ 0xFF]))


# Exceptions that indicate the WORK is wrong, not the world: retrying them
# burns the budget on a deterministic failure.  Everything else — injected
# transients, I/O errors, runtime/XLA errors (OOMs come back as
# RuntimeError subclasses) — is worth a bounded retry.
PERMANENT_TYPES = (ValueError, TypeError, KeyError, IndexError,
                   AttributeError, AssertionError, NotImplementedError,
                   ZeroDivisionError)


def classify_error(exc: BaseException) -> str:
    """"transient" (bounded retry is worth it) or "permanent" (fail now)."""
    if isinstance(exc, FaultError):
        return "transient" if exc.transient else "permanent"
    if isinstance(exc, PERMANENT_TYPES):
        return "permanent"
    return "transient"
