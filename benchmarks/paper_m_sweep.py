"""Paper Figs. 15-16: effect of chromosome width m on speed (N=32).

On the FPGA, clock falls ~linearly with m (LUT depth) and LUT count rises.
Here m changes the fixed-point tables and bit widths; the vectorized engine
should be nearly m-invariant — which is itself a finding we record."""

from __future__ import annotations

from benchmarks.ga_common import bench_engine, time_call

K = 200


def run():
    rows = []
    for m in (20, 22, 24, 26, 28):
        eng = bench_engine("F3", n=32, m=m, generations=K, mode="lut")
        dt, _ = time_call(eng.run, iters=3)
        rows.append((f"m_sweep_m{m}", dt / K * 1e6,
                     f"gens_per_s={K/dt:.0f}"))
    return rows
