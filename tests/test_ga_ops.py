"""Property tests on the GA operators (paper Secs. 3.2–3.4 invariants)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing.hypothesis_fallback import given, settings, st

from repro.core import fitness as F
from repro.core import ga as G


def _cfg(n=32, c=10, v=2, mr=0.05, minimize=True, seed=0, mode="arith"):
    return G.GAConfig(n=n, c=c, v=v, mutation_rate=mr, minimize=minimize,
                      seed=seed, mode=mode)


@given(st.integers(2, 6), st.integers(4, 14), st.integers(1, 3),
       st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_generation_preserves_population_shape_and_width(log_n, c, v, seed):
    n = 2 ** log_n
    cfg = _cfg(n=n, c=c, v=v, seed=seed)
    fit = G.make_blackbox_fitness(
        lambda p: jnp.sum(p * p, axis=-1), c, [(-1, 1)] * v)
    st_ = G.init_state(cfg)
    st2, y = G.generation(st_, cfg, fit)
    assert st2.x.shape == (n, v)
    assert y.shape == (n,)
    # no gene exceeds its c-bit width (the paper's m-bit registers)
    assert int(jnp.max(st2.x)) < (1 << c)


@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_crossover_bit_conservation(seed):
    """Single-point crossover: at EVERY bit position, the multiset of bits
    across each offspring pair equals the parent pair's (Eqs. 15–20)."""
    cfg = _cfg(n=64, c=12, seed=seed)
    st_ = G.init_state(cfg)
    w = st_.x  # any population serves as "selected parents"
    z, _ = G._crossover(w, st_.cross_lfsr, cfg)
    w1, w2 = np.asarray(w[0::2]), np.asarray(w[1::2])
    z1, z2 = np.asarray(z[0::2]), np.asarray(z[1::2])
    # XOR-sum per position is conserved iff bits are swapped, never invented
    np.testing.assert_array_equal(w1 ^ w2, z1 ^ z2)
    # and each offspring bit comes from one of the two parents
    assert ((z1 & ~(w1 | w2)) == 0).all()
    assert ((z2 & ~(w1 | w2)) == 0).all()


@given(st.integers(0, 10_000), st.floats(0.01, 0.5))
@settings(max_examples=20, deadline=None)
def test_mutation_touches_exactly_first_p(seed, mr):
    cfg = _cfg(n=64, c=12, seed=seed, mr=mr)
    st_ = G.init_state(cfg)
    z = st_.x
    x2, _ = G._mutate(z, st_.mut_lfsr, cfg)
    changed = np.asarray((x2 != z).any(axis=1))
    assert not changed[cfg.p:].any(), "only the first P individuals mutate"
    # mutation is XOR: applying the same random word again restores z
    # (Eq. 6/21 is an involution)
    mut2, _ = G._mutate(x2, st_.mut_lfsr, cfg)
    # same draw because we reuse the same starting lfsr state
    np.testing.assert_array_equal(np.asarray(mut2), np.asarray(z))


@given(st.integers(0, 10_000), st.booleans())
@settings(max_examples=20, deadline=None)
def test_selection_winner_is_better(seed, minimize):
    cfg = _cfg(n=32, c=10, seed=seed, minimize=minimize)
    st_ = G.init_state(cfg)
    fit = G.make_blackbox_fitness(
        lambda p: jnp.sum(p, axis=-1), cfg.c, [(-1, 1)] * cfg.v)
    y = fit(st_.x)
    w, _ = G._select(st_.x, y, st_.sel_lfsr, cfg)
    yw = fit(w)
    # every selected chromosome's fitness exists in the population and the
    # winner of each tournament is at least as good as the median loser odds:
    # directly recompute the tournament to check the comparator
    from repro.core import lfsr as L
    sel2 = L.steps(st_.sel_lfsr, cfg.steps_per_draw)
    i1 = np.asarray(L.truncate(sel2[0], cfg.idx_bits)).astype(int)
    i2 = np.asarray(L.truncate(sel2[1], cfg.idx_bits)).astype(int)
    yn = np.asarray(y)
    expect = np.where(
        (yn[i1] <= yn[i2]) if minimize else (yn[i1] >= yn[i2]), i1, i2)
    np.testing.assert_array_equal(np.asarray(w), np.asarray(st_.x)[expect])


def test_run_is_deterministic():
    cfg = _cfg(n=32, c=10, seed=7, mode="arith")
    fit = G.fitness_for_problem(F.F3, cfg)
    a = G.run_scan(cfg, fit, 50)
    b = G.run_scan(cfg, fit, 50)
    np.testing.assert_array_equal(np.asarray(a.state.x), np.asarray(b.state.x))
    assert float(a.best_y) == float(b.best_y)


def test_maximize_mode():
    cfg = _cfg(n=64, c=10, seed=3, minimize=False, mode="arith")
    # maximize -(x^2+y^2) -> best at 0
    fit = G.make_blackbox_fitness(
        lambda p: -jnp.sum(p * p, axis=-1), cfg.c, [(-1, 1)] * 2)
    out = G.run_scan(cfg, fit, 100)
    assert float(out.best_y) > -0.05
