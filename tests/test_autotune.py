"""Trace-driven autotuner: replay stability, cost-table persistence, the
two-tier planner decision matrix (measured argmax / interpolation /
heuristic fallback / forced override), the migration-free resident mode,
plan provenance through telemetry, and the scheduler's TTL GC +
cost-table-aware ordering."""

import itertools
import json
import threading

import numpy as np
import pytest

from repro import ga
from repro.autotune import (CostTable, Replay, replay_until_stable,
                            resolve_table)
from repro.autotune import table as table_mod
from repro.ga import compile_cache as CC


def _spec(**kw):
    base = dict(problem="F3", n=16, bits_per_var=8, mode="arith",
                mutation_rate=0.02, seed=1, generations=8,
                n_islands=2, migrate_every=4, gens_per_epoch=8)
    base.update(kw)
    return ga.GASpec(**base)


def _point(spec, mode):
    return CC.plan_point(spec, executor="fused", mode=mode, n_shards=1)


def _topo(spec, **kw):
    return ga.Engine(spec, "fused-islands", **kw).backend.topology


# ---------------------------------------------------------------------------
# Replay-until-stable (deterministic fake timer)
# ---------------------------------------------------------------------------


class FakeTimer:
    """perf_counter stand-in fed a script of per-call durations."""

    def __init__(self, durations):
        self.durations = list(durations)
        self.now = 0.0
        self.i = 0

    def __call__(self):
        # replay calls the timer before and after each rep; advance on the
        # "after" call by consuming the next scripted duration
        if self.i % 2 == 1:
            self.now += self.durations.pop(0)
        self.i += 1
        return self.now


def test_replay_stops_at_min_reps_when_stable():
    calls = []
    timer = FakeTimer([1.0, 1.0, 1.0, 1.0])
    rep = replay_until_stable(lambda: calls.append(1), warmup=1,
                              min_reps=3, max_reps=16, cov_threshold=0.10,
                              timer=timer)
    assert isinstance(rep, Replay)
    assert rep.stable and rep.reps == 3
    assert rep.mean_s == pytest.approx(1.0)
    assert rep.cov == pytest.approx(0.0)
    assert len(calls) == 4            # 1 warmup (untimed) + 3 timed


def test_replay_keeps_going_until_cov_settles():
    # noisy head, stable tail: needs more than min_reps
    timer = FakeTimer([1.0, 3.0, 1.0, 1.0, 1.0, 1.0])
    rep = replay_until_stable(lambda: None, warmup=0, min_reps=3,
                              max_reps=16, cov_threshold=0.05, window=3,
                              timer=timer)
    assert rep.stable
    assert rep.reps > 3
    assert rep.mean_s == pytest.approx(1.0)


def test_replay_gives_up_at_max_reps():
    timer = FakeTimer([1.0, 5.0] * 4)
    rep = replay_until_stable(lambda: None, warmup=0, min_reps=2,
                              max_reps=8, cov_threshold=0.01, timer=timer)
    assert not rep.stable
    assert rep.reps == 8
    assert rep.cov > 0.01


def test_replay_validates_arguments():
    with pytest.raises(ValueError):
        replay_until_stable(lambda: None, min_reps=1)
    with pytest.raises(ValueError):
        replay_until_stable(lambda: None, min_reps=4, max_reps=2)


# ---------------------------------------------------------------------------
# CostTable: lookup semantics + persistence gates
# ---------------------------------------------------------------------------


def test_lookup_exact_interpolated_and_out_of_range():
    spec = _spec()
    pt = _point(spec, "resident")
    t = CostTable(host={"platform": "cpu", "device_count": 1})
    t.add(pt, 4, 100.0)
    t.add(pt, 12, 200.0)
    assert t.lookup(pt, 4) == 100.0                      # exact
    assert t.lookup(pt, 8) == pytest.approx(150.0)       # linear midpoint
    assert t.lookup(pt, 6) == pytest.approx(125.0)
    assert t.lookup(pt, 2) is None                       # no extrapolation
    assert t.lookup(pt, 16) is None
    assert t.lookup(_point(spec, "gridded"), 4) is None  # unknown point
    assert len(t) == 2


def test_table_roundtrip_and_merge(tmp_path):
    spec = _spec()
    t = CostTable(host={"platform": "cpu", "device_count": 8})
    t.add(_point(spec, "resident"), 8, 123.4, reps=5, cov=0.02)
    path = t.save(str(tmp_path / "table.json"))
    back = CostTable.load(path)
    assert back is not None
    assert back.lookup(_point(spec, "resident"), 8) == 123.4
    assert back.host == t.host
    other = CostTable()
    other.add(_point(spec, "resident"), 8, 999.0)
    other.add(_point(spec, "gridded"), 4, 50.0)
    back.merge(other)
    assert back.lookup(_point(spec, "resident"), 8) == 999.0  # other wins
    assert len(back) == 2


def test_load_rejects_stale_version_and_foreign_host(tmp_path):
    spec = _spec()
    t = CostTable(host={"platform": "cpu", "device_count": 8})
    t.add(_point(spec, "resident"), 8, 1.0)
    path = str(tmp_path / "t.json")
    t.save(path)
    # strict (ambient) load: host mismatch -> silently None
    assert CostTable.load(path, expect_host={"platform": "cpu",
                                             "device_count": 4}) is None
    # trusted load ignores the host
    assert CostTable.load(path) is not None
    obj = json.load(open(path))
    obj["version"] = -99
    json.dump(obj, open(path, "w"))
    with pytest.warns(UserWarning, match="version"):
        assert CostTable.load(path) is None


def test_resolve_table_forms(tmp_path, monkeypatch):
    assert resolve_table(False) is None
    t = CostTable()
    assert resolve_table(t) is t
    with pytest.raises(TypeError):
        resolve_table(42)
    for off in ("", "off", "none", "0"):
        monkeypatch.setenv("REPRO_GA_COST_TABLE", off)
        assert resolve_table(None) is None
    spec = _spec()
    t2 = CostTable(host={"platform": "weird", "device_count": 3})
    t2.add(_point(spec, "resident"), 8, 7.0)
    path = t2.save(str(tmp_path / "pinned.json"))
    monkeypatch.setenv("REPRO_GA_COST_TABLE", path)
    got = resolve_table(None)          # env pin is trusted: host ignored
    assert got is not None and got.lookup(_point(spec, "resident"), 8) == 7.0
    assert resolve_table(path) is not None     # explicit path, same deal


# ---------------------------------------------------------------------------
# Planner decision matrix (tier 2: measured argmax over feasible modes)
# ---------------------------------------------------------------------------


def test_no_table_plan_is_exactly_the_heuristic():
    topo = _topo(_spec(), cost_table=False)
    heur = topo.epoch_candidates()[0]
    assert topo.plan["plan_source"] == "heuristic"
    assert {k: topo.plan[k] for k in heur} == heur
    assert "plan_gens_per_s" not in topo.plan


def test_measured_argmax_flips_the_mode():
    spec = _spec()
    t = CostTable()
    t.add(_point(spec, "resident"), 8, 10.0)
    t.add(_point(spec, "gridded"), 4, 100.0)
    topo = _topo(spec, cost_table=t)
    assert topo.plan["mode"] == "gridded"
    assert topo.plan["plan_source"] == "measured"
    assert topo.plan["plan_gens_per_s"] == 100.0


def test_measured_argmax_keeps_heuristic_winner():
    spec = _spec()
    t = CostTable()
    t.add(_point(spec, "resident"), 8, 100.0)
    t.add(_point(spec, "gridded"), 4, 10.0)
    topo = _topo(spec, cost_table=t)
    assert topo.plan["mode"] == "resident"
    assert topo.plan["plan_source"] == "measured"


def test_partial_table_interpolates_on_the_launch_axis():
    spec = _spec()
    t = CostTable()
    # resident measured at brackets of its g=8 launch; gridded exact
    t.add(_point(spec, "resident"), 4, 100.0)
    t.add(_point(spec, "resident"), 12, 300.0)
    t.add(_point(spec, "gridded"), 4, 150.0)
    topo = _topo(spec, cost_table=t)
    # resident interpolates to 200 at g=8 and beats gridded's 150
    assert topo.plan["mode"] == "resident"
    assert topo.plan["plan_gens_per_s"] == pytest.approx(200.0)


def test_table_not_covering_heuristic_falls_back_bit_identically():
    spec = _spec()
    t = CostTable()
    t.add(_point(spec, "gridded"), 4, 9999.0)   # only the alternative
    topo = _topo(spec, cost_table=t)
    heur = _topo(spec, cost_table=False).plan
    assert topo.plan == heur
    assert topo.plan["plan_source"] == "heuristic"


def test_measured_plan_results_bit_identical_to_heuristic():
    spec = _spec()
    t = CostTable()
    t.add(_point(spec, "resident"), 8, 10.0)
    t.add(_point(spec, "gridded"), 4, 100.0)    # flips to gridded
    meas = ga.solve(spec, backend="fused-islands", cost_table=t)
    heur = ga.solve(spec, backend="fused-islands", cost_table=False)
    assert meas.telemetry.plan.mode == "gridded"
    assert heur.telemetry.plan.mode == "resident"
    assert meas.best_fitness == heur.best_fitness
    np.testing.assert_array_equal(np.asarray(meas.best_params),
                                  np.asarray(heur.best_params))


def test_plan_override_forces_and_validates():
    spec = _spec()
    topo = _topo(spec, cost_table=False, plan_override="gridded")
    assert topo.plan["mode"] == "gridded"
    assert topo.plan["plan_source"] == "forced"
    with pytest.raises(ValueError, match="resident"):
        _topo(spec, cost_table=False, plan_override="resident-sharded")


# ---------------------------------------------------------------------------
# Migration-free resident mode (migration="none", unlimited gen folding)
# ---------------------------------------------------------------------------


def test_migration_none_offers_resident_free():
    spec = _spec(migration="none", generations=16, gens_per_epoch=16)
    cands = _topo(spec, cost_table=False).epoch_candidates()
    modes = [c["mode"] for c in cands]
    assert modes == ["gridded", "resident-free"]   # heuristic stays gridded
    free = cands[1]
    assert free["gens_per_launch"] == 16           # no whole-multiple rule


def test_resident_free_bit_identical_and_unthrottled():
    spec = _spec(migration="none", generations=16, gens_per_epoch=16)
    free = ga.solve(spec, backend="fused-islands", cost_table=False,
                    plan_override="resident-free")
    grid = ga.solve(spec, backend="fused-islands", cost_table=False)
    assert free.telemetry.plan.mode == "resident-free"
    assert free.telemetry.plan.source == "forced"
    assert free.telemetry.topology.migrations == 0
    assert free.best_fitness == grid.best_fitness
    np.testing.assert_array_equal(np.asarray(free.best_params),
                                  np.asarray(grid.best_params))


def test_vmem_fallback_reason_surfaces_in_plan_and_telemetry(monkeypatch):
    monkeypatch.setenv("REPRO_RESIDENT_VMEM_BUDGET", "1024")   # 1 KiB: no fit
    spec = _spec()
    topo = _topo(spec, cost_table=False)
    assert topo.plan["mode"] == "gridded"
    assert "fallback" in topo.plan
    out = ga.solve(spec, backend="fused-islands", cost_table=False)
    assert out.telemetry.plan.fallback == topo.plan["fallback"]


# ---------------------------------------------------------------------------
# Plan provenance through job telemetry
# ---------------------------------------------------------------------------


def test_plan_fields_flow_into_job_metrics():
    from repro.serve.engine import GAMetricsRegistry
    reg = GAMetricsRegistry()
    spec = _spec()
    eng = ga.Engine(spec, "fused-islands", cost_table=False)
    jid = reg.allocate_job_id("F3")
    reg.start_job(jid, backend=eng.backend_name, gens_total=spec.generations)
    for tele in eng.run_chunked():
        reg.record_chunk(jid, tele)
    reg.finish_job(jid)
    m = reg.metrics()["jobs"][jid]
    assert m["epoch_mode"] == "resident"
    assert m["plan_source"] == "heuristic"
    assert m["plan_fallback"] is None


def test_metrics_http_renders_autotune_gauges():
    from repro.serve.metrics_http import render_prometheus
    text = render_prometheus({
        "jobs": {},
        "scheduler": {"queue_depth": 0, "jobs_evicted": 3,
                      "plans_measured": 2, "plans_heuristic": 5,
                      "plan_table_entries": 6}})
    for gauge in ("repro_ga_sched_evicted_total 3",
                  "repro_ga_plan_measured_total 2",
                  "repro_ga_plan_heuristic_total 5",
                  "repro_ga_plan_table_entries 6"):
        assert gauge in text


# ---------------------------------------------------------------------------
# Scheduler: TTL GC + cost-table-aware dispatch ordering
# ---------------------------------------------------------------------------


def test_scheduler_ttl_evicts_finished_jobs():
    import time as _t
    from repro.serve.engine import GAMetricsRegistry
    from repro.serve.scheduler import GAScheduler
    reg = GAMetricsRegistry()
    sched = GAScheduler(registry=reg, backend="reference", job_ttl_s=30.0,
                        cost_table=False)
    try:
        jid = sched.submit(_spec(n_islands=1, gens_per_epoch=1,
                                 generations=4))
        sched.result(jid, timeout=300)
        assert jid in reg.metrics()["jobs"]
        assert sched.gc_now(now=_t.monotonic()) == 0      # too young
        assert sched.gc_now(now=_t.monotonic() + 60.0) == 1
        assert jid not in reg.metrics()["jobs"]
        with pytest.raises(KeyError):
            sched.job(jid)
        assert sched.stats()["jobs_evicted"] == 1
    finally:
        sched.shutdown()


def test_scheduler_without_ttl_never_evicts():
    from repro.serve.engine import GAMetricsRegistry
    from repro.serve.scheduler import GAScheduler
    reg = GAMetricsRegistry()
    sched = GAScheduler(registry=reg, backend="reference", cost_table=False)
    try:
        jid = sched.submit(_spec(n_islands=1, gens_per_epoch=1,
                                 generations=4))
        sched.result(jid, timeout=300)
        assert sched.gc_now(now=1e18) == 0
        assert jid in reg.metrics()["jobs"]
    finally:
        sched.shutdown()


def test_unit_ordering_shortest_estimated_wall_first():
    from repro.serve.engine import GAMetricsRegistry
    from repro.serve.scheduler import GAScheduler, Job, _Unit
    sched = GAScheduler(registry=GAMetricsRegistry(), backend="reference",
                        cost_table=False)
    try:
        seq = itertools.count()

        def unit(gens, est, priority=0):
            j = Job(job_id=f"j{next(seq)}", spec=_spec(generations=gens),
                    priority=priority, est_gens_per_s=est)
            return _Unit(seq=next(seq), jobs=[j])

        a, b, c = unit(100, 10.0), unit(100, 50.0), unit(100, None)
        # estimated units outrank unestimated; shorter wall wins among them
        assert max([a, b, c], key=sched._unit_order_key) is b
        # without any estimate the key reduces to (priority, FIFO)
        u0, u1 = unit(100, None), unit(100, None)
        assert max([u1, u0], key=sched._unit_order_key) is u0
        # priority still dominates every estimate
        hot = unit(100, None, priority=10)
        assert max([a, b, hot], key=sched._unit_order_key) is hot
    finally:
        sched.shutdown()


def test_scheduler_plan_counters_and_table_gauge():
    from repro.serve.engine import GAMetricsRegistry
    from repro.serve.scheduler import GAScheduler
    spec = _spec()
    t = CostTable()
    t.add(_point(spec, "resident"), 8, 10.0)
    t.add(_point(spec, "gridded"), 4, 100.0)
    reg = GAMetricsRegistry()
    sched = GAScheduler(registry=reg, backend="fused-islands", cost_table=t)
    try:
        jid = sched.submit(spec)
        res = sched.result(jid, timeout=600)
        stats = sched.stats()
        assert stats["plans_measured"] == 1
        assert stats["plans_heuristic"] == 0
        assert stats["plan_table_entries"] == 2
        assert sched.job(jid).est_gens_per_s == 100.0
        assert reg.metrics()["jobs"][jid]["plan_source"] == "measured"
        assert reg.metrics()["jobs"][jid]["epoch_mode"] == "gridded"
        # measured plan, identical result
        solo = ga.solve(spec, backend="fused-islands", cost_table=False)
        assert res["best_fitness"] == solo.best_fitness
    finally:
        sched.shutdown()


def test_estimate_gens_per_s():
    from repro.autotune import estimate_gens_per_s
    spec = _spec()
    assert estimate_gens_per_s(spec, None) is None
    t = CostTable()
    t.add(_point(spec, "resident"), 8, 42.0)
    t.add(_point(spec, "gridded"), 4, 1.0)
    assert estimate_gens_per_s(spec, t,
                               backend="fused-islands") == pytest.approx(42.0)


# ---------------------------------------------------------------------------
# plan_point identity discipline
# ---------------------------------------------------------------------------


def test_plan_point_excludes_seed_generations_and_repeats():
    a = _point(_spec(seed=1, generations=8), "resident")
    b = _point(_spec(seed=99, generations=800, n_repeats=4), "resident")
    assert a == b
    assert _point(_spec(n=32), "resident") != a
    assert a["stage"].startswith("F3:v")
