#!/usr/bin/env python
"""CI smoke for the trace-driven autotuner: sweep, persist, consume.

Forces an 8-device host platform (same environment as scheduler_smoke),
runs a tiny autotune sweep over the exact spec shapes the engine_backends
--smoke fused-islands rows use, writes the cost table to --out, then
asserts the whole loop closes:

  * the sweep measured > 0 points, including a resident-free one
    (migration="none" folding past migrate_every without ring exchange)
    and a streamed one (an 8-island stack under a forced vmem_budget that
    only fits a double-buffered tile);
  * an Engine pointed at the written table plans with
    plan_source="measured" and its result is bit-identical to the
    heuristic plan's;
  * with the table disabled the plan is exactly the heuristic candidate
    (no table -> bit-identical pre-autotune behavior);
  * the committed fake-8 snapshot (benchmarks/autotune_snapshot_fake8.json)
    still loads and steers the planner — the F3 point prefers resident,
    the rastrigin point prefers gridded, both marked "measured".

    PYTHONPATH=src python scripts/autotune_smoke.py \
        --out artifacts/autotune_table.json
"""

import argparse
import os
import sys

# must precede the first jax import: fake an 8-device host platform
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
# this smoke pins every table explicitly; never consume an ambient one
os.environ["REPRO_GA_COST_TABLE"] = "off"

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import ga                                    # noqa: E402
from repro.autotune import CostTable, sweep             # noqa: E402

SNAPSHOT = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "autotune_snapshot_fake8.json")

# the engine_backends --smoke fused-islands shape (n=16, m=16, islands=2,
# E=4, gens_per_epoch=2*E) — sweeping the same shapes means the bench's
# '+measured' rows find their points in the table this smoke writes
BASE = dict(n=16, bits_per_var=8, mode="arith", mutation_rate=0.02, seed=1,
            generations=8, n_islands=2, migrate_every=4, gens_per_epoch=8)


def _plan(spec, cost_table):
    eng = ga.Engine(spec, "fused-islands", cost_table=cost_table)
    return eng.backend.topology.plan


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="artifacts/autotune_table.json")
    args = ap.parse_args()

    specs = [ga.GASpec(problem=p, **BASE) for p in ("F3", "rastrigin:4")]
    # resident-free coverage: no ring exchange, the whole epoch in one launch
    free_spec = ga.GASpec(problem="F3", migration="none",
                          **{**BASE, "generations": 16,
                             "gens_per_epoch": 16})
    table = sweep(specs + [free_spec], backend="fused-islands", log=print)
    # streamed coverage: an 8-island stack under a forced budget that only
    # fits a double-buffered 2-island tile -> candidates [streamed, gridded]
    from repro.kernels import ga_step as K
    stream_spec = ga.GASpec(problem="F3", **{**BASE, "n_islands": 8})
    probe = ga.Engine(stream_spec, "fused-islands", cost_table=False)
    budget = K.resident_vmem_bytes(probe.backend.topology.cfg, 5)
    sweep([stream_spec], backend="fused-islands",
          options=ga.EngineOptions(cost_table=False, vmem_budget=budget),
          table=table, log=print)
    table.save(args.out)
    print(f"wrote {len(table)} measured point(s) -> {args.out}")

    assert len(table) > 0, "sweep measured nothing"
    modes = {e["mode"] for e in table.entries()}
    assert "resident-free" in modes, f"no resident-free point (got {modes})"
    assert "streamed" in modes, f"no streamed point (got {modes})"

    # planner consumes the table it just wrote (path form, trusted load)
    plan = _plan(specs[0], args.out)
    assert plan["plan_source"] == "measured", plan
    assert plan.get("plan_gens_per_s"), plan
    print(f"measured plan: {plan['mode']} "
          f"({plan['plan_gens_per_s']:.1f} gens/s expected)")

    # measured vs heuristic plans differ only in launch shape, never results
    out_meas = ga.solve(specs[0], backend="fused-islands",
                        cost_table=args.out)
    out_heur = ga.solve(specs[0], backend="fused-islands", cost_table=False)
    assert out_meas.best_fitness == out_heur.best_fitness, \
        (out_meas.best_fitness, out_heur.best_fitness)
    assert out_heur.telemetry.plan.source == "heuristic"

    # no table -> exactly the heuristic candidate (bit-identical pre-PR plan)
    eng = ga.Engine(specs[0], "fused-islands", cost_table=False)
    heur = eng.backend.topology.epoch_candidates()[0]
    got = {k: eng.backend.topology.plan[k] for k in heur}
    assert got == heur, (got, heur)

    # the committed snapshot still steers the planner as encoded
    snap = CostTable.load(SNAPSHOT)
    assert snap is not None, f"unusable snapshot {SNAPSHOT}"
    p_f3 = _plan(specs[0], snap)
    p_ras = _plan(specs[1], snap)
    assert (p_f3["plan_source"], p_f3["mode"]) == ("measured", "resident"), \
        p_f3
    assert (p_ras["plan_source"], p_ras["mode"]) == ("measured", "gridded"), \
        p_ras
    print(f"snapshot plans: F3 -> {p_f3['mode']}, "
          f"rastrigin:4 -> {p_ras['mode']}")
    print("autotune smoke OK")


if __name__ == "__main__":
    main()
