"""zamba2-2.7b — Mamba2 backbone + one SHARED attention block applied every
6 layers [arXiv:2411.15242; hf].  d_state 64; shared block = GQA(32h, hd 80)
+ gated MLP (d_ff 10240).  Per-invocation LoRA specialization of the shared
block is not implemented (DESIGN.md §Arch-applicability)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=10240, vocab=32000, d_state=64, ssm_headdim=64, attn_every=6,
    tie_embeddings=True,
)
