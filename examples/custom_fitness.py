"""Bring your own fitness: three ways to put a custom objective on the GA
engine — including the fused Pallas kernel, which traces YOUR function into
its FFM stage (no closed-form/two-variable restriction).

    PYTHONPATH=src python examples/custom_fitness.py
"""

import jax.numpy as jnp
import numpy as np

from repro import ga


def main():
    # --- 1. One-off blackbox: any traceable (N, V) -> (N,) batch fn ------
    # Captured arrays are fine — the kernel hoists them into inputs.
    target = jnp.asarray([0.5, -1.0, 2.0], jnp.float32)

    def weighted_offset(pop):                     # (N, 3) -> (N,)
        return jnp.sum(jnp.array([1.0, 2.0, 4.0]) * (pop - target) ** 2,
                       axis=-1)

    spec = ga.GASpec(fitness=weighted_offset, bounds=((-4.0, 4.0),) * 3,
                     n=64, bits_per_var=12, mutation_rate=0.05,
                     seed=0, generations=150)
    for backend in ("reference", "fused"):        # identical results
        r = ga.solve(spec, backend=backend)
        print(f"blackbox [{backend:9s}] best={r.best_fitness:.3e} "
              f"params={np.round(r.best_params, 3)}")

    # --- 2. Register a reusable problem (name + default box) -------------
    # A separable `term` additionally unlocks the LUT (ROM) lowering.
    ga.register_problem(ga.ProblemDef(
        name="styblinski_tang",
        fn=lambda v: 0.5 * jnp.sum(v ** 4 - 16.0 * v ** 2 + 5.0 * v,
                                   axis=-1),
        domain=(-5.0, 5.0),
        term=lambda v, i: 0.5 * (v ** 4 - 16.0 * v ** 2 + 5.0 * v),
    ))
    spec = ga.GASpec(problem="styblinski_tang:6", n=64, bits_per_var=12,
                     mutation_rate=0.05, seed=1, generations=200,
                     n_islands=4, migrate_every=16)
    r = ga.solve(spec, backend="fused-islands")
    print(f"styblinski_tang:6 [fused-islands] best={r.best_fitness:.2f} "
          f"(optimum {-39.166 * 6:.2f})")

    # --- 3. The built-in n-variable suite at any V ------------------------
    for problem in ("sphere:8", "rastrigin:8", "rosenbrock:8", "ackley:8"):
        r = ga.solve(ga.GASpec(problem=problem, n=64, bits_per_var=12,
                               mutation_rate=0.05, seed=2,
                               generations=150), backend="fused")
        print(f"{problem:13s} [fused] best={r.best_fitness:.4f}")


if __name__ == "__main__":
    main()
