"""Quickstart: the paper's parallel GA through the unified `repro.ga` API,
then the same engine as the framework's blackbox tuner.

    PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro import ga
from repro.core import evolve


def main():
    # --- 1. Reproduce the paper's F1 experiment (Fig. 11): N=32, m=26 ----
    spec1 = ga.paper_spec("F1", n=32, m=26, mode="lut", mutation_rate=0.05,
                          seed=7, generations=100)
    out = ga.solve(spec1)
    print(f"F1 best fitness after 100 generations: {out.best_fitness:.4g} "
          f"(global minimum ≈ -6.897e10) [backend={out.backend}]")
    print(f"decoded solution: {out.best_params}")

    # --- 2. F3 on every backend from the SAME spec -----------------------
    spec3 = ga.paper_spec("F3", n=64, m=20, mode="arith", mutation_rate=0.05,
                          seed=3, generations=100)
    for backend in ("reference", "fused", "eager"):
        r = ga.solve(spec3, backend=backend)
        print(f"F3 [{backend:9s}] best: {r.best_fitness:.4f} (optimum 0)")
    r = ga.solve(dataclasses.replace(spec3, n_islands=8), backend="islands")
    print(f"F3 [islands x8] best: {r.best_fitness:.4f}")

    # --- 3. Swap the selection scheme, batch 8 seeds in one vmapped run --
    r = ga.solve(dataclasses.replace(spec3, selection="tournament4",
                                     n_repeats=8))
    print(f"F3 [tournament4, 8 repeats] best: {r.best_fitness:.4f}, "
          f"per-seed: {np.round(r.telemetry.per_repeat.best, 3)}")

    # --- 4. The GA as a tuning service: minimize a 4-var blackbox --------
    target = jnp.array([0.5, -1.0, 2.0, 0.0])

    def objective(p):          # (N, 4) -> (N,)
        return jnp.sum((p - target) ** 2, axis=-1)

    r = evolve(objective, bounds=[(-4, 4)] * 4, population=128,
               generations=200, mutation_rate=0.05, seed=0)
    print(f"evolve() found {np.round(r.best_params, 3)} "
          f"(target {np.asarray(target)}) fitness={r.best_fitness:.2e}")


if __name__ == "__main__":
    main()
