#!/usr/bin/env python
"""RELATIVE benchmark regression gate for the engine backend matrix.

Compares a fresh `benchmarks.engine_backends --smoke` artifact against the
committed baseline and fails (exit 1) when any (topology × executor ×
problem) combo regressed — where "regressed" is measured MACHINE-
INDEPENDENTLY: each combo's gens/s is divided by the same artifact's anchor
row (`engine_reference[<problem>]`, devices=1), and it is that
combo-vs-reference RATIO that must stay within tolerance of the baseline's
ratio.  A uniformly slower machine scales every row equally and cancels
out; only a composition that got slower *relative to the reference
executor* trips the gate.

    PYTHONPATH=src python -m benchmarks.engine_backends --smoke \
        --out artifacts/engine_backends.json
    python scripts/check_bench.py artifacts/engine_backends.json

A combo missing from the current artifact also fails — a silently dropped
backend is a coverage regression, not a speedup.  Ratios are only compared
when the row's `devices` count matches the baseline's (mesh rows scale
with the host and their relative cost depends on the shard count).

Seed the committed baseline from SEVERAL artifacts (collected across
repeated runs): --write-baseline keeps the per-combo MINIMUM ratio across
the artifacts scaled by `RATIO_MARGIN`, so run-to-run ratio noise does not
trip the tolerance gate.  Regenerate when a deliberate change shifts
relative throughput:

    python scripts/check_bench.py run1.json run2.json run3.json \
        --write-baseline

Ratios alone are blind to a regression in the reference path itself (every
ratio's denominator slows equally), so the anchor rows additionally get a
VERY loose absolute floor: `engine_reference[*]` must stay above
ANCHOR_FLOOR (default 0.10) × its baseline gens/s — 10× machine-speed
variance passes, a catastrophic shared-path slowdown does not.

Three same-artifact gates ride along: `+measured` rows must keep up with
their static twin (measured_gate), `+streamed` rows must actually plan
the streamed epoch mode and keep up with their `+streamed-gridded`
fallback twin (streamed_gate), and `+gather` rows must actually run the
gather selection lane and keep up with their `+onehot` twin at N >= 512
(lane_gate) — all absolute-safe because each pair ran on the same machine
in the same run.

`--append-trajectory` appends the merged artifacts' headline rows to a
committed JSON history (`benchmarks/BENCH_trajectory.json`), one entry per
CI run, so throughput drift across PRs stays inspectable.

Env overrides: CHECK_BENCH_TOLERANCE (float, default 0.30),
CHECK_BENCH_ANCHOR_FLOOR (float, default 0.10) and CHECK_BENCH_SKIP=1
(escape hatch for pathological machines — prints a warning, exits 0).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks", "baseline_engine_backends.json")
RATIO_MARGIN = 0.67  # baseline ratio = observed_min_ratio * RATIO_MARGIN
ANCHOR_FLOOR = float(os.environ.get("CHECK_BENCH_ANCHOR_FLOOR", "0.10"))


def load_rows(path: str) -> dict:
    with open(path) as f:
        rows = json.load(f)
    return {r["name"]: r for r in rows}


def _base_name(name: str) -> str:
    """Mesh rows embed the host's device count ('engine_islands[F3]@mesh8');
    strip it so rows recorded on differently-sized hosts still pair up."""
    return name.split("@mesh")[0] + ("@mesh" if "@mesh" in name else "")


def _anchor_name(row: dict) -> str:
    """The reference row every combo is measured against (same problem,
    single device) — the denominator of the machine-independent ratio.
    The problem token comes from the row NAME ('engine_fused[rastrigin:4]'
    includes the :V suffix; the payload's `problem` field is the bare
    registry name)."""
    name = row.get("name", "")
    token = name[name.find("[") + 1:name.find("]")] if "[" in name else "F3"
    return f"engine_reference[{token}]"


def _ratio(row: dict, rows: dict):
    """gens/s of `row` relative to its anchor in the same artifact, or None
    when the anchor is absent/zero (nothing to normalize against)."""
    anchor = rows.get(_anchor_name(row))
    if not anchor or not anchor.get("gens_per_s"):
        return None
    return row["gens_per_s"] / anchor["gens_per_s"]


def compare(current: dict, baseline: dict, tolerance: float):
    """Returns (failures, notes): failures are relative regressions and
    missing combos; device-count mismatches and missing anchors are notes."""
    failures, notes = [], []
    cur_bases = {_base_name(n) for n in current}
    for name, base in sorted(baseline.items()):
        cur = current.get(name)
        if cur is None:
            if _base_name(name) in cur_bases:
                notes.append(f"{name}: no row at {base.get('devices')} "
                             "device(s) on this host; skipping")
            else:
                failures.append(f"{name}: combo missing from current "
                                "artifact (was it dropped from the "
                                "registry?)")
            continue
        if cur.get("devices") != base.get("devices"):
            notes.append(f"{name}: device count changed "
                         f"({base.get('devices')} -> {cur.get('devices')}); "
                         "skipping ratio comparison")
            continue
        if name == _anchor_name(base):
            # anchor rows have ratio == 1 by construction; gate them with a
            # very loose ABSOLUTE floor instead, so a shared-path slowdown
            # that drags every backend down equally still fails
            floor = base.get("gens_per_s", 0.0) * ANCHOR_FLOOR
            if cur.get("gens_per_s", 0.0) < floor:
                failures.append(
                    f"{name}: anchor at {cur.get('gens_per_s', 0.0):.1f} "
                    f"gens/s < absolute floor {floor:.1f} "
                    f"({ANCHOR_FLOOR:.0%} of baseline "
                    f"{base.get('gens_per_s', 0.0):.1f}) — shared/reference "
                    "path regression or pathological machine "
                    "(CHECK_BENCH_ANCHOR_FLOOR / CHECK_BENCH_SKIP=1)")
            continue
        base_ratio = base.get("ratio")
        if base_ratio is None:
            notes.append(f"{name}: baseline has no ratio (reseed with "
                         "--write-baseline); skipping")
            continue
        # the ratio stored at merge time was computed WITHIN the row's own
        # artifact — recomputing against the min-merged dict could pair a
        # numerator and an anchor from different machines
        cur_ratio = cur.get("ratio")
        if cur_ratio is None:
            notes.append(f"{name}: anchor {_anchor_name(cur)!r} missing "
                         "from current artifact; skipping")
            continue
        floor = base_ratio * (1.0 - tolerance)
        if cur_ratio < floor:
            failures.append(
                f"{name}: {cur_ratio:.3f}x of {_anchor_name(cur)} < floor "
                f"{floor:.3f}x (baseline ratio {base_ratio:.3f}, "
                f"tolerance {tolerance:.0%}; "
                f"{cur['gens_per_s']:.1f} gens/s here)")
    for name in sorted(set(current) - set(baseline)):
        notes.append(f"{name}: new combo (no baseline yet)")
    return failures, notes


def measured_gate(current: dict, tolerance: float):
    """Gate the autotuned planner against the static one: every
    '<combo>+measured' row (epoch plan chosen from a cost table) must reach
    at least (1 - tolerance) × its static twin's gens/s IN THE SAME
    artifact — same machine, same run, so the comparison is absolute-safe.
    A measured plan slower than the heuristic means the table is stale or
    the argmax is wrong; either way the autotuner regressed."""
    failures, notes = [], []
    for name in sorted(n for n in current if n.endswith("+measured")):
        static = current.get(name[:-len("+measured")])
        cur = current[name]
        if static is None or not static.get("gens_per_s"):
            notes.append(f"{name}: no static twin row; skipping")
            continue
        floor = static["gens_per_s"] * (1.0 - tolerance)
        if cur.get("gens_per_s", 0.0) < floor:
            failures.append(
                f"{name}: measured plan at {cur.get('gens_per_s', 0.0):.1f} "
                f"gens/s < floor {floor:.1f} ({(1.0 - tolerance):.0%} of the "
                f"static plan's {static['gens_per_s']:.1f}; "
                f"plan_source={cur.get('plan_source', '?')}, "
                f"epoch_mode={cur.get('epoch_mode', '?')})")
    return failures, notes


def streamed_gate(current: dict, tolerance: float):
    """Gate the HBM-streaming epoch lane: every '<combo>+streamed' row (an
    island stack past the forced VMEM budget) must have actually planned
    `epoch_mode == "streamed"` AND reach at least (1 - tolerance) × its
    '+streamed-gridded' twin — the same oversized spec forced through the
    gridded per-interval fallback, in the same artifact.  A streamed row
    that silently fell back, or that is slower than the fallback it exists
    to beat, is a regression of the streaming pipeline."""
    failures, notes = [], []
    for name in sorted(n for n in current if n.endswith("+streamed")):
        cur = current[name]
        if cur.get("epoch_mode") != "streamed":
            failures.append(
                f"{name}: planned epoch_mode="
                f"{cur.get('epoch_mode', '?')!r}, expected 'streamed' — "
                "the oversized-stack row no longer exercises the "
                "streaming lane")
            continue
        twin = current.get(name + "-gridded")
        if twin is None or not twin.get("gens_per_s"):
            notes.append(f"{name}: no '+streamed-gridded' twin row; "
                         "skipping throughput comparison")
            continue
        floor = twin["gens_per_s"] * (1.0 - tolerance)
        if cur.get("gens_per_s", 0.0) < floor:
            failures.append(
                f"{name}: streamed at {cur.get('gens_per_s', 0.0):.1f} "
                f"gens/s < floor {floor:.1f} ({(1.0 - tolerance):.0%} of "
                f"the gridded fallback's {twin['gens_per_s']:.1f}; "
                f"tile_islands={cur.get('tile_islands', '?')})")
    return failures, notes


LANE_GATE_MIN_N = 512   # below this the (N, N) working set is too small for
                        # the lane choice to matter; the pair is informational


def lane_gate(current: dict, tolerance: float):
    """Gate the gather selection lane: every '<combo>+gather' row must have
    actually run `sel_lane == "gather"` AND — when its population is at
    least LANE_GATE_MIN_N — reach (1 - tolerance) × its '+onehot' twin in
    the same artifact.  The gather lane exists to shrink the tournament
    working set from O(N²) to O(N·V) WITHOUT giving up throughput; a gather
    row losing to onehot at large N is a regression of that lane."""
    failures, notes = [], []
    for name in sorted(n for n in current if n.endswith("+gather")):
        cur = current[name]
        if cur.get("sel_lane") != "gather":
            failures.append(
                f"{name}: ran sel_lane={cur.get('sel_lane', '?')!r}, "
                "expected 'gather' — the pinned-lane row no longer "
                "exercises the gather selection lane")
            continue
        twin = current.get(name[:-len("+gather")] + "+onehot")
        if twin is None or not twin.get("gens_per_s"):
            notes.append(f"{name}: no '+onehot' twin row; skipping "
                         "throughput comparison")
            continue
        if cur.get("n", 0) < LANE_GATE_MIN_N:
            notes.append(f"{name}: N={cur.get('n')} < {LANE_GATE_MIN_N}; "
                         "lane pair is informational at this size")
            continue
        floor = twin["gens_per_s"] * (1.0 - tolerance)
        if cur.get("gens_per_s", 0.0) < floor:
            failures.append(
                f"{name}: gather lane at {cur.get('gens_per_s', 0.0):.1f} "
                f"gens/s < floor {floor:.1f} ({(1.0 - tolerance):.0%} of "
                f"the onehot twin's {twin['gens_per_s']:.1f} at "
                f"N={cur.get('n')})")
    return failures, notes


# the committed per-PR throughput history and the rows worth tracking in it
DEFAULT_TRAJECTORY = os.path.join(os.path.dirname(__file__), "..",
                                  "benchmarks", "BENCH_trajectory.json")
TRAJECTORY_ROWS = ("engine_reference[F3]", "engine_fused-islands[F3]",
                   "engine_fused-islands[F3]+streamed",
                   "engine_fused-islands[F3]+onehot",
                   "engine_fused-islands[F3]+gather")


def append_trajectory(path: str, current: dict) -> None:
    """Append one entry of headline gens/s (and the fused-vs-reference
    ratio) to the committed trajectory file.  Entries are labeled by git
    commit when available; absolute rates are machine-dependent, the ratio
    column is the comparable series."""
    import subprocess
    import time as _time
    try:
        with open(path) as f:
            history = json.load(f)
    except (OSError, ValueError):
        history = []
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or None
    except OSError:
        commit = None
    entry = {"commit": commit,
             "date": _time.strftime("%Y-%m-%d"),
             "rows": {}}
    for name in TRAJECTORY_ROWS:
        r = current.get(name)
        if r is None:
            continue
        entry["rows"][name] = {
            "gens_per_s": r.get("gens_per_s"),
            "ratio": (round(r["ratio"], 4)
                      if r.get("ratio") is not None else None)}
    history.append(entry)
    with open(path, "w") as f:
        json.dump(history, f, indent=2)
        f.write("\n")
    print(f"appended trajectory entry ({len(entry['rows'])} rows) "
          f"to {path}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("artifacts", nargs="+",
                    help="engine_backends --smoke --out JSON(s); several "
                         "are min-ratio-merged per combo (use with "
                         "--write-baseline to seed from repeated runs)")
    ap.add_argument("--baseline", default=os.path.normpath(DEFAULT_BASELINE))
    ap.add_argument("--tolerance", type=float,
                    default=float(os.environ.get("CHECK_BENCH_TOLERANCE",
                                                 "0.30")),
                    help="allowed fractional drop of the combo-vs-reference "
                         "gens/s ratio")
    ap.add_argument("--write-baseline", action="store_true",
                    help="(re)seed the baseline from the artifacts "
                         f"(min ratio per combo scaled by {RATIO_MARGIN})")
    ap.add_argument("--append-trajectory", nargs="?", default=None,
                    const=os.path.normpath(DEFAULT_TRAJECTORY),
                    metavar="PATH",
                    help="append the headline rows' gens/s + ratio to the "
                         "committed per-PR trajectory history (default "
                         "path: benchmarks/BENCH_trajectory.json)")
    args = ap.parse_args()

    artifacts = [load_rows(p) for p in args.artifacts]
    # current view: per combo, the row with the WORST (lowest) ratio across
    # the artifacts, its ratio evaluated within its own artifact
    current: dict = {}
    for rows in artifacts:
        for name, r in rows.items():
            ratio = _ratio(r, rows)
            r = dict(r, ratio=ratio)
            old = current.get(name)
            if (old is None or (ratio is not None
                                and (old.get("ratio") is None
                                     or ratio < old["ratio"]))):
                current[name] = r

    if args.write_baseline:
        rows_out = []
        for name, r in sorted(current.items()):
            if name.endswith("+measured"):
                continue   # gated against their static twin, not a baseline
            rows_out.append({
                "name": name,
                "problem": r.get("problem", "F3"),
                "gens_per_s": r.get("gens_per_s"),   # informational
                "ratio": (round(r["ratio"] * RATIO_MARGIN, 4)
                          if r.get("ratio") is not None else None),
                "devices": r.get("devices", 1)})
        with open(args.baseline, "w") as f:
            json.dump(rows_out, f, indent=2)
            f.write("\n")
        print(f"wrote {args.baseline} ({len(rows_out)} combos, "
              f"ratio margin {RATIO_MARGIN})")
        return 0

    if args.append_trajectory:
        append_trajectory(args.append_trajectory, current)

    if os.environ.get("CHECK_BENCH_SKIP") == "1":
        print("check_bench: CHECK_BENCH_SKIP=1 — skipping regression gate")
        return 0

    baseline = load_rows(args.baseline)
    failures, notes = compare(current, baseline, args.tolerance)
    m_failures, m_notes = measured_gate(current, args.tolerance)
    failures += m_failures
    notes += m_notes
    s_failures, s_notes = streamed_gate(current, args.tolerance)
    failures += s_failures
    notes += s_notes
    l_failures, l_notes = lane_gate(current, args.tolerance)
    failures += l_failures
    notes += l_notes
    for n in notes:
        print(f"note: {n}")
    if failures:
        print(f"check_bench: {len(failures)} regression(s) vs "
              f"{args.baseline}:")
        for f_ in failures:
            print(f"  FAIL {f_}")
        return 1
    print(f"check_bench: OK — {len(baseline)} combos within "
          f"{args.tolerance:.0%} of baseline combo-vs-reference ratios")
    return 0


if __name__ == "__main__":
    sys.exit(main())
