"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps on
the synthetic pipeline with checkpointing + auto-resume, then serve it.

    PYTHONPATH=src python examples/train_lm_e2e.py [--steps 300]

This exercises the full production path (data -> sharded step -> async
checkpoints -> watchdog -> serving engine) at laptop scale.
"""

import argparse
import dataclasses
import os
import tempfile

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig
from repro.optim.adamw import AdamWConfig
from repro.serve.engine import Engine, EngineConfig
from repro.train.loop import TrainConfig, train

# ~100M params: 12 layers, d=512, llama-style
CFG_100M = ModelConfig(
    name="repro-100m", family="dense", n_layers=12, d_model=512,
    n_heads=8, n_kv_heads=4, head_dim=64, d_ff=2048, vocab=32000,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    ckpt_dir = args.ckpt_dir or os.path.join(tempfile.gettempdir(),
                                             "repro_100m_ckpt")
    print(f"~{CFG_100M.param_count()/1e6:.0f}M params; ckpts -> {ckpt_dir}")

    out = train(
        CFG_100M,
        TrainConfig(steps=args.steps, log_every=20, ckpt_every=100,
                    ckpt_dir=ckpt_dir, resume=True),
        DataConfig(vocab=CFG_100M.vocab_, seq_len=args.seq_len,
                   global_batch=args.global_batch),
        AdamWConfig(lr=1e-3),
    )
    print(f"trained to loss {out['loss']:.4f} "
          f"({out['straggler_events']} straggler events)")

    # serve the trained weights
    eng = Engine(CFG_100M, out["params"],
                 EngineConfig(batch=4, max_len=args.seq_len + 64))
    prompts = np.tile(np.arange(16, dtype=np.int32)[None], (4, 1))
    toks, stats = eng.generate(prompts, max_new_tokens=24)
    print("continuations:", toks[:, :12])
    print(f"decode throughput: {stats['decode_tok_per_s']:.1f} tok/s")
    # the synthetic corpus is a noisy +1 (mod 64) walk — a trained model
    # should often continue the pattern:
    expect = (prompts[:, -1:] + 1 + np.arange(toks.shape[1])) % 64
    acc = float((toks == expect).mean())
    print(f"pattern-continuation accuracy: {acc:.2f}")


if __name__ == "__main__":
    main()
