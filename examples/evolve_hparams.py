"""The paper's engine as the platform's tuning service: evolve training
hyperparameters (log-LR, weight decay) of a tiny LM — each GA fitness
evaluation runs a short training trial.

    PYTHONPATH=src python examples/evolve_hparams.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, DataIterator
from repro.models import common as C
from repro.models import lm as LM
from repro.optim import adamw as OPT
from repro.train import step as TS

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab=256)
TRIAL_STEPS = 10


def make_fitness():
    defs = LM.model_defs(TINY, max_seq=64)
    params0 = C.init_params(defs, jax.random.key(0))
    it = DataIterator(DataConfig(vocab=TINY.vocab_, seq_len=64,
                                 global_batch=4))
    stacked = [it.batch_at(i) for i in range(TRIAL_STEPS)]
    it.close()
    batches = {k: jnp.stack([jnp.asarray(b[k]) for b in stacked])
               for k in stacked[0]}
    loss_fn = TS.make_loss_fn(TINY, remat=False)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    @jax.jit
    def trial(lr, wd):  # traced hyperparameters -> ONE compilation
        def adam_step(carry, batch):
            params, m, v, t = carry
            (loss, _), grads = grad_fn(params, batch)
            t = t + 1
            b1, b2, eps = 0.9, 0.95, 1e-8
            m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) *
                             g.astype(jnp.float32), m, grads)
            v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) *
                             jnp.square(g.astype(jnp.float32)), v, grads)
            bc1 = 1 - b1 ** t
            bc2 = 1 - b2 ** t

            def upd(p, mm, vv):
                u = (mm / bc1) / (jnp.sqrt(vv / bc2) + eps)
                pf = p.astype(jnp.float32)
                return (pf - lr * (u + wd * pf)).astype(p.dtype)

            params = jax.tree.map(upd, params, m, v)
            return (params, m, v, t), loss

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params0)
        (_, _, _, _), losses = jax.lax.scan(
            adam_step, (params0, zeros, zeros, jnp.float32(0)), batches)
        return losses[-1]

    def fitness(pop):  # (N, 2) -> (N,); vmap over candidates
        pop = jnp.asarray(pop)
        return jax.vmap(lambda hp: trial(10.0 ** hp[0], hp[1]))(pop)

    return fitness


def main():
    # small population/generations — each fitness eval trains a model.
    # Built as a GASpec so the run rides the unified repro.ga engine
    # (equivalently: repro.core.evolve(fitness, bounds, ...)).
    from repro import ga

    fitness = make_fitness()
    spec = ga.GASpec(fitness=fitness, bounds=((-4.0, -1.0), (0.0, 0.2)),
                     n=8, bits_per_var=8, mutation_rate=0.1, seed=1,
                     generations=5)
    r = ga.solve(spec)
    print(f"[backend={r.backend}] best hparams: "
          f"log10_lr={r.best_params[0]:.2f} wd={r.best_params[1]:.3f}")
    print(f"best trial loss: {r.best_fitness:.4f}")
    assert 10.0 ** r.best_params[0] > 3e-4, "GA should avoid tiny LRs"


if __name__ == "__main__":
    main()
