"""Pallas TPU kernel: one fused GA generation per island.

This is the TPU re-expression of the paper's full-parallel datapath: on the
FPGA, FFM/SM/CM/MM are N physically parallel circuits clocked as one 3-cycle
pipeline; here the whole generation is ONE kernel launch whose working set
(population, fitness vector, LFSR banks, one-hot tournament matrices) lives
entirely in VMEM — no HBM round-trips between GA stages.

Key adaptation — MUX trees → two selection lanes (``GAConfig.sel_lane``):
  the paper gathers tournament contestants through N-input multiplexer trees
  (SMMUX1..3, the source of its O(N²) LUT growth).  The kernels implement
  that gather two bit-identical ways:

  * ``"onehot"`` — the systolic array contracts an (N, N) one-hot matrix
    against the population in O(N²) MACs, the MUX tree's asymptotics in
    hardware we do have.  Bit-exactness is preserved by splitting each
    uint32 word into two 16-bit halves before the f32 matmul (≤ 2^16 is
    exactly representable; each one-hot row has a single nonzero so the
    accumulation is exact), then recombining.
  * ``"gather"`` — plain dynamic indexing (``jnp.take`` row gathers on the
    VPU): O(N·V) working set, trivially exact, no one-hot scratch.  This
    drops the dominant VMEM term and with it the N ≤ 1024 cap.

  Both lanes consume the same tournament indices and apply the same tie
  rules; the one-hot matmuls were already exact, so the lanes are
  bit-identical to each other and to the reference path.

Grid: one program instance per island.  VMEM budget per instance is
lane-dependent (`resident_vmem_bytes`):

  * onehot lane — dominated by the (N, N) one-hot f32 matrices (iota + two
    contestant one-hots + winner ≈ 16·N² B): N ≤ 1024 keeps one island at
    ≤ ~4 MiB apart from state, and `check_kernel_lane` raises past that
    (fix: more islands, or ``sel_lane="gather"``).
  * gather lane — state + offspring only, O(N·(V + 1)) per island: the
    selection working set collapses from 16·N² B to a few index/fitness
    vectors (~64× smaller at N=1024), so N = 2048+ single-island runs are
    feasible.  Power-of-two N is still required on BOTH lanes (the
    tournament indices are the top `idx_bits` of the LFSR draw).

The FPGA paper tops out at N=64; larger populations use more islands, the
gather lane, or the pure-JAX path in repro.core.ga.

The FFM stage is PLUGGABLE: the kernel takes a traceable ``ffm`` function
``uint32[N, V] bits -> f32[N]`` (normally ``FitnessProgram.stage`` from
repro.core.fitness — decode + the problem's jnp expression on the VPU) and
traces it into the kernel body, so any n-variable registry problem or user
blackbox runs fused, not just the paper's two-variable polynomials.  Because
the reference executor evaluates the SAME function, fused stays bit-identical
to reference for every program.  LUT-mode (HBM gather tables) stays in the
pure-JAX path — gathers inside a TPU kernel would defeat the fusion.

Epoch planning & VMEM budget — the TWO-TIER decision:

  The file exposes the candidate launch shapes for the island_ring topology;
  the engine's epoch planner (`ga/backends.IslandRingTopology._epoch_plan`)
  picks among them in two tiers:

  tier 1 — FEASIBILITY (modeled, this module): `epoch_mode_candidates`
  enumerates which modes a spec can legally run, gated by the
  `resident_fit_reason` VMEM byte estimator.  The candidate modes are:

  * gridded (`ga_generation_kernel`) — one island per grid step; a launch
    folds up to `migrate_every` generations and the ring migration runs
    BETWEEN launches in XLA (`islands.migrate_ring`).  VMEM per program
    instance holds ONE island.  Always feasible; always the fallback.
  * resident (`ga_epoch_kernel`) — the island axis moves out of the grid
    into the kernel block: all (local-shard) islands live in one program
    instance's VMEM, and the launch folds `intervals × migrate_every`
    generations with the ring migration (`islands.ring_migrate_stack`, the
    same elite/worst tie rules) executed INSIDE the `fori_loop`.  One launch
    spans many migration intervals, so `gens_per_epoch` is no longer capped
    at `migrate_every`.  On a mesh, `boundary=True` keeps one interval per
    launch and performs the intra-shard part of the migration in VMEM; the
    boundary elite is handed back for the between-launch `lax.ppermute`
    (mode "resident-sharded").
  * resident-free (`ga_epoch_kernel` with `migrate=False`) — the
    `migration="none"` ablation has no ring to run, so one launch folds the
    WHOLE `gens_per_epoch` (any value, no whole-multiple rule) with zero
    in-kernel migration work.
  * streamed (`ga_streamed_epoch_kernel`) — the HBM-streaming lane for
    populations PAST the residency budget: the island axis joins the grid
    in tiles of `tile_islands` islands, and Pallas's grid pipeline
    double-buffers the tile loads (the next tile's HBM→VMEM copy overlaps
    the current tile's `migrate_every` generations), so only ~2 tiles of
    working set ever occupy VMEM.  Elite/worst-slot extraction still runs
    in-kernel per tile; the ring splice between tiles runs in XLA between
    kernel passes, inside one jitted `lax.scan` over the migration
    intervals (sharded meshes `ppermute` the boundary elite inside the
    same scan, so unlike resident-sharded a launch folds k > 1 intervals).
    `streamed_tile_islands` picks the largest island tile whose
    double-buffered working set fits; when a spec outgrows residency the
    planner now prefers this mode over the gridded fallback.

  tier 2 — SELECTION (measured, `repro.autotune`): among feasible
  candidates the planner picks the best *measured* gens/s from a per-host
  cost table when one covers the spec, and otherwise keeps the first
  candidate — `epoch_mode_candidates` orders candidates so that index 0 IS
  the heuristic (resident when it fits, else streamed when a tile fits,
  else gridded), making the no-table path deterministic without
  measurement.

  The VMEM estimator is LANE-AWARE: the island state stack (population +
  LFSR banks + fitness) PLUS the per-island selection working set — on the
  onehot lane the one-hot tournament matrices, which materialize as
  [I, N, N] under the in-kernel island vmap; on the gather lane a few O(N)
  index/fitness vectors — PLUS any hoisted FFM
  constants must stay under `resident_vmem_budget()` (default 16 MiB ≈ one
  TPU core's VMEM; override with REPRO_RESIDENT_VMEM_BUDGET).  When it does
  not fit, the engine silently falls back to the gridded kernel (capping
  generations per launch at `migrate_every` again) — a perf fallback, never
  an error.  On real TPUs the estimate can additionally be cross-checked
  against the compiler's own VMEM accounting (`resident_compiler_check`
  compiles with `pltpu.CompilerParams(vmem_limit_bytes=budget)` and records
  the estimator-vs-compiler margin); in interpret mode the check reports
  "unavailable" and the byte estimator stands alone.

  Hoisted FFM closure constants are size-gated separately: both kernels
  refuse constants above `ffm_const_limit()` (default 2 MiB, override with
  REPRO_FFM_CONST_LIMIT) because every grid step re-reads them into VMEM —
  a large captured array (e.g. a dataset) should run on the reference path
  (the engine's capability check does that fallback automatically).
"""

from __future__ import annotations

import functools
import os
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import islands as ISL
from repro.core import lfsr
from repro.core.ga import GAConfig, ONEHOT_MAX_N

# The kernel-facing FFM stage: uint32 bits (N, V) -> f32 fitness (N,).
FfmStage = Callable[[jax.Array], jax.Array]


def _lfsr_draw(state, steps: int):
    """In-kernel LFSR-32 advance (paper polynomial r^32+r^22+r^2+1).

    Uses the precomputed GF(2) leap (`lfsr.leap_feedback_masks`): the
    register shifts `steps` bits at once and each inserted feedback bit is
    an XOR of masked original-state bits — bit-identical to `steps`
    sequential clocks, without the clock-to-clock dependency chain of the
    unrolled shift loop (the parities are independent and share their
    `s >> b` subterms)."""
    while steps > 0:                      # leap in chunks of < 32 clocks
        t = min(steps, 31)
        masks = lfsr.leap_feedback_masks(t)
        shifted = {}
        out = state << jnp.uint32(t)
        for j, m in enumerate(masks):
            acc = None
            for b in range(32):
                if not (m >> b) & 1:
                    continue
                if b not in shifted:
                    shifted[b] = state >> jnp.uint32(b) if b else state
                acc = shifted[b] if acc is None else acc ^ shifted[b]
            bit = acc & jnp.uint32(1)
            out = out | (bit << jnp.uint32(j) if j else bit)
        state = out
        steps -= t
    return state


def _lfsr_draw_banks(banks, steps: int):
    """One fused GF(2) leap advancing several LFSR banks at once.

    The paper clocks its three RNG banks (selection / crossover / mutation)
    in lockstep; leaping each bank separately pays the leap-table mask loop
    three times per generation.  The leap is elementwise in the register
    word and every bank advances by the same `steps`, so the banks
    concatenate — each flattened to one (1, size) lane row — into a single
    register file, ONE `_lfsr_draw` advances everything, and the result
    splits back.  Bit-identical per element to leaping each bank alone."""
    flat = jnp.concatenate([b.reshape(1, -1) for b in banks], axis=1)
    flat = _lfsr_draw(flat, steps)
    out, off = [], 0
    for b in banks:
        size = int(np.prod(b.shape))
        out.append(flat[:, off:off + size].reshape(b.shape))
        off += size
    return tuple(out)


def _onehot_gather_u32(oh: jax.Array, x: jax.Array) -> jax.Array:
    """Exact uint32 gather via two 16-bit-half f32 matmuls on the MXU."""
    hi = (x >> 16).astype(jnp.float32)
    lo = (x & jnp.uint32(0xFFFF)).astype(jnp.float32)
    ghi = jax.lax.dot(oh, hi, precision=jax.lax.Precision.HIGHEST)
    glo = jax.lax.dot(oh, lo, precision=jax.lax.Precision.HIGHEST)
    return (ghi.astype(jnp.uint32) << 16) | glo.astype(jnp.uint32)


def check_kernel_lane(cfg: GAConfig) -> None:
    """THE lane-aware validity gate for the fused kernel path — called by
    all three kernel entry points and by `GASpec` validation, replacing the
    bare asserts that used to be triplicated across the kernels.

    The tournament indices are the top `idx_bits` of the LFSR draw, so the
    kernel path requires a power-of-two N on ANY lane (the reference
    backend folds indices modulo N instead and takes any even N).  The
    onehot lane additionally caps N at `ONEHOT_MAX_N`: its (N, N) one-hot
    tournament matrices are the dominant VMEM term.  Raises ValueError —
    these conditions are reachable from user specs, not internal
    invariants."""
    if cfg.n & (cfg.n - 1):
        raise ValueError(
            f"N={cfg.n}: the fused kernel path draws tournament indices "
            "from the top idx_bits LFSR bits and requires a power-of-two N "
            "(the reference backend accepts any even N)")
    if cfg.sel_lane == "onehot" and cfg.n > ONEHOT_MAX_N:
        raise ValueError(
            f"N={cfg.n} > {ONEHOT_MAX_N} on the 'onehot' selection lane: "
            "the (N, N) one-hot tournament matrices would exceed VMEM.  "
            "Fix: split the population across more islands, or switch to "
            "the O(N*V) dynamic-indexing lane with sel_lane='gather'")


# ---------------------------------------------------------------------------
# FFM closure-constant hoisting + size gates / VMEM budget
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=64)
def _ffm_jaxpr(ffm: FfmStage, n: int, v: int):
    """One shared trace of the FFM stage per (ffm, n, v).

    A fused engine build consults this trace up to three times — the
    `supports` const gate, the epoch planner's VMEM budget check and
    `_hoist_ffm` at kernel-build time — so a slow-to-trace blackbox fitness
    must not pay 3×.  `ffm` is a bound `FitnessProgram.stage` method (the
    spec caches its program, so the SAME bound method arrives each call) or
    a user callable; both hash by identity, and the cached jaxpr's consts
    keep any captured arrays (and the callable itself) alive, so id-keyed
    entries can't go stale."""
    return jax.make_jaxpr(lambda xx: jnp.asarray(ffm(xx), jnp.float32))(
        jax.ShapeDtypeStruct((n, v), jnp.uint32))


def ffm_trace_cache_info():
    """Hit/miss counters of the shared FFM trace cache (for tests/metrics)."""
    return _ffm_jaxpr.cache_info()


def _hoist_ffm(ffm: FfmStage, n: int, v: int):
    """Lower the FFM stage to a jaxpr and hoist its captured array constants
    into explicit kernel inputs (Pallas kernels cannot capture non-scalar
    constants; `jax.closure_convert` only hoists autodiff-perturbed consts).
    Returns (conv_fn(x, *consts), const_shapes, flat_consts, const_bytes):
    each const rides in flattened to one 2-D (1, size) lane row for TPU
    friendliness and is reshaped back inside the kernel."""
    closed = _ffm_jaxpr(ffm, n, v)
    consts = closed.consts
    conv = lambda xx, *cs: jax.core.eval_jaxpr(closed.jaxpr, cs, xx)[0]
    const_shapes = tuple(np.shape(c) for c in consts)
    flat = [jnp.reshape(jnp.asarray(c), (1, max(int(np.size(c)), 1)))
            for c in consts]
    nbytes = int(sum(int(np.size(c)) * np.dtype(jnp.asarray(c).dtype).itemsize
                     for c in consts))
    return conv, const_shapes, flat, nbytes


def ffm_const_bytes(ffm: FfmStage, cfg: GAConfig) -> int:
    """Total bytes of array constants the FFM stage closes over (what the
    kernels would replicate into VMEM) — the engine's capability check uses
    this to route oversized-const programs to the reference path.  Trace
    only: sizes come from the jaxpr consts' metadata, no flattening or
    device transfers (this runs at capability-check time, possibly against
    MB-scale captured arrays)."""
    closed = _ffm_jaxpr(ffm, cfg.n, cfg.v)
    return int(sum(int(np.size(c)) * np.dtype(c.dtype).itemsize
                   for c in closed.consts))


def ffm_const_limit() -> int:
    """Hoisted-const VMEM gate (bytes); REPRO_FFM_CONST_LIMIT overrides."""
    return int(os.environ.get("REPRO_FFM_CONST_LIMIT", str(2 << 20)))


def _check_const_gate(nbytes: int) -> None:
    limit = ffm_const_limit()
    if nbytes > limit:
        raise ValueError(
            f"FFM stage captures {nbytes} bytes of array constants > the "
            f"{limit}-byte VMEM gate: hoisted consts are replicated into "
            "VMEM on every grid step, so large captured arrays (datasets, "
            "big tables) should run on the 'reference' backend instead — "
            "the engine's capability check does this fallback automatically "
            "(REPRO_FFM_CONST_LIMIT overrides the gate)")


def resident_vmem_budget() -> int:
    """VMEM byte budget for the resident-epoch kernel (default 16 MiB ≈ one
    TPU core); REPRO_RESIDENT_VMEM_BUDGET overrides."""
    return int(os.environ.get("REPRO_RESIDENT_VMEM_BUDGET", str(16 << 20)))


def resident_vmem_bytes(cfg: GAConfig, n_islands: int,
                        const_bytes: int = 0) -> int:
    """Estimated VMEM working set of one resident-epoch program instance:
    the island state stack (population, LFSR banks, fitness) plus the
    LANE-DEPENDENT selection working set — on the onehot lane the one-hot
    tournament matrices, the dominant term, since the in-kernel island vmap
    materializes the (N, N) iota/one-hot matrices as [I, N, N]; on the
    gather lane just the O(N) tournament index/fitness vectors — plus
    offspring temporaries and the hoisted FFM consts."""
    n, v = cfg.n, cfg.v
    state = 4 * (n * v + 2 * n + v * (n // 2) + v * n + n)  # x/sel/cross/mut/y
    if cfg.sel_lane == "gather":
        sel = 4 * 6 * n                 # i1/i2/y1/y2/winner idx + mask, i32
    else:
        sel = 4 * 4 * n * n             # iota + oh1 + oh2 + winner, f32
    work = 4 * (2 * n * v + 4 * n)      # offspring + tournament temporaries
    best = 4 * (1 + v)                  # running best fold
    return n_islands * (state + sel + work + best) + const_bytes


def resident_fit_reason(cfg: GAConfig, n_islands: int, const_bytes: int = 0,
                        budget: int = None) -> str:
    """None when `n_islands` VMEM-resident islands fit the budget, else the
    reason string — the epoch planner's fallback-to-gridded decision."""
    budget = resident_vmem_budget() if budget is None else budget
    need = resident_vmem_bytes(cfg, n_islands, const_bytes)
    if need > budget:
        return (f"resident epoch needs ~{need} B of VMEM for {n_islands} "
                f"island(s) at N={cfg.n} (> budget {budget} B); falling "
                "back to the gridded per-interval kernel "
                "(REPRO_RESIDENT_VMEM_BUDGET overrides)")
    return None


def streamed_tile_islands(cfg: GAConfig, i_local: int, const_bytes: int = 0,
                          budget: int = None) -> int:
    """The streamed lane's VMEM tile estimator: the largest island-tile size
    T (a divisor of `i_local`) whose DOUBLE-BUFFERED working set fits the
    budget — the grid pipeline prefetches the next tile's block while the
    current one computes, so ~2 tiles of state + one-hot scratch (+ the
    hoisted FFM consts, replicated per buffer: conservative) live in VMEM
    at once.  None when even a single double-buffered island won't fit —
    then only the gridded fallback remains."""
    budget = resident_vmem_budget() if budget is None else budget
    for t in range(i_local, 0, -1):
        if i_local % t:
            continue
        if 2 * resident_vmem_bytes(cfg, t, const_bytes) <= budget:
            return t
    return None


def epoch_mode_candidates(cfg: GAConfig, i_local: int, const_bytes: int = 0,
                          *, executor: str, migration: str,
                          gens_per_epoch: int, migrate_every: int,
                          sharded: bool, budget: int = None) -> list:
    """Tier 1 of the epoch plan: the FEASIBLE launch shapes for a spec,
    ordered so candidates[0] is the heuristic choice (what a planner with
    no cost table must pick, deterministically).

    Each candidate is a plan dict: {"mode", "lane", "epochs_per_launch",
    "gens_per_launch"} (+ "fallback" carrying the VMEM-estimator reason when
    a resident shape was rejected, + "tile_islands" for the streamed mode).
    The "lane" is `cfg.sel_lane` throughout — this function enumerates the
    launch shapes of ONE lane; the planner builds the cross-lane (mode ×
    lane) grid by calling it once per lane (see
    `IslandRingTopology._epoch_plan`), keeping the default candidate list
    (and the no-table heuristic) exactly what it was before lanes existed.
    `gens_per_launch` is the generations one kernel launch folds — the cost
    table's interpolation axis.  When the resident stack exceeds the budget
    the streamed lane — NOT gridded — is the heuristic for ring migration:
    it keeps kernel throughput at any population size, which is the lane's
    whole point.
    """
    # the gridded path launches one migrate_every-generation epoch at a
    # time; the fused executor's block folds min(gens_per_epoch, E) of those
    # generations per kernel launch, the reference executor scans all E
    g_gridded = (min(gens_per_epoch, migrate_every) if executor == "fused"
                 else migrate_every)
    gridded = {"mode": "gridded", "lane": cfg.sel_lane,
               "epochs_per_launch": 1, "gens_per_launch": g_gridded}
    if executor != "fused":
        return [gridded]
    if migration == "ring" and gens_per_epoch >= migrate_every:
        reason = resident_fit_reason(cfg, i_local, const_bytes, budget)
        if reason is not None:
            tile = streamed_tile_islands(cfg, i_local, const_bytes, budget)
            if tile is None:
                return [dict(gridded, fallback=reason)]
            k = max(1, gens_per_epoch // migrate_every)
            return [{"mode": "streamed", "lane": cfg.sel_lane,
                     "epochs_per_launch": k,
                     "gens_per_launch": k * migrate_every,
                     "tile_islands": tile, "fallback": reason},
                    dict(gridded, fallback=reason)]
        if sharded:
            return [{"mode": "resident-sharded", "lane": cfg.sel_lane,
                     "epochs_per_launch": 1,
                     "gens_per_launch": migrate_every}, gridded]
        k = max(1, gens_per_epoch // migrate_every)
        return [{"mode": "resident", "lane": cfg.sel_lane,
                 "epochs_per_launch": k,
                 "gens_per_launch": k * migrate_every}, gridded]
    if migration == "none" and gens_per_epoch > migrate_every and not sharded:
        # no ring to run: the resident kernel can fold the WHOLE epoch in
        # one launch (satellite of the autotune PR).  Gridded stays the
        # heuristic default — resident-free is selected by measurement (or
        # forced via plan_override), never silently.
        reason = resident_fit_reason(cfg, i_local, const_bytes, budget)
        if reason is not None:
            # gridded stays the heuristic for migration="none" (matching the
            # fitting case below); a feasible streamed tile is offered for
            # measurement/plan_override to pick.
            tile = streamed_tile_islands(cfg, i_local, const_bytes, budget)
            out = [dict(gridded, fallback=reason)]
            if tile is not None:
                k = max(1, gens_per_epoch // migrate_every)
                out.append({"mode": "streamed", "lane": cfg.sel_lane,
                            "epochs_per_launch": k,
                            "gens_per_launch": k * migrate_every,
                            "tile_islands": tile, "fallback": reason})
            return out
        return [gridded,
                {"mode": "resident-free", "lane": cfg.sel_lane,
                 "epochs_per_launch": max(1, gens_per_epoch // migrate_every),
                 "gens_per_launch": gens_per_epoch}]
    return [gridded]


def resident_compiler_check(cfg: GAConfig, ffm: FfmStage, i_local: int, *,
                            budget: int = None, interpret: bool = None
                            ) -> dict:
    """Tier-1 cross-check: does the COMPILER agree the resident working set
    fits?  Lowers a one-generation resident launch with
    `pltpu.CompilerParams(vmem_limit_bytes=budget)` and reports
    {"status": "ok" | "exceeds" | "unavailable", "estimator_bytes",
    "budget_bytes", "estimator_margin"} — the margin is the headroom the
    byte estimator claims, so an "exceeds" with positive margin means the
    hand-written model underestimates on this config.  In interpret mode
    (CPU CI) there is no Mosaic lowering to ask, hence "unavailable"."""
    budget = resident_vmem_budget() if budget is None else budget
    est = resident_vmem_bytes(cfg, i_local, ffm_const_bytes(ffm, cfg))
    out = {"estimator_bytes": est, "budget_bytes": budget,
           "estimator_margin": round(1.0 - est / budget, 4)}
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if interpret:
        out.update(status="unavailable",
                   reason="compiler VMEM accounting needs a real TPU "
                          "(Mosaic) lowering; interpret mode has none")
        return out
    n, v = cfg.n, cfg.v
    shapes = (jax.ShapeDtypeStruct((1, i_local, n, v), jnp.uint32),
              jax.ShapeDtypeStruct((1, i_local, 2, n), jnp.uint32),
              jax.ShapeDtypeStruct((1, i_local, v, n // 2), jnp.uint32),
              jax.ShapeDtypeStruct((1, i_local, v, n), jnp.uint32))
    fn = functools.partial(ga_epoch_kernel, cfg=cfg, ffm=ffm,
                           migrate_every=1, intervals=1, interpret=False,
                           vmem_limit_bytes=budget)
    try:
        jax.jit(lambda *a: fn(*a)).lower(*shapes).compile()
        out["status"] = "ok"
    except Exception as e:                  # compiler rejected the budget
        out.update(status="exceeds", reason=repr(e))
    return out


def _gen_best(x, y, cfg: GAConfig):
    """First-occurrence generation best — the reference scan's argmin/argmax
    tie rule: the index is a min-reduction over a masked iota (no argmin
    inside the kernel), the chromosome pick then runs on the configured
    selection lane (one-hot matmul gather vs a jnp.take row gather)."""
    m = jnp.min(y) if cfg.minimize else jnp.max(y)
    iota = jax.lax.broadcasted_iota(jnp.int32, (cfg.n,), 0)
    idx = jnp.min(jnp.where(y == m, iota, cfg.n))
    if cfg.sel_lane == "gather":
        return m, jnp.take(x, idx[None], axis=0)[0]          # (V,)
    oh = (iota == idx).astype(jnp.float32)[None, :]          # (1, N)
    return m, _onehot_gather_u32(oh, x)[0]                   # (V,)


def _kernel(x_ref, sel_ref, cross_ref, mut_ref,              # inputs
            *rest,                                           # consts + outputs
            cfg: GAConfig, ffm, const_shapes=(), gens: int = 1,
            track_best: bool = False):
    """One or MANY generations per launch.

    gens > 1 is the VMEM-residency optimization (EXPERIMENTS.md §Perf GA
    iter 2): the FPGA keeps population + LFSRs in registers between clock
    beats; we keep them in VMEM between generations, so HBM sees one state
    read + one write per `gens` generations instead of per generation.

    `rest` leads with one VMEM ref per FFM closure constant (arrays the
    user's fitness captured, hoisted by `jax.closure_convert` in
    `ga_generation_kernel` — Pallas kernels cannot capture array constants
    directly); `const_shapes` restores their original shapes.

    track_best=True adds two outputs (best_y, best_x) folding the running
    best individual *inside* the launch with the reference scan's strict
    improvement + first-occurrence tie rule — so a gens>1 launch loses no
    best-tracking fidelity, only per-generation trajectory resolution
    (y_out is the fitness of the LAST pre-update population)."""
    n_consts = len(const_shapes)
    const_refs, out_refs = rest[:n_consts], rest[n_consts:]
    if n_consts:
        consts = [r[0].reshape(s) for r, s in zip(const_refs, const_shapes)]
        ffm_stage = lambda x: ffm(x, *consts)
    else:
        ffm_stage = ffm
    if track_best:
        x_out, sel_out, cross_out, mut_out, y_out, by_out, bx_out = out_refs
    else:
        x_out, sel_out, cross_out, mut_out, y_out = out_refs

    def step(carry):
        x, sel, cross, mut, y = carry[:5]
        out = _one_generation(x, sel, cross, mut, y, cfg=cfg, ffm=ffm_stage)
        if track_best:
            by, bx = carry[5], carry[6]
            y2 = out[4]
            gb, gx = _gen_best(x, y2, cfg)   # y2 scores x (pre-update)
            better = gb < by if cfg.minimize else gb > by
            out = out + (jnp.where(better, gb, by),
                         jnp.where(better, gx, bx))
        return out

    init = (x_ref[0], sel_ref[0], cross_ref[0], mut_ref[0],
            jnp.zeros((cfg.n,), jnp.float32))
    if track_best:
        init = init + (jnp.float32(jnp.inf if cfg.minimize else -jnp.inf),
                       jnp.zeros((cfg.v,), jnp.uint32))
    if gens > 1:
        final = jax.lax.fori_loop(0, gens, lambda _, c: step(c), init)
    else:
        final = step(init)
    x_out[0], sel_out[0], cross_out[0], mut_out[0], y_out[0] = final[:5]
    if track_best:
        by_out[0], bx_out[0] = final[5], final[6]


def _one_generation(x, sel_in, cross_in, mut_in, _y_prev,
                    *, cfg: GAConfig, ffm: FfmStage):
    n, v, c = cfg.n, cfg.v, cfg.c
    var_mask = jnp.uint32((1 << c) - 1)

    # ---- RNG: ONE fused GF(2) leap clocks all three LFSR banks -----------
    sel, cross, mut = _lfsr_draw_banks((sel_in, cross_in, mut_in),
                                       cfg.steps_per_draw)

    # ---- FFM (pluggable traced stage: decode + problem expression, VPU) --
    y = jnp.asarray(ffm(x), jnp.float32)                  # (N,)

    # ---- SM: tournaments on the configured selection lane -----------------
    i1 = (sel[0] >> jnp.uint32(32 - cfg.idx_bits)).astype(jnp.int32)
    i2 = (sel[1] >> jnp.uint32(32 - cfg.idx_bits)).astype(jnp.int32)
    if cfg.sel_lane == "gather":
        # dynamic-indexing lane: VPU row gathers, O(N·V) scratch — both
        # lanes read the same indices and tie rules, so they are
        # bit-identical (the one-hot matmuls below were already exact)
        y1 = jnp.take(y, i1, axis=0)
        y2 = jnp.take(y, i2, axis=0)
        first_wins = (y1 <= y2) if cfg.minimize else (y1 >= y2)
        wi = jnp.where(first_wins, i1, i2)                # winner index
        w = jnp.take(x, wi, axis=0)                       # (N, V)
    else:
        # one-hot lane: exact gathers as (N, N) MXU contractions
        iota = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
        oh1 = (iota == i1[:, None]).astype(jnp.float32)
        oh2 = (iota == i2[:, None]).astype(jnp.float32)
        y1 = jax.lax.dot(oh1, y[:, None],
                         precision=jax.lax.Precision.HIGHEST)[:, 0]
        y2 = jax.lax.dot(oh2, y[:, None],
                         precision=jax.lax.Precision.HIGHEST)[:, 0]
        first_wins = (y1 <= y2) if cfg.minimize else (y1 >= y2)
        ohw = jnp.where(first_wins[:, None], oh1, oh2)    # winner one-hot
        w = _onehot_gather_u32(ohw, x)                    # (N, V)

    # ---- CM: mask-shift single-point crossover ----------------------------
    cut = (cross >> jnp.uint32(32 - cfg.cut_bits)).astype(jnp.uint32)
    cut = jnp.minimum(cut, jnp.uint32(c))
    s = (var_mask >> cut).T                               # (N/2, V)
    wp = w.reshape(n // 2, 2, v)
    w1, w2 = wp[:, 0], wp[:, 1]
    z1 = (w1 & ~s) | (w2 & s)
    z2 = (w2 & ~s) | (w1 & s)
    z = jnp.stack([z1, z2], axis=1).reshape(n, v)

    # ---- MM: XOR-mutate the first P --------------------------------------
    rbits = (mut >> jnp.uint32(32 - c)).T                 # (N, V)
    mut_row = (jax.lax.broadcasted_iota(jnp.int32, (n, 1), 0) < cfg.p)
    x_new = jnp.where(mut_row, z ^ rbits, z)
    return x_new, sel, cross, mut, y


def ga_generation_kernel(x, sel, cross, mut, *, cfg: GAConfig,
                         ffm: FfmStage, interpret: bool = False,
                         gens: int = 1, track_best: bool = False
                         ) -> Tuple[jax.Array, ...]:
    """Launch the fused generation(s) over a stack of islands.

    x: uint32[I, N, V]; sel: uint32[I, 2, N]; cross: uint32[I, V, N//2];
    mut: uint32[I, V, N].  Returns (x', sel', cross', mut', y[I, N]).
    ffm: the traced FFM stage — uint32[N, V] -> f32[N] (normally
    `FitnessProgram.stage`; any traceable n-variable/blackbox objective).
    gens: generations per launch (VMEM-resident state between them).
    track_best appends (best_y[I], best_x[I, V]) — the running best over all
    `gens` in-kernel generations, reference tie rule (see `_kernel`).
    """
    check_kernel_lane(cfg)
    i_islands, n, v = x.shape
    assert (n, v) == (cfg.n, cfg.v)

    # Hoist any array constants the FFM stage closed over (decode bounds,
    # blackbox targets, ...) into explicit kernel inputs — Pallas kernels
    # cannot capture non-scalar constants.  Every const rides in replicated
    # (block index 0 on every grid step), which is why oversized consts are
    # rejected by the VMEM gate — see the module docstring.
    ffm_conv, const_shapes, flat_consts, const_bytes = _hoist_ffm(ffm, n, v)
    _check_const_gate(const_bytes)

    blk = lambda *shape: pl.BlockSpec((1,) + shape, lambda i: (i,) + (0,) * len(shape))
    cblk = lambda k: pl.BlockSpec((1, k), lambda i: (0, 0))
    grid = (i_islands,)
    kernel = functools.partial(_kernel, cfg=cfg, ffm=ffm_conv,
                               const_shapes=const_shapes, gens=gens,
                               track_best=track_best)
    out_specs = [blk(n, v), blk(2, n), blk(v, n // 2), blk(v, n), blk(n)]
    out_shape = [
        jax.ShapeDtypeStruct((i_islands, n, v), jnp.uint32),
        jax.ShapeDtypeStruct((i_islands, 2, n), jnp.uint32),
        jax.ShapeDtypeStruct((i_islands, v, n // 2), jnp.uint32),
        jax.ShapeDtypeStruct((i_islands, v, n), jnp.uint32),
        jax.ShapeDtypeStruct((i_islands, n), jnp.float32),
    ]
    if track_best:
        out_specs += [blk(), blk(v)]
        out_shape += [jax.ShapeDtypeStruct((i_islands,), jnp.float32),
                      jax.ShapeDtypeStruct((i_islands, v), jnp.uint32)]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[blk(n, v), blk(2, n), blk(v, n // 2), blk(v, n)]
                 + [cblk(c.shape[1]) for c in flat_consts],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(x, sel, cross, mut, *flat_consts)


# ---------------------------------------------------------------------------
# Resident-epoch kernel: whole island shard in VMEM, migration in the loop
# ---------------------------------------------------------------------------


def _epoch_body(x_ref, sel_ref, cross_ref, mut_ref,          # inputs
                *rest,                                       # consts + outputs
                cfg: GAConfig, ffm, const_shapes=(),
                migrate_every: int, intervals: int, boundary: bool,
                migrate: bool = True):
    """`intervals × migrate_every` generations + in-VMEM ring migration.

    The block holds a whole island stack [I, N, V] (the grid axis is the
    replica axis, not the island axis): generations vmap over the islands,
    and after every `migrate_every` of them the migration fitness is
    evaluated in-kernel and `islands.ring_migrate_stack` splices the shifted
    elites — the same masked-iota/select math the XLA path runs between
    launches, so state stays bit-identical to reference × island_ring.

    boundary=True is the sharded variant (intervals == 1): the ring wraps
    across shards, so the kernel performs only the INTRA-shard part (islands
    1..I-1 receive elites 0..I-2) and instead of splicing island 0 it
    outputs (boundary elite of island I-1, worst slot of island 0) for the
    between-launch `lax.ppermute` + splice.

    migrate=False is the migration-free resident mode (`migration="none"`):
    the interval loop runs the generations and evaluates the interval
    fitness but skips `ring_migrate_stack` entirely — no ring means no
    whole-multiple constraint, so one launch can fold ANY number of
    generations (callers pass intervals=1, migrate_every=the full fold).

    The per-island running best folds every generation with the reference
    strict-improvement/first-occurrence rule; the y output is the migration
    fitness of the final (pre-splice) populations — one trajectory sample
    per launch.
    """
    n_consts = len(const_shapes)
    const_refs, out_refs = rest[:n_consts], rest[n_consts:]
    if n_consts:
        consts = [r[0].reshape(s) for r, s in zip(const_refs, const_shapes)]
        ffm_stage = lambda x: ffm(x, *consts)
    else:
        ffm_stage = ffm
    x_out, sel_out, cross_out, mut_out, y_out, by_out, bx_out = out_refs[:7]
    mini = cfg.minimize
    i_islands = x_ref.shape[1]

    vgen = jax.vmap(functools.partial(_one_generation, cfg=cfg,
                                      ffm=ffm_stage))
    vfit = jax.vmap(lambda xx: jnp.asarray(ffm_stage(xx), jnp.float32))

    def gen_step(carry):
        x, sel, cross, mut, y, by, bx = carry
        x2, sel2, cross2, mut2, y2 = vgen(x, sel, cross, mut, y)
        gx, gb = ISL.elites_stack(x, y2, minimize=mini)  # y2 scores x
        better = gb < by if mini else gb > by
        by = jnp.where(better, gb, by)
        bx = jnp.where(better[:, None], gx, bx)
        return (x2, sel2, cross2, mut2, y2, by, bx)

    def block(carry):
        """One migration interval's generations + the migration fitness."""
        carry = jax.lax.fori_loop(0, migrate_every,
                                  lambda _, c: gen_step(c), carry)
        x = carry[0]
        return carry, vfit(x)                            # scores final pops

    init = (x_ref[0], sel_ref[0], cross_ref[0], mut_ref[0],
            jnp.zeros((i_islands, cfg.n), jnp.float32),
            jnp.full((i_islands,), jnp.inf if mini else -jnp.inf,
                     jnp.float32),
            jnp.zeros((i_islands, cfg.v), jnp.uint32))

    if boundary:
        send_out, w0_out = out_refs[7:]
        carry, ymig = block(init)
        x, sel, cross, mut, _y, by, bx = carry
        elite_x, _elite_y = ISL.elites_stack(x, ymig, minimize=mini)
        widx = ISL.worst_slot(ymig, minimize=mini)
        # islands 1..I-1 take elites 0..I-2; island 0 waits for the ppermute
        shifted = jnp.concatenate([elite_x[:1], elite_x[:-1]], axis=0)
        not_first = (jax.lax.broadcasted_iota(jnp.int32, (i_islands, 1), 0)
                     >= 1)
        x = ISL.splice_at(x, widx, shifted, island_mask=not_first)
        send_out[0], w0_out[0] = elite_x[-1], widx[0]
    else:
        def interval(_, carry):
            carry, ymig = block(carry)
            x, sel, cross, mut, _y, by, bx = carry
            if migrate:
                x, _ex, _ey = ISL.ring_migrate_stack(x, ymig, minimize=mini)
            return (x, sel, cross, mut, ymig, by, bx)

        x, sel, cross, mut, ymig, by, bx = jax.lax.fori_loop(
            0, intervals, interval, init)

    x_out[0], sel_out[0], cross_out[0], mut_out[0] = x, sel, cross, mut
    y_out[0], by_out[0], bx_out[0] = ymig, by, bx


def ga_epoch_kernel(x, sel, cross, mut, *, cfg: GAConfig, ffm: FfmStage,
                    migrate_every: int, intervals: int = 1,
                    boundary: bool = False, migrate: bool = True,
                    interpret: bool = False, vmem_limit_bytes: int = None
                    ) -> Tuple[jax.Array, ...]:
    """Launch the resident-epoch kernel over replica-stacked island shards.

    x: uint32[G, I, N, V]; sel: uint32[G, I, 2, N]; cross: uint32[G, I, V,
    N//2]; mut: uint32[G, I, V, N] — G independent replica groups ride the
    grid, each program instance keeps its I islands VMEM-resident for
    `intervals × migrate_every` generations with the ring migration folded
    into the loop (see `_epoch_body`; `boundary=True` for the sharded
    intra-shard variant, which requires intervals == 1).

    Returns (x', sel', cross', mut', y[G, I, N], best_y[G, I],
    best_x[G, I, V]) — y is the final migration fitness (pre-splice) —
    plus (send_elite[G, V], worst0[G]) when boundary=True.

    migrate=False (migration-free resident mode) skips the in-loop ring
    splice; pass the full generation fold as `migrate_every` with
    intervals=1.  vmem_limit_bytes threads a
    `pltpu.CompilerParams(vmem_limit_bytes=...)` into the launch on real
    TPU lowerings (ignored in interpret mode) — `resident_compiler_check`
    uses it to make the compiler referee the byte estimator.

    Callers should consult `resident_fit_reason` first; this function
    asserts the budget (and the hoisted-const gate) rather than silently
    overflowing VMEM.
    """
    check_kernel_lane(cfg)
    assert intervals >= 1 and migrate_every >= 1
    assert not (boundary and intervals != 1), \
        "boundary (sharded) epochs exchange elites between launches: one " \
        "migration interval per launch"
    assert migrate or not boundary, \
        "boundary epochs exist to exchange elites: migrate=False has none"
    g_grid, i_islands, n, v = x.shape
    assert (n, v) == (cfg.n, cfg.v)

    ffm_conv, const_shapes, flat_consts, const_bytes = _hoist_ffm(ffm, n, v)
    _check_const_gate(const_bytes)
    reason = resident_fit_reason(cfg, i_islands, const_bytes)
    if reason is not None:
        raise ValueError(reason)

    blk = lambda *shape: pl.BlockSpec((1,) + shape,
                                      lambda i: (i,) + (0,) * len(shape))
    cblk = lambda k: pl.BlockSpec((1, k), lambda i: (0, 0))
    kernel = functools.partial(_epoch_body, cfg=cfg, ffm=ffm_conv,
                               const_shapes=const_shapes,
                               migrate_every=migrate_every,
                               intervals=intervals, boundary=boundary,
                               migrate=migrate)
    state_blks = [blk(i_islands, n, v), blk(i_islands, 2, n),
                  blk(i_islands, v, n // 2), blk(i_islands, v, n)]
    state_shapes = [
        jax.ShapeDtypeStruct((g_grid, i_islands, n, v), jnp.uint32),
        jax.ShapeDtypeStruct((g_grid, i_islands, 2, n), jnp.uint32),
        jax.ShapeDtypeStruct((g_grid, i_islands, v, n // 2), jnp.uint32),
        jax.ShapeDtypeStruct((g_grid, i_islands, v, n), jnp.uint32),
    ]
    out_specs = state_blks + [blk(i_islands, n), blk(i_islands),
                              blk(i_islands, v)]
    out_shape = state_shapes + [
        jax.ShapeDtypeStruct((g_grid, i_islands, n), jnp.float32),
        jax.ShapeDtypeStruct((g_grid, i_islands), jnp.float32),
        jax.ShapeDtypeStruct((g_grid, i_islands, v), jnp.uint32),
    ]
    if boundary:
        out_specs += [blk(v), blk()]
        out_shape += [jax.ShapeDtypeStruct((g_grid, v), jnp.uint32),
                      jax.ShapeDtypeStruct((g_grid,), jnp.int32)]
    call_kwargs = {}
    if vmem_limit_bytes is not None and not interpret:
        from jax.experimental.pallas import tpu as pltpu
        params_cls = (getattr(pltpu, "CompilerParams", None)
                      or getattr(pltpu, "TPUCompilerParams"))
        call_kwargs["compiler_params"] = params_cls(
            vmem_limit_bytes=int(vmem_limit_bytes))
    return pl.pallas_call(
        kernel,
        grid=(g_grid,),
        in_specs=state_blks + [cblk(c.shape[1]) for c in flat_consts],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
        **call_kwargs,
    )(x, sel, cross, mut, *flat_consts)


# ---------------------------------------------------------------------------
# Streamed-epoch kernel: HBM→VMEM island tiles through the grid pipeline
# ---------------------------------------------------------------------------


def _streamed_body(x_ref, sel_ref, cross_ref, mut_ref,       # inputs
                   *rest,                                    # consts + outputs
                   cfg: GAConfig, ffm, const_shapes=(),
                   migrate_every: int, migrate: bool = True):
    """One migration interval for ONE island tile of a streamed epoch.

    The block holds `tile_islands` islands — a slice of the island axis, not
    the whole stack — so the working set is bounded by the tile, not the
    population.  Each tile runs `migrate_every` vmapped generations with the
    per-generation best fold (identical math to `_epoch_body`), evaluates
    the migration fitness in-kernel, and — when a ring runs — emits the
    per-island elites and worst slots so the caller can splice the shifted
    elites in XLA between kernel passes.  The outputs are therefore
    PRE-splice; `elites_stack`/`worst_slot` in here and `splice_at` outside
    are the same rule set `ring_migrate_stack` composes, so the streamed
    interval stays bit-identical to the resident and gridded plans."""
    n_consts = len(const_shapes)
    const_refs, out_refs = rest[:n_consts], rest[n_consts:]
    if n_consts:
        consts = [r[0].reshape(s) for r, s in zip(const_refs, const_shapes)]
        ffm_stage = lambda x: ffm(x, *consts)
    else:
        ffm_stage = ffm
    if migrate:
        (x_out, sel_out, cross_out, mut_out, y_out, by_out, bx_out,
         ex_out, w_out) = out_refs
    else:
        x_out, sel_out, cross_out, mut_out, y_out, by_out, bx_out = out_refs
    mini = cfg.minimize
    t_islands = x_ref.shape[1]

    vgen = jax.vmap(functools.partial(_one_generation, cfg=cfg,
                                      ffm=ffm_stage))
    vfit = jax.vmap(lambda xx: jnp.asarray(ffm_stage(xx), jnp.float32))

    def gen_step(carry):
        x, sel, cross, mut, y, by, bx = carry
        x2, sel2, cross2, mut2, y2 = vgen(x, sel, cross, mut, y)
        gx, gb = ISL.elites_stack(x, y2, minimize=mini)   # y2 scores x
        better = gb < by if mini else gb > by
        by = jnp.where(better, gb, by)
        bx = jnp.where(better[:, None], gx, bx)
        return (x2, sel2, cross2, mut2, y2, by, bx)

    init = (x_ref[0], sel_ref[0], cross_ref[0], mut_ref[0],
            jnp.zeros((t_islands, cfg.n), jnp.float32),
            jnp.full((t_islands,), jnp.inf if mini else -jnp.inf,
                     jnp.float32),
            jnp.zeros((t_islands, cfg.v), jnp.uint32))
    carry = jax.lax.fori_loop(0, migrate_every, lambda _, c: gen_step(c),
                              init)
    x, sel, cross, mut, _y, by, bx = carry
    ymig = vfit(x)                                        # scores final pops
    x_out[0], sel_out[0], cross_out[0], mut_out[0] = x, sel, cross, mut
    y_out[0], by_out[0], bx_out[0] = ymig, by, bx
    if migrate:
        elite_x, _elite_y = ISL.elites_stack(x, ymig, minimize=mini)
        ex_out[0] = elite_x
        w_out[0] = ISL.worst_slot(ymig, minimize=mini)


def ga_streamed_epoch_kernel(x, sel, cross, mut, *, cfg: GAConfig,
                             ffm: FfmStage, migrate_every: int,
                             tile_islands: int, migrate: bool = True,
                             interpret: bool = False,
                             vmem_limit_bytes: int = None
                             ) -> Tuple[jax.Array, ...]:
    """One migration interval streamed through VMEM in island tiles.

    x: uint32[G, I, N, V] (+ the sel/cross/mut LFSR banks, same leading
    axes): G replica groups × I islands, tiled through the kernel
    `tile_islands` islands at a time over grid (G, I // tile_islands).
    Pallas's grid pipeline double-buffers the block loads — the next tile's
    HBM→VMEM copy overlaps the current tile's `migrate_every` generations —
    so populations far past `resident_vmem_budget()` keep kernel throughput.

    Returns (x', sel', cross', mut', y[G, I, N], best_y[G, I],
    best_x[G, I, V]) plus, when migrate=True, (elite_x[G, I, V],
    worst_idx[G, I]) — the PRE-splice migration ingredients.  The caller
    owns the ring: shift the elites by one island (`ppermute` across shards
    at the boundary) and `islands.splice_at` the worst slots in XLA, then
    feed the spliced state to the next interval's kernel pass (see
    `ga/backends.IslandRingTopology._streamed_runner`).  migrate=False (the
    `migration="none"` ablation) skips the elite outputs and the caller
    skips the splice.

    Callers should consult `streamed_tile_islands` first; this function
    raises on a tile whose double-buffered working set exceeds the REAL
    budget (env-derived — a planner-forced smaller budget never makes a
    legitimate tile illegal here).
    """
    check_kernel_lane(cfg)
    assert migrate_every >= 1 and tile_islands >= 1
    g_grid, i_islands, n, v = x.shape
    assert (n, v) == (cfg.n, cfg.v)
    assert i_islands % tile_islands == 0, \
        f"tile_islands={tile_islands} must divide the island count {i_islands}"

    ffm_conv, const_shapes, flat_consts, const_bytes = _hoist_ffm(ffm, n, v)
    _check_const_gate(const_bytes)
    need = 2 * resident_vmem_bytes(cfg, tile_islands, const_bytes)
    real_budget = resident_vmem_budget()
    if need > real_budget:
        raise ValueError(
            f"streamed tile of {tile_islands} island(s) at N={cfg.n} needs "
            f"~{need} B of VMEM double-buffered (> budget {real_budget} B); "
            "use streamed_tile_islands to size the tile")

    blk = lambda *shape: pl.BlockSpec(
        (1, tile_islands) + shape,
        lambda g, t: (g, t) + (0,) * len(shape))
    cblk = lambda k: pl.BlockSpec((1, k), lambda g, t: (0, 0))
    kernel = functools.partial(_streamed_body, cfg=cfg, ffm=ffm_conv,
                               const_shapes=const_shapes,
                               migrate_every=migrate_every, migrate=migrate)
    state_blks = [blk(n, v), blk(2, n), blk(v, n // 2), blk(v, n)]
    state_shapes = [
        jax.ShapeDtypeStruct((g_grid, i_islands, n, v), jnp.uint32),
        jax.ShapeDtypeStruct((g_grid, i_islands, 2, n), jnp.uint32),
        jax.ShapeDtypeStruct((g_grid, i_islands, v, n // 2), jnp.uint32),
        jax.ShapeDtypeStruct((g_grid, i_islands, v, n), jnp.uint32),
    ]
    out_specs = state_blks + [blk(n), blk(), blk(v)]
    out_shape = state_shapes + [
        jax.ShapeDtypeStruct((g_grid, i_islands, n), jnp.float32),
        jax.ShapeDtypeStruct((g_grid, i_islands), jnp.float32),
        jax.ShapeDtypeStruct((g_grid, i_islands, v), jnp.uint32),
    ]
    if migrate:
        out_specs += [blk(v), blk()]
        out_shape += [jax.ShapeDtypeStruct((g_grid, i_islands, v),
                                           jnp.uint32),
                      jax.ShapeDtypeStruct((g_grid, i_islands), jnp.int32)]
    call_kwargs = {}
    if vmem_limit_bytes is not None and not interpret:
        from jax.experimental.pallas import tpu as pltpu
        params_cls = (getattr(pltpu, "CompilerParams", None)
                      or getattr(pltpu, "TPUCompilerParams"))
        call_kwargs["compiler_params"] = params_cls(
            vmem_limit_bytes=int(vmem_limit_bytes))
    return pl.pallas_call(
        kernel,
        grid=(g_grid, i_islands // tile_islands),
        in_specs=state_blks + [cblk(c.shape[1]) for c in flat_consts],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
        **call_kwargs,
    )(x, sel, cross, mut, *flat_consts)
