"""Roofline analysis from compiled HLO — the dry-run's perf report.

XLA's HloCostAnalysis visits a while body ONCE (verified empirically: a
10-layer scan reports 1 layer of FLOPs), so we parse the optimized HLO
ourselves and walk the call graph, multiplying while bodies by their
`backend_config known_trip_count`:

  * FLOPs: every `dot` op contributes 2 · |result| · |contracted dims|
    (dimension numbers parsed from the op line).
  * HBM bytes: for each top-level op of a non-fused computation we count
    operand + result bytes; a fusion's internals live in registers/VMEM, so
    only the fusion op's own operands/results hit HBM — and a fusion operand
    that the fused computation merely dynamic-slices (the scan-over-stacked-
    layers pattern) is charged only its sliced window, not the full stack.
  * Collective bytes: all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute, counted as bytes crossing links per device
    (all-reduce counts 2× its operand: reduce-scatter + all-gather phases).

Terms (per device, seconds):
  compute    = flops / PEAK_FLOPS
  memory     = hbm_bytes / HBM_BW
  collective = coll_bytes / ICI_BW
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_CALL_RE = re.compile(r"(?:body|to_apply|calls)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SKIP_BYTES = ("parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "while", "call", "conditional", "after-all",
               "iota", "partition-id", "replica-id")


def type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def type_elems_dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


def _operands(line: str, op: str) -> List[str]:
    """Operand *names* of `op` in an HLO instruction line.

    Operands are printed as `f32[128,128]{1,0} %name` — commas appear inside
    shape brackets too, so split at depth-0 commas only, drop `/*index=k*/`
    comments, and keep the trailing `%name` token of each operand.
    """
    m = re.search(r"\b" + re.escape(op) + r"\(", line)
    if not m:
        return []
    depth_paren, depth_brack = 1, 0
    args, cur = [], []
    for ch in line[m.end():]:
        if ch == "(":
            depth_paren += 1
        elif ch == ")":
            depth_paren -= 1
            if depth_paren == 0:
                break
        elif ch in "[{":
            depth_brack += 1
        elif ch in "]}":
            depth_brack -= 1
        if ch == "," and depth_paren == 1 and depth_brack == 0:
            args.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        args.append("".join(cur))
    names = []
    for a in args:
        a = re.sub(r"/\*.*?\*/", "", a).strip()
        if not a:
            continue
        names.append(a.split()[-1].lstrip("%"))
    return names


@dataclasses.dataclass
class Computation:
    name: str
    lines: List[str]
    symtab: Dict[str, str] = dataclasses.field(default_factory=dict)
    ops: List[Tuple[str, str, str, List[str], str]] = \
        dataclasses.field(default_factory=list)  # (var, type, op, operands, line)
    params: Dict[str, int] = dataclasses.field(default_factory=dict)

    def parse(self):
        for line in self.lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            var, type_str, op = m.group(1), m.group(2), m.group(3)
            self.symtab[var] = type_str
            opnds = _operands(line, op)
            self.ops.append((var, type_str, op, opnds, line))
            if op == "parameter":
                pm = re.search(r"parameter\((\d+)\)", line)
                if pm:
                    self.params[var] = int(pm.group(1))


def _split_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        m = _HDR_RE.match(line)
        if m and (line.startswith("ENTRY") or not line.startswith(" ")):
            cur = m.group(1)
            comps[cur] = Computation(cur, [])
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].lines.append(line)
    for c in comps.values():
        c.parse()
    return comps


def _dot_flops(c: Computation) -> float:
    flops = 0.0
    for var, type_str, op, opnds, line in c.ops:
        if op != "dot":
            continue
        dims = type_elems_dims(type_str)
        n_out = 1
        for d in (dims or []):
            n_out *= d
        k = 1
        cm = _CONTRACT_RE.search(line)
        if cm and opnds:
            lhs_dims = type_elems_dims(c.symtab.get(opnds[0], ""))
            if lhs_dims is not None and cm.group(1):
                for ci in cm.group(1).split(","):
                    ci = int(ci)
                    if ci < len(lhs_dims):
                        k *= lhs_dims[ci]
        flops += 2.0 * n_out * k
    return flops


def _fusion_param_effective_bytes(c: Computation) -> Dict[int, float]:
    """For a fused computation: params that are only dynamic-sliced count as
    their window size, not their full size."""
    eff: Dict[int, float] = {}
    uses: Dict[str, List[Tuple[str, str]]] = {}
    for var, type_str, op, opnds, line in c.ops:
        for o in opnds:
            uses.setdefault(o, []).append((op, type_str))
    for pname, pidx in c.params.items():
        u = uses.get(pname, [])
        if u and all(op in ("dynamic-slice", "dynamic-update-slice", "slice",
                            "gather") for op, _ in u):
            eff[pidx] = sum(float(type_bytes(t)) for _, t in u)
    return eff


def _comp_costs(c: Computation, fusion_eff: Dict[str, Dict[int, float]],
                is_fusion_body: bool):
    """(flops, hbm_bytes, coll_bytes_by_kind, calls)."""
    flops = _dot_flops(c)
    hbm = 0.0
    coll: Dict[str, float] = {}
    calls: List[Tuple[str, float]] = []
    for var, type_str, op, opnds, line in c.ops:
        res_bytes = type_bytes(type_str)
        if op in COLLECTIVES:
            factor = 2.0 if op == "all-reduce" else 1.0
            coll[op] = coll.get(op, 0.0) + factor * res_bytes
        if op == "while":
            trips = 1.0
            tm = _TRIP_RE.search(line)
            if tm:
                trips = float(tm.group(1))
            bm, cm = _CALL_RE.search(line), _COND_RE.search(line)
            if bm:
                calls.append((bm.group(1), trips))
            if cm:
                calls.append((cm.group(1), trips))
            continue
        if op in ("call", "fusion"):
            bm = _CALL_RE.search(line)
            if bm:
                calls.append((bm.group(1), 1.0))
        if op == "conditional":
            bm = _BRANCH_RE.search(line)
            if bm:
                for b in bm.group(1).split(","):
                    calls.append((b.strip().lstrip("%"), 1.0))

        if is_fusion_body:
            continue  # internals are VMEM/registers
        if op in ("dynamic-slice", "gather", "slice"):
            hbm += 2.0 * res_bytes
        elif op == "dynamic-update-slice":
            upd = res_bytes
            if len(opnds) >= 2 and opnds[1] in c.symtab:
                upd = type_bytes(c.symtab[opnds[1]])
            hbm += 2.0 * upd
        elif op == "fusion":
            bm = _CALL_RE.search(line)
            callee_eff = fusion_eff.get(bm.group(1), {}) if bm else {}
            hbm += res_bytes
            for i, o in enumerate(opnds):
                if i in callee_eff:
                    hbm += callee_eff[i]
                elif o in c.symtab:
                    hbm += type_bytes(c.symtab[o])
        elif op not in _SKIP_BYTES and op not in COLLECTIVES:
            hbm += res_bytes + sum(
                type_bytes(c.symtab[o]) for o in opnds if o in c.symtab)
        elif op in COLLECTIVES:
            hbm += 2.0 * res_bytes
    return flops, hbm, coll, calls


def analyze_hlo(hlo: str) -> Dict[str, float]:
    comps = _split_computations(hlo)

    fusion_bodies = set()
    for c in comps.values():
        for var, type_str, op, opnds, line in c.ops:
            if op == "fusion":
                m = _CALL_RE.search(line)
                if m:
                    fusion_bodies.add(m.group(1))

    fusion_eff = {n: _fusion_param_effective_bytes(comps[n])
                  for n in fusion_bodies if n in comps}

    costs = {n: _comp_costs(c, fusion_eff, n in fusion_bodies)
             for n, c in comps.items()}

    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"^ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
    if entry is None and comps:
        entry = max(comps, key=lambda n: len(costs[n][3]))

    totals = {"flops": 0.0, "hbm_bytes": 0.0}
    coll: Dict[str, float] = {}
    stack: List[str] = []

    def visit(name: str, mult: float):
        if name not in comps or name in stack or mult <= 0:
            return
        f, h, cl, calls = costs[name]
        totals["flops"] += mult * f
        totals["hbm_bytes"] += mult * h
        for k, v in cl.items():
            coll[k] = coll.get(k, 0.0) + mult * v
        stack.append(name)
        for callee, m2 in calls:
            visit(callee, mult * m2)
        stack.pop()

    if entry:
        visit(entry, 1.0)
    totals["collective_bytes"] = sum(coll.values())
    totals["collective_breakdown"] = coll
    return totals


# ---------------------------------------------------------------------------
# Cell report
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CellReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_per_dev: float
    hbm_bytes_per_dev: float
    coll_bytes_per_dev: float
    coll_breakdown: Dict[str, float]
    t_compute: float
    t_memory: float
    t_collective: float
    model_flops_total: float
    xla_flops_reported: float
    memory_analysis: Dict[str, float]

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total_compiled = self.flops_per_dev * self.n_devices
        return self.model_flops_total / total_compiled if total_compiled else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful-FLOP throughput at the bound, as a fraction of peak."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        if t <= 0:
            return 0.0
        from repro.launch.mesh import PEAK_FLOPS_BF16
        ach = self.model_flops_total / (self.n_devices * t)
        return ach / PEAK_FLOPS_BF16

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["dominant"] = self.dominant
        d["useful_flops_ratio"] = self.useful_flops_ratio
        d["roofline_fraction"] = self.roofline_fraction
        return d


def ga_measured_points(table) -> List[Dict]:
    """Flatten a `repro.autotune.CostTable` into report rows.

    The HLO roofline above is *modeled* (bytes and FLOPs against peak
    bandwidths); this is its measured GA counterpart: one row per
    (plan point, gens_per_launch) with `frac_of_best` — the fraction of
    the best throughput any epoch mode demonstrated for the same spec
    family — so a report can show how far each mode sits from the best
    plan the hardware actually achieved (1.0 marks the winner the
    two-tier planner picks)."""
    rows = list(table.entries())
    # family = everything identifying the spec except the competing
    # mode/executor and the launch fold
    def fam(r):
        return (r["stage"], r["migration"], r["n"], r["i_local"], r["c"],
                r["shards"], r["E"])
    best: Dict[Tuple, float] = {}
    for r in rows:
        best[fam(r)] = max(best.get(fam(r), 0.0), r["gens_per_s"])
    return [{**r, "frac_of_best":
             r["gens_per_s"] / best[fam(r)] if best[fam(r)] else 0.0}
            for r in rows]


def analyze_cell(arch: str, shape: str, mesh_name: str, n_devices: int,
                 hlo: str, cost: Dict[str, float],
                 mem: Dict[str, float], model_flops_total: float) -> CellReport:
    from repro.launch.mesh import PEAK_FLOPS_BF16, HBM_BW, ICI_BW
    parsed = analyze_hlo(hlo)
    flops = parsed["flops"]
    hbm = parsed["hbm_bytes"]
    coll = parsed["collective_bytes"]
    return CellReport(
        arch=arch, shape=shape, mesh=mesh_name, n_devices=n_devices,
        flops_per_dev=flops, hbm_bytes_per_dev=hbm, coll_bytes_per_dev=coll,
        coll_breakdown=parsed["collective_breakdown"],
        t_compute=flops / PEAK_FLOPS_BF16,
        t_memory=hbm / HBM_BW,
        t_collective=coll / ICI_BW,
        model_flops_total=model_flops_total,
        xla_flops_reported=float(cost.get("flops", 0.0)),
        memory_analysis=mem,
    )
