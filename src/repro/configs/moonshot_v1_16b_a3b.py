"""moonshot-v1-16b-a3b — Moonlight-16B-A3B-style MoE
[hf:moonshotai/Moonlight-16B-A3B; hf].

64 routed experts, top-6, 2 shared experts, first layer dense (DeepSeek-V3
routing recipe at small scale, softmax top-k here — see DESIGN.md).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    vocab=163840, rope_theta=50_000.0,
    n_experts=64, top_k=6, expert_ff=1408, n_shared_experts=2,
    n_dense_layers=1, moe_ff_dense=5632,
)
