"""The Engine: one entry point for every GA execution strategy.

    from repro import ga

    spec = ga.GASpec(problem="F3", n=64, bits_per_var=10, generations=100)
    result = ga.solve(spec)                      # auto-picks a backend
    result = ga.solve(spec, backend="fused")     # or pin one explicitly

Backends are (topology × executor) compositions (see repro.ga.backends).
Backend selection (`backend="auto"`) walks the capability matrix: eager when
the fitness is not traceable, an island_ring topology when the spec asks for
one (preferring the fused×island_ring composition on TPU when the kernel's
constraints hold), fused on TPU, reference otherwise.  Pinning an
unsupported backend warns and falls back gracefully instead of crashing.

Streaming + checkpointing:

    eng = ga.Engine(spec)
    for tele in eng.run_chunked(chunk_generations=25, ckpt_dir="/tmp/ga"):
        print(tele["gens_done"], tele["best_fitness"])

Each chunk persists the full backend-native GAState through
`repro.ckpt.checkpoint`, so a killed run resumes from the last chunk
(`resume=True`, the default).
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Dict, Iterator, Optional

import jax
import numpy as np

from repro import faults as FLT
from repro.ckpt import checkpoint as CKPT
from repro.ga import telemetry as RT
from repro.ga.backends import BACKENDS, Backend, Segment
from repro.ga.options import EngineOptions, resolve_options
from repro.ga.spec import GASpec


class BackendUnsupported(ValueError):
    """Raised when no backend can run a spec."""


def capability_matrix(spec: GASpec, mesh=None) -> Dict[str, Optional[str]]:
    """Backend name -> None (supported) or the reason it cannot run."""
    return {name: cls.supports(spec, mesh)
            for name, cls in BACKENDS.items()}


def _auto_order(spec: GASpec):
    if not spec.jit_fitness:
        return ["eager"]
    order = []
    tpu = jax.default_backend() == "tpu"
    if spec.effective_topology == "island_ring":
        if tpu:
            order.append("fused-islands")   # kernel speed × parallel pops
        order.append("islands")
    if tpu:
        order.append("fused")   # the fast path where the MXU gathers pay off
    order += ["reference", "islands", "eager"]
    return order


def resolve_backend(spec: GASpec, backend: str = "auto",
                    mesh=None) -> str:
    """Pick the backend name for a spec, with graceful fallback."""
    caps = capability_matrix(spec, mesh)
    if backend != "auto":
        if backend not in BACKENDS:
            raise BackendUnsupported(
                f"unknown backend {backend!r}; registered: {sorted(BACKENDS)}")
        reason = caps[backend]
        if reason is None:
            return backend
        fallback = next((n for n in _auto_order(spec) if caps[n] is None),
                        None)
        if fallback is None:
            raise BackendUnsupported(
                f"backend {backend!r} cannot run this spec ({reason}) and "
                f"no fallback applies: {caps}")
        warnings.warn(f"backend {backend!r} cannot run this spec ({reason}); "
                      f"falling back to {fallback!r}", stacklevel=3)
        return fallback
    for name in _auto_order(spec):
        if caps[name] is None:
            return name
    raise BackendUnsupported(f"no backend supports this spec: {caps}")


@dataclasses.dataclass
class EngineResult:
    """Uniform result across backends (fitness in real units — lut-mode
    fixed-point scaling is already divided out).  How the run executed is
    in `telemetry` (ga.RunTelemetry: .plan / .topology / .per_repeat)."""

    spec: GASpec
    backend: str
    best_fitness: float
    best_x: np.ndarray            # uint32[V] chromosome
    best_params: np.ndarray       # float64[V] decoded variables
    traj_best: np.ndarray
    traj_mean: np.ndarray
    generations: int
    wall_s: float
    telemetry: RT.RunTelemetry = dataclasses.field(
        default_factory=RT.RunTelemetry)

    @property
    def extras(self) -> Dict[str, Any]:
        """DEPRECATED dict view of `telemetry` (one release grace)."""
        return RT.deprecated_extras(self.telemetry, "EngineResult")


class Engine:
    """A spec bound to a backend, with cached compiled runners.

    Execution knobs ride in one frozen `ga.EngineOptions` (`options=`);
    the legacy `mesh= / interpret= / cost_table= / plan_override=` kwargs
    still work and build one internally.  cost_table / plan_override steer
    the measured epoch planner (see `Backend` and `repro.autotune`): the
    default cost_table=None discovers the ambient per-host table, False
    pins the pure heuristic, and plan_override forces one epoch mode by
    name.  None of these change results — plans differ only in launch
    shape."""

    def __init__(self, spec: GASpec, backend: str = "auto", *,
                 options: Optional[EngineOptions] = None,
                 mesh=None, interpret: Optional[bool] = None,
                 cost_table=None, plan_override=None):
        self.spec = spec
        self.options = resolve_options(options, mesh=mesh,
                                       interpret=interpret,
                                       cost_table=cost_table,
                                       plan_override=plan_override)
        self.backend_name = resolve_backend(spec, backend, self.options.mesh)
        # resolved ONCE and shared with every checkpoint write: fault-rule
        # occurrence counters live on the injector instance
        self.faults = FLT.resolve_faults(self.options.faults)
        self.backend: Backend = BACKENDS[self.backend_name](
            spec, options=self.options)

    def init_state(self):
        return self.backend.init()

    def _result(self, seg: Segment, wall_s: float) -> EngineResult:
        scale = self.spec.fitness_scale()
        tele = seg.telemetry
        if tele.problem is None:
            tele.problem = self.spec.problem or "blackbox"
            tele.n_vars = self.spec.v
        return EngineResult(
            spec=self.spec, backend=self.backend_name,
            best_fitness=seg.best_y / scale,
            best_x=np.asarray(seg.best_x, np.uint32),
            best_params=self.spec.decode(seg.best_x),
            traj_best=np.asarray(seg.traj_best) / scale,
            traj_mean=np.asarray(seg.traj_mean) / scale,
            generations=seg.gens, wall_s=wall_s, telemetry=tele)

    def run(self, generations: Optional[int] = None,
            state=None) -> EngineResult:
        gens = generations or self.spec.generations
        t0 = time.perf_counter()
        if state is None:
            state = self.init_state()
        seg = self.backend.segment(state, gens)
        jax.block_until_ready(jax.tree.leaves(seg.state))
        return self._result(seg, time.perf_counter() - t0)

    def run_chunked(self, *, chunk_generations: Optional[int] = None,
                    generations: Optional[int] = None,
                    ckpt_dir: Optional[str] = None,
                    resume: bool = True,
                    fault_tag: str = "") -> Iterator[Dict[str, Any]]:
        """Stream the run chunk by chunk, yielding per-chunk telemetry.

        With `ckpt_dir`, each chunk checkpoints the backend-native state; a
        restarted run with the same spec/ckpt_dir resumes at the last chunk
        (the newest VALID one — a corrupt step falls back to its
        predecessor; the first chunk after a resume carries
        ``"resumed_from"``).  `fault_tag` rides into every `repro.faults`
        injection-site tag (the scheduler passes its job ids) so armed
        fault rules can target one run.

        Telemetry granularity follows the backend's LAUNCH unit: island
        topologies sample trajectories once per launch, and a resident-epoch
        launch covers several migration intervals — UP TO
        `telemetry_unit_gens` generations per `traj_best` entry (a
        segment's final launch folds only the remaining intervals);
        `migrations` counts every ring migration including the ones folded
        inside resident launches.
        """
        total = generations or self.spec.generations
        # the default chunk never undercuts gens_per_epoch: a chunk smaller
        # than one resident launch would cap the interval folding the spec
        # asked for (an explicit chunk_generations is honored as given)
        chunk = chunk_generations or max(1, total // 10,
                                         self.spec.gens_per_epoch)
        scale = self.spec.fitness_scale()
        mini = self.spec.minimize

        state = self.init_state()
        done, chunk_idx, migrations = 0, 0, 0
        resumed_from: Optional[int] = None
        best_y: Optional[float] = None
        best_x = None
        if ckpt_dir and resume:
            step = CKPT.latest_step(ckpt_dir)
            if step is not None:
                resumed_from = int(step)
                state, extra = CKPT.restore(ckpt_dir, step, state)
                ck_backend = extra.get("backend")
                if ck_backend is not None and ck_backend != self.backend_name:
                    raise ValueError(
                        f"checkpoint in {ckpt_dir} was written by the "
                        f"{ck_backend!r} backend; resuming it with "
                        f"{self.backend_name!r} would load a mismatched "
                        "state layout — rerun with the original backend or "
                        "a fresh ckpt_dir")
                done = int(extra["gens_done"])
                chunk_idx = int(extra.get("chunk_idx", 0))
                migrations = int(extra.get("migrations", 0))
                best_y = float(extra["best_y"])
                best_x = np.asarray(extra["best_x"], np.uint32)

        if done >= total and best_y is not None:
            # resumed a finished run: surface the stored result instead of
            # yielding nothing
            yield {
                "chunk": chunk_idx, "gens_done": done, "gens_total": total,
                "chunk_gens": 0, "chunk_best": best_y / scale,
                "best_fitness": best_y / scale,
                "best_params": self.spec.decode(best_x),
                "traj_best": np.empty((0,)), "wall_s": 0.0,
                "gens_per_s": 0.0, "backend": self.backend_name,
                "problem": self.spec.problem or "blackbox",
                "n_vars": self.spec.v,
                "migrations": migrations,
                "already_complete": True,
            }
            return

        while done < total:
            tag = f"{fault_tag}|{self.backend_name}|chunk={chunk_idx + 1}"
            if self.faults is not None:
                self.faults.inject("slow_chunk", tag)
            t0 = time.perf_counter()
            seg = self.backend.segment(state, min(chunk, total - done))
            jax.block_until_ready(jax.tree.leaves(seg.state))
            dt = time.perf_counter() - t0
            if self.faults is not None:
                # crash AFTER the compute, BEFORE the checkpoint: the
                # chunk's work is lost, earlier checkpoints are not, and a
                # retry recomputes it deterministically
                self.faults.inject("chunk_crash", tag)
            state = seg.state
            done += seg.gens
            chunk_idx += 1
            migrations += seg.telemetry.topology.migrations
            if resumed_from is not None:
                seg.telemetry.resumed_from = resumed_from
            if best_y is None or (seg.best_y < best_y if mini
                                  else seg.best_y > best_y):
                best_y, best_x = seg.best_y, np.asarray(seg.best_x)
            if ckpt_dir:
                CKPT.save(ckpt_dir, step=done, tree=state,
                          extra={"gens_done": done, "chunk_idx": chunk_idx,
                                 "migrations": migrations,
                                 "best_y": float(best_y),
                                 "best_x": [int(v) for v in best_x],
                                 "backend": self.backend_name},
                          faults=self.faults, fault_tag=fault_tag)
            yield {
                "chunk": chunk_idx,
                "resumed_from": resumed_from,
                "gens_done": done,
                "gens_total": total,
                "chunk_gens": seg.gens,
                "chunk_best": seg.best_y / scale,
                "best_fitness": best_y / scale,
                "best_params": self.spec.decode(best_x),
                "traj_best": np.asarray(seg.traj_best) / scale,
                "wall_s": dt,
                "gens_per_s": seg.gens / dt if dt > 0 else float("inf"),
                "backend": self.backend_name,
                "problem": self.spec.problem or "blackbox",
                "n_vars": self.spec.v,
                "migrations": migrations,
                "telemetry_unit_gens": seg.telemetry.topology
                                          .telemetry_unit_gens,
                "telemetry": seg.telemetry,
            }
            resumed_from = None    # only the first post-resume chunk carries it


def solve(spec: GASpec, backend: str = "auto", *,
          generations: Optional[int] = None,
          options: Optional[EngineOptions] = None, mesh=None,
          interpret: Optional[bool] = None, cost_table=None,
          plan_override=None) -> EngineResult:
    """Run a GASpec end to end and return the uniform result."""
    return Engine(spec, backend, options=options, mesh=mesh,
                  interpret=interpret, cost_table=cost_table,
                  plan_override=plan_override).run(generations)


class PackedEngine:
    """K shape-compatible GASpecs multiplexed through ONE backend run.

    The engine already vmaps `n_repeats` independent replicas down a stack
    axis; packing reuses that axis as a *tenant* axis: job j contributes
    `n_repeats` slots seeded `seed+0..seed+r-1` — exactly the seeds the job
    would use alone — so every slot, and therefore every job's result, is
    bit-identical to running that job solo (the per-replica bit-identity the
    repeat tests already pin down).  Specs must share `compile_key()` and
    `generations`; only seeds and repeat counts may differ.

        pe = PackedEngine([spec_a, spec_b, spec_c])
        for tele in pe.run_chunked(ckpt_dir="/tmp/pack"):
            for jt in tele["jobs"]:
                print(jt["job_index"], jt["best_fitness"])

    `run_chunked` mirrors `Engine.run_chunked` (chunked telemetry +
    checkpoint/resume — the scheduler's preemption primitive) but yields a
    pack-level dict whose `"jobs"` list carries one Engine-style telemetry
    dict per job, unpacked from the segment's per-replica telemetry."""

    def __init__(self, specs, backend: str = "auto", *,
                 options: Optional[EngineOptions] = None,
                 mesh=None, interpret: Optional[bool] = None,
                 cost_table=None, plan_override=None):
        self.options = resolve_options(options, mesh=mesh,
                                       interpret=interpret,
                                       cost_table=cost_table,
                                       plan_override=plan_override)
        specs = list(specs)
        if not specs:
            raise ValueError("PackedEngine needs at least one spec")
        key0, gens0 = specs[0].compile_key(), specs[0].generations
        for s in specs[1:]:
            if s.compile_key() != key0:
                raise BackendUnsupported(
                    "specs are not shape-compatible for packing (their "
                    "compile_key()s differ); submit them separately")
            if s.generations != gens0:
                raise BackendUnsupported(
                    "packed jobs must share generations= (the pack runs the "
                    "stack lock-step); submit unequal-length jobs separately")
        self.specs = specs
        self.slots, self.seeds = [], []
        off = 0
        for s in specs:
            self.slots.append((off, s.n_repeats))
            self.seeds.extend(s.seed + r for r in range(s.n_repeats))
            off += s.n_repeats
        self.n_slots = off
        self.batch_spec = dataclasses.replace(specs[0], n_repeats=self.n_slots)
        self.backend_name = resolve_backend(self.batch_spec, backend,
                                            self.options.mesh)
        self.faults = FLT.resolve_faults(self.options.faults)
        if self.backend_name == "eager":
            raise BackendUnsupported(
                "the eager backend steps replicas in a host loop — nothing "
                "to pack; run eager jobs singly")
        # a single 1-repeat job has no stack axis to pack: delegate to the
        # plain Engine (same result layout, zero packing overhead)
        self._solo: Optional[Engine] = None
        if self.n_slots == 1:
            self._solo = Engine(specs[0], self.backend_name,
                                options=self.options)
            self.backend = self._solo.backend
        else:
            self.backend = BACKENDS[self.backend_name](
                self.batch_spec, options=self.options)

    def init_state(self):
        if self._solo is not None:
            return self._solo.init_state()
        return self.backend.init_packed(list(self.seeds))

    def _job_tele(self, j: int, *, chunk_idx, done, total, dt, seg_gens,
                  slot_y, slot_x, chunk_y, traj, migrations, telemetry):
        off, cnt = self.slots[j]
        spec = self.specs[j]
        scale = spec.fitness_scale()
        mini = spec.minimize
        yj = slot_y[off:off + cnt]
        r = off + (int(np.argmin(yj)) if mini else int(np.argmax(yj)))
        cyj = chunk_y[off:off + cnt]
        tj = traj[off:off + cnt]                     # [r_j, T]
        return {
            "chunk": chunk_idx, "gens_done": done, "gens_total": total,
            "chunk_gens": seg_gens,
            "chunk_best": float(np.min(cyj) if mini else np.max(cyj)) / scale,
            "best_fitness": float(slot_y[r]) / scale,
            "best_params": spec.decode(slot_x[r]),
            "traj_best": (np.min(tj, axis=0) if mini
                          else np.max(tj, axis=0)) / scale,
            "wall_s": dt,
            "gens_per_s": seg_gens / dt if dt > 0 else float("inf"),
            "backend": self.backend_name,
            "problem": spec.problem or "blackbox",
            "n_vars": spec.v,
            "migrations": migrations,
            "telemetry_unit_gens": (telemetry.topology.telemetry_unit_gens
                                    if telemetry is not None else 1),
            "job_index": j, "pack_size": len(self.specs),
            "slots": (off, cnt),
            "telemetry": (telemetry.job_view()
                          if telemetry is not None else None),
        }

    def run_chunked(self, *, chunk_generations: Optional[int] = None,
                    ckpt_dir: Optional[str] = None,
                    resume: bool = True,
                    fault_tag: str = "") -> Iterator[Dict[str, Any]]:
        """Chunked pack run: yields {"chunk", "gens_done", ..., "jobs": [...]}
        with one Engine-style telemetry dict per job.  With `ckpt_dir`, every
        chunk checkpoints the whole packed state + per-slot bests, so an
        abandoned run (preemption) resumes bit-identically — the checkpoint
        records the slot seeds and refuses a mismatched pack composition."""
        if self._solo is not None:
            for tele in self._solo.run_chunked(
                    chunk_generations=chunk_generations,
                    ckpt_dir=ckpt_dir, resume=resume, fault_tag=fault_tag):
                jt = dict(tele)
                jt.update(job_index=0, pack_size=1, slots=(0, 1))
                yield {"chunk": tele["chunk"], "gens_done": tele["gens_done"],
                       "gens_total": tele["gens_total"],
                       "chunk_gens": tele["chunk_gens"],
                       "wall_s": tele["wall_s"],
                       "gens_per_s": tele["gens_per_s"],
                       "backend": self.backend_name, "pack_size": 1,
                       "jobs": [jt]}
            return

        spec = self.batch_spec
        total = spec.generations
        chunk = chunk_generations or max(1, total // 10, spec.gens_per_epoch)
        mini = spec.minimize
        L = self.n_slots

        state = self.init_state()
        done, chunk_idx, migrations = 0, 0, 0
        resumed_from: Optional[int] = None
        slot_y = np.full((L,), np.inf if mini else -np.inf, np.float32)
        slot_x = np.zeros((L, spec.v), np.uint32)
        if ckpt_dir and resume:
            step = CKPT.latest_step(ckpt_dir)
            if step is not None:
                resumed_from = int(step)
                state, extra = CKPT.restore(ckpt_dir, step, state)
                ck_backend = extra.get("backend")
                if ck_backend is not None and ck_backend != self.backend_name:
                    raise ValueError(
                        f"checkpoint in {ckpt_dir} was written by the "
                        f"{ck_backend!r} backend; resuming it with "
                        f"{self.backend_name!r} would load a mismatched "
                        "state layout")
                ck_seeds = [int(s) for s in extra.get("seeds", [])]
                if ck_seeds and ck_seeds != [int(s) for s in self.seeds]:
                    raise ValueError(
                        f"checkpoint in {ckpt_dir} holds a pack with slot "
                        f"seeds {ck_seeds}, not {list(self.seeds)} — a pack "
                        "must resume with the same jobs in the same order")
                done = int(extra["gens_done"])
                chunk_idx = int(extra.get("chunk_idx", 0))
                migrations = int(extra.get("migrations", 0))
                slot_y = np.asarray(extra["slot_y"], np.float32)
                slot_x = np.asarray(extra["slot_x"],
                                    np.uint32).reshape(L, spec.v)

        if done >= total:
            # resumed a finished pack: surface the stored per-job results
            yield {
                "chunk": chunk_idx, "gens_done": done, "gens_total": total,
                "chunk_gens": 0, "wall_s": 0.0, "gens_per_s": 0.0,
                "backend": self.backend_name, "pack_size": len(self.specs),
                "already_complete": True,
                "jobs": [self._job_tele(
                    j, chunk_idx=chunk_idx, done=done, total=total, dt=0.0,
                    seg_gens=0, slot_y=slot_y, slot_x=slot_x, chunk_y=slot_y,
                    traj=slot_y[:, None], migrations=migrations,
                    telemetry=None)
                    for j in range(len(self.specs))],
            }
            return

        while done < total:
            tag = f"{fault_tag}|{self.backend_name}|chunk={chunk_idx + 1}"
            if self.faults is not None:
                self.faults.inject("slow_chunk", tag)
            t0 = time.perf_counter()
            seg = self.backend.segment(state, min(chunk, total - done))
            jax.block_until_ready(jax.tree.leaves(seg.state))
            dt = time.perf_counter() - t0
            if self.faults is not None:
                # crash AFTER the compute, BEFORE the checkpoint (see Engine)
                self.faults.inject("chunk_crash", tag)
            state = seg.state
            done += seg.gens
            chunk_idx += 1
            migrations += seg.telemetry.topology.migrations
            if resumed_from is not None:
                seg.telemetry.resumed_from = resumed_from
            rep = seg.telemetry.per_repeat
            by = np.asarray(rep.best, np.float32).reshape(L)
            bx = np.asarray(rep.best_x, np.uint32).reshape(L, spec.v)
            traj = np.asarray(rep.traj_best, np.float32).reshape(L, -1)
            better = by < slot_y if mini else by > slot_y
            slot_y = np.where(better, by, slot_y)
            slot_x = np.where(better[:, None], bx, slot_x)
            if ckpt_dir:
                CKPT.save(ckpt_dir, step=done, tree=state,
                          extra={"gens_done": done, "chunk_idx": chunk_idx,
                                 "migrations": migrations,
                                 "slot_y": [float(v) for v in slot_y],
                                 "slot_x": [[int(v) for v in row]
                                            for row in slot_x],
                                 "seeds": [int(s) for s in self.seeds],
                                 "backend": self.backend_name},
                          faults=self.faults, fault_tag=fault_tag)
            yield {
                "chunk": chunk_idx, "resumed_from": resumed_from,
                "gens_done": done, "gens_total": total,
                "chunk_gens": seg.gens, "wall_s": dt,
                "gens_per_s": seg.gens / dt if dt > 0 else float("inf"),
                "backend": self.backend_name, "pack_size": len(self.specs),
                "jobs": [self._job_tele(
                    j, chunk_idx=chunk_idx, done=done, total=total, dt=dt,
                    seg_gens=seg.gens, slot_y=slot_y, slot_x=slot_x,
                    chunk_y=by, traj=traj, migrations=migrations,
                    telemetry=seg.telemetry)
                    for j in range(len(self.specs))],
            }
            resumed_from = None

    def run(self, *, chunk_generations: Optional[int] = None):
        """Run the pack to completion; returns the final per-job telemetry
        list (one Engine-style dict per job)."""
        last = None
        for last in self.run_chunked(chunk_generations=chunk_generations):
            pass
        return last["jobs"]


def repack_checkpoint(old_dir: str, specs, keep, new_dir: str,
                      backend: str = "auto", *,
                      options: Optional[EngineOptions] = None) -> Optional[int]:
    """Slice a pack checkpoint down to the jobs in `keep` (indices into
    `specs`) and write it to `new_dir`, so survivors of a quarantined pack
    resume bit-identically from where the pack left off.

    Packed state leaves carry the slot stack down their leading axis (the
    replica axis `init_packed` builds); slicing that axis at the kept jobs'
    slot offsets yields exactly the state those slots would hold had they
    run alone from the same seeds — the packing bit-identity invariant run
    in reverse.  Leaves whose shape does not change between pack sizes
    (island ring buffers etc.) pass through; anything that matches neither
    pattern is a layout change and raises.  Returns the checkpointed step
    (generations done), or None when `old_dir` holds no valid step."""
    specs = list(specs)
    keep = list(keep)
    pe_old = PackedEngine(specs, backend, options=options)
    step = CKPT.latest_step(old_dir)
    if step is None:
        return None
    state, extra = CKPT.restore(old_dir, step, pe_old.init_state())
    ck_backend = extra.get("backend")
    if ck_backend is not None and ck_backend != pe_old.backend_name:
        raise ValueError(
            f"checkpoint in {old_dir} was written by the {ck_backend!r} "
            f"backend, not {pe_old.backend_name!r}; repack with the "
            "original backend")
    ck_seeds = [int(s) for s in extra.get("seeds", [])]
    if ck_seeds and ck_seeds != [int(s) for s in pe_old.seeds]:
        raise ValueError(
            f"checkpoint in {old_dir} holds slot seeds {ck_seeds}, but the "
            f"given specs produce {list(pe_old.seeds)} — pass the pack's "
            "original specs in their original order")

    pe_new = PackedEngine([specs[j] for j in keep], backend, options=options)
    idx = []
    for j in keep:
        off, cnt = pe_old.slots[j]
        idx.extend(range(off, off + cnt))
    idx_arr = np.asarray(idx)

    def _slice(new_like, old_leaf):
        old_arr = np.asarray(jax.device_get(old_leaf))
        want = tuple(np.shape(new_like))
        if old_arr.shape == want:
            return old_arr
        if old_arr.ndim and old_arr.shape[0] == pe_old.n_slots:
            sl = old_arr[idx_arr]
            if sl.shape == want:
                return sl
            if len(idx) == 1 and sl.shape[1:] == want:
                return sl[0]        # 1-slot target runs the solo (lead=0) layout
        raise ValueError(
            f"cannot repack state leaf of shape {old_arr.shape} into "
            f"{want}: neither shape-stable nor sliceable down the "
            f"{pe_old.n_slots}-slot axis")

    new_state = jax.tree.map(_slice, pe_new.init_state(), state)

    done = int(extra["gens_done"])
    slot_y = np.asarray(extra["slot_y"], np.float32)
    slot_x = np.asarray(extra["slot_x"], np.uint32).reshape(
        pe_old.n_slots, specs[0].v)
    if pe_new.n_slots > 1:
        new_extra = {"gens_done": done,
                     "chunk_idx": int(extra.get("chunk_idx", 0)),
                     "migrations": int(extra.get("migrations", 0)),
                     "slot_y": [float(v) for v in slot_y[idx_arr]],
                     "slot_x": [[int(v) for v in row]
                                for row in slot_x[idx_arr]],
                     "seeds": [int(s) for s in pe_new.seeds],
                     "backend": pe_new.backend_name}
    else:
        # a 1-slot pack delegates to the plain Engine, whose resume reads
        # the solo extra format
        r = idx[0]
        new_extra = {"gens_done": done,
                     "chunk_idx": int(extra.get("chunk_idx", 0)),
                     "migrations": int(extra.get("migrations", 0)),
                     "best_y": float(slot_y[r]),
                     "best_x": [int(v) for v in slot_x[r]],
                     "backend": pe_new.backend_name}
    # recovery machinery is not an injection site: faults=False keeps an
    # ambient ckpt_corrupt rule from eating the repacked checkpoint
    CKPT.save(new_dir, step=done, tree=new_state, extra=new_extra,
              faults=False)
    return done
